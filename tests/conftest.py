import os

# smoke tests and benches must see ONE device — the 512-device XLA flag is
# set only inside the dry-run subprocesses (see launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
