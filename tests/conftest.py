import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Engine Layer 6 (mesh-aware execution) is tested on a FORCED multi-device
# host platform: 8 CPU "devices" carved out of the host before jax
# initializes. Single-device tests are unaffected (default placement stays
# device 0; the 512-device production flag still lives only inside the
# dry-run subprocesses, which overwrite XLA_FLAGS themselves). Gated so a
# caller-provided XLA_FLAGS or REPRO_TEST_DEVICE_COUNT=1 opts out.
_DEV = os.environ.get("REPRO_TEST_DEVICE_COUNT", "8")
if _DEV not in ("", "0", "1") and "xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_DEV}").strip()

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

import dataclasses  # noqa: E402
from typing import Optional  # noqa: E402

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import engine, optim  # noqa: E402
from repro.core import losses  # noqa: E402

# ---------------------------------------------------------------------------
# Executor conformance harness — the shared scaffolding every executor-
# equivalence test builds on (consolidated from test_engine / test_flat_update
# / test_pipeline, which used to carry three divergent copies).
# ---------------------------------------------------------------------------

# The full executor grid. Parametrize with
#   @pytest.mark.parametrize("executor", EXECUTOR_GRID)
# and construct via make_executor() so CPU runs get the right interpret/
# donate defaults in one place.
EXECUTOR_GRID = sorted(engine.EXECUTORS)

# per-executor construction kwargs for CPU test runs: the Pallas-backed
# executors run their kernels in interpret mode off-TPU
EXECUTOR_KW = {"compiled": {}, "streaming": {}, "fused": {"interpret": True},
               "flat": {"interpret": True}}


def make_executor(name: str, loss_fn, optimizer, plan, **overrides):
    """Construct the named executor with the test-suite defaults
    (interpret mode for Pallas executors) merged with ``overrides``.
    ``donate=False`` is accepted (and dropped) for the streaming executor
    so call sites can disable donation across the whole grid."""
    kw = dict(EXECUTOR_KW[name])
    kw.update(overrides)
    if name == "streaming":
        kw.pop("donate", None)
        kw.pop("interpret", None)
    return engine.get_executor(name)(loss_fn, optimizer, plan, **kw)


# ---------------------------------------------------------------------------
# mesh dimension of the conformance grid (engine Layer 6)
# ---------------------------------------------------------------------------

def host_mesh(data: int):
    """A (data, model=1) mesh over the forced host devices; skips when the
    platform has fewer (e.g. REPRO_TEST_DEVICE_COUNT=1 opt-out runs)."""
    import pytest
    from repro.launch import mesh as mesh_lib
    if jax.device_count() < data:
        pytest.skip(f"needs {data} devices, have {jax.device_count()} "
                    "(conftest forces 8 unless REPRO_TEST_DEVICE_COUNT=1)")
    return mesh_lib.make_host_mesh(data=data, model=1)


def make_sharded_executor(inner: str, loss_fn, optimizer, plan, mesh,
                          **overrides):
    """ShardedExecutor over the named inner strategy, with the same
    CPU-interpret defaults as :func:`make_executor`."""
    kw = dict(EXECUTOR_KW[inner])
    kw.pop("donate", None)
    kw.update(overrides)
    return engine.ShardedExecutor(loss_fn, optimizer, plan, mesh=mesh,
                                  inner=inner, **kw)


# ---------------------------------------------------------------------------
# pipeline dimension of the conformance grid (engine Layer 11)
# ---------------------------------------------------------------------------

def pipeline_mesh(data: int, stages: int):
    """A 2-D ``(data, model=stages)`` mesh over the forced host devices;
    skips when the platform has fewer than ``data * stages``."""
    import pytest
    from repro.launch import mesh as mesh_lib
    need = data * stages
    if jax.device_count() < need:
        pytest.skip(f"needs {need} devices, have {jax.device_count()} "
                    "(conftest forces 8 unless REPRO_TEST_DEVICE_COUNT=1)")
    return mesh_lib.make_host_mesh(data=data, model=stages)


def make_pipelined_executor(staged, optimizer, plan, mesh, **overrides):
    """PipelinedExecutor with the test-suite defaults (none currently —
    the 1F1B step is plain XLA, no Pallas interpret switch needed)."""
    return engine.PipelinedExecutor(staged, optimizer, plan, mesh=mesh,
                                    **overrides)


# Golden 5-step loss trajectory, recorded once from CompiledScanExecutor on
# the tiny model (seed 0, ragged mini-batch 10 -> 3 x 4, SGD-m
# 0.1/0.9/1e-4, exact normalization). Every executor — and every mesh
# shape (Layer 6) — must reproduce it: the tolerance only absorbs
# BLAS/platform noise. If an engine change moves these numbers, that is a
# *numerics* change — record new values only if the change is intentional
# and explained.
GOLDEN_LOSSES = [1.4693074, 1.6477259, 1.5571915, 1.3139976, 1.5032679]


# absolute tolerance per result dtype: fp32 paths agree to rounding noise,
# reduced-precision accumulators only to their own epsilon
DTYPE_ATOL = {
    jnp.dtype(jnp.float32): 2e-6,
    jnp.dtype(jnp.bfloat16): 2e-2,
    jnp.dtype(jnp.float16): 2e-3,
}


def max_abs_err(a, b) -> float:
    """Largest absolute elementwise difference across two pytrees (in fp32)."""
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def assert_trees_close(actual, expected, *, atol: Optional[float] = None,
                       what: str = "trees"):
    """Leafwise comparison with per-dtype tolerances (``DTYPE_ATOL``);
    an explicit ``atol`` overrides for every leaf. Structure must match."""
    la, le = jax.tree.leaves(actual), jax.tree.leaves(expected)
    assert len(la) == len(le), (
        f"{what}: {len(la)} leaves vs {len(le)} expected")
    for i, (x, y) in enumerate(zip(la, le)):
        tol = atol if atol is not None else DTYPE_ATOL.get(
            jnp.dtype(getattr(x, "dtype", jnp.float32)), 2e-6)
        err = float(jnp.max(jnp.abs(jnp.asarray(x).astype(jnp.float32)
                                    - jnp.asarray(y).astype(jnp.float32))))
        assert err <= tol, (
            f"{what}: leaf {i} ({getattr(x, 'dtype', '?')}) differs by "
            f"{err:.3e} > atol {tol:.0e}")


def assert_scalar_close(actual, expected, atol: float = 2e-6,
                        what: str = "scalar"):
    err = abs(float(actual) - float(expected))
    assert err <= atol, f"{what}: |{float(actual)} - {float(expected)}| = " \
                        f"{err:.3e} > {atol:.0e}"


# ---------------------------------------------------------------------------
# tiny-model factory: the 2-layer tanh MLP + CE loss every equivalence test
# uses (small enough that all four executors run in milliseconds on CPU)
# ---------------------------------------------------------------------------

def tiny_loss_fn(p, batch, exact_denom=None):
    h = jnp.tanh(batch["x"] @ p["w1"])
    logits = h @ p["w2"]
    return losses.cross_entropy(
        logits, batch["y"], sample_weight=batch.get("sample_weight"),
        exact_denom=exact_denom), {}


def tiny_params(seed: int = 0):
    rng = np.random.default_rng(seed)
    return {"w1": jnp.asarray(rng.normal(0, 0.3, (8, 16)), jnp.float32),
            "w2": jnp.asarray(rng.normal(0, 0.3, (16, 4)), jnp.float32)}


def tiny_batch(n: int, seed: int = 0):
    rng = np.random.default_rng(seed + 100)
    return {"x": rng.normal(size=(n, 8)).astype(np.float32),
            "y": rng.integers(0, 4, n).astype(np.int32)}


@dataclasses.dataclass
class ToyDataset:
    """Deterministic-in-(seed, step) dataset with the synthetic datasets'
    ``batch(batch_size, seed)`` interface, over the tiny model's features."""
    n_features: int = 8
    n_classes: int = 4
    seed: int = 0

    def batch(self, batch_size, seed):
        rng = np.random.default_rng((self.seed, seed))
        return {"x": rng.normal(size=(batch_size, self.n_features)
                                ).astype(np.float32),
                "y": rng.integers(0, self.n_classes, batch_size
                                  ).astype(np.int32)}


def tiny_optimizer(lr: float = 0.1, momentum: float = 0.9,
                   weight_decay: float = 1e-4) -> optim.Optimizer:
    return optim.sgd(lr, momentum=momentum, weight_decay=weight_decay)


# ---------------------------------------------------------------------------
# staged tiny model: the pipeline-parallel counterpart of the tanh MLP —
# a NUM_LAYERS-deep stacked-middle network whose loss factors into the
# StagedLoss (prelude / stage_fn / finale) contract, with a single-device
# reference (staged_ref_loss) computing the identical function
# ---------------------------------------------------------------------------

STAGED_NUM_LAYERS = 4


def staged_params(seed: int = 0):
    rng = np.random.default_rng(seed)
    return {
        "w_in": jnp.asarray(rng.normal(0, 0.3, (8, 16)), jnp.float32),
        "mid": jnp.asarray(rng.normal(0, 0.3, (STAGED_NUM_LAYERS, 16, 16)),
                           jnp.float32),
        "w_out": jnp.asarray(rng.normal(0, 0.3, (16, 4)), jnp.float32),
    }


def staged_batch(n: int, seed: int = 0):
    rng = np.random.default_rng(seed + 100)
    return {"x": jnp.asarray(rng.normal(0, 1.0, (n, 8)), jnp.float32),
            "y": jnp.asarray(rng.integers(0, 4, (n,)), jnp.int32)}


def staged_ref_loss(params, batch, exact_denom=None):
    """Single-device reference — the exact function the staged split
    computes, as one flat forward."""
    x = jnp.tanh(batch["x"] @ params["w_in"])
    for k in range(STAGED_NUM_LAYERS):
        x = jnp.tanh(x @ params["mid"][k])
    logits = x @ params["w_out"]
    return losses.cross_entropy(
        logits, batch["y"], sample_weight=batch.get("sample_weight"),
        exact_denom=exact_denom), {}


def staged_spec() -> "engine.StagedLoss":
    """The StagedLoss factorization of :func:`staged_ref_loss`. The finale
    returns the RAW loss sum (``exact_denom=1.0``) per the executor's
    normalization contract."""
    def prelude(shared, mb):
        return jnp.tanh(mb["x"] @ shared["w_in"])

    def stage_fn(stage_p, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        x, _ = jax.lax.scan(body, x, stage_p)
        return x

    def finale(shared, x, mb):
        logits = x @ shared["w_out"]
        return losses.cross_entropy(
            logits, mb["y"], sample_weight=mb.get("sample_weight"),
            exact_denom=1.0), {}

    return engine.StagedLoss(num_layers=STAGED_NUM_LAYERS, prelude=prelude,
                             stage_fn=stage_fn, finale=finale,
                             stacked_key="mid")


# Golden 5-step loss trajectory of the staged tiny model, recorded once
# from CompiledScanExecutor on staged_ref_loss (seed-0 params, SGD-m
# 0.1/0.9/1e-4, mini 8 -> 4 x 2 exact, batch at step t = staged_batch(8,
# seed=t)). Every (stages x dp) pipelined mesh must reproduce it — same
# numerics-change policy as GOLDEN_LOSSES above.
GOLDEN_STAGED_LOSSES = [1.5686746, 1.5398949, 1.6100299, 1.5499518,
                        1.3625731]
