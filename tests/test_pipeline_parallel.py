"""Pipeline-parallel conformance matrix (engine Layer 11).

Runs on the conftest-forced 8-device CPU host platform and proves, for
the 1F1B PipelinedExecutor over 2-D ``data × model`` meshes:

  * **schedule** — the closed-form 1F1B tables satisfy the structural
    invariants the module docstring claims (no forward/backward collision
    on a stage, activations arrive before use, every micro runs exactly
    once per stage per direction);
  * **equivalence** — pipelined execution is semantically invisible:
    gradients, loss, and the full optimizer step match the single-device
    CompiledScanExecutor at stages ∈ {2, 4} × dp ∈ {1, 2}, with and
    without FSDP parameter sharding, ragged tails included;
  * **trajectory** — the 5-step golden staged-model loss trajectory is
    reproduced on pipelined meshes;
  * **contracts** — the JX005/HLO005 schedule census passes on the
    deferred-sync step and FIRES on the per-micro-sync negative control
    (so the rules detect what they claim to detect);
  * **launcher** — ``--mesh DATA:MODEL`` parsing fails fast on malformed
    specs and device-count overruns, and ``steps.make_staged_loss``
    stages real transformer configs (rejecting families that do not
    factor into a pipeline).
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import (GOLDEN_STAGED_LOSSES, STAGED_NUM_LAYERS,
                      assert_scalar_close, assert_trees_close,
                      make_pipelined_executor, pipeline_mesh, staged_batch,
                      staged_params, staged_ref_loss, staged_spec,
                      tiny_optimizer)
from repro import analysis, configs, engine
from repro.launch import mesh as mesh_lib, steps

pytestmark = pytest.mark.mesh

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# the (stages, dp) conformance grid — every cell fits the forced 8 devices
GRID = [(2, 1), (2, 2), (4, 1), (4, 2)]


def _pipelined(stages, dp, mini=8, micro=2, **overrides):
    mesh = pipeline_mesh(dp, stages)
    # remat=False: the toy staged loss has no checkpoint lattice (JX002
    # would rightly flag a plan that claims a policy the trace lacks)
    plan = engine.plan_mbs(mini, micro_batch_size=micro,
                           normalization="exact", remat=False,
                           mesh=mesh, pipeline=True)
    ex = make_pipelined_executor(staged_spec(), tiny_optimizer(), plan,
                                 mesh, **overrides)
    return ex, plan


def _reference(mini=8, micro=2):
    plan = engine.plan_mbs(mini, micro_batch_size=micro,
                           normalization="exact")
    return engine.CompiledScanExecutor(staged_ref_loss, tiny_optimizer(),
                                       plan), plan


# ---------------------------------------------------------------------------
# the closed-form schedule
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stages,micros", [(2, 2), (2, 4), (4, 4), (4, 7),
                                           (3, 5), (8, 8)])
def test_schedule_1f1b_invariants(stages, micros):
    fwd, bwd, recv, ticks = engine.schedule_1f1b(stages, micros)
    assert ticks == 2 * (micros + stages - 1)
    assert fwd.shape == bwd.shape == recv.shape == (ticks, stages)
    # forward and backward never collide on one stage in one tick
    assert not ((fwd >= 0) & (bwd >= 0)).any()
    # every micro-batch runs exactly once per stage per direction
    for s in range(stages):
        assert sorted(fwd[fwd[:, s] >= 0, s]) == list(range(micros))
        assert sorted(bwd[bwd[:, s] >= 0, s]) == list(range(micros))
    # causality: stage s runs micro i only after receiving it from s-1,
    # and the backward for (s, j) only after the forward for (s, j)
    for s in range(1, stages):
        for i in range(micros):
            t_recv = int(np.where(recv[:, s] == i)[0][0])
            t_fwd = int(np.where(fwd[:, s] == i)[0][0])
            assert t_recv < t_fwd
    for s in range(stages):
        for j in range(micros):
            assert int(np.where(fwd[:, s] == j)[0][0]) \
                < int(np.where(bwd[:, s] == j)[0][0])


def test_schedule_rejects_degenerate():
    with pytest.raises(ValueError, match="stages >= 1"):
        engine.schedule_1f1b(0, 4)


# ---------------------------------------------------------------------------
# numerical equivalence vs the single-device reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stages,dp", GRID)
def test_pipelined_matches_single_device(stages, dp):
    ex, plan = _pipelined(stages, dp)
    ref, ref_plan = _reference()
    params = staged_params()
    batch = staged_batch(8)
    split = ex.stage(plan.split(batch))
    ref_split = ref_plan.device_split(batch)

    g, loss = ex.gradients(params, split)
    g_ref, loss_ref = ref.gradients(params, ref_split)
    assert_scalar_close(loss, loss_ref, what=f"loss s{stages} dp{dp}")
    assert_trees_close(g, g_ref, what=f"grads s{stages} dp{dp}")

    opt = tiny_optimizer()
    p1, o1, m1 = ex.step_split(params, opt.init(params), split)
    p2, o2, m2 = ref.step_split(staged_params(),
                                opt.init(staged_params()), ref_split)
    assert_trees_close(p1, p2, what=f"params s{stages} dp{dp}")
    assert_trees_close(o1, o2, what=f"opt state s{stages} dp{dp}")
    assert_scalar_close(m1["loss"], m2["loss"], what="step loss")
    assert_scalar_close(m1["grad_norm"], m2["grad_norm"], atol=1e-5,
                        what="grad_norm")


@pytest.mark.parametrize("stages,dp", [(2, 2), (4, 1)])
def test_fsdp_matches_single_device(stages, dp):
    ex, plan = _pipelined(stages, dp, fsdp=True)
    ref, ref_plan = _reference()
    params = staged_params()
    batch = staged_batch(8)
    g, loss = ex.gradients(params, ex.stage(plan.split(batch)))
    g_ref, loss_ref = ref.gradients(params, ref_plan.device_split(batch))
    assert_scalar_close(loss, loss_ref, what="fsdp loss")
    assert_trees_close(g, g_ref, what=f"fsdp grads s{stages} dp{dp}")


@pytest.mark.parametrize("stages", [2, 4])
def test_golden_staged_trajectory(stages):
    ex, plan = _pipelined(stages, 2)
    opt = tiny_optimizer()
    params = staged_params()
    opt_state = opt.init(params)
    for t, expected in enumerate(GOLDEN_STAGED_LOSSES):
        split = ex.stage(plan.split(staged_batch(8, seed=t)))
        params, opt_state, m = ex.step_split(params, opt_state, split)
        assert_scalar_close(m["loss"], expected,
                            what=f"golden staged loss step {t}")


def test_ragged_plan_auto_upgrades_and_matches():
    # mini 7 / micro 4 is ragged: "paper" normalization upgrades to exact
    # with a one-sample zero-weight pad (a ragged paper tail would land
    # on one DP shard and skew the mean)
    mesh = pipeline_mesh(2, 2)
    plan = engine.plan_mbs(7, micro_batch_size=4, normalization="paper",
                           mesh=mesh, pipeline=True)
    assert plan.normalization == "exact" and plan.pad == 1
    ex = make_pipelined_executor(staged_spec(), tiny_optimizer(), plan, mesh)
    ref, ref_plan = _reference(7, 4)
    params = staged_params()
    batch = staged_batch(7)
    g, loss = ex.gradients(params, ex.stage(plan.split(batch)))
    g_ref, loss_ref = ref.gradients(params, ref_plan.device_split(batch))
    assert_scalar_close(loss, loss_ref, what="ragged loss")
    assert_trees_close(g, g_ref, what="ragged grads")


# ---------------------------------------------------------------------------
# admission / construction errors
# ---------------------------------------------------------------------------

def test_paper_ragged_plan_refused():
    mesh = pipeline_mesh(2, 2)
    plan = engine.plan_mbs(7, micro_batch_size=4, normalization="paper",
                           mesh=mesh, pipeline=True)
    forced = dataclasses.replace(plan, normalization="paper")
    with pytest.raises(ValueError, match="cannot be pipelined exactly"):
        make_pipelined_executor(staged_spec(), tiny_optimizer(), forced,
                                mesh)


def test_non_dividing_stage_count_raises():
    # STAGED_NUM_LAYERS = 4 does not split over 3 stages
    mesh = pipeline_mesh(2, 3)
    plan = engine.plan_mbs(8, micro_batch_size=2, normalization="exact",
                           mesh=mesh, pipeline=True)
    with pytest.raises(ValueError, match="does not divide the"):
        make_pipelined_executor(staged_spec(), tiny_optimizer(), plan, mesh)
    with pytest.raises(ValueError, match="does not divide the block"):
        staged_spec().partition(staged_params(), 3)


def test_single_stage_mesh_refused():
    mesh = pipeline_mesh(2, 1)
    plan = engine.plan_mbs(8, micro_batch_size=2, normalization="exact",
                           mesh=mesh)
    with pytest.raises(ValueError, match="model axis of >= 2"):
        make_pipelined_executor(staged_spec(), tiny_optimizer(), plan, mesh)


def test_fsdp_requires_deferred_sync():
    mesh = pipeline_mesh(2, 2)
    plan = engine.plan_mbs(8, micro_batch_size=2, normalization="exact",
                           mesh=mesh, pipeline=True)
    with pytest.raises(ValueError, match="per-micro"):
        make_pipelined_executor(staged_spec(), tiny_optimizer(), plan, mesh,
                                fsdp=True, defer_sync=False)


def test_partition_combine_roundtrip():
    spec = staged_spec()
    params = staged_params()
    shared, staged = spec.partition(params, 2)
    assert jax.tree.leaves(staged)[0].shape[:2] == (2, STAGED_NUM_LAYERS // 2)
    back = spec.combine(jax.tree.map(jnp.asarray, shared), staged)
    assert_trees_close(back, params, what="partition/combine roundtrip")


# ---------------------------------------------------------------------------
# the JX005 / HLO005 schedule census — positive AND negative controls
# ---------------------------------------------------------------------------

def _abstract_args(ex, plan):
    params = staged_params()
    opt_state = tiny_optimizer().init(params)
    split = plan.split(staged_batch(8))
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.asarray(x).dtype),
        (params, opt_state, split))


def test_jx005_census_deferred_clean():
    ex, plan = _pipelined(2, 2)
    jaxpr = ex.trace_step(*_abstract_args(ex, plan))
    rep = analysis.check_pipelined_step(jaxpr, plan, stages=2,
                                        expect_sync="deferred")
    assert rep.ok, rep.format()
    # JX001/JX004 are structurally N/A for the pipelined factorization
    # (no micro-batch scan carry; gradients split into staged + shared
    # buckets, each below JX004's whole-tree payload threshold)
    assert rep.checks_run == ["JX002", "JX003", "JX005"]


def test_jx005_fires_on_per_micro_negative_control():
    ex, plan = _pipelined(2, 2, defer_sync=False)
    jaxpr = ex.trace_step(*_abstract_args(ex, plan))
    findings = analysis.check_pipeline_collectives(jaxpr, plan, stages=2,
                                                   expect="deferred")
    assert findings, "per-micro step passed the deferred census"
    assert any("data-axis gradient psum" in f.message for f in findings)
    # and the same trace is CLEAN under the census that matches its mode
    assert not analysis.check_pipeline_collectives(jaxpr, plan, stages=2,
                                                   expect="per-micro")


def test_jx005_ppermute_count_is_schedule_exact():
    ex, plan = _pipelined(2, 2)
    jaxpr = ex.trace_step(*_abstract_args(ex, plan))
    fwd, bwd, _, _ = engine.schedule_1f1b(2, int(plan.num_micro_batches))
    expected = int((fwd >= 0).any(axis=1).sum()
                   + (bwd >= 0).any(axis=1).sum())
    found = sum(t for e, _, t in analysis.iter_eqns(jaxpr)
                if e.primitive.name == "ppermute")
    assert found == expected


def test_hlo005_compiled_schedule():
    ex, plan = _pipelined(2, 2)
    args = _abstract_args(ex, plan)
    compiled = ex.lower_step(*args, donate=True).compile()
    fwd, bwd, _, _ = engine.schedule_1f1b(2, int(plan.num_micro_batches))
    max_pp = int((fwd >= 0).any(axis=1).sum()
                 + (bwd >= 0).any(axis=1).sum())
    n_micro = int(plan.num_micro_batches)
    assert not analysis.check_pipeline_hlo(
        compiled, expect="deferred", n_micro=n_micro, max_ppermutes=max_pp)

    # negative control: the per-micro baseline must NOT pass as deferred
    ex_pm, plan_pm = _pipelined(2, 2, defer_sync=False)
    compiled_pm = ex_pm.lower_step(*args, donate=True).compile()
    assert analysis.check_pipeline_hlo(
        compiled_pm, expect="deferred", n_micro=n_micro,
        max_ppermutes=max_pp), "per-micro compile passed deferred census"
    assert not analysis.check_pipeline_hlo(
        compiled_pm, expect="per-micro", n_micro=n_micro,
        max_ppermutes=max_pp)


def test_pipelined_state_fully_aliased():
    # the zero-copy update contract under the model-sharded steady state:
    # donated per-device state (block shards + replicated rest) is
    # reused in place
    ex, plan = _pipelined(2, 2)
    args = _abstract_args(ex, plan)
    compiled = ex.lower_step(*args, donate=True).compile()
    floor = ex.donated_state_bytes(args[0], args[1])
    assert not analysis.check_aliasing(compiled, floor)


# ---------------------------------------------------------------------------
# launcher surface: mesh specs + staged transformer losses
# ---------------------------------------------------------------------------

def test_parse_mesh_spec():
    assert mesh_lib.parse_mesh_spec("2:4", device_count=8) == (2, 4)
    assert mesh_lib.parse_mesh_spec("8:1", device_count=8) == (8, 1)
    with pytest.raises(ValueError, match="DATA:MODEL"):
        mesh_lib.parse_mesh_spec("2x4", device_count=8)
    with pytest.raises(ValueError, match="DATA:MODEL"):
        mesh_lib.parse_mesh_spec("2:banana", device_count=8)
    with pytest.raises(ValueError, match=">= 1"):
        mesh_lib.parse_mesh_spec("0:4", device_count=8)
    with pytest.raises(ValueError, match="needs 16 devices"):
        mesh_lib.parse_mesh_spec("4:4", device_count=8)


def test_build_mesh_from_spec():
    from repro.launch import train as train_mod
    ns = type("A", (), {"mesh": "2:2", "multi_pod": False})
    mesh = train_mod.build_mesh(ns)
    assert mesh_lib.data_parallel_size(mesh) == 2
    assert mesh_lib.axis_size(mesh, mesh_lib.MODEL_AXIS) == 2
    ns_host = type("A", (), {"mesh": "host", "multi_pod": False})
    host = train_mod.build_mesh(ns_host)
    assert mesh_lib.axis_size(host, mesh_lib.MODEL_AXIS) == 1
    ns_bad = type("A", (), {"mesh": "9:9", "multi_pod": False})
    with pytest.raises(ValueError, match="devices"):
        train_mod.build_mesh(ns_bad)


@pytest.mark.slow
def test_train_cli_rejects_bad_mesh_specs():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")

    def run(*extra):
        return subprocess.run(
            [sys.executable, "-m", "repro.launch.train", "--arch",
             "qwen2-1.5b", "--reduced", *extra],
            capture_output=True, text=True, timeout=300, cwd=ROOT, env=env)

    bad = run("--mesh", "2x4")
    assert bad.returncode == 2 and "DATA:MODEL" in bad.stderr
    over = run("--mesh", "64:64")
    assert over.returncode == 2 and "devices" in over.stderr
    fsdp = run("--fsdp")  # default --mesh host has no model axis
    assert fsdp.returncode == 2 and "DATA:MODEL" in fsdp.stderr


def test_make_staged_loss_matches_flat_forward():
    cfg = configs.get_reduced("qwen2-1.5b")
    mesh = pipeline_mesh(2, 2)
    plan = engine.plan_mbs(8, micro_batch_size=2, normalization="exact",
                           mesh=mesh, pipeline=True)
    staged = steps.make_staged_loss(cfg, jnp.float32,
                                    remat_policy=plan.remat_policy)
    assert staged.num_layers == cfg.num_periods
    opt = steps.make_optimizer(cfg)
    ex = make_pipelined_executor(staged, opt, plan, mesh)
    ref_plan = engine.plan_mbs(8, micro_batch_size=2, normalization="exact")
    ref = engine.CompiledScanExecutor(
        steps.make_loss_fn(cfg, jnp.float32,
                           remat_policy=ref_plan.remat_policy),
        opt, ref_plan)
    from repro.models import transformer
    params = jax.jit(lambda k: transformer.init_params(cfg, k))(
        jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                                   jnp.int32)}
    g, loss = ex.gradients(params, ex.stage(plan.split(batch)))
    g_ref, loss_ref = ref.gradients(params, ref.plan.device_split(batch))
    assert_scalar_close(loss, loss_ref, atol=5e-6, what="staged qwen2 loss")
    assert_trees_close(g, g_ref, atol=5e-5, what="staged qwen2 grads")


@pytest.mark.parametrize("arch,family", [
    ("mixtral-8x22b", "MoE"),
    ("qwen2-vl-72b", "VLM"),
    ("seamless-m4t-medium", "encoder-decoder"),
])
def test_make_staged_loss_rejects_unstageable_families(arch, family):
    with pytest.raises(ValueError, match="do not factor"):
        steps.make_staged_loss(configs.get_reduced(arch))
