"""Sharding/dry-run integration: the production-mesh lowering path runs in a
subprocess (the 512-device XLA flag must be set before jax initializes) with
REDUCED configs — proves mesh construction, the sharding policy, jit
lowering and compile end-to-end without waiting on full-size compiles."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(arch, shape, extra=()):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("JAX_PLATFORMS", None)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--reduced", "--no-probe", *extra]
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=1200,
                          cwd=ROOT, env=env)
    assert proc.returncode == 0, proc.stderr[-2000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("qwen2-1.5b", "train_4k"),
    ("mamba2-780m", "decode_32k"),
])
def test_reduced_dryrun_single_pod(arch, shape):
    res = _run(arch, shape)
    assert res["num_devices"] == 256
    assert res["memory"]["temp_bytes"] >= 0
    assert res["raw_cost_analysis"]["flops"] > 0


@pytest.mark.slow
def test_reduced_dryrun_multi_pod():
    res = _run("qwen2-1.5b", "train_4k", extra=("--multi-pod",))
    assert res["num_devices"] == 512
    assert res["axes"] == ["pod", "data", "model"]
