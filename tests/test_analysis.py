"""Static-analysis subsystem conformance (ISSUE 7).

Two directions, both mandatory:

  * POSITIVE — the shipped engine produces ZERO findings: every executor
    × mesh × remat combination traces and compiles clean through the
    jaxpr/HLO contract rules, and the repo source is lint-clean.
  * NEGATIVE — every rule actually FIRES on a seeded violation: a
    checker that cannot catch the bug it documents is worse than no
    checker (it certifies broken code).

The matrix uses the tiny conftest model (fast); one real reduced config
exercises the remat lattice (JX002 needs a model with a checkpoint
boundary to apply the policy to).
"""
import jax
import jax.numpy as jnp
import pytest

from conftest import (EXECUTOR_GRID, host_mesh, make_executor,
                      make_sharded_executor, tiny_batch, tiny_loss_fn,
                      tiny_optimizer, tiny_params)
from repro import analysis, engine
from repro.analysis import findings as F


def _setup(n_micro=4, mesh=None, **plan_kw):
    plan = engine.plan_mbs(4 * n_micro, num_microbatches=n_micro,
                           mesh=mesh, **plan_kw)
    opt = tiny_optimizer()
    params = tiny_params()
    return plan, opt, params, opt.init(params), \
        plan.device_split(tiny_batch(4 * n_micro))


# ---------------------------------------------------------------------------
# positive: the shipped engine is contract-clean
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("executor", EXECUTOR_GRID)
@pytest.mark.parametrize("mesh_mode", ["single", "host"])
def test_zero_findings_matrix(executor, mesh_mode):
    """Every executor × mesh combination traces (and, where jittable,
    compiles) with zero contract findings."""
    mesh = host_mesh(4) if mesh_mode == "host" else None
    plan, opt, params, opt_state, split = _setup(mesh=mesh, unroll=4)
    if mesh is not None:
        ex = make_sharded_executor(executor, tiny_loss_fn, opt, plan, mesh)
    else:
        ex = make_executor(executor, tiny_loss_fn, opt, plan)

    jaxpr = ex.trace_step(params, opt_state, split)
    report = analysis.check_train_step(
        jaxpr, plan, params,
        expect_sync="deferred" if mesh is not None else "none",
        policy="none")
    assert report.ok, report.format()

    if executor == "streaming":
        return  # per-micro dispatch loop: nothing to jit whole
    compiled = ex.lower_step(params, opt_state, split, donate=True).compile()
    state_bytes = analysis.tree_bytes((params, opt_state))
    hlo_findings = (
        analysis.check_aliasing(compiled, state_bytes, context=executor)
        + analysis.check_unexpected_ops(compiled, context=executor)
        + analysis.check_gradient_sync(
            compiled, expect="deferred" if mesh is not None else "none",
            n_micro=plan.num_micro_batches, context=executor))
    assert not hlo_findings, [f.format() for f in hlo_findings]


def test_remat_policy_applied_on_real_model():
    """JX002 positive leg on a REAL reduced config: the traced step under
    remat_policy=period carries checkpoint sub-jaxprs (the tiny model has
    no remat boundary, so this needs the transformer target)."""
    report = analysis.run_suite("qwen2_reduced", executor="compiled",
                                hlo=False, lint=False)
    assert report.ok, report.format()
    assert "JX002" in report.checks_run


def test_repo_is_lint_clean():
    assert analysis.lint_repo() == []


# ---------------------------------------------------------------------------
# negative: each jaxpr rule fires on a seeded violation
# ---------------------------------------------------------------------------

def _rules(findings):
    return {f.rule for f in findings}


def test_jx001_fires_on_bf16_accumulator():
    # executor honestly accumulates in bf16 (plan says so), but the
    # contract under check demands fp32 — the checker must see through it
    plan_bf16, opt, params, opt_state, split = _setup(
        accum_dtype=jnp.bfloat16)
    plan_fp32 = engine.plan_mbs(16, num_microbatches=4)
    ex = make_executor("compiled", tiny_loss_fn, opt, plan_bf16)
    jaxpr = ex.trace_step(params, opt_state, split)
    findings = analysis.check_accum_dtype(jaxpr, plan_fp32, params)
    assert "JX001" in _rules(findings), [f.format() for f in findings]


def test_jx002_fires_on_missing_and_unexpected_remat():
    plan, opt, params, opt_state, split = _setup()
    ex = make_executor("compiled", tiny_loss_fn, opt, plan)
    jaxpr = ex.trace_step(params, opt_state, split)
    # policy says "period" but the trace has no checkpoint sub-jaxpr
    missing = analysis.check_remat_policy(jaxpr, "period")
    assert "JX002" in _rules(missing)

    def remat_loss(p, b, exact_denom=None):
        f = jax.checkpoint(lambda q: tiny_loss_fn(q, b, exact_denom))
        return f(p)

    ex2 = make_executor("compiled", remat_loss, opt, plan)
    jaxpr2 = ex2.trace_step(params, opt_state, split)
    # checkpoint present under policy "none" — remat the planner did not
    # budget for
    unexpected = analysis.check_remat_policy(jaxpr2, "none")
    assert "JX002" in _rules(unexpected)
    # and the matched case is clean
    assert analysis.check_remat_policy(jaxpr2, "period") == []


def test_jx003_fires_on_host_callback():
    plan, opt, params, opt_state, split = _setup()

    def chatty_loss(p, b, exact_denom=None):
        loss, metrics = tiny_loss_fn(p, b, exact_denom)
        jax.debug.callback(lambda x: None, loss)
        return loss, metrics

    ex = make_executor("compiled", chatty_loss, opt, plan)
    jaxpr = ex.trace_step(params, opt_state, split)
    findings = analysis.check_host_callbacks(jaxpr)
    assert "JX003" in _rules(findings)


def test_jx004_fires_on_per_micro_sync():
    mesh = host_mesh(4)
    plan, opt, params, opt_state, split = _setup(mesh=mesh, unroll=4)
    eager = make_sharded_executor("compiled", tiny_loss_fn, opt, plan, mesh,
                                  defer_sync=False)
    jaxpr = eager.trace_step(params, opt_state, split)
    findings = analysis.check_collectives(
        jaxpr, params, n_micro=plan.num_micro_batches, expect="deferred")
    assert "JX004" in _rules(findings), [f.format() for f in findings]
    # the same trace is CORRECT under the per-micro expectation
    assert analysis.check_collectives(
        jaxpr, params, n_micro=plan.num_micro_batches,
        expect="per-micro") == []


# ---------------------------------------------------------------------------
# negative: HLO rules
# ---------------------------------------------------------------------------

def test_hlo001_fires_on_dropped_donation():
    plan, opt, params, opt_state, split = _setup()
    ex = make_executor("compiled", tiny_loss_fn, opt, plan)
    compiled = ex.lower_step(params, opt_state, split, donate=False).compile()
    findings = analysis.check_aliasing(
        compiled, analysis.tree_bytes((params, opt_state)), context="neg")
    assert "HLO001" in _rules(findings)


def test_hlo003_fires_on_wild_memory_model():
    plan, opt, params, opt_state, split = _setup()
    ex = make_executor("compiled", tiny_loss_fn, opt, plan)
    compiled = ex.lower_step(params, opt_state, split, donate=True).compile()
    # model claims 256 GiB for a KB-scale step: outside any sane band
    findings = analysis.check_memory_model(compiled, 1 << 38, context="neg")
    assert "HLO003" in _rules(findings)
    # a model equal to the measurement is inside the band
    measured = analysis.measured_peak_bytes(compiled)
    assert analysis.check_memory_model(compiled, measured,
                                       context="pos") == []


def test_hlo004_fires_on_per_micro_schedule():
    mesh = host_mesh(4)
    plan, opt, params, opt_state, split = _setup(mesh=mesh, unroll=4)
    eager = make_sharded_executor("compiled", tiny_loss_fn, opt, plan, mesh,
                                  donate=False, defer_sync=False)
    compiled = jax.jit(eager.make_train_step()).lower(
        params, opt_state, split).compile()
    findings = analysis.check_gradient_sync(
        compiled, expect="deferred", n_micro=plan.num_micro_batches,
        context="neg")
    assert "HLO004" in _rules(findings)


# ---------------------------------------------------------------------------
# negative: lint rules + the escape hatch
# ---------------------------------------------------------------------------

LINT_FIXTURES = {
    "LINT001": ("loss_val = float(metrics['loss'])\n", "engine-hot"),
    "LINT002": ("import jax.numpy as jnp\nq = jnp.pad(x, 4)\n", "kernels"),
    "LINT003": ("import jax\nf = jax.jit(step, donate_argnums=(0, 1))\n",
                "general"),
    "LINT004": ("from jax.experimental import pallas as pl\n"
                "out = pl.pallas_call(kernel, out_shape=s)(x)\n", "kernels"),
    "LINT005": ("from repro.kernels.grad_accum import grad_accum\n",
                "general"),
    # one-liner handler so the noqa-waiver fixture lands on the except line
    # (LINT006's waiver must sit there, not anywhere in the handler body)
    "LINT006": ("try: x = 1\nexcept Exception: pass\n", "engine"),
}


@pytest.mark.parametrize("rule", sorted(LINT_FIXTURES))
def test_lint_rule_fires(rule):
    src, category = LINT_FIXTURES[rule]
    findings = analysis.lint_source(src, f"fixture_{rule}.py",
                                    category=category)
    assert rule in _rules(findings), [f.format() for f in findings]


@pytest.mark.parametrize("rule", sorted(LINT_FIXTURES))
def test_lint_noqa_waives(rule):
    src, category = LINT_FIXTURES[rule]
    lines = src.rstrip("\n").split("\n")
    lines[-1] += f"  # repro: noqa({rule})"
    waived = analysis.lint_source("\n".join(lines) + "\n",
                                  f"fixture_{rule}.py", category=category)
    assert rule not in _rules(waived)


def test_lint001_ignores_cold_code():
    src, _ = LINT_FIXTURES["LINT001"]
    assert analysis.lint_source(src, "fixture.py", category="general") == []


def test_lint006_taxonomy_routing_passes():
    src = ("try:\n    x = 1\nexcept Exception as e:\n"
           "    if faults.is_oom(e):\n        raise\n")
    assert analysis.lint_source(src, "fixture.py", category="engine") == []


def test_lint006_ignores_engine_external_code():
    src, _ = LINT_FIXTURES["LINT006"]
    assert analysis.lint_source(src, "fixture.py", category="general") == []


# ---------------------------------------------------------------------------
# findings vocabulary + CLI gate
# ---------------------------------------------------------------------------

def test_finding_rejects_unknown_rule():
    with pytest.raises(ValueError):
        F.Finding(rule="XX999", severity=F.SEVERITY_ERROR, message="?")


def test_report_exit_codes():
    rep = F.Report()
    assert rep.ok and rep.exit_code() == F.EXIT_OK
    rep.extend([F.Finding(rule="LINT001", severity=F.SEVERITY_ERROR,
                          message="seeded")], "LINT")
    assert not rep.ok and rep.exit_code() == F.EXIT_CONTRACT
    assert (F.EXIT_OK, F.EXIT_ERROR, F.EXIT_BUDGET, F.EXIT_CONTRACT) == \
        (0, 1, 2, 3)


def test_cli_lint_only_clean_and_violating(monkeypatch, capsys):
    from repro.analysis import __main__ as cli
    from repro.analysis import lint as lint_mod

    assert cli.main(["--lint-only"]) == F.EXIT_OK

    seeded = [F.Finding(rule="LINT002", severity=F.SEVERITY_ERROR,
                        message="seeded violation", location="x.py:1")]
    monkeypatch.setattr(lint_mod, "lint_repo", lambda root=None: seeded)
    assert cli.main(["--lint-only", "--json"]) == F.EXIT_CONTRACT
    out = capsys.readouterr().out
    assert "seeded violation" in out and '"exit_code": 3' in out
