"""Property-based tests (hypothesis) of the MBS invariants: for ANY batch
size, micro-batch size, model shape and data, the loss-normalized
accumulated gradient equals the mini-batch gradient (paper eq. 15–17) —
and the Layer-5 planner invariants: admission is monotone in the HBM
budget and in the remat-policy weight, and the joint (policy, N_μ) choice
always satisfies the analytic budget it was admitted under."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import configs, engine  # noqa: E402
from repro.core import losses, mbs as M, memory_model  # noqa: E402
from repro.models import remat  # noqa: E402


def _loss_fn(p, batch, exact_denom=None):
    h = jnp.tanh(batch["x"] @ p["w1"])
    logits = h @ p["w2"]
    return losses.cross_entropy(
        logits, batch["y"], sample_weight=batch.get("sample_weight"),
        exact_denom=exact_denom), {}


def _max_err(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@settings(max_examples=25, deadline=None)
@given(n_b=st.integers(2, 24), n_mu=st.integers(1, 24),
       din=st.integers(2, 10), dh=st.integers(2, 12),
       seed=st.integers(0, 2 ** 16))
def test_mbs_gradient_equivalence(n_b, n_mu, din, dh, seed):
    rng = np.random.default_rng(seed)
    params = {"w1": jnp.asarray(rng.normal(0, 0.4, (din, dh)), jnp.float32),
              "w2": jnp.asarray(rng.normal(0, 0.4, (dh, 3)), jnp.float32)}
    batch = {"x": rng.normal(size=(n_b, din)).astype(np.float32),
             "y": rng.integers(0, 3, n_b).astype(np.int32)}
    _, ref = jax.value_and_grad(lambda p: _loss_fn(p, batch)[0])(params)
    split = {k: jnp.asarray(v) for k, v in M.split_minibatch(batch, n_mu).items()}
    # exact mode is correct for every (n_b, n_mu) including ragged tails
    g, _ = M.mbs_gradients(_loss_fn, params, split,
                           M.MBSConfig(n_mu, "exact"))
    assert _max_err(g, ref) < 2e-5


@settings(max_examples=25, deadline=None)
@given(n_b=st.integers(2, 24), n_mu=st.integers(1, 24),
       seed=st.integers(0, 2 ** 16))
def test_paper_mode_equivalence_when_uniform(n_b, n_mu, seed):
    """Algorithm 1 (paper mode) is exact whenever the split is uniform —
    i.e. the paper's own experimental setting."""
    n_mu_eff = min(n_mu, n_b)
    if n_b % n_mu_eff:
        n_b = (n_b // n_mu_eff) * n_mu_eff  # make it uniform
    rng = np.random.default_rng(seed)
    params = {"w1": jnp.asarray(rng.normal(0, 0.4, (6, 8)), jnp.float32),
              "w2": jnp.asarray(rng.normal(0, 0.4, (8, 3)), jnp.float32)}
    batch = {"x": rng.normal(size=(n_b, 6)).astype(np.float32),
             "y": rng.integers(0, 3, n_b).astype(np.int32)}
    _, ref = jax.value_and_grad(lambda p: _loss_fn(p, batch)[0])(params)
    split = {k: jnp.asarray(v) for k, v in M.split_minibatch(batch, n_mu).items()}
    g, _ = M.mbs_gradients(_loss_fn, params, split, M.MBSConfig(n_mu, "paper"))
    assert _max_err(g, ref) < 2e-5


# ---------------------------------------------------------------------------
# Layer-5 planner invariants (remat policy × micro-batch admission)
# ---------------------------------------------------------------------------

_ARCHS = ["qwen2-1.5b", "mixtral-8x22b", "mamba2-780m", "recurrentgemma-2b"]
_CFGS = {a: configs.get_reduced(a) for a in _ARCHS}


def _budget_around(cfg, seq, frac):
    """A budget spanning 'nothing fits' .. 'everything fits': steady state
    plus ``frac`` of the whole-mini-batch no-remat activation range."""
    est = memory_model.estimate(cfg, seq, remat_policy="none")
    return int(est.total(0) + frac * 64 * est.activation_bytes_per_sample)


@settings(max_examples=25, deadline=None)
@given(arch=st.sampled_from(_ARCHS), seq=st.sampled_from([16, 64, 256]),
       f1=st.floats(0.0, 1.0), f2=st.floats(0.0, 1.0),
       policy=st.sampled_from(remat.POLICIES))
def test_admission_monotone_in_budget(arch, seq, f1, f2, policy):
    """More HBM never admits a smaller micro-batch (fixed policy)."""
    cfg = _CFGS[arch]
    lo, hi = sorted([_budget_around(cfg, seq, f1), _budget_around(cfg, seq, f2)])
    m_lo = memory_model.suggest_micro_batch_size(
        cfg, seq, 64, budget_bytes=lo, remat_policy=policy) or 0
    m_hi = memory_model.suggest_micro_batch_size(
        cfg, seq, 64, budget_bytes=hi, remat_policy=policy) or 0
    assert m_lo <= m_hi


@settings(max_examples=25, deadline=None)
@given(arch=st.sampled_from(_ARCHS), seq=st.sampled_from([16, 64, 256]),
       frac=st.floats(0.0, 1.0))
def test_admission_monotone_in_policy_weight(arch, seq, frac):
    """Heavier remat never admits a smaller micro-batch (fixed budget):
    the activation term is monotone non-increasing along the lattice, so
    admission is monotone non-decreasing in ``remat.policy_weight``."""
    cfg = _CFGS[arch]
    budget = _budget_around(cfg, seq, frac)
    admitted = [memory_model.suggest_micro_batch_size(
        cfg, seq, 64, budget_bytes=budget, remat_policy=p) or 0
        for p in remat.POLICIES]
    assert admitted == sorted(admitted), dict(zip(remat.POLICIES, admitted))


@settings(max_examples=25, deadline=None)
@given(arch=st.sampled_from(_ARCHS), seq=st.sampled_from([16, 64, 256]),
       frac=st.floats(0.0, 1.0), mini=st.integers(1, 64))
def test_joint_choice_satisfies_analytic_budget(arch, seq, frac, mini):
    """The (policy, N_μ) pair plan_mbs picks under "auto" always fits the
    budget it was admitted under, and never understates what the cheapest
    equally-admitting policy could do."""
    cfg = _CFGS[arch]
    budget = _budget_around(cfg, seq, frac)
    plan = engine.plan_mbs(mini, model_cfg=cfg, seq_len=seq,
                           budget_bytes=budget, remat_policy="auto")
    est = memory_model.estimate(cfg, seq, remat_policy=plan.remat_policy)
    if est.total(1) <= budget:  # something fits: the choice must too
        assert est.total(plan.micro_batch_size) <= budget
    # no cheaper policy admits strictly more than the chosen one
    w = remat.policy_weight(plan.remat_policy)
    for p in remat.POLICIES[:w]:
        cheaper = memory_model.suggest_micro_batch_size(
            cfg, seq, mini, budget_bytes=budget, remat_policy=p) or 0
        assert cheaper <= plan.micro_batch_size


# ---------------------------------------------------------------------------
# Layer-6 planner invariants (mesh-aware admission)
# ---------------------------------------------------------------------------


class _FakeMesh:
    """Planner-level mesh stand-in: plan_mbs/param_specs only read
    ``shape``/``axis_names``, so properties can sweep device counts far
    beyond what the forced host platform provides."""

    def __init__(self, data, model=1):
        self.shape = {"data": data, "model": model}
        self.axis_names = ("data", "model")


@settings(max_examples=25, deadline=None)
@given(arch=st.sampled_from(_ARCHS), seq=st.sampled_from([16, 64]),
       frac=st.floats(0.0, 1.0), dpe=st.integers(1, 6),
       mini=st.integers(64, 512))
def test_mesh_plan_covers_global_batch(arch, seq, frac, dpe, mini):
    """local_micro × data_parallel × N_Sμ >= the global mini-batch (every
    sample is processed), and the global micro-batch stays divisible by
    the data axis (every worker gets an equal slice)."""
    cfg = _CFGS[arch]
    mesh = _FakeMesh(2 ** dpe)
    plan = engine.plan_mbs(mini, model_cfg=cfg, seq_len=seq,
                           budget_bytes=_budget_around(cfg, seq, frac),
                           mesh=mesh, fsdp_params=False)
    assert plan.data_parallel == 2 ** dpe
    assert plan.micro_batch_size == plan.local_micro * plan.data_parallel
    assert (plan.local_micro * plan.data_parallel * plan.num_micro_batches
            >= mini)


@settings(max_examples=20, deadline=None)
@given(arch=st.sampled_from(_ARCHS), seq=st.sampled_from([16, 64]),
       frac=st.floats(0.0, 1.0), d1=st.integers(0, 6), d2=st.integers(0, 6))
def test_mesh_admission_monotone_in_device_count(arch, seq, frac, d1, d2):
    """More data-parallel workers never admit a smaller GLOBAL batch at a
    fixed per-device budget (a power-of-two mini-batch keeps the
    mini//dp cap from truncating unevenly)."""
    cfg = _CFGS[arch]
    budget = _budget_around(cfg, seq, frac)
    lo, hi = sorted([2 ** d1, 2 ** d2])
    mini = 512

    def admitted(dp):
        return engine.plan_mbs(mini, model_cfg=cfg, seq_len=seq,
                               budget_bytes=budget, mesh=_FakeMesh(dp),
                               fsdp_params=False).micro_batch_size

    assert admitted(lo) <= admitted(hi)


@settings(max_examples=20, deadline=None)
@given(arch=st.sampled_from(_ARCHS), seq=st.sampled_from([16, 64]),
       frac=st.floats(0.0, 1.0), dpe=st.integers(1, 5),
       fsdp=st.booleans())
def test_mesh_plan_never_exceeds_per_device_budget(arch, seq, frac, dpe,
                                                   fsdp):
    """The plan's own per-device estimate at its chosen local_micro fits
    the budget it was admitted under (whenever anything fits at all)."""
    cfg = _CFGS[arch]
    mesh = _FakeMesh(2 ** dpe)
    budget = _budget_around(cfg, seq, frac)
    plan = engine.plan_mbs(256, model_cfg=cfg, seq_len=seq,
                           budget_bytes=budget, mesh=mesh, fsdp_params=fsdp)
    est = memory_model.estimate(cfg, seq, remat_policy=plan.remat_policy,
                                mesh=mesh, fsdp_params=fsdp)
    if est.total(1) <= budget:  # something fits: the choice must too
        assert est.total(plan.local_micro) <= budget


# ---------------------------------------------------------------------------
# Layer-11 planner invariants (pipeline-aware admission)
# ---------------------------------------------------------------------------

# archs whose reduced block stacks split over 2 stages (num_periods = 2);
# pipeline admission is only defined for stageable dense stacks
_PIPE_ARCHS = ["qwen2-1.5b", "mamba2-780m"]


@settings(max_examples=20, deadline=None)
@given(arch=st.sampled_from(_PIPE_ARCHS), seq=st.sampled_from([16, 64]),
       f1=st.floats(0.0, 1.0), f2=st.floats(0.0, 1.0),
       dpe=st.integers(0, 4))
def test_pipeline_admission_monotone_in_budget(arch, seq, f1, f2, dpe):
    """More per-device HBM never admits a smaller micro-batch on a
    pipelined 2-D mesh (fixed stage count)."""
    cfg = _CFGS[arch]
    mesh = _FakeMesh(2 ** dpe, model=2)
    lo, hi = sorted([_budget_around(cfg, seq, f1),
                     _budget_around(cfg, seq, f2)])

    def admitted(budget):
        return engine.plan_mbs(256, model_cfg=cfg, seq_len=seq,
                               budget_bytes=budget, mesh=mesh,
                               fsdp_params=False,
                               pipeline=True).micro_batch_size

    assert admitted(lo) <= admitted(hi)


@settings(max_examples=20, deadline=None)
@given(arch=st.sampled_from(_PIPE_ARCHS), seq=st.sampled_from([16, 64]),
       frac=st.floats(0.0, 1.0), dpe=st.integers(0, 4))
def test_pipeline_plan_never_exceeds_per_device_budget(arch, seq, frac,
                                                       dpe):
    """The pipelined plan's own per-device estimate — stage-local params
    + warmup-depth stage activations — fits the budget it was admitted
    under (whenever anything fits at all), and records the mesh's stage
    count."""
    cfg = _CFGS[arch]
    mesh = _FakeMesh(2 ** dpe, model=2)
    budget = _budget_around(cfg, seq, frac)
    plan = engine.plan_mbs(256, model_cfg=cfg, seq_len=seq,
                           budget_bytes=budget, mesh=mesh,
                           fsdp_params=False, pipeline=True)
    assert plan.pipeline_stages == 2
    est = memory_model.estimate(cfg, seq, remat_policy=plan.remat_policy,
                                mesh=mesh, fsdp_params=False, pipeline=True)
    if est.total(1) <= budget:  # something fits: the choice must too
        assert est.total(plan.local_micro) <= budget


@settings(max_examples=15, deadline=None)
@given(arch=st.sampled_from(_PIPE_ARCHS), seq=st.sampled_from([16, 64]),
       stages=st.integers(3, 7))
def test_pipeline_non_dividing_stages_raise(arch, seq, stages):
    """A model axis that does not divide the block stack is refused at
    plan time with an actionable message (num_periods = 2 for every
    reduced arch here, so any odd/oversized stage count must raise)."""
    cfg = _CFGS[arch]
    if cfg.num_periods % stages == 0:
        return  # hypothesis found a dividing count — nothing to refuse
    with pytest.raises(ValueError, match="does not divide the block stack"):
        engine.plan_mbs(256, model_cfg=cfg, seq_len=seq,
                        mesh=_FakeMesh(1, model=stages), pipeline=True)


@settings(max_examples=30, deadline=None)
@given(n_b=st.integers(1, 40), n_mu=st.integers(1, 40))
def test_split_partition_invariants(n_b, n_mu):
    """eq. (1)-(3): the micro-batches are a partition; sizes obey
    N_mu <= N_B and N_Smu = ceil(N_B / N_mu)."""
    rng = np.random.default_rng(0)
    batch = {"x": rng.normal(size=(n_b, 3)).astype(np.float32)}
    split = M.split_minibatch(batch, n_mu)
    n_s, mu = split["x"].shape[:2]
    assert mu <= n_b  # eq. (3) + Algorithm 1 clamp
    assert n_s == -(-n_b // mu)
    w = split["sample_weight"].reshape(-1)
    assert w.sum() == n_b
    flat = split["x"].reshape(-1, 3)[w > 0]
    np.testing.assert_array_equal(flat, batch["x"])
