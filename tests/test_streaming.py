"""Stream-based pipeline (paper Fig. 1): the eager streaming executor
produces the same parameter update as the compiled MBS step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import losses, mbs as M
from repro.core.streaming import MBSStreamExecutor, prefetch_iterator
from repro import optim


def _make_batch(n, seed=0):
    rng = np.random.default_rng(seed)
    return {"x": rng.normal(size=(n, 8)).astype(np.float32),
            "y": rng.integers(0, 4, n).astype(np.int32)}


def _loss_fn(p, batch, exact_denom=None):
    h = jnp.tanh(batch["x"] @ p["w1"])
    logits = h @ p["w2"]
    return losses.cross_entropy(
        logits, batch["y"], sample_weight=batch.get("sample_weight"),
        exact_denom=exact_denom), {}


def test_stream_executor_matches_compiled_step():
    key = jax.random.PRNGKey(0)
    params = {"w1": jax.random.normal(key, (8, 16)) * 0.3,
              "w2": jax.random.normal(jax.random.fold_in(key, 1), (16, 4)) * 0.3}
    rng = np.random.default_rng(0)
    batch = {"x": rng.normal(size=(12, 8)).astype(np.float32),
             "y": rng.integers(0, 4, 12).astype(np.int32)}
    opt = optim.sgd(0.1, momentum=0.9)

    ex = MBSStreamExecutor(_loss_fn, opt, M.MBSConfig(4))
    p_stream, _, m_stream = ex.step(params, opt.init(params), dict(batch))

    split = {k: jnp.asarray(v) for k, v in M.split_minibatch(batch, 4).items()}
    step = M.make_mbs_train_step(_loss_fn, opt, M.MBSConfig(4))
    p_comp, _, m_comp = jax.jit(step)(params, opt.init(params), split)

    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(p_stream), jax.tree.leaves(p_comp)))
    assert err < 1e-6
    assert abs(m_stream["loss"] - float(m_comp["loss"])) < 1e-5


def test_prefetch_iterator_order_and_completeness():
    out = list(prefetch_iterator(iter(range(57)), size=3))
    assert out == list(range(57))


@pytest.mark.parametrize("normalization,n_b", [("paper", 12), ("exact", 12),
                                               ("exact", 10)])
def test_stream_executor_honors_normalization(normalization, n_b):
    """Regression: the streaming executor used to silently ignore
    MBSConfig.normalization="exact" — its gradients must match the compiled
    executor's in BOTH modes (including a ragged tail in exact mode)."""
    key = jax.random.PRNGKey(3)
    params = {"w1": jax.random.normal(key, (8, 16)) * 0.3,
              "w2": jax.random.normal(jax.random.fold_in(key, 1), (16, 4)) * 0.3}
    batch = _make_batch(n_b)
    cfg = M.MBSConfig(4, normalization=normalization)
    split = {k: jnp.asarray(v) for k, v in M.split_minibatch(batch, 4).items()}
    opt = optim.sgd(0.1)
    g_s, l_s = MBSStreamExecutor(_loss_fn, opt, cfg).gradients(params, split)
    from repro.engine import CompiledScanExecutor
    g_c, l_c = CompiledScanExecutor(_loss_fn, opt, cfg).gradients(params, split)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(g_s), jax.tree.leaves(g_c)))
    assert err < 1e-6
    assert abs(float(l_s) - float(l_c)) < 1e-6
    # exact mode equals the full-batch gradient even with a ragged tail
    if normalization == "exact":
        _, ref = jax.value_and_grad(lambda p: _loss_fn(p, batch)[0])(params)
        err = max(float(jnp.max(jnp.abs(a - b)))
                  for a, b in zip(jax.tree.leaves(g_s), jax.tree.leaves(ref)))
        assert err < 1e-6


def test_stream_executor_honors_accum_dtype():
    """Regression: the streaming executor used to accumulate in whatever
    zeros_like(params) gave, ignoring MBSConfig.accum_dtype."""
    key = jax.random.PRNGKey(4)
    params = {"w1": jax.random.normal(key, (8, 16)) * 0.3,
              "w2": jax.random.normal(jax.random.fold_in(key, 1), (16, 4)) * 0.3}
    split = {k: jnp.asarray(v)
             for k, v in M.split_minibatch(_make_batch(8), 4).items()}
    ex = MBSStreamExecutor(_loss_fn, optim.sgd(0.1),
                           M.MBSConfig(4, accum_dtype=jnp.bfloat16))
    g, _ = ex.gradients(params, split)
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(g))
