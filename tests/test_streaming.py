"""Stream-based pipeline (paper Fig. 1): the eager streaming executor
produces the same parameter update as the compiled MBS step."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses, mbs as M
from repro.core.streaming import MBSStreamExecutor, prefetch_iterator
from repro import optim


def _loss_fn(p, batch, exact_denom=None):
    h = jnp.tanh(batch["x"] @ p["w1"])
    logits = h @ p["w2"]
    return losses.cross_entropy(
        logits, batch["y"], sample_weight=batch.get("sample_weight"),
        exact_denom=exact_denom), {}


def test_stream_executor_matches_compiled_step():
    key = jax.random.PRNGKey(0)
    params = {"w1": jax.random.normal(key, (8, 16)) * 0.3,
              "w2": jax.random.normal(jax.random.fold_in(key, 1), (16, 4)) * 0.3}
    rng = np.random.default_rng(0)
    batch = {"x": rng.normal(size=(12, 8)).astype(np.float32),
             "y": rng.integers(0, 4, 12).astype(np.int32)}
    opt = optim.sgd(0.1, momentum=0.9)

    ex = MBSStreamExecutor(_loss_fn, opt, M.MBSConfig(4))
    p_stream, _, m_stream = ex.step(params, opt.init(params), dict(batch))

    split = {k: jnp.asarray(v) for k, v in M.split_minibatch(batch, 4).items()}
    step = M.make_mbs_train_step(_loss_fn, opt, M.MBSConfig(4))
    p_comp, _, m_comp = jax.jit(step)(params, opt.init(params), split)

    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(p_stream), jax.tree.leaves(p_comp)))
    assert err < 1e-6
    assert abs(m_stream["loss"] - float(m_comp["loss"])) < 1e-5


def test_prefetch_iterator_order_and_completeness():
    out = list(prefetch_iterator(iter(range(57)), size=3))
    assert out == list(range(57))
