"""Layer-level unit tests: RoPE/M-RoPE, softcap, chunked attention vs naive,
SSD chunk invariance, RG-LRU scan vs sequential recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, attention, nn, recurrent, ssm


def test_rope_rotation_preserves_norm():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 8, 4, 16))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    y = nn.apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(jnp.linalg.norm(x, axis=-1),
                               jnp.linalg.norm(y, axis=-1), rtol=1e-5)
    # position 0 is identity
    y0 = nn.apply_rope(x, jnp.zeros((2, 8), jnp.int32), 10_000.0)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(x), atol=1e-6)


def test_rope_relative_property():
    """<rope(q,m), rope(k,n)> depends only on m-n."""
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, 1, 1, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 32))

    def dot(m, n):
        qm = nn.apply_rope(q, jnp.full((1, 1), m, jnp.int32), 1e4)
        kn = nn.apply_rope(k, jnp.full((1, 1), n, jnp.int32), 1e4)
        return float(jnp.sum(qm * kn))

    assert abs(dot(5, 3) - dot(12, 10)) < 1e-4


def test_mrope_equals_rope_when_positions_equal():
    """M-RoPE with identical t/h/w position streams == plain RoPE."""
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (2, 6, 2, 24))
    pos = jnp.broadcast_to(jnp.arange(6)[None], (2, 6))
    mpos = jnp.broadcast_to(pos[None], (3, 2, 6))
    a = nn.apply_rope(x, pos, 1e4)
    b = nn.apply_mrope(x, mpos, 1e4, (4, 4, 4))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_softcap_bounds_and_identity():
    x = jnp.asarray([-100.0, -1.0, 0.0, 1.0, 100.0])
    y = nn.softcap(x, 30.0)
    assert float(jnp.max(jnp.abs(y))) <= 30.0
    np.testing.assert_allclose(np.asarray(nn.softcap(x, None)), np.asarray(x))
    # small values pass ~unchanged
    assert abs(float(nn.softcap(jnp.asarray(1.0), 30.0)) - 1.0) < 1e-3


@pytest.mark.parametrize("S,chunk", [(32, 8), (33, 8), (16, 16), (40, 13)])
def test_ssd_chunk_size_invariance(S, chunk):
    key = jax.random.PRNGKey(3)
    B, H, P, N = 2, 3, 8, 4
    x = jax.random.normal(key, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.1)
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, N))
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, S, N))
    y1, f1 = ssm.ssd_chunked(x, dt, A, Bm, Cm, chunk)
    y2, f2 = ssm.ssd_chunked(x, dt, A, Bm, Cm, S)  # single chunk
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), atol=1e-4)


def test_ssd_matches_sequential_recurrence():
    key = jax.random.PRNGKey(4)
    B, S, H, P, N = 1, 12, 2, 4, 3
    x = jax.random.normal(key, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (B, S, H)))
    A = -jnp.exp(jnp.zeros((H,)))
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, N))
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, S, N))
    y, final = ssm.ssd_chunked(x, dt, A, Bm, Cm, 4)
    # sequential reference: h_t = exp(dt*A) h_{t-1} + dt * B x
    h = np.zeros((B, H, P, N))
    for t in range(S):
        dec = np.exp(np.asarray(dt[:, t]) * np.asarray(A))  # (B,H)
        xdt = np.asarray(x[:, t]) * np.asarray(dt[:, t])[..., None]  # (B,H,P)
        h = h * dec[..., None, None] + np.einsum("bn,bhp->bhpn",
                                                 np.asarray(Bm[:, t]), xdt)
        yt = np.einsum("bn,bhpn->bhp", np.asarray(Cm[:, t]), h)
        np.testing.assert_allclose(np.asarray(y[:, t]), yt, atol=1e-4)
    np.testing.assert_allclose(np.asarray(final), h, atol=1e-4)


def test_rg_lru_scan_matches_sequential():
    cfg = ModelConfig(name="r", family="h", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=1, head_dim=8, d_ff=32,
                      vocab_size=8, lru_width=16)
    p = recurrent.recurrent_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 16))
    out_full, h_full = recurrent.recurrent_block(p, cfg, x)
    # sequential: feed one token at a time through the decode path
    cache = recurrent.init_recurrent_cache(cfg, 2, jnp.float32)
    outs = []
    for t in range(10):
        o, cache = recurrent.recurrent_decode_step(p, cfg, x[:, t:t + 1], cache)
        outs.append(o)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_full), np.asarray(seq),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(cache["h"]),
                               atol=1e-4)


def test_chunked_attention_kvalid_ring():
    """Decode against a partially-filled ring cache masks empty slots."""
    cfg = ModelConfig(name="a", family="d", num_layers=1, d_model=32,
                      num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                      vocab_size=8, sliding_window=4)
    p = attention.attn_init(jax.random.PRNGKey(0), cfg)
    cache = attention.init_kv_cache(cfg, 1, 8, 4, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 32))
    out, cache = attention.attn_decode_step(p, cfg, x, cache,
                                            jnp.zeros((1,), jnp.int32),
                                            window=4)
    assert not bool(jnp.isnan(out).any())
    assert int((cache["pos"] >= 0).sum()) == 1
