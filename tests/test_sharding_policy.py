"""Unit tests of the divisibility-aware sharding policy (pure logic — the
production-mesh integration runs in tests/test_dryrun_reduced.py)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import mesh as mesh_lib, sharding


class FakeMesh:
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


def test_param_specs_tp_and_fsdp():
    mesh = FakeMesh({"data": 16, "model": 16})
    params = {
        "ffn": {"w_up": jax.ShapeDtypeStruct((3584, 14336), jnp.float32)},
        "norm": {"scale": jax.ShapeDtypeStruct((3584,), jnp.float32)},
    }
    specs = sharding.param_specs(params, mesh)
    assert specs["ffn"]["w_up"] == P("data", "model")
    assert specs["norm"]["scale"] == P(None)  # 1-D: replicated


def test_param_specs_skips_stacked_dim():
    mesh = FakeMesh({"data": 16, "model": 16})
    params = {"blocks": ({"w": jax.ShapeDtypeStruct((64, 128, 256), jnp.float32)},)}
    specs = sharding.param_specs(params, mesh)
    # leading period dim (64) must NOT be sharded even though divisible
    assert specs["blocks"][0]["w"] == P(None, "data", "model")


def test_param_specs_nondivisible_replicated():
    mesh = FakeMesh({"data": 16, "model": 16})
    params = {"w": jax.ShapeDtypeStruct((10, 7), jnp.float32)}
    assert sharding.param_specs(params, mesh)["w"] == P(None, None)


def test_embed_table_vocab_sharded():
    mesh = FakeMesh({"data": 16, "model": 16})
    params = {"embed": {"table": jax.ShapeDtypeStruct((256000, 3584), jnp.float32)}}
    assert sharding.param_specs(params, mesh)["embed"]["table"] == \
        P("model", "data")
    # non-divisible vocab falls back to the generic rule
    params = {"embed": {"table": jax.ShapeDtypeStruct((256206, 1024), jnp.float32)}}
    spec = sharding.param_specs(params, mesh)["embed"]["table"]
    # 256206 not divisible by 16 -> vocab dim replicated, d_model TP-sharded
    assert spec == P(None, "model")


def test_fsdp_over_pod():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    params = {"w": jax.ShapeDtypeStruct((8, 6144, 2048), jnp.float32)}
    spec = sharding.param_specs(params, mesh, fsdp_over_pod=True)["w"]
    assert spec == P(None, ("pod", "data"), "model")


def test_batch_specs():
    mesh = FakeMesh({"data": 16, "model": 16})
    batch = {"tokens": jax.ShapeDtypeStruct((8, 32, 4096), jnp.int32),
             "small": jax.ShapeDtypeStruct((8, 3), jnp.float32)}
    specs = sharding.batch_specs(batch, mesh, batch_dim=1)
    assert specs["tokens"] == P(None, "data", None)
    assert specs["small"] == P(None, None)  # 3 not divisible by 16


def test_cache_specs_prefers_largest_dim():
    mesh = FakeMesh({"data": 16, "model": 16})
    cache = {"k": jax.ShapeDtypeStruct((21, 128, 32768, 8, 256), jnp.bfloat16)}
    specs = sharding.cache_specs(cache, mesh, stacked=True)
    # window dim (32768) sharded on model, batch (128) on data
    assert specs["k"] == P(None, "data", "model", None, None)
