"""Unit tests of the divisibility-aware sharding policy (pure logic — the
production-mesh integration runs in tests/test_dryrun_reduced.py)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import mesh as mesh_lib, sharding


class FakeMesh:
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


def test_param_specs_tp_and_fsdp():
    mesh = FakeMesh({"data": 16, "model": 16})
    params = {
        "ffn": {"w_up": jax.ShapeDtypeStruct((3584, 14336), jnp.float32)},
        "norm": {"scale": jax.ShapeDtypeStruct((3584,), jnp.float32)},
    }
    specs = sharding.param_specs(params, mesh)
    assert specs["ffn"]["w_up"] == P("data", "model")
    assert specs["norm"]["scale"] == P(None)  # 1-D: replicated


def test_param_specs_skips_stacked_dim():
    mesh = FakeMesh({"data": 16, "model": 16})
    params = {"blocks": ({"w": jax.ShapeDtypeStruct((64, 128, 256), jnp.float32)},)}
    specs = sharding.param_specs(params, mesh)
    # leading period dim (64) must NOT be sharded even though divisible
    assert specs["blocks"][0]["w"] == P(None, "data", "model")


def test_param_specs_nondivisible_replicated():
    mesh = FakeMesh({"data": 16, "model": 16})
    params = {"w": jax.ShapeDtypeStruct((10, 7), jnp.float32)}
    assert sharding.param_specs(params, mesh)["w"] == P(None, None)


def test_embed_table_vocab_sharded():
    mesh = FakeMesh({"data": 16, "model": 16})
    params = {"embed": {"table": jax.ShapeDtypeStruct((256000, 3584), jnp.float32)}}
    assert sharding.param_specs(params, mesh)["embed"]["table"] == \
        P("model", "data")
    # non-divisible vocab falls back to the generic rule
    params = {"embed": {"table": jax.ShapeDtypeStruct((256206, 1024), jnp.float32)}}
    spec = sharding.param_specs(params, mesh)["embed"]["table"]
    # 256206 not divisible by 16 -> vocab dim replicated, d_model TP-sharded
    assert spec == P(None, "model")


def test_fsdp_over_pod():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    params = {"w": jax.ShapeDtypeStruct((8, 6144, 2048), jnp.float32)}
    spec = sharding.param_specs(params, mesh, fsdp_over_pod=True)["w"]
    assert spec == P(None, ("pod", "data"), "model")


def test_batch_specs():
    mesh = FakeMesh({"data": 16, "model": 16})
    batch = {"tokens": jax.ShapeDtypeStruct((8, 32, 4096), jnp.int32),
             "small": jax.ShapeDtypeStruct((8, 3), jnp.float32)}
    specs = sharding.batch_specs(batch, mesh, batch_dim=1)
    assert specs["tokens"] == P(None, "data", None)
    assert specs["small"] == P(None, None)  # 3 not divisible by 16


def test_cache_specs_prefers_largest_dim():
    mesh = FakeMesh({"data": 16, "model": 16})
    cache = {"k": jax.ShapeDtypeStruct((21, 128, 32768, 8, 256), jnp.bfloat16)}
    specs = sharding.cache_specs(cache, mesh, stacked=True)
    # window dim (32768) sharded on model, batch (128) on data
    assert specs["k"] == P(None, "data", "model", None, None)


# ---------------------------------------------------------------------------
# edge cases (previously only exercised indirectly via the smoke paths)
# ---------------------------------------------------------------------------

def test_param_specs_partially_divisible_leaf():
    """Only the divisible dim shards; the model axis claims the LAST
    divisible dim (searching from the right), the rest replicate."""
    mesh = FakeMesh({"data": 16, "model": 16})
    params = {"w": jax.ShapeDtypeStruct((3584, 7), jnp.float32)}
    # dim 1 (7) not divisible -> model falls back to dim 0; nothing left
    # for FSDP
    assert sharding.param_specs(params, mesh)["w"] == P("model", None)


def test_param_specs_1d_leaves_replicated_even_when_divisible():
    mesh = FakeMesh({"data": 16, "model": 16})
    params = {"bias": jax.ShapeDtypeStruct((4096,), jnp.float32),
              "scalar": jax.ShapeDtypeStruct((), jnp.float32)}
    specs = sharding.param_specs(params, mesh)
    assert specs["bias"] == P(None)
    assert specs["scalar"] == P()


def test_param_specs_stacked_2d_leaf_fully_replicated():
    """Under a stacked root the leading (scan) dim never shards, and a
    2-D leaf then has only ONE remaining dim — a per-layer vector, which
    stays replicated like any 1-D leaf."""
    mesh = FakeMesh({"data": 16, "model": 16})
    params = {"blocks": ({"scale": jax.ShapeDtypeStruct((24, 4096),
                                                        jnp.float32)},)}
    assert sharding.param_specs(params, mesh)["blocks"][0]["scale"] == \
        P(None, None)


def test_param_specs_stacked_skip_applies_to_every_stacked_root():
    mesh = FakeMesh({"data": 16, "model": 16})
    for root in ("blocks", "enc_layers", "dec_layers"):
        params = {root: ({"w": jax.ShapeDtypeStruct((16, 256, 512),
                                                    jnp.float32)},)}
        spec = sharding.param_specs(params, mesh)[root][0]["w"]
        assert spec == P(None, "data", "model"), (root, spec)


def test_embed_table_nondivisible_fsdp_dim():
    """Divisible vocab shards Megatron-style on model; a d_model that the
    data axis does not divide leaves the FSDP dim replicated (instead of
    corrupting the layout)."""
    mesh = FakeMesh({"data": 16, "model": 16})
    params = {"embed": {"table": jax.ShapeDtypeStruct((256000, 1000),
                                                      jnp.float32)}}
    assert sharding.param_specs(params, mesh)["embed"]["table"] == \
        P("model", None)


def test_batch_specs_with_pod_axis_and_nondivisible():
    mesh = FakeMesh({"pod": 2, "data": 8, "model": 1})
    batch = {"tokens": jax.ShapeDtypeStruct((4, 16, 128), jnp.int32),
             "ragged": jax.ShapeDtypeStruct((4, 10, 128), jnp.int32)}
    specs = sharding.batch_specs(batch, mesh, batch_dim=1)
    # 16 % (2*8) == 0 -> sharded over the (pod, data) product
    assert specs["tokens"] == P(None, ("pod", "data"), None)
    # 10 % 16 != 0 -> replicated, GSPMD handles the layout
    assert specs["ragged"] == P(None, None, None)


def test_cache_specs_nondivisible_fully_replicated():
    mesh = FakeMesh({"data": 16, "model": 16})
    cache = {"state": jax.ShapeDtypeStruct((21, 10, 7, 3), jnp.float32)}
    assert sharding.cache_specs(cache, mesh, stacked=True)["state"] == \
        P(None, None, None, None)


def test_fsdp_disabled_leaves_data_axis_unused():
    mesh = FakeMesh({"data": 16, "model": 16})
    params = {"w": jax.ShapeDtypeStruct((3584, 14336), jnp.float32)}
    assert sharding.param_specs(params, mesh, fsdp=False)["w"] == \
        P(None, "model")
