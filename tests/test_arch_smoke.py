"""Per-architecture smoke tests: every assigned architecture, as a REDUCED
variant of the same family, runs one forward and one MBS train step on CPU —
asserting output shapes and the absence of NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, optim
from repro.core import mbs as M
from repro.launch import steps
from repro.models import encdec, transformer

B, S = 4, 16


def _batch(cfg, key):
    i32 = jnp.int32
    if cfg.is_encdec:
        return {
            "frames": jax.random.normal(key, (B, S, cfg.d_model), jnp.float32),
            "tgt_tokens": jax.random.randint(key, (B, S // 4), 0, cfg.vocab_size),
            "labels": jax.random.randint(key, (B, S // 4), 0, cfg.vocab_size),
        }
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.is_vlm:
        batch["vision_embeds"] = jax.random.normal(
            key, (B, 4, transformer.VISION_EMBED_DIM), jnp.float32)
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, S))
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_forward_and_mbs_train_step(arch):
    cfg = configs.get_reduced(arch)
    assert cfg.d_model <= 512 and cfg.num_layers <= 6
    assert cfg.num_experts <= 4
    key = jax.random.PRNGKey(0)
    init = encdec.init_params if cfg.is_encdec else transformer.init_params
    params = init(cfg, key)
    batch = _batch(cfg, key)

    # forward
    if cfg.is_encdec:
        logits, aux = encdec.forward(params, cfg, batch["frames"],
                                     batch["tgt_tokens"], dtype=jnp.float32)
        assert logits.shape == (B, S // 4, cfg.vocab_size)
    else:
        logits, aux = transformer.forward(
            params, cfg, batch["tokens"],
            vision_embeds=batch.get("vision_embeds"),
            mrope_positions=batch.get("mrope_positions"), dtype=jnp.float32)
        assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())

    # one MBS train step (2 micro-batches)
    loss_fn = steps.make_loss_fn(cfg, dtype=jnp.float32, remat=False)
    opt = optim.sgd(1e-2, momentum=0.9)
    step = M.make_mbs_train_step(loss_fn, opt, M.MBSConfig(B // 2))
    split = jax.tree.map(
        lambda x: x.reshape((2, B // 2) + x.shape[1:]) if x.shape[0] == B
        else x.reshape(x.shape[:1] + (2, B // 2) + x.shape[2:]).transpose(1, 0, 2, 3),
        batch)
    p2, s2, metrics = jax.jit(step)(params, opt.init(params), split)
    assert np.isfinite(float(metrics["loss"]))
    assert not any(bool(jnp.isnan(l).any()) for l in jax.tree.leaves(p2))


@pytest.mark.parametrize("arch", [a for a in configs.ARCHS])
def test_decode_step_smoke(arch):
    cfg = configs.get_reduced(arch)
    key = jax.random.PRNGKey(1)
    if cfg.is_encdec:
        params = encdec.init_params(cfg, key)
        frames = jax.random.normal(key, (B, 8, cfg.d_model), jnp.float32)
        cache = encdec.init_decode_cache(params, cfg, frames, 16, jnp.float32)
        tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
        logits, cache = encdec.decode_step(params, cfg, tok, cache,
                                           jnp.zeros((B,), jnp.int32),
                                           dtype=jnp.float32)
    else:
        params = transformer.init_params(cfg, key)
        cache = transformer.init_cache(cfg, B, 16, jnp.float32)
        tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
        logits, cache = transformer.decode_step(params, cfg, tok, cache,
                                                jnp.zeros((B,), jnp.int32),
                                                dtype=jnp.float32)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


def test_full_configs_match_assignment():
    spec = {
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
    }
    for arch, (L, d, H, K, ff, V) in spec.items():
        c = configs.get(arch)
        assert c.num_layers == L, arch
        assert c.d_model == d, arch
        assert c.num_heads == H, arch
        assert c.num_kv_heads == K, arch
        assert (c.d_ff == ff or c.moe_d_ff == ff), arch
        assert c.vocab_size == V, arch
    assert configs.get("grok-1-314b").num_experts == 8
    assert configs.get("grok-1-314b").experts_per_token == 2
    assert configs.get("mixtral-8x22b").num_experts == 8
    assert configs.get("moonshot-v1-16b-a3b").num_experts == 64
    assert configs.get("moonshot-v1-16b-a3b").experts_per_token == 6
    assert configs.get("mamba2-780m").ssm_state == 128
