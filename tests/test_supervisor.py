"""Fault-tolerant runtime (engine Layer 9): the recovery conformance matrix.

Every recovery path of the :class:`engine.Supervisor` is proven against
the deterministic fault-injection harness (``engine.faults``) on the tiny
conformance model, across the full executor grid (+ the sharded wrapper
under ``@pytest.mark.mesh``):

  * **negative control** — with no faults injected, the supervised loop
    is *bitwise identical* to the unsupervised ``Trainer`` (the guard-off
    executors compile the same program; supervision must be invisible);
  * **OOM** — an injected ``RESOURCE_EXHAUSTED`` at dispatch degrades the
    plan deterministically (remat escalation first, then micro-shrink)
    and the post-recovery trajectory equals an *uninterrupted* run at the
    degraded plan, within the harness per-dtype tolerances;
  * **non-finite gradients** — the on-device guard skips the poisoned
    update (params/opt-state provably untouched), the bounded clean
    re-draw retry recovers the exact clean trajectory, and the
    consecutive-skip circuit breaker / ``on_nan="halt"`` raise the
    documented ``SupervisorError`` subclasses (exit codes 40–44);
  * **transient worker/stream faults** — absorbed by the Pipeline's
    seeded-backoff retries (counted in ``stats.retries``) or by the
    supervisor's bounded stream restarts, with the data stream unchanged;
  * **crash-safe checkpoints** — torn writes (crash between npz rename
    and manifest commit) are invisible to ``committed_steps``/restore,
    CRC catches silent payload corruption, orphaned npz files don't break
    ``latest_step``, keep-last-k rotation holds, and checkpoint-I/O
    faults are retried then skipped without sinking training;
  * **calibrated re-plan** — an OOM at a calibrated plan records a
    negative bound in the tuning cache and triggers EXACTLY ONE re-plan
    whose admission is strictly smaller (the injected fault persists
    until admission actually drops below it), even when the cache file is
    corrupted mid-recovery.
"""
import os

import jax
import numpy as np
import pytest

from conftest import (EXECUTOR_GRID, GOLDEN_LOSSES, ToyDataset,
                      assert_trees_close, host_mesh, make_executor,
                      make_sharded_executor, max_abs_err, tiny_loss_fn,
                      tiny_optimizer, tiny_params)
from repro import configs, engine
from repro.checkpoint import checkpoint as ckpt_lib
from repro.core import memory_model
from repro.engine import faults

MINI, STEPS = 10, 5


def make_plan(**kw):
    base = dict(micro_batch_size=4, normalization="exact")
    base.update(kw)
    return engine.plan_mbs(MINI, **base)


def fresh_state():
    params = tiny_params()
    return params, tiny_optimizer().init(params)


def make_build(executor: str, *, guard: bool = True, mesh=None,
               pipeline_kw=None):
    """The launcher-shaped rebuild factory over the tiny model."""
    ds = ToyDataset()

    def build(plan):
        if mesh is not None:
            ex = make_sharded_executor("compiled", tiny_loss_fn,
                                       tiny_optimizer(), plan, mesh,
                                       guard=guard)
            sharding = ex.batch_shardings
        else:
            ex = make_executor(executor, tiny_loss_fn, tiny_optimizer(),
                               plan, guard=guard)
            sharding = None
        pipeline = engine.Pipeline(ds, plan, prefetch=0, sharding=sharding,
                                   **(pipeline_kw or {}))
        return ex.step_split, pipeline

    return build


def run_supervised(build, specs=(), *, plan=None, sup_kw=None, steps=STEPS,
                   **sup_ctor_kw):
    plan = plan or make_plan()
    sup = engine.Supervisor(build, plan,
                            config=engine.SupervisorConfig(**(sup_kw or {})),
                            log_fn=None, **sup_ctor_kw)
    params, opt_state = fresh_state()
    with faults.inject(faults.FaultPlan(*specs)) as fp:
        params, opt_state, last = sup.fit(params, opt_state, steps)
    return sup, fp, params, opt_state, last


def run_unsupervised(build, plan, steps=STEPS):
    step_fn, pipeline = build(plan)
    trainer = engine.Trainer(step_fn, pipeline, log_fn=None)
    params, opt_state = fresh_state()
    return trainer.fit(params, opt_state, steps)


# ---------------------------------------------------------------------------
# negative control: supervision is invisible when nothing goes wrong
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("executor", EXECUTOR_GRID)
def test_negative_control_bitwise(executor):
    build = make_build(executor, guard=False)
    sup, fp, p_sup, s_sup, _ = run_supervised(build)
    p_ref, s_ref, _ = run_unsupervised(build, make_plan())
    assert fp.fired == []
    assert sup.restarts == 0 and sup.records == []
    assert max_abs_err(p_sup, p_ref) == 0.0
    assert max_abs_err(s_sup, s_ref) == 0.0


def test_supervised_golden_trajectory():
    sup, _, _, _, _ = run_supervised(make_build("compiled"))
    np.testing.assert_allclose(
        [sup.history[i] for i in range(STEPS)], GOLDEN_LOSSES, atol=2e-6)


# ---------------------------------------------------------------------------
# OOM: degrade + re-plan + resume == uninterrupted run at the degraded plan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("executor", EXECUTOR_GRID)
def test_oom_recovery_matches_degraded_golden(executor):
    # remat pinned to "full": degradation takes the micro-shrink rung, so
    # the recovered run executes a genuinely different schedule (4 -> 2)
    plan = make_plan(remat_policy="full")
    build = make_build(executor)
    sup, fp, p_got, s_got, _ = run_supervised(build, [faults.oom_at(2)],
                                              plan=plan)
    assert fp.fired_kinds() == ["oom"]
    assert sup.restarts == 1
    assert sup.plan.micro_batch_size == 2
    [rec] = [r for r in sup.records if r.kind == "oom"]
    assert rec.action == "halve micro 4->2"
    degraded, _ = engine.degrade_plan(plan)
    p_ref, s_ref, _ = run_unsupervised(build, degraded)
    assert_trees_close(p_got, p_ref, what=f"{executor} params after OOM")
    assert_trees_close(s_got, s_ref, what=f"{executor} opt state after OOM")


def test_oom_remat_escalation_first():
    # default plan sits mid-lattice: the first rung is more recompute at
    # UNCHANGED geometry (the paper's point: don't give back batch)
    plan = make_plan()
    sup, _, p_got, _, _ = run_supervised(make_build("compiled"),
                                         [faults.oom_at(2)], plan=plan)
    [rec] = sup.records
    assert rec.kind == "oom" and "remat" in rec.action
    assert sup.plan.micro_batch_size == plan.micro_batch_size
    assert sup.plan.remat_policy != plan.remat_policy
    degraded, _ = engine.degrade_plan(plan)
    p_ref, _, _ = run_unsupervised(make_build("compiled"), degraded)
    assert_trees_close(p_got, p_ref, what="params after remat escalation")


def test_oom_restart_budget_and_plan_exhaustion():
    plan = make_plan(remat_policy="full")
    build = make_build("compiled")
    with pytest.raises(engine.RestartBudgetExceeded):
        run_supervised(build, [faults.oom_at(0, times=99)], plan=plan,
                       sup_kw={"max_restarts": 1})
    # micro=1 at remat=full: nothing left on the ladder
    with pytest.raises(engine.PlanExhausted):
        run_supervised(build, [faults.oom_at(0, times=99)],
                       plan=make_plan(micro_batch_size=1,
                                      remat_policy="full"),
                       sup_kw={"max_restarts": 99})


# ---------------------------------------------------------------------------
# non-finite gradients: guard + retry/skip + circuit breaker
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("executor", EXECUTOR_GRID)
def test_nan_retry_recovers_clean_trajectory(executor):
    build = make_build(executor)
    sup, fp, p_got, s_got, _ = run_supervised(build, [faults.nan_at(1)])
    assert fp.fired_kinds() == ["nan"]
    [rec] = sup.records
    assert rec.kind == "nonfinite" and rec.action.startswith("retried ok")
    p_ref, s_ref, _ = run_unsupervised(build, make_plan())
    assert max_abs_err(p_got, p_ref) == 0.0, \
        f"{executor}: clean re-draw retry must be invisible"
    assert max_abs_err(s_got, s_ref) == 0.0


@pytest.mark.parametrize("executor", EXECUTOR_GRID)
def test_nan_skip_leaves_state_untouched(executor):
    build = make_build(executor)
    # retries off: the clean re-draw (which bypasses injection by
    # construction) never runs, so the poisoned step must be skipped
    sup, _, p_got, s_got, _ = run_supervised(
        build, [faults.nan_at(1)], sup_kw={"nan_retries": 0})
    [rec] = sup.records
    assert rec.action == "skipped" and rec.steps_lost == 1
    # expected = the same stream with step 1's update elided entirely
    # (the guarded update must not have touched params or opt state)
    ds = ToyDataset()
    plan = make_plan()
    ex = make_executor(executor, tiny_loss_fn, tiny_optimizer(), plan,
                       guard=True)
    p_ref, s_ref = fresh_state()
    for i in (0, 2, 3, 4):
        batch = jax.device_put(plan.split(ds.batch(MINI, i)))
        p_ref, s_ref, _ = ex.step_split(p_ref, s_ref, batch)
    assert max_abs_err(p_got, p_ref) == 0.0, \
        f"{executor}: skipped step must leave state bitwise untouched"
    assert max_abs_err(s_got, s_ref) == 0.0


def test_nan_circuit_breaker():
    with pytest.raises(engine.NaNCircuitBreaker):
        run_supervised(make_build("compiled"),
                       [faults.nan_at(None, times=99)],
                       sup_kw={"nan_retries": 0, "max_consecutive_nan": 2})


def test_on_nan_halt():
    with pytest.raises(engine.NaNHalt):
        run_supervised(make_build("compiled"), [faults.nan_at(1)],
                       sup_kw={"on_nan": "halt"})


def test_exit_code_contract():
    assert engine.SupervisorError.exit_code == 40
    assert engine.RestartBudgetExceeded.exit_code == 41
    assert engine.PlanExhausted.exit_code == 42
    assert engine.NaNCircuitBreaker.exit_code == 43
    assert engine.NaNHalt.exit_code == 44
    for sub in (engine.RestartBudgetExceeded, engine.PlanExhausted,
                engine.NaNCircuitBreaker, engine.NaNHalt):
        assert issubclass(sub, engine.SupervisorError)


# ---------------------------------------------------------------------------
# transient worker / stream failures
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("executor", EXECUTOR_GRID)
def test_worker_fault_absorbed_by_pipeline_retry(executor):
    build = make_build(executor)
    sup, fp, p_got, _, _ = run_supervised(build, [faults.worker_at(1)])
    assert fp.fired_kinds() == ["worker"]
    assert sup.pipeline.stats.retries == 1  # surfaced next to wait stats
    assert sup.restarts == 0 and sup.records == []
    p_ref, _, _ = run_unsupervised(build, make_plan())
    assert max_abs_err(p_got, p_ref) == 0.0, \
        f"{executor}: absorbed retry must not perturb the data stream"


def test_stream_restart_resumes_midstream():
    # pipeline retries disabled: the transient escapes to the supervisor,
    # which re-opens the stream at the current step (bounded restarts)
    build = make_build("compiled", pipeline_kw={"retries": 0})
    sup, fp, p_got, _, _ = run_supervised(build,
                                          [faults.worker_at(2, times=2)])
    assert fp.fired_kinds() == ["worker", "worker"]
    assert [r.action for r in sup.records] == ["stream restart"] * 2
    p_ref, _, _ = run_unsupervised(build, make_plan())
    assert max_abs_err(p_got, p_ref) == 0.0


def test_stream_restart_budget_exhausts():
    build = make_build("compiled", pipeline_kw={"retries": 0})
    with pytest.raises(faults.TransientWorkerError):
        run_supervised(build, [faults.worker_at(2, times=99)],
                       sup_kw={"stream_retries": 2})


# ---------------------------------------------------------------------------
# crash-safe checkpoints
# ---------------------------------------------------------------------------

def _tree():
    params, opt_state = fresh_state()
    return {"params": params, "opt_state": opt_state}


def test_torn_write_is_invisible_then_resume_matches_clean(tmp_path):
    ckpt_dir = str(tmp_path / "ckpt")
    build = make_build("compiled")
    sup = engine.Supervisor(build, make_plan(), log_fn=None,
                            ckpt_dir=ckpt_dir, ckpt_every=1)
    params, opt_state = fresh_state()
    with faults.inject(faults.FaultPlan(faults.torn_write_at(2))):
        with pytest.raises(faults.InjectedCrash):
            sup.fit(params, opt_state, STEPS)
    # the crash hit between npz rename and manifest commit: the orphaned
    # npz is on disk but MUST be invisible to the commit record
    assert os.path.exists(os.path.join(ckpt_dir, "ckpt_00000002.npz"))
    assert not os.path.exists(os.path.join(ckpt_dir, "ckpt_00000002.json"))
    assert ckpt_lib.committed_steps(ckpt_dir) == [1]
    assert ckpt_lib.latest_step(ckpt_dir) == 1

    # "process restart": a fresh supervisor resumes from the commit record
    sup2 = engine.Supervisor(build, make_plan(), log_fn=None,
                             ckpt_dir=ckpt_dir, ckpt_every=1)
    params, opt_state = fresh_state()
    restored = sup2.restore(params, opt_state)
    assert restored is not None and restored[2] == 1
    p_got, s_got, _ = sup2.fit(restored[0], restored[1], STEPS,
                               start_step=1)
    p_ref, _, _ = run_unsupervised(build, make_plan())
    assert max_abs_err(p_got, p_ref) == 0.0, \
        "resume-after-crash must replay onto the clean trajectory"


def test_crc_detects_silent_payload_corruption(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    ckpt_lib.save(d, 1, tree)
    ckpt_lib.save(d, 2, tree)
    # silently corrupt step 2's payload: valid npz, same keys, wrong bytes
    path = os.path.join(d, "ckpt_00000002.npz")
    data = dict(np.load(path))
    data[list(data)[0]] = data[list(data)[0]] + 1.0
    with open(path, "wb") as f:
        np.savez(f, **data)
    with pytest.raises(ckpt_lib.CheckpointCorruptError):
        ckpt_lib.restore(d, tree, 2)
    # the resume walk skips it and lands on the older good checkpoint
    build = make_build("compiled")
    sup = engine.Supervisor(build, make_plan(), log_fn=None, ckpt_dir=d)
    restored = sup.restore(*fresh_state())
    assert restored is not None and restored[2] == 1


def test_orphan_npz_does_not_break_latest_step(tmp_path):
    d = str(tmp_path)
    ckpt_lib.save(d, 3, _tree())
    # an orphaned npz with no manifest (the pre-crash-safety failure mode)
    with open(os.path.join(d, "ckpt_00000007.npz"), "wb") as f:
        np.savez(f, junk=np.zeros(3))
    assert ckpt_lib.committed_steps(d) == [3]
    assert ckpt_lib.latest_step(d) == 3
    restored = ckpt_lib.restore(d, _tree())
    assert restored is not None


def test_keep_last_k_rotation(tmp_path):
    d = str(tmp_path)
    for step in (1, 2, 3, 4):
        ckpt_lib.save(d, step, _tree(), keep=2)
    assert ckpt_lib.committed_steps(d) == [3, 4]
    names = sorted(os.listdir(d))
    assert names == ["ckpt_00000003.json", "ckpt_00000003.npz",
                     "ckpt_00000004.json", "ckpt_00000004.npz"]


def test_trainer_ckpt_keep_and_corrupt_skip(tmp_path):
    d = str(tmp_path)
    build = make_build("compiled", guard=False)
    step_fn, pipeline = build(make_plan())
    trainer = engine.Trainer(step_fn, pipeline, ckpt_dir=d, ckpt_every=1,
                             ckpt_keep=3, log_fn=None)
    trainer.fit(*fresh_state(), STEPS)
    assert ckpt_lib.committed_steps(d) == [3, 4, 5]
    # tear the newest: Trainer.restore must fall back to the next one
    os.remove(os.path.join(d, "ckpt_00000005.json"))
    restored = trainer.restore(*fresh_state())
    assert restored is not None and restored[2] == 4


def test_ckpt_io_fault_retried_then_skipped(tmp_path):
    ckpt_dir = str(tmp_path / "ckpt")
    build = make_build("compiled")
    # one transient I/O failure: absorbed by the save retry loop
    sup, _, _, _, _ = run_supervised(build, [faults.ckpt_io_at(2)],
                                     ckpt_dir=ckpt_dir, ckpt_every=2)
    assert [r.action for r in sup.records] == ["ckpt-io retry 1"]
    assert ckpt_lib.committed_steps(ckpt_dir) == [2, 4, STEPS]

    # persistent I/O failure: the save is SKIPPED (training continues,
    # durability catches up at the next cadence), never fatal
    ckpt_dir2 = str(tmp_path / "ckpt2")
    with pytest.warns(UserWarning, match="checkpoint at step 2 failed"):
        sup, _, _, _, _ = run_supervised(
            build, [faults.ckpt_io_at(2, times=99)], ckpt_dir=ckpt_dir2,
            ckpt_every=2, sup_kw={"io_retries": 1})
    assert ckpt_lib.committed_steps(ckpt_dir2) == [4, STEPS]


# ---------------------------------------------------------------------------
# calibrated re-plan: the OOM feeds the Layer-7 cache as a negative bound
# ---------------------------------------------------------------------------

def _calibrated_setup(tmp_path):
    cfg = configs.get_reduced("qwen2-1.5b")
    seq = 32
    cache_path = str(tmp_path / "tuning.json")
    est = memory_model.estimate(cfg, seq, remat_policy="full")
    budget = est.total(4)  # admits a handful of samples at remat=full
    plan = engine.plan_mbs(16, model_cfg=cfg, seq_len=seq,
                           budget_bytes=budget, remat_policy="full",
                           calibrate="auto", tuning_cache=cache_path)
    ctx = dict(model_cfg=cfg, seq_len=seq, budget_bytes=budget,
               executor="compiled", tuning_cache=cache_path)
    ds = ToyDataset()

    def build(pl):
        ex = make_executor("compiled", tiny_loss_fn, tiny_optimizer(), pl,
                           guard=True)
        return ex.step_split, engine.Pipeline(ds, pl, prefetch=0)

    return plan, ctx, build, cache_path


def test_calibrated_oom_exactly_one_replan_strictly_smaller(tmp_path):
    plan, ctx, build, _ = _calibrated_setup(tmp_path)
    assert plan.micro_batch_size >= 2
    sup = engine.Supervisor(build, plan, log_fn=None, plan_ctx=ctx)
    params, opt_state = fresh_state()
    # the fault persists until admission genuinely drops below the size
    # that OOMed — so a re-plan that failed to shrink would fire it again
    specs = [faults.oom_at(1, times=99,
                           min_micro=plan.micro_batch_size)]
    with faults.inject(faults.FaultPlan(*specs)) as fp:
        sup.fit(params, opt_state, 4)
    assert sup.restarts == 1, "must re-plan EXACTLY once"
    assert fp.fired_kinds() == ["oom"]
    assert sup.plan.micro_batch_size < plan.micro_batch_size, \
        "re-planned admission must be strictly smaller"
    [rec] = [r for r in sup.records if r.kind == "oom"]
    assert "replan" in rec.action or "halve" in rec.action


def test_corrupt_cache_never_sinks_recovery(tmp_path):
    plan, ctx, build, cache_path = _calibrated_setup(tmp_path)
    sup = engine.Supervisor(build, plan, log_fn=None, plan_ctx=ctx)
    params, opt_state = fresh_state()
    specs = [faults.oom_at(1, times=99, min_micro=plan.micro_batch_size),
             faults.corrupt_cache()]
    with faults.inject(faults.FaultPlan(*specs)) as fp:
        sup.fit(params, opt_state, 4)
    assert "corrupt_cache" in fp.fired_kinds()
    assert sup.restarts == 1
    assert sup.plan.micro_batch_size < plan.micro_batch_size


# ---------------------------------------------------------------------------
# the degradation ladder itself (unit)
# ---------------------------------------------------------------------------

def test_degradation_ladder_is_deterministic():
    plan = make_plan(remat_policy="none")
    seen = []
    while True:
        try:
            plan, action = engine.degrade_plan(plan)
        except engine.PlanExhausted:
            break
        seen.append(action)
    assert seen == ["remat none->dots", "remat dots->period",
                    "remat period->full", "halve micro 4->2",
                    "halve micro 2->1"]


def test_degradation_respects_data_parallel_divisibility():
    mesh = host_mesh(2)
    plan = engine.plan_mbs(MINI, micro_batch_size=4, mesh=mesh,
                           remat_policy="full", normalization="exact")
    degraded, action = engine.degrade_plan(plan)
    assert degraded.micro_batch_size == 2
    assert degraded.micro_batch_size % 2 == 0
    assert degraded.local_micro == 1
    with pytest.raises(engine.PlanExhausted):
        engine.degrade_plan(degraded)  # can't go below the data extent


def test_fault_taxonomy_classification():
    assert faults.classify(faults.injected_oom()) == "oom"
    assert faults.classify(RuntimeError("RESOURCE_EXHAUSTED: oom")) == "oom"
    assert faults.classify(faults.TransientWorkerError("x")) == "transient"
    assert faults.classify(faults.InjectedIOError("x")) == "transient"
    assert faults.classify(OSError("disk")) == "transient"
    assert faults.classify(faults.InjectedCrash("x")) == "crash"
    assert faults.classify(ValueError("bug")) == "fatal"
    assert isinstance(faults.InjectedIOError("x"), OSError)


# ---------------------------------------------------------------------------
# sharded dimension (engine Layer 6 x Layer 9)
# ---------------------------------------------------------------------------

@pytest.mark.mesh
def test_sharded_negative_control_bitwise():
    mesh = host_mesh(2)
    plan = engine.plan_mbs(MINI, micro_batch_size=4, mesh=mesh,
                           normalization="exact")
    build = make_build("compiled", guard=False, mesh=mesh)
    sup, fp, p_sup, s_sup, _ = run_supervised(build, plan=plan)
    p_ref, s_ref, _ = run_unsupervised(build, plan)
    assert fp.fired == [] and sup.records == []
    assert max_abs_err(p_sup, p_ref) == 0.0
    assert max_abs_err(s_sup, s_ref) == 0.0


@pytest.mark.mesh
def test_sharded_oom_recovery_matches_degraded_golden():
    mesh = host_mesh(2)
    plan = engine.plan_mbs(MINI, micro_batch_size=4, mesh=mesh,
                           remat_policy="full", normalization="exact")
    build = make_build("compiled", mesh=mesh)
    sup, fp, p_got, s_got, _ = run_supervised(build, [faults.oom_at(2)],
                                              plan=plan)
    assert fp.fired_kinds() == ["oom"]
    assert sup.plan.micro_batch_size == 2
    assert sup.plan.local_micro == 1
    degraded, _ = engine.degrade_plan(plan)
    p_ref, s_ref, _ = run_unsupervised(build, degraded)
    assert_trees_close(p_got, p_ref, what="sharded params after OOM")
    assert_trees_close(s_got, s_ref, what="sharded opt state after OOM")


@pytest.mark.mesh
def test_sharded_nan_retry_recovers_clean_trajectory():
    mesh = host_mesh(2)
    plan = engine.plan_mbs(MINI, micro_batch_size=4, mesh=mesh,
                           normalization="exact")
    build = make_build("compiled", mesh=mesh)
    sup, fp, p_got, _, _ = run_supervised(build, [faults.nan_at(1)],
                                          plan=plan)
    [rec] = sup.records
    assert rec.kind == "nonfinite" and rec.action.startswith("retried ok")
    p_ref, _, _ = run_unsupervised(build, plan)
    assert max_abs_err(p_got, p_ref) == 0.0
