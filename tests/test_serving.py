"""Serving engine (Layer 10) conformance: continuous batching produces
EXACTLY the tokens a one-request-at-a-time reference decode produces,
slot admission never exceeds the planned KV budget, evicted slots are
reusable, and unsupported families fail fast with per-family messages."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import memory_model
from repro.engine import serving
from repro.engine.kv import KVPool, PoolExhausted
from repro.models import ModelConfig, transformer

VOCAB = 101


def _cfg(pattern=("global", "local"), **kw):
    base = dict(name="serve-toy", family="t", num_layers=len(pattern),
                d_model=48, num_heads=4, num_kv_heads=2, head_dim=12,
                d_ff=96, vocab_size=VOCAB, layer_pattern=pattern,
                sliding_window=8)
    base.update(kw)
    return ModelConfig(**base)


def _reference_tokens(params, cfg, req, max_len):
    """One-request greedy decode straight through prefill/decode_step."""
    logits, cache = transformer.prefill(params, cfg, req.prompt[None, :],
                                        max_len=max_len, dtype=jnp.float32)
    toks = [int(jnp.argmax(logits[0]))]
    tok = jnp.array([[toks[-1]]], jnp.int32)
    pos = jnp.array([req.prompt_len], jnp.int32)
    while len(toks) < req.max_new_tokens:
        lg, cache = transformer.decode_step(params, cfg, tok, cache, pos,
                                            dtype=jnp.float32)
        toks.append(int(jnp.argmax(lg[0, 0])))
        tok = jnp.array([[toks[-1]]], jnp.int32)
        pos = pos + 1
    return toks


def _run_engine(cfg, reqs, max_len, **plan_kw):
    plan = serving.plan_serve(cfg, budget_bytes=1 << 28, max_len=max_len,
                              **plan_kw)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    eng = serving.ServingEngine(params, cfg, plan, dtype=jnp.float32,
                                cache_dtype=jnp.float32)
    rep = eng.run(reqs, warmup_prompt_lens=[r.prompt_len for r in reqs])
    return plan, params, eng, rep


# ---------------------------------------------------------------------------
# continuous batching == sequential reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pattern", [("global", "local"),
                                     ("ssm", "global"),
                                     ("recurrent", "recurrent", "local")])
def test_engine_matches_reference(pattern):
    kw = {}
    if "ssm" in pattern:
        kw = dict(ssm_state=16, ssm_head_dim=32, conv_width=4)
    if "recurrent" in pattern:
        kw = dict(lru_width=48)
    cfg = _cfg(pattern, **kw)
    reqs = list(serving.synthetic_traffic(
        9, rate_rps=500.0, prompt_lens=(4, 7, 11), new_tokens=(3, 6),
        vocab_size=VOCAB, seed=2))
    plan, params, eng, rep = _run_engine(cfg, reqs, max_len=32)
    assert rep["requests"]["finished"] == len(reqs)
    # ragged padding only on pure-attention stacks (exact elsewhere)
    assert plan.ragged_prefill == (pattern == ("global", "local"))
    for r in reqs:
        assert r.state == serving.FINISHED
        assert r.tokens == _reference_tokens(params, cfg, r, plan.max_len), \
            (pattern, r.rid)


def test_decode_token_accounting_excludes_prefill_token():
    """The old launcher's bug: the prefill-produced token must NOT count
    as decode throughput. decode_tokens == sum(max_new - 1) and every
    request still receives max_new tokens total."""
    cfg = _cfg()
    reqs = [serving.Request(rid=i, prompt=np.arange(1, 6, dtype=np.int32),
                            max_new_tokens=4) for i in range(3)]
    _, _, eng, rep = _run_engine(cfg, reqs, max_len=24)
    assert all(len(r.tokens) == 4 for r in reqs)
    assert rep["decode"]["tokens"] == sum(4 - 1 for _ in reqs)
    assert rep["decode"]["steps"] == 3  # batched: one step per new token
    assert rep["prefill"]["batches"] == 1


def test_temperature_sampling_runs():
    cfg = _cfg()
    plan = serving.plan_serve(cfg, budget_bytes=1 << 28, max_len=24)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    eng = serving.ServingEngine(params, cfg, plan, dtype=jnp.float32,
                                cache_dtype=jnp.float32, temperature=0.9)
    reqs = [serving.Request(rid=0, prompt=np.arange(1, 9, dtype=np.int32),
                            max_new_tokens=6)]
    eng.run(reqs, warmup_prompt_lens=[8])
    assert len(reqs[0].tokens) == 6
    assert all(0 <= t < VOCAB for t in reqs[0].tokens)


# ---------------------------------------------------------------------------
# slot pool: admission bound, eviction, reuse
# ---------------------------------------------------------------------------

def test_kv_pool_alloc_free_reuse():
    cfg = _cfg()
    pool = KVPool(cfg, 3, 16, dtype=jnp.float32)
    slots = [pool.alloc() for _ in range(3)]
    assert sorted(slots) == [0, 1, 2] and pool.free_count == 0
    with pytest.raises(PoolExhausted):
        pool.alloc()
    pool.free(slots[1])
    assert pool.alloc() == slots[1]  # evicted slot is immediately reusable
    pool.free(slots[1])
    with pytest.raises(ValueError):
        pool.free(slots[1])  # double evict
    with pytest.raises(ValueError):
        pool.free(99)  # out of range


def test_evicted_slots_reused_without_contamination():
    """More requests than slots: the engine must finish them all through
    slot reuse, and a reused slot's output must equal the reference (the
    previous occupant's cache row is fully overwritten on insert)."""
    cfg = _cfg()
    reqs = list(serving.synthetic_traffic(
        10, rate_rps=10_000.0, prompt_lens=(4, 6), new_tokens=(2, 5),
        vocab_size=VOCAB, seed=7))
    plan, params, eng, rep = _run_engine(cfg, reqs, max_len=24,
                                         max_slots=2, prefill_micro=2)
    assert plan.max_decode_slots == 2
    assert rep["requests"]["finished"] == 10
    assert rep["slots"]["max_concurrent"] <= 2  # admission bound held
    assert eng.pool.free_count == 2  # every slot evicted back
    for r in reqs:
        assert r.tokens == _reference_tokens(params, cfg, r, plan.max_len)


# ---------------------------------------------------------------------------
# plan_serve admission properties (seeded sweep — no hypothesis dependency)
# ---------------------------------------------------------------------------

def test_plan_serve_never_exceeds_budget():
    """For ANY (config, max_len, budget) the planner accepts, the modeled
    peak at full admission is within budget; infeasible budgets raise
    instead of over-admitting."""
    rng = np.random.default_rng(0)
    patterns = [("global",), ("global", "local"), ("ssm", "global"),
                ("recurrent", "local")]
    for _ in range(40):
        pat = patterns[rng.integers(len(patterns))]
        kw = {}
        if "ssm" in pat:
            kw = dict(ssm_state=int(rng.choice([8, 16])), ssm_head_dim=24)
        if "recurrent" in pat:
            kw = dict(lru_width=int(rng.choice([32, 48])))
        cfg = _cfg(pat, d_model=int(rng.choice([24, 48])),
                   num_heads=4, num_kv_heads=int(rng.choice([1, 2])),
                   head_dim=int(rng.choice([6, 12])), **kw)
        max_len = int(rng.choice([16, 64, 256]))
        budget = int(rng.choice([1 << 22, 1 << 26, 1 << 30]))
        try:
            plan = serving.plan_serve(cfg, budget_bytes=budget,
                                      max_len=max_len)
        except ValueError:
            continue  # refusing to admit is always safe
        assert plan.modeled_peak_bytes() <= budget, (pat, max_len, budget)
        assert plan.max_decode_slots >= 1
        assert 1 <= plan.prefill_micro <= max(plan.max_decode_slots, 1)


def test_plan_serve_monotone_in_budget():
    cfg = configs.get_reduced("qwen2-1.5b")
    est = memory_model.serve_estimate(cfg, 64, prefill_len=64)
    budgets = [est.total(s, 8) for s in (1, 4, 16, 64)]
    slots = [serving.plan_serve(cfg, budget_bytes=b, max_len=64,
                                prefill_micro=8).max_decode_slots
             for b in budgets]
    assert slots == sorted(slots), slots
    assert slots[-1] >= 64


def test_plan_serve_pinned_overrun_raises():
    cfg = configs.get_reduced("qwen2-1.5b")
    est = memory_model.serve_estimate(cfg, 64, prefill_len=64)
    tight = est.total(2, 1)
    with pytest.raises(ValueError, match="fits at most"):
        serving.plan_serve(cfg, budget_bytes=tight, max_len=64,
                           max_slots=64, prefill_micro=1)


# ---------------------------------------------------------------------------
# family guards
# ---------------------------------------------------------------------------

def test_encdec_fails_fast_with_family_message():
    cfg = configs.get_reduced("seamless-m4t-medium")
    with pytest.raises(ValueError, match="encoder-decoder"):
        serving.check_servable(cfg)
    with pytest.raises(ValueError, match="encoder-decoder"):
        serving.plan_serve(cfg, budget_bytes=1 << 30, max_len=32)


def test_moe_and_state_families_group_exact_length():
    moe = _cfg(("global",), num_experts=4, experts_per_token=2, moe_d_ff=64,
               d_ff=0, capacity_factor=8.0)
    for cfg in (moe, _cfg(("ssm",), ssm_state=16, ssm_head_dim=24,
                          num_heads=0, num_kv_heads=0, head_dim=0, d_ff=0)):
        plan = serving.plan_serve(cfg, budget_bytes=1 << 28, max_len=24)
        assert not plan.ragged_prefill
        # the model layer enforces it too: ragged lengths= must refuse
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        toks = jnp.zeros((2, 8), jnp.int32)
        with pytest.raises(ValueError, match="ragged"):
            transformer.prefill(params, cfg, toks, max_len=24,
                                dtype=jnp.float32,
                                lengths=jnp.array([5, 8], jnp.int32))


def test_all_archs_plan_or_fail_cleanly():
    """Satellite 3: every --arch either plans (and its cache slots
    round-trip init_cache/decode_step — exercised via abstract decode
    lowering) or raises a clear per-family ValueError, never a shape
    error."""
    for arch in configs.ARCHS:
        cfg = configs.get_reduced(arch)
        try:
            plan = serving.plan_serve(cfg, budget_bytes=1 << 30, max_len=32,
                                      max_slots=2, prefill_micro=1)
        except ValueError as e:
            assert "servable" in str(e) or "serve" in str(e), (arch, e)
            continue
        cache = jax.eval_shape(
            lambda c=cfg, p=plan: transformer.init_cache(
                c, p.max_decode_slots, p.max_len, jnp.float32,
                p.global_window))
        params = steps_abstract(cfg)
        tok = jax.ShapeDtypeStruct((plan.max_decode_slots, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((plan.max_decode_slots,), jnp.int32)
        jax.eval_shape(
            lambda p, c, t, cp, cfg=cfg, plan=plan: transformer.decode_step(
                p, cfg, t, c, cp, dtype=jnp.float32,
                global_window=plan.global_window),
            params, cache, tok, pos)


def steps_abstract(cfg):
    from repro.launch import steps
    return steps.abstract_params(cfg)


# ---------------------------------------------------------------------------
# memory model serving terms
# ---------------------------------------------------------------------------

def test_kv_bytes_per_token_counts_attention_layers_only():
    attn = _cfg(("global", "local"))
    per_layer = 2 * attn.num_kv_heads * attn.head_dim * 2 \
        + memory_model.CACHE_POS_BYTES
    assert memory_model.kv_bytes_per_token(attn) == 2 * per_layer
    hybrid = _cfg(("ssm", "global"), ssm_state=16, ssm_head_dim=24)
    assert memory_model.kv_bytes_per_token(hybrid) == per_layer
    assert memory_model.slot_state_bytes(hybrid) > 0
    assert memory_model.slot_state_bytes(attn) == 0


def test_kv_slot_bytes_honors_windows():
    cfg = _cfg(("global", "local"), sliding_window=8)
    # the local ring holds min(window, max_len) entries, the global ring
    # max_len: a longer context only grows the global share
    short = memory_model.kv_slot_bytes(cfg, 8)
    longer = memory_model.kv_slot_bytes(cfg, 64)
    per_entry = 2 * cfg.num_kv_heads * cfg.head_dim * 2 \
        + memory_model.CACHE_POS_BYTES
    assert longer - short == (64 - 8) * per_entry
    # and the pool's REAL allocation matches the model's slot accounting
    pool = KVPool(cfg, 4, 64, dtype=jnp.bfloat16)
    assert pool.bytes() == 4 * memory_model.kv_slot_bytes(cfg, 64)


def test_serve_estimate_affine_in_slots():
    cfg = configs.get_reduced("qwen2-1.5b")
    est = memory_model.serve_estimate(cfg, 64)
    fixed, per_slot = est.affine_coeffs(prefill_micro=2)
    for s in (0, 1, 7):
        assert est.total(s, 2) == fixed + per_slot * s
