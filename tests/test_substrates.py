"""Substrate tests: optimizers vs analytic references, losses, data
pipeline determinism, checkpoint round-trip, memory model."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint, configs, optim
from repro.core import losses, memory_model
from repro.data import ClassificationDataset, LMDataset, MBSLoader, SegmentationDataset


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def test_sgd_momentum_matches_manual():
    params = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 1.0])}
    opt = optim.sgd(0.1, momentum=0.9, weight_decay=0.0)
    state = opt.init(params)
    mom = np.zeros(2)
    w = np.array([1.0, -2.0])
    for _ in range(3):
        upd, state = opt.update(g, state, params)
        params = jax.tree.map(lambda p, u: p + u, params, upd)
        mom = 0.9 * mom + np.array([0.5, 1.0])
        w = w - 0.1 * mom
    np.testing.assert_allclose(np.asarray(params["w"]), w, rtol=1e-6)


def test_sgd_weight_decay_coupled():
    params = {"w": jnp.asarray([2.0])}
    opt = optim.sgd(0.1, momentum=0.0, weight_decay=0.5)
    upd, _ = opt.update({"w": jnp.asarray([0.0])}, opt.init(params), params)
    np.testing.assert_allclose(np.asarray(upd["w"]), [-0.1 * 0.5 * 2.0],
                               rtol=1e-6)


def test_adam_matches_manual():
    params = {"w": jnp.asarray([1.0])}
    opt = optim.adam(0.01, b1=0.9, b2=0.999, eps=1e-8)
    state = opt.init(params)
    g = {"w": jnp.asarray([0.3])}
    m = v = 0.0
    w = 1.0
    for t in range(1, 4):
        upd, state = opt.update(g, state, params)
        params = jax.tree.map(lambda p, u: p + u, params, upd)
        m = 0.9 * m + 0.1 * 0.3
        v = 0.999 * v + 0.001 * 0.09
        w = w - 0.01 * (m / (1 - 0.9 ** t)) / (np.sqrt(v / (1 - 0.999 ** t)) + 1e-8)
    np.testing.assert_allclose(np.asarray(params["w"]), [w], rtol=1e-5)


def test_schedules():
    lin = optim.linear_decay(1.0, 10)
    assert float(lin(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(lin(jnp.asarray(10))) == pytest.approx(0.0)
    cos = optim.cosine_decay(1.0, 10, warmup=2)
    assert float(cos(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(cos(jnp.asarray(2))) == pytest.approx(1.0)


def test_clip_by_global_norm():
    opt = optim.clip_by_global_norm(optim.sgd(1.0), max_norm=1.0)
    params = {"w": jnp.zeros(4)}
    g = {"w": jnp.full((4,), 10.0)}
    upd, _ = opt.update(g, opt.init(params), params)
    assert float(jnp.linalg.norm(upd["w"])) == pytest.approx(1.0, rel=1e-4)


# ---------------------------------------------------------------------------
# losses (paper eq. 18-20)
# ---------------------------------------------------------------------------

def test_dice_loss_perfect_prediction():
    target = jnp.asarray(np.random.default_rng(0).integers(0, 2, (2, 8, 8, 1))
                         .astype(np.float32))
    logits = (target * 2 - 1) * 20.0  # saturated correct prediction
    assert float(losses.dice_loss(logits, target)) < 0.05
    assert float(losses.iou(logits, target)) > 0.99


def test_bce_dice_is_sum():
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(size=(2, 8, 8, 1)), jnp.float32)
    target = jnp.asarray(rng.integers(0, 2, (2, 8, 8, 1)), jnp.float32)
    total = losses.bce_dice_loss(logits, target)
    parts = losses.bce_with_logits(logits, target) + losses.dice_loss(logits, target)
    assert float(jnp.abs(total - parts)) < 1e-6


def test_cross_entropy_token_weights():
    logits = jnp.zeros((2, 4, 8))
    labels = jnp.zeros((2, 4), jnp.int32)
    w = jnp.asarray([[1, 1, 0, 0], [1, 1, 1, 1]], jnp.float32)
    out = losses.cross_entropy(logits, labels, token_weight=w)
    assert float(out) == pytest.approx(np.log(8), rel=1e-5)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_lm_dataset_deterministic_and_learnable():
    ds = LMDataset(vocab_size=128, seq_len=16, seed=3)
    b1, b2 = ds.batch(4, 7), ds.batch(4, 7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 16)
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()


def test_mbs_loader_splits():
    ds = ClassificationDataset(num_classes=4, image_size=8)
    loader = MBSLoader(ds, mini_batch_size=10, micro_batch_size=4, prefetch=0)
    batches = list(loader(2))
    assert len(batches) == 2
    assert batches[0]["image"].shape == (3, 4, 8, 8, 3)
    assert batches[0]["sample_weight"].sum() == 10


def test_segmentation_masks_nontrivial():
    ds = SegmentationDataset(image_size=16)
    b = ds.batch(4, 0)
    assert 0 < b["mask"].mean() < 1


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.float32)},
            "c": (jnp.ones(4), jnp.zeros((), jnp.int32))}
    checkpoint.save(str(tmp_path), 3, tree)
    assert checkpoint.latest_step(str(tmp_path)) == 3
    out = checkpoint.restore(str(tmp_path), tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# memory model (the paper's max-batch "Failed" boundary, made analytic)
# ---------------------------------------------------------------------------

def test_memory_model_micro_batch_fits_where_mini_batch_fails():
    cfg = configs.get("qwen2-1.5b")
    budget = 16 * 1024 ** 3
    max_nomb = memory_model.max_minibatch_without_mbs(
        cfg, seq=4096, budget_bytes=budget, tp=16, fsdp=16)
    # a mini-batch far beyond the no-MBS limit still trains with MBS:
    micro = memory_model.suggest_micro_batch_size(
        cfg, seq=4096, mini_batch=64 * max(max_nomb, 1), budget_bytes=budget,
        tp=16, fsdp=16)
    assert micro is not None and micro >= 1
    est = memory_model.estimate(cfg, 4096, tp=16, fsdp=16)
    assert est.total(micro) <= budget < est.total(64 * max(max_nomb, 1))


def test_memory_model_monotone_in_image_of_seq():
    cfg = configs.get("qwen2-1.5b")
    short = memory_model.activation_bytes_per_sample(cfg, 1024)
    long = memory_model.activation_bytes_per_sample(cfg, 8192)
    assert long > short  # larger items -> smaller feasible micro-batch
