"""Async input pipeline + resumable Trainer (engine layer 3).

Covers the regressions this layer exists to prevent:
  * prefetch worker exceptions must propagate, never truncate the epoch;
  * Pipeline/MBSLoader batches go through the planner, so ragged
    mini-batches get exact normalization and match the full-batch
    gradient on every executor;
  * dataset-provided sample weights survive the split (composed with the
    padding mask) instead of being clobbered;
  * save → resume through the Trainer reproduces an uninterrupted run
    bitwise (params AND optimizer state round-trip with placement).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import (EXECUTOR_GRID, ToyDataset as _ToyDataset,
                      make_executor, max_abs_err as _max_err,
                      tiny_loss_fn as _loss_fn, tiny_params as _params)
from repro import engine, optim
from repro.core.streaming import prefetch_iterator
from repro.data import MBSLoader


# ---------------------------------------------------------------------------
# prefetch error propagation
# ---------------------------------------------------------------------------

def test_prefetch_propagates_worker_exception():
    """Regression: a raising producer used to silently END the stream
    (epoch truncation); it must re-raise in the consumer."""
    def gen():
        yield 0
        yield 1
        raise ValueError("corrupt shard")

    it = prefetch_iterator(gen(), size=2)
    assert next(it) == 0 and next(it) == 1
    with pytest.raises(ValueError, match="corrupt shard"):
        next(it)


def test_prefetch_propagates_immediate_exception():
    def gen():
        raise RuntimeError("boom")
        yield  # pragma: no cover

    with pytest.raises(RuntimeError, match="boom"):
        list(prefetch_iterator(gen(), size=1))


def test_pipeline_propagates_dataset_exception():
    class Bad:
        def batch(self, batch_size, seed):
            if seed >= 2:
                raise OSError("read failed")
            return {"x": np.zeros((batch_size, 4), np.float32)}

    pipe = engine.Pipeline(Bad(), engine.plan_mbs(6, micro_batch_size=2),
                           prefetch=2, stage=False)
    with pytest.raises(OSError, match="read failed"):
        list(pipe.batches(5))


# ---------------------------------------------------------------------------
# plan-aware splitting: ragged + weighted batches through the pipeline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("executor", EXECUTOR_GRID)
def test_pipeline_ragged_batch_matches_full_batch(executor):
    """mini=10, micro=4 through Pipeline: the planner auto-upgrades to
    exact normalization, so every executor reproduces the full-batch
    gradient from the pipeline's pre-split batch."""
    ds = _ToyDataset()
    plan = engine.plan_mbs(10, micro_batch_size=4)
    assert plan.normalization == "exact" and plan.pad == 2
    pipe = engine.Pipeline(ds, plan, prefetch=2)
    split = next(iter(pipe.batches(1)))
    assert split["x"].shape == (3, 4, 8)

    params = _params()
    ex = make_executor(executor, _loss_fn, optim.sgd(0.1), plan)
    g, loss = ex.gradients(params, split)

    full = ds.batch(10, 0)
    _, ref = jax.value_and_grad(lambda p: _loss_fn(p, full)[0])(params)
    assert _max_err(g, ref) < 2e-6
    assert abs(float(loss) - float(_loss_fn(params, full)[0])) < 2e-6


def test_mbs_loader_goes_through_planner():
    """Regression: MBSLoader used to bypass plan_mbs, keeping the
    tail-over-weighting paper normalization on ragged mini-batches."""
    loader = MBSLoader(_ToyDataset(), mini_batch_size=10,
                       micro_batch_size=4, prefetch=0)
    assert loader.plan.normalization == "exact"
    assert loader.plan.auto_normalization
    batches = list(loader(2))
    assert len(batches) == 2
    assert batches[0]["x"].shape == (3, 4, 8)
    assert batches[0]["sample_weight"].sum() == 10


@pytest.mark.parametrize("executor", EXECUTOR_GRID)
def test_split_composes_dataset_sample_weight(executor):
    """Regression: split_minibatch used to clobber a dataset-provided
    sample_weight with the all-ones padding mask. Composed weights must
    reproduce the weighted full-batch gradient in exact mode."""
    rng = np.random.default_rng(5)
    w = rng.uniform(0.25, 1.0, 10).astype(np.float32)
    batch = _ToyDataset().batch(10, 0)
    batch["sample_weight"] = w

    plan = engine.plan_mbs(10, micro_batch_size=4, normalization="exact")
    split = plan.split(batch)
    sw = split["sample_weight"].reshape(-1)
    np.testing.assert_allclose(sw[:10], w, rtol=1e-6)  # weights kept
    np.testing.assert_array_equal(sw[10:], 0)  # padding masked

    params = _params()
    ex = make_executor(executor, _loss_fn, optim.sgd(0.1), plan)
    g, loss = ex.gradients(params, plan.device_split(batch))
    _, ref = jax.value_and_grad(lambda p: _loss_fn(p, batch)[0])(params)
    assert _max_err(g, ref) < 2e-6
    assert abs(float(loss) - float(_loss_fn(params, batch)[0])) < 2e-6


def test_split_rejects_nonuniform_weights_in_paper_mode():
    """Paper normalization averages micro means with equal 1/N_Sμ weight,
    which silently mis-normalizes non-uniform sample weights even on a
    uniform split — the plan must refuse, not corrupt the gradient."""
    batch = _ToyDataset().batch(12, 0)
    batch["sample_weight"] = np.linspace(0.2, 1.0, 12).astype(np.float32)
    plan = engine.plan_mbs(12, micro_batch_size=4)  # uniform: stays "paper"
    assert plan.normalization == "paper"
    with pytest.raises(ValueError, match="exact"):
        plan.split(batch)
    # uniform weights are fine in paper mode (weighted mean == mean)
    batch["sample_weight"] = np.full(12, 0.5, np.float32)
    assert plan.split(batch)["x"].shape == (3, 4, 8)


# ---------------------------------------------------------------------------
# streaming executor: no per-micro-batch host sync
# ---------------------------------------------------------------------------

def test_streaming_step_returns_device_metrics():
    """Regression: step() used to float() the loss every micro-batch,
    serializing the double buffer; metrics now stay on device."""
    plan = engine.plan_mbs(8, micro_batch_size=4)
    ex = engine.StreamingExecutor(_loss_fn, optim.sgd(0.1), plan)
    params = _params()
    batch = _ToyDataset().batch(8, 0)
    _, _, m = ex.step(params, optim.sgd(0.1).init(params), batch)
    assert isinstance(m["loss"], jax.Array)
    assert isinstance(m["grad_norm"], jax.Array)


def test_streaming_step_split_matches_step():
    plan = engine.plan_mbs(10, micro_batch_size=4)
    opt = optim.sgd(0.1, momentum=0.9)
    ex = engine.StreamingExecutor(_loss_fn, opt, plan)
    params = _params()
    batch = _ToyDataset().batch(10, 0)
    p1, _, m1 = ex.step(params, opt.init(params), dict(batch))
    p2, _, m2 = ex.step_split(params, opt.init(params),
                              plan.device_split(batch))
    assert _max_err(p1, p2) == 0
    assert float(m1["loss"]) == float(m2["loss"])


# ---------------------------------------------------------------------------
# trainer: save -> resume bitwise round-trip
# ---------------------------------------------------------------------------

def _fit(tmp_path, num_steps, *, ckpt_every=0, resume=False, subdir="a"):
    ds = _ToyDataset()
    plan = engine.plan_mbs(10, micro_batch_size=4)
    opt = optim.sgd(0.1, momentum=0.9, weight_decay=1e-4)
    ex = engine.CompiledScanExecutor(_loss_fn, opt, plan)
    pipe = engine.Pipeline(ds, plan, prefetch=2)
    trainer = engine.Trainer(ex.step_split, pipe,
                             ckpt_dir=str(tmp_path / subdir),
                             ckpt_every=ckpt_every, log_fn=None)
    params, opt_state = _params(), opt.init(_params())
    start = 0
    if resume:
        restored = trainer.restore(params, opt_state)
        assert restored is not None
        params, opt_state, start = restored
    return trainer.fit(params, opt_state, num_steps, start_step=start)


def test_save_resume_matches_uninterrupted_run_bitwise(tmp_path):
    p_full, s_full, _ = _fit(tmp_path, 6, subdir="full")
    # interrupted run: 3 steps, checkpoint, fresh Trainer resumes 3 -> 6
    _fit(tmp_path, 3, ckpt_every=3, subdir="resumed")
    p_res, s_res, _ = _fit(tmp_path, 6, resume=True, subdir="resumed")
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(s_full), jax.tree.leaves(s_res)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trainer_final_checkpoint_and_restore_placement(tmp_path):
    p, s, last = _fit(tmp_path, 4, subdir="final")
    from repro import checkpoint
    assert checkpoint.latest_step(str(tmp_path / "final")) == 4
    # restore returns device-placed arrays, not bare host numpy
    ds = _ToyDataset()
    plan = engine.plan_mbs(10, micro_batch_size=4)
    opt = optim.sgd(0.1, momentum=0.9, weight_decay=1e-4)
    ex = engine.CompiledScanExecutor(_loss_fn, opt, plan)
    trainer = engine.Trainer(ex.step_split,
                             engine.Pipeline(ds, plan, prefetch=0),
                             ckpt_dir=str(tmp_path / "final"), log_fn=None)
    params, opt_state, step = trainer.restore(_params(),
                                              opt.init(_params()))
    assert step == 4
    assert all(isinstance(l, jax.Array) for l in jax.tree.leaves(params))
    assert all(isinstance(l, jax.Array)
               for l in jax.tree.leaves(opt_state))
    assert "loss" in last and isinstance(last["loss"], float)


def test_trainer_restores_legacy_params_only_checkpoint(tmp_path):
    """Pre-Trainer checkpoints held bare params; restore must fall back
    to them (fresh optimizer state) instead of raising KeyError."""
    from repro import checkpoint
    params = _params()
    checkpoint.save(str(tmp_path), 7, params)
    plan = engine.plan_mbs(10, micro_batch_size=4)
    opt = optim.sgd(0.1, momentum=0.9)
    ex = engine.CompiledScanExecutor(_loss_fn, opt, plan)
    trainer = engine.Trainer(ex.step_split,
                             engine.Pipeline(_ToyDataset(), plan, prefetch=0),
                             ckpt_dir=str(tmp_path), log_fn=None)
    p, s, step = trainer.restore(params, opt.init(params))
    assert step == 7
    assert _max_err(p, params) == 0


def test_trainer_fit_past_end_does_not_mislabel_checkpoint(tmp_path):
    """Resuming with num_steps < start_step must not overwrite/emit a
    checkpoint tagged with the earlier step index."""
    _fit(tmp_path, 4, subdir="past")
    from repro import checkpoint
    ds = _ToyDataset()
    plan = engine.plan_mbs(10, micro_batch_size=4)
    opt = optim.sgd(0.1, momentum=0.9, weight_decay=1e-4)
    ex = engine.CompiledScanExecutor(_loss_fn, opt, plan)
    trainer = engine.Trainer(ex.step_split,
                             engine.Pipeline(ds, plan, prefetch=2),
                             ckpt_dir=str(tmp_path / "past"), log_fn=None)
    params, opt_state, start = trainer.restore(_params(),
                                               opt.init(_params()))
    trainer.fit(params, opt_state, 2, start_step=start)  # already past 2
    assert checkpoint.latest_step(str(tmp_path / "past")) == 4
    import os
    assert not os.path.exists(str(tmp_path / "past" / "ckpt_00000002.npz"))


def test_trainer_fit_finalizes_pipeline_stats(tmp_path):
    ds = _ToyDataset()
    plan = engine.plan_mbs(10, micro_batch_size=4)
    opt = optim.sgd(0.1)
    ex = engine.CompiledScanExecutor(_loss_fn, opt, plan)
    pipe = engine.Pipeline(ds, plan, prefetch=2)
    trainer = engine.Trainer(ex.step_split, pipe, log_fn=None)
    trainer.fit(_params(), opt.init(_params()), 3)
    assert pipe.stats.batches == 3
    assert pipe.stats.elapsed_s > 0  # finalized by exhaustion, not GC


def test_pipeline_stats_track_input_wait():
    ds = _ToyDataset()
    plan = engine.plan_mbs(8, micro_batch_size=4)
    pipe = engine.Pipeline(ds, plan, prefetch=2, stage=False)
    n = sum(1 for _ in pipe.batches(5))
    assert n == 5
    assert pipe.stats.batches == 5
    assert 0.0 <= pipe.stats.input_wait_fraction <= 1.0
    assert pipe.stats.elapsed_s > 0


def test_pipeline_seeding_is_step_indexed():
    """batches(n, start=k) must yield exactly the tail of batches(n+k) —
    the invariant resume correctness rests on."""
    ds = _ToyDataset()
    plan = engine.plan_mbs(6, micro_batch_size=3)
    pipe = engine.Pipeline(ds, plan, prefetch=0, stage=False)
    full = list(pipe.batches(4))
    tail = list(pipe.batches(2, start=2))
    for a, b in zip(full[2:], tail):
        np.testing.assert_array_equal(a["x"], b["x"])
