"""Fused flat-buffer update path (DESIGN.md §Update path, Layer 4):

  * FlatSpec round-trip — flatten/unflatten identity for arbitrary trees
    (property-based when hypothesis is installed), stable leaf ordering,
    dtype bucketing;
  * fused SGD-m / Adam / AdamW kernels vs the unfused ``apply_update``
    reference, on flat buffers and end-to-end through every executor
    (ragged tails + exact normalization + global-norm clip included);
  * donation safety — ``step_split`` donates params/opt-state/batch, so a
    threading caller must survive the donated buffers actually dying;
  * the memory model's step-❺ transient term — the fused path admits a
    micro-batch the unfused model rejects, corroborated by a dryrun-style
    ``memory_analysis`` of the compiled step (donation aliases the state
    buffers in place).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import (EXECUTOR_GRID, make_executor,
                      max_abs_err as _max_err, tiny_batch as _batch,
                      tiny_loss_fn as _loss_fn, tiny_params as _params)
from repro import configs, engine, optim
from repro.core import memory_model
from repro.engine import exec_core, flat
from repro.kernels import fused_update, ref

# ---------------------------------------------------------------------------
# fixtures (tiny model + executor grid come from conftest's harness)
# ---------------------------------------------------------------------------


def _mixed_tree(seed=0):
    """Nested tree with mixed dtypes/shapes incl. ragged (non-block) sizes."""
    rng = np.random.default_rng(seed)
    return {
        "emb": jnp.asarray(rng.normal(size=(7, 5)), jnp.float32),
        "blocks": [
            {"w": jnp.asarray(rng.normal(size=(3, 11)), jnp.float32),
             "b": jnp.asarray(rng.normal(size=(11,)), jnp.bfloat16)},
            {"w": jnp.asarray(rng.normal(size=(13,)), jnp.float32),
             "b": jnp.asarray(rng.normal(size=(2, 2, 3)), jnp.bfloat16)},
        ],
        "head": jnp.asarray(rng.normal(size=(1,)), jnp.float32),
    }


# ---------------------------------------------------------------------------
# FlatSpec round-trip
# ---------------------------------------------------------------------------

def test_flat_roundtrip_mixed_dtypes():
    tree = _mixed_tree()
    spec = flat.FlatSpec.for_tree(tree)
    assert spec.num_leaves == len(jax.tree.leaves(tree))
    assert spec.num_buckets == 2  # fp32 + bf16
    bufs = spec.flatten(tree)
    assert all(b.ndim == 1 for b in bufs)
    assert [b.dtype for b in bufs] == list(spec.bucket_dtypes)
    assert sum(b.size for b in bufs) == sum(
        l.size for l in jax.tree.leaves(tree))
    back = spec.unflatten(bufs)
    assert jax.tree.structure(back) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_flat_ordering_is_stable_and_offsets_contiguous():
    tree = _mixed_tree()
    spec1 = flat.FlatSpec.for_tree(tree)
    spec2 = flat.FlatSpec.for_tree(jax.tree.map(lambda x: x * 2, tree))
    assert spec1.slots == spec2.slots  # same structure -> same layout
    fill = [0] * spec1.num_buckets
    for slot in spec1.slots:  # leaf order fills each bucket densely
        assert slot.offset == fill[slot.bucket]
        fill[slot.bucket] += slot.size
    assert tuple(fill) == spec1.bucket_sizes


def test_flat_grads_share_param_layout():
    """Gradients flattened with dtype=accum route into buffers whose
    offsets line up with the param buckets (the fused-kernel contract)."""
    tree = _mixed_tree()
    spec = flat.FlatSpec.for_tree(tree)
    gbufs = spec.flatten(tree, dtype=jnp.float32)
    assert all(b.dtype == jnp.float32 for b in gbufs)
    assert tuple(b.size for b in gbufs) == spec.bucket_sizes
    # cast=False round-trips the accumulator as an fp32-leaf tree
    gtree = spec.unflatten(gbufs, cast=False)
    assert all(l.dtype == jnp.float32 for l in jax.tree.leaves(gtree))


def test_flat_roundtrip_property():
    hp = pytest.importorskip("hypothesis",
                             reason="property tests need hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.tuples(st.integers(1, 60), st.booleans()),
                    min_size=1, max_size=12),
           st.integers(0, 2 ** 16))
    def run(shapes, seed):
        rng = np.random.default_rng(seed)
        tree = {f"l{i}": jnp.asarray(rng.normal(size=n),
                                     jnp.bfloat16 if bf else jnp.float32)
                for i, (n, bf) in enumerate(shapes)}
        spec = flat.FlatSpec.for_tree(tree)
        back = spec.unflatten(spec.flatten(tree))
        for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    run()


# ---------------------------------------------------------------------------
# fused kernels vs the unfused reference (flat buffers, ragged blocks)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("momentum,nesterov,wd", [
    (0.0, False, 0.0), (0.9, False, 5e-4), (0.9, True, 1e-4)])
def test_fused_sgd_kernel_matches_reference(momentum, nesterov, wd):
    rng = np.random.default_rng(1)
    N = 1000  # not a multiple of the block: masked final block
    p = jnp.asarray(rng.normal(size=N), jnp.float32)
    g = jnp.asarray(rng.normal(size=N), jnp.float32)
    m = jnp.asarray(rng.normal(size=N), jnp.float32) if momentum else None
    out = fused_update.fused_sgd(p, g, m, 0.1, 0.7, momentum=momentum,
                                 weight_decay=wd, nesterov=nesterov,
                                 block=256, interpret=True)
    p2, m2 = out if momentum else (out, None)
    pr, mr = ref.fused_sgd_ref(p, g, m, 0.1, 0.7, momentum=momentum,
                               weight_decay=wd, nesterov=nesterov)
    np.testing.assert_allclose(p2, pr, atol=1e-6, rtol=1e-6)
    if momentum:
        np.testing.assert_allclose(m2, mr, atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("decoupled", [False, True])
def test_fused_adam_kernel_matches_reference(decoupled):
    rng = np.random.default_rng(2)
    N = 777
    p = jnp.asarray(rng.normal(size=N), jnp.float32)
    g = jnp.asarray(rng.normal(size=N), jnp.float32)
    m = jnp.asarray(rng.normal(size=N), jnp.float32)
    v = jnp.abs(jnp.asarray(rng.normal(size=N), jnp.float32))
    kw = dict(b1=0.9, b2=0.999, eps=1e-8, weight_decay=1e-2,
              decoupled=decoupled)
    p2, m2, v2 = fused_update.fused_adam(p, g, m, v, 0.01, 0.1, 0.002, 0.9,
                                         block=128, interpret=True, **kw)
    pr, mr, vr = ref.fused_adam_ref(p, g, m, v, 0.01, 0.1, 0.002, 0.9, **kw)
    np.testing.assert_allclose(p2, pr, atol=1e-6, rtol=1e-6)
    np.testing.assert_allclose(m2, mr, atol=1e-6, rtol=1e-6)
    np.testing.assert_allclose(v2, vr, atol=1e-6, rtol=1e-6)


def _optimizers():
    return [
        ("sgd", optim.sgd(0.1)),
        ("sgd-m", optim.sgd(0.1, momentum=0.9, weight_decay=5e-4)),
        ("sgd-nesterov", optim.sgd(0.1, momentum=0.9, nesterov=True)),
        ("adam", optim.adam(0.01, weight_decay=5e-4)),
        ("adamw", optim.adamw(0.01)),
        ("clip-sgd-m",
         optim.clip_by_global_norm(optim.sgd(0.1, momentum=0.9), 0.05)),
        ("clip-adam", optim.clip_by_global_norm(optim.adam(0.01), 0.05)),
    ]


@pytest.mark.parametrize("name,opt", _optimizers(), ids=lambda o: o
                         if isinstance(o, str) else "")
def test_apply_update_flat_matches_reference(name, opt):
    """Two consecutive fused flat updates == two unfused reference updates
    (state threading included), on a mixed ragged tree."""
    tree = {k: v for k, v in _mixed_tree().items() if k != "blocks"}
    tree["blocks"] = [jax.tree.map(lambda x: x.astype(jnp.float32), b)
                      for b in _mixed_tree()["blocks"]]  # fp32 for tolerance
    spec = flat.FlatSpec.for_tree(tree)
    rng = np.random.default_rng(3)
    grads = jax.tree.map(
        lambda p: jnp.asarray(rng.normal(size=p.shape), jnp.float32), tree)

    p_ref, s_ref = tree, opt.init(tree)
    p_fl, s_fl = tree, opt.init(tree)
    for _ in range(2):
        p_ref, s_ref = exec_core.apply_update(opt, grads, s_ref, p_ref)
        p_fl, s_fl = exec_core.apply_update_flat(
            opt, spec, spec.flatten(grads, dtype=jnp.float32), s_fl, p_fl,
            interpret=True, block=64)
    assert _max_err(p_fl, p_ref) < 1e-6
    assert int(s_fl["step"]) == int(s_ref["step"]) == 2
    state_leaves = [(a, b) for a, b in zip(jax.tree.leaves(s_fl),
                                           jax.tree.leaves(s_ref))]
    assert _max_err([a for a, _ in state_leaves],
                    [b for _, b in state_leaves]) < 1e-6


def test_double_clip_drops_fused_hook():
    """Only one clip scalar rides into the kernel: a double-wrapped clip
    falls back to the reference update (both clips applied) instead of
    silently dropping the inner one."""
    opt = optim.clip_by_global_norm(
        optim.clip_by_global_norm(optim.sgd(0.1, momentum=0.9), 0.5), 0.05)
    assert opt.fused is None
    tree = _params()
    spec = flat.FlatSpec.for_tree(tree)
    grads = jax.tree.map(jnp.ones_like, tree)
    p1, _ = exec_core.apply_update_flat(
        opt, spec, spec.flatten(grads, dtype=jnp.float32),
        opt.init(tree), tree)
    p2, _ = exec_core.apply_update(opt, grads, opt.init(tree), tree)
    assert _max_err(p1, p2) < 1e-7


def test_apply_update_flat_falls_back_without_hook():
    """Optimizers with no fused spec route through the reference update."""
    base = optim.sgd(0.1, momentum=0.9)
    nohook = optim.Optimizer(base.init, base.update)  # fused defaults None
    tree = _params()
    spec = flat.FlatSpec.for_tree(tree)
    grads = jax.tree.map(jnp.ones_like, tree)
    p1, s1 = exec_core.apply_update_flat(
        nohook, spec, spec.flatten(grads, dtype=jnp.float32),
        nohook.init(tree), tree)
    p2, s2 = exec_core.apply_update(base, grads, base.init(tree), tree)
    assert _max_err(p1, p2) < 1e-7
    assert _max_err(s1["mom"], s2["mom"]) < 1e-7


# ---------------------------------------------------------------------------
# end-to-end: the flat executor vs every other executor + the baseline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_b,n_mu,normalization", [
    (16, 4, "paper"), (10, 4, "exact"), (13, 5, "exact")])
@pytest.mark.parametrize("opt_name", ["sgd-m", "adam", "clip-sgd-m"])
def test_flat_executor_step_matches_baseline(n_b, n_mu, normalization,
                                             opt_name):
    """Ragged tails, exact normalization, clipping: the full flat step
    (bucketed accumulate + fused in-place update) equals the no-MBS
    baseline update."""
    opt = dict(_optimizers())[opt_name]
    params, batch = _params(4), _batch(n_b, seed=4)
    base = jax.jit(engine.make_baseline_train_step(_loss_fn, opt))
    p_ref, s_ref, m_ref = base(params, opt.init(params),
                               {k: jnp.asarray(v) for k, v in batch.items()})
    plan = engine.plan_mbs(n_b, micro_batch_size=n_mu,
                           normalization=normalization)
    ex = engine.FlatFusedExecutor(_loss_fn, opt, plan, interpret=True,
                                  donate=False)
    p, s, m = ex.step(params, opt.init(params), dict(batch))
    assert _max_err(p, p_ref) < 2e-6
    assert abs(float(m["loss"]) - float(m_ref["loss"])) < 2e-6
    assert abs(float(m["grad_norm"]) - float(m_ref["grad_norm"])) < 2e-5


def test_flat_executor_matches_other_executors():
    """All four executors produce the same update from the same split."""
    params, batch = _params(5), _batch(12, seed=5)
    opt = optim.sgd(0.1, momentum=0.9, weight_decay=1e-4)
    plan = engine.plan_mbs(12, micro_batch_size=4)
    results = {}
    for name in EXECUTOR_GRID:
        ex = make_executor(name, _loss_fn, opt, plan, donate=False)
        results[name] = ex.step(params, opt.init(params), dict(batch))
    for name in ("streaming", "fused", "flat"):
        assert _max_err(results[name][0], results["compiled"][0]) < 2e-6
        assert abs(float(results[name][2]["loss"])
                   - float(results["compiled"][2]["loss"])) < 2e-6


def test_flat_executor_respects_accum_dtype():
    params, batch = _params(), _batch(8)
    plan = engine.plan_mbs(8, micro_batch_size=4, accum_dtype=jnp.bfloat16)
    ex = engine.FlatFusedExecutor(_loss_fn, optim.sgd(0.1), plan,
                                  interpret=True)
    g, _ = ex.gradients(params, plan.device_split(batch))
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(g))


# ---------------------------------------------------------------------------
# donation safety
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("executor", ["compiled", "flat"])
def test_step_split_donation_safety(executor):
    """step_split donates params/opt-state/batch. Thread the state through
    three steps and assert (a) the outputs never alias a dead buffer —
    every output stays readable after its inputs are deleted — and (b) the
    donated inputs really died (donation supported on this backend), so a
    buffer reuse anywhere in the step would have raised."""
    opt = optim.sgd(0.1, momentum=0.9)
    plan = engine.plan_mbs(8, micro_batch_size=4)
    ex = make_executor(executor, _loss_fn, opt, plan)
    params = _params(6)
    opt_state = opt.init(params)
    for i in range(3):
        split = plan.device_split(_batch(8, seed=10 + i))
        donated = (jax.tree.leaves(params) + jax.tree.leaves(opt_state)
                   + jax.tree.leaves(split))
        params, opt_state, metrics = ex.step_split(params, opt_state, split)
        # outputs are alive and independent of the donated inputs
        for leaf in jax.tree.leaves((params, opt_state)):
            assert not leaf.is_deleted()
            np.asarray(leaf)  # readable
        float(metrics["loss"])
        # donated buffers were consumed (not silently copied & kept alive):
        # the big fp32 state buffers must be gone on backends with donation
        dead = [l for l in donated if l.is_deleted()]
        if jax.default_backend() in ("cpu", "tpu", "gpu"):
            assert dead, "donation had no effect — step_split stopped donating?"


def test_step_split_donate_false_allows_reuse():
    """Benchmarks/A-B comparisons construct with donate=False and may call
    step_split repeatedly with the same buffers."""
    opt = optim.sgd(0.1)
    plan = engine.plan_mbs(8, micro_batch_size=4)
    ex = engine.FlatFusedExecutor(_loss_fn, opt, plan, interpret=True,
                                  donate=False)
    params, opt_state = _params(), opt.init(_params())
    split = plan.device_split(_batch(8))
    p1, _, _ = ex.step_split(params, opt_state, split)
    p2, _, _ = ex.step_split(params, opt_state, split)  # same buffers again
    assert _max_err(p1, p2) == 0
    assert not any(l.is_deleted() for l in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# memory model: the eliminated step-❺ transient changes admission
# ---------------------------------------------------------------------------

def test_update_transient_term_and_fused_admission():
    cfg = configs.get_reduced("qwen2-1.5b")
    est_u = memory_model.estimate(cfg, 16)
    est_f = memory_model.estimate(cfg, 16, fused_update=True)
    # the two estimates differ exactly by the step-❺ transient
    assert est_u.update_transient_bytes == \
        memory_model.update_transient_bytes(est_u.params_bytes, "sgd")
    assert est_f.update_transient_bytes == 0
    assert est_u.total(4) - est_f.total(4) == est_u.update_transient_bytes
    # adam carries two fresh state trees in its transient
    est_a = memory_model.estimate(cfg, 16, optimizer="adam")
    assert est_a.update_transient_bytes == 3 * est_a.params_bytes

    # a budget the unfused update just overflows: fused admits more
    budget = est_u.total(4) - 1
    mu_u = memory_model.suggest_micro_batch_size(
        cfg, 16, 64, budget_bytes=budget)
    mu_f = memory_model.suggest_micro_batch_size(
        cfg, 16, 64, budget_bytes=budget, fused_update=True)
    assert (mu_u or 0) < 4 <= (mu_f or 0)
    # plan_mbs wires the flag through (launch/train.py --executor flat)
    plan_u = engine.plan_mbs(64, model_cfg=cfg, seq_len=16,
                             budget_bytes=budget)
    plan_f = engine.plan_mbs(64, model_cfg=cfg, seq_len=16,
                             budget_bytes=budget, fused_update=True)
    assert plan_f.micro_batch_size > plan_u.micro_batch_size


def test_fused_admission_gated_on_optimizer_hook():
    """The planner only drops the step-❺ transient when the optimizer
    actually publishes a fused hook — a hook-less optimizer under
    ``--executor flat`` falls back to the unfused tree update at runtime,
    so its transient must stay modeled (``optim.memory_model_kw``)."""
    hooked = optim.sgd(0.05, momentum=0.9)
    nohook = optim.Optimizer(hooked.init, hooked.update)
    assert optim.memory_model_kw(hooked, fused=True) == {
        "opt_slots": 1, "fused_update": True}
    assert optim.memory_model_kw(nohook, fused=True) == {
        "opt_slots": 1, "fused_update": False}
    # the slot count is measured from the optimizer's own init — a
    # hook-less Adam still budgets its two state trees
    adam = optim.adam(1e-3)
    assert optim.memory_model_kw(optim.Optimizer(adam.init, adam.update),
                                 fused=True) == {
        "opt_slots": 2, "fused_update": False}
    assert optim.memory_model_kw(optim.sgd(0.1), fused=True) == {
        "opt_slots": 0, "fused_update": True}


def test_dryrun_memory_analysis_reflects_donated_update():
    """Dryrun-style corroboration: lower+compile the donating train step
    and read XLA's own memory analysis — the donated state buffers are
    aliased in place (alias bytes cover params + opt state), which is the
    mechanism that removes the unfused path's update transients. The
    census goes through the shared analysis rule (HLO001); the executor's
    own ``lower_step(donate=True)`` is the artifact under test."""
    from repro import analysis

    opt = optim.sgd(0.1, momentum=0.9)
    plan = engine.plan_mbs(8, micro_batch_size=4)
    params = _params(7)
    opt_state = opt.init(params)
    split = plan.device_split(_batch(8, seed=7))
    state_bytes = analysis.tree_bytes((params, opt_state))
    for name in ("compiled", "flat"):
        ex = make_executor(name, _loss_fn, opt, plan)
        compiled = ex.lower_step(params, opt_state, split,
                                 donate=True).compile()
        findings = analysis.check_aliasing(compiled, state_bytes,
                                           context=name)
        assert not findings, [f.format() for f in findings]
        # and the negative control: without donation there is nothing to
        # alias, so the same rule must fire
        undonated = ex.lower_step(params, opt_state, split,
                                  donate=False).compile()
        neg = analysis.check_aliasing(undonated, state_bytes, context=name)
        assert neg and neg[0].rule == "HLO001"
