"""The paper's own models (ResNet / U-Net) + MBS semantics with BatchNorm."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses, mbs as M
from repro.models import cnn
from repro import optim


def test_resnet_forward_shapes():
    key = jax.random.PRNGKey(0)
    params, state = cnn.resnet_init(key, num_classes=8, stage_sizes=(1, 1),
                                    width=16)
    x = jax.random.normal(key, (2, 24, 24, 3))
    logits, new_state = cnn.resnet_forward(params, state, x,
                                           stage_sizes=(1, 1), train=True)
    assert logits.shape == (2, 8)
    assert not bool(jnp.isnan(logits).any())
    # BN running stats updated
    assert float(jnp.abs(new_state["bn_stem"]["mean"]
                         - state["bn_stem"]["mean"]).max()) > 0


def test_unet_forward_shapes():
    key = jax.random.PRNGKey(1)
    params, state = cnn.unet_init(key, base=8, depth=2)
    x = jax.random.normal(key, (2, 32, 32, 3))
    logits, _ = cnn.unet_forward(params, state, x, depth=2, train=True)
    assert logits.shape == (2, 32, 32, 1)
    assert not bool(jnp.isnan(logits).any())


def test_mbs_equivalence_with_frozen_bn():
    """With BN in eval mode (batch-independent), MBS == full batch exactly.
    (In train mode BN stats are per-micro-batch — the paper's own PyTorch
    semantics, §4.2.2.)"""
    key = jax.random.PRNGKey(2)
    params, state = cnn.resnet_init(key, num_classes=4, stage_sizes=(1,),
                                    width=8)
    rng = np.random.default_rng(0)
    batch = {"image": rng.normal(size=(8, 16, 16, 3)).astype(np.float32),
             "label": rng.integers(0, 4, 8).astype(np.int32)}

    def loss_fn(p, b, exact_denom=None):
        logits, _ = cnn.resnet_forward(p, state, b["image"],
                                       stage_sizes=(1,), train=False)
        return losses.cross_entropy(
            logits, b["label"], sample_weight=b.get("sample_weight"),
            exact_denom=exact_denom), {}

    _, ref = jax.value_and_grad(lambda p: loss_fn(p, batch)[0])(params)
    split = {k: jnp.asarray(v) for k, v in M.split_minibatch(batch, 2).items()}
    g, _ = M.mbs_gradients(loss_fn, params, split, M.MBSConfig(2, "paper"))
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(ref)))
    assert err < 1e-5


def test_unet_trains_with_bce_dice():
    """One MBS step on the paper's segmentation setup decreases loss over a
    few steps (Adam lr .01, BCE+Dice — paper §4.2.4)."""
    key = jax.random.PRNGKey(3)
    params, state = cnn.unet_init(key, base=4, depth=1)
    rng = np.random.default_rng(1)
    x = rng.normal(size=(4, 16, 16, 3)).astype(np.float32)
    m = (rng.random((4, 16, 16, 1)) > 0.5).astype(np.float32)
    opt = optim.adam(1e-2, weight_decay=5e-4)

    def loss_fn(p, b, exact_denom=None):
        # train=True -> BN uses per-micro-batch statistics (paper §4.2.2);
        # running stats are only consumed at eval time.
        logits, _ = cnn.unet_forward(p, state, b["image"], depth=1,
                                     train=True)
        return losses.bce_dice_loss(
            logits, b["mask"], sample_weight=b.get("sample_weight"),
            exact_denom=exact_denom), {}

    step = M.make_mbs_train_step(loss_fn, opt, M.MBSConfig(2, "paper"))
    opt_state = opt.init(params)
    split = {k: jnp.asarray(v)
             for k, v in M.split_minibatch({"image": x, "mask": m}, 2).items()}
    losses_seq = []
    for _ in range(5):
        params, opt_state, metrics = step(params, opt_state, split)
        losses_seq.append(float(metrics["loss"]))
    assert losses_seq[-1] < losses_seq[0]
