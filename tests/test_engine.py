"""Unified MBS engine: planner geometry + the four executors (compiled
scan / streaming / Pallas-fused / flat, interpret mode on CPU) produce
numerically equal gradients and parameter updates — eq. (15)–(17) behind
one interface. Shared fixtures live in ``conftest.py`` (the executor
conformance harness)."""
import argparse

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import (EXECUTOR_GRID, assert_scalar_close, make_executor,
                      max_abs_err as _max_err, tiny_batch as _batch,
                      tiny_loss_fn as _loss_fn, tiny_params as _params)
from repro import configs, engine, optim
from repro.core import losses, memory_model
from repro.data import LMDataset
from repro.launch import steps, train as train_lib


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------

def test_plan_pins_micro_batch_size():
    plan = engine.plan_mbs(16, micro_batch_size=4)
    assert (plan.micro_batch_size, plan.num_micro_batches, plan.pad) == (4, 4, 0)
    assert not plan.auto_micro and plan.normalization == "paper"


def test_plan_pins_num_microbatches_with_ragged_tail():
    plan = engine.plan_mbs(10, num_microbatches=3)
    assert (plan.micro_batch_size, plan.num_micro_batches, plan.pad) == (4, 3, 2)
    # Algorithm 1 ("paper") is only exact for uniform splits: auto-upgrade
    assert plan.normalization == "exact" and plan.auto_normalization


def test_plan_auto_micro_from_memory_model():
    cfg = configs.get_reduced("qwen2-1.5b")
    plan = engine.plan_mbs(64, model_cfg=cfg, seq_len=16)
    assert plan.auto_micro
    suggested = memory_model.suggest_micro_batch_size(cfg, 16, 64)
    assert plan.micro_batch_size == (suggested or 1)
    # the chosen micro-batch actually fits the budget per the model
    est = memory_model.estimate(cfg, 16)
    assert est.total(plan.micro_batch_size) <= memory_model.V5E_HBM_BYTES


def test_plan_auto_micro_respects_tight_budget():
    cfg = configs.get_reduced("qwen2-1.5b")
    act = memory_model.activation_bytes_per_sample(cfg, 16)
    est = memory_model.estimate(cfg, 16)
    cap = est.total(0) + act * 3  # room for <= 3 samples of activations
    plan = engine.plan_mbs(64, model_cfg=cfg, seq_len=16, budget_bytes=cap)
    assert plan.auto_micro and plan.micro_batch_size <= 3


def test_plan_split_is_masked_partition():
    plan = engine.plan_mbs(10, num_microbatches=3)
    batch = _batch(10)
    split = plan.split(batch)
    assert split["x"].shape == (3, 4, 8)
    w = split["sample_weight"].reshape(-1)
    assert w.sum() == 10
    np.testing.assert_array_equal(split["x"].reshape(-1, 8)[w > 0], batch["x"])


def test_plan_from_legacy_config_roundtrip():
    cfg = engine.MBSConfig(4, "exact", jnp.bfloat16)
    plan = engine.MBSPlan.from_config(cfg, 12)
    assert plan.micro_batch_size == 4 and plan.num_micro_batches == 3
    assert plan.as_config() == cfg


# ---------------------------------------------------------------------------
# executor equivalence (acceptance: all three equal on a shared fixture)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("executor", EXECUTOR_GRID)
@pytest.mark.parametrize("n_b,n_mu,normalization", [
    (12, 4, "paper"), (16, 8, "paper"),
    (12, 4, "exact"), (10, 4, "exact"), (13, 5, "exact"),
])
def test_executor_gradients_match_full_batch(executor, n_b, n_mu, normalization):
    params, batch = _params(), _batch(n_b)
    _, ref = jax.value_and_grad(lambda p: _loss_fn(p, batch)[0])(params)
    ref_loss = float(_loss_fn(params, batch)[0])
    plan = engine.plan_mbs(n_b, micro_batch_size=n_mu,
                           normalization=normalization)
    assert plan.normalization == "exact" or n_b % n_mu == 0
    ex = make_executor(executor, _loss_fn, optim.sgd(0.1), plan)
    g, loss = ex.gradients(params, plan.device_split(batch))
    assert _max_err(g, ref) < 2e-6
    assert abs(float(loss) - ref_loss) < 2e-6


@pytest.mark.parametrize("executor", EXECUTOR_GRID)
def test_executor_step_matches_baseline_update(executor):
    """One optimizer step via any engine executor == the no-MBS baseline."""
    params, batch = _params(2), _batch(16, seed=2)
    opt = optim.sgd(0.1, momentum=0.9, weight_decay=1e-4)
    base = engine.make_baseline_train_step(_loss_fn, opt)
    p_ref, _, m_ref = jax.jit(base)(params, opt.init(params),
                                    {k: jnp.asarray(v) for k, v in batch.items()})
    plan = engine.plan_mbs(16, micro_batch_size=4)
    ex = make_executor(executor, _loss_fn, opt, plan)
    p, _, m = ex.step(params, opt.init(params), dict(batch))
    assert _max_err(p, p_ref) < 2e-6
    assert abs(float(m["loss"]) - float(m_ref["loss"])) < 2e-6
    assert abs(float(m["grad_norm"]) - float(m_ref["grad_norm"])) < 2e-5


def _aux_loss_fn(p, batch, exact_denom=None):
    """CE + an additive (non-per-sample) regularizer following the exact-mode
    contract: the aux term carries this micro-batch's valid-sample share."""
    h = jnp.tanh(batch["x"] @ p["w1"])
    logits = h @ p["w2"]
    ce = losses.cross_entropy(logits, batch["y"],
                              sample_weight=batch.get("sample_weight"),
                              exact_denom=exact_denom),
    aux = 0.1 * jnp.mean(jnp.square(h))
    if exact_denom is not None:
        sw = batch.get("sample_weight")
        n_valid = (jnp.sum(sw) if sw is not None
                   else jnp.asarray(float(batch["x"].shape[0])))
        aux = aux * (n_valid / exact_denom)
    return ce[0] + aux, {}


@pytest.mark.parametrize("n_b,n_mu", [(12, 4), (10, 4)])
def test_additive_aux_loss_consistent_across_executors(n_b, n_mu):
    """Regression: additive regularizers (e.g. MoE router aux) must get the
    same weight from every executor in exact mode, ragged tails included."""
    params, batch = _params(), _batch(n_b)
    plan = engine.plan_mbs(n_b, micro_batch_size=n_mu, normalization="exact")
    split = plan.device_split(batch)
    grads, ls = {}, {}
    for name in EXECUTOR_GRID:
        ex = make_executor(name, _aux_loss_fn, optim.sgd(0.1), plan)
        grads[name], ls[name] = ex.gradients(params, split)
    for name in ("streaming", "fused"):
        assert _max_err(grads[name], grads["compiled"]) < 2e-6
        assert abs(float(ls[name]) - float(ls["compiled"])) < 2e-6
    if n_b % n_mu == 0:  # uniform split: exact == paper == mean-of-micro aux
        plan_p = engine.plan_mbs(n_b, micro_batch_size=n_mu)
        g_p, _ = engine.CompiledScanExecutor(
            _aux_loss_fn, optim.sgd(0.1), plan_p).gradients(params, split)
        assert _max_err(g_p, grads["compiled"]) < 2e-6


def test_fused_accum_dtype_is_respected():
    params, batch = _params(), _batch(8)
    plan = engine.plan_mbs(8, micro_batch_size=4, accum_dtype=jnp.bfloat16)
    ex = engine.FusedAccumExecutor(_loss_fn, optim.sgd(0.1), plan,
                                   interpret=True)
    g, _ = ex.gradients(params, plan.device_split(batch))
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(g))


# ---------------------------------------------------------------------------
# ragged end-to-end through launch/train.py's step path
# ---------------------------------------------------------------------------

def _train_args(**over):
    base = dict(microbatches=3, executor="compiled", normalization="paper",
                hbm_budget_gb=None, seq=16, mini_batch=10, dtype="float32",
                lr=0.05, reduced=True)
    base.update(over)
    return argparse.Namespace(**base)


@pytest.mark.parametrize("executor", EXECUTOR_GRID)
def test_ragged_train_path_matches_full_batch(executor):
    """mini_batch=10, micro=4 through the launcher's step construction
    produces the same update as the full-batch baseline (this path used to
    die on a divisibility assert)."""
    cfg = configs.get_reduced("qwen2-1.5b")
    args = _train_args(executor=executor)
    plan = train_lib.build_plan(cfg, args)
    assert plan.micro_batch_size == 4 and plan.pad == 2
    assert plan.normalization == "exact"  # auto-upgraded for the ragged tail
    if executor == "fused":  # CPU: run the Pallas kernel in interpret mode
        ex, opt = train_lib.build_executor(cfg, plan, args)
        ex = engine.FusedAccumExecutor(ex.loss_fn, opt, plan, interpret=True)
    else:
        ex, opt = train_lib.build_executor(cfg, plan, args)

    ds = LMDataset(vocab_size=cfg.vocab_size, seq_len=args.seq, seed=0)
    mini = ds.batch(args.mini_batch, 0)
    from repro.models import transformer
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))

    base = jax.jit(engine.make_baseline_train_step(ex.loss_fn, opt))
    p_ref, _, m_ref = base(params, opt.init(params),
                           {k: jnp.asarray(v) for k, v in mini.items()})
    p, _, m = ex.step(params, opt.init(params), mini)
    assert _max_err(p, p_ref) < 1e-5
    assert abs(float(m["loss"]) - float(m_ref["loss"])) < 1e-5


def test_build_train_step_auto_micro_and_mask_shapes():
    """steps.build_train_step goes through the planner: no divisibility
    assert, sample-weight mask in the abstract batch."""
    cfg = configs.get_reduced("qwen2-1.5b")
    shape = configs.SHAPES["train_4k"]
    bundle = steps.build_train_step(cfg, shape, num_microbatches=8,
                                    dtype=jnp.float32, remat=False)
    batch = bundle.arg_shapes[2]
    assert batch["tokens"].shape[:2] == (8, 32)
    assert batch["sample_weight"].shape == (8, 32)
    # auto: planner consults the memory model when N_Smu is not pinned
    auto = steps.build_train_step(cfg, shape, dtype=jnp.float32, remat=False)
    n, m = auto.arg_shapes[2]["tokens"].shape[:2]
    assert n * m >= shape.global_batch
    assert m == (memory_model.suggest_micro_batch_size(
        cfg, shape.seq_len, shape.global_batch) or 1)
