"""Multi-device conformance matrix (engine Layer 6).

Runs on the conftest-forced 8-device CPU host platform and proves, for
every executor in the conformance grid × mesh shapes × split regimes:

  * **equivalence** — sharded execution is semantically invisible: the
    deferred-sync ShardedExecutor reproduces the single-device gradients,
    loss, and full optimizer update (ragged tails + exact normalization +
    global-norm clipping included) within the harness's per-dtype
    tolerances;
  * **deferred sync** — the compiled mini-batch step's HLO contains
    exactly ONE gradient all-reduce, independent of the number of
    micro-batches (asserted against a fully unrolled scan, where the
    per-micro-sync baseline shows one collective per micro-batch);
  * **trajectory** — the 5-step golden loss trajectory pinned in PR 4
    (single device) is reproduced bit-for-tolerance on a (data=4) mesh;
  * **planning** — ``plan_mbs(mesh=...)`` keeps micro sizes divisible by
    the data axis, records ``data_parallel``/``local_micro``, and admits
    a growing global batch at a fixed per-device budget as the data axis
    grows 2 -> 4 -> 8.
"""
import jax
import numpy as np
import pytest

from conftest import (EXECUTOR_GRID, GOLDEN_LOSSES, ToyDataset,
                      assert_scalar_close, assert_trees_close, host_mesh,
                      make_executor, make_sharded_executor, tiny_batch,
                      tiny_loss_fn, tiny_optimizer, tiny_params)
from repro import analysis, configs, engine, optim
from repro.core import memory_model

pytestmark = pytest.mark.mesh

# (label, mini_batch, micro_batch, expected normalization after planning):
# the uniform split keeps Algorithm 1's "paper" mode; the ragged split
# auto-upgrades to "exact" and exercises the zero-weight-padding shards
SPLIT_CASES = {
    "uniform-paper": (16, 8, "paper"),
    "ragged-exact": (10, 4, "exact"),
}


def _plan_and_split(mini, micro, mesh, seed=0):
    plan = engine.plan_mbs(mini, micro_batch_size=micro, mesh=mesh)
    return plan, plan.device_split(tiny_batch(mini, seed))


# ---------------------------------------------------------------------------
# gradient/loss equivalence: executors × mesh shapes × split regimes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("executor", EXECUTOR_GRID)
@pytest.mark.parametrize("data", [2, 4])
@pytest.mark.parametrize("case", sorted(SPLIT_CASES))
def test_sharded_gradients_match_single_device(executor, data, case):
    mini, micro, norm = SPLIT_CASES[case]
    mesh = host_mesh(data)
    plan, split = _plan_and_split(mini, micro, mesh)
    assert plan.normalization == norm
    params, opt = tiny_params(), tiny_optimizer()
    g_ref, l_ref = make_executor(executor, tiny_loss_fn, opt, plan,
                                 donate=False).gradients(params, split)
    g, l = make_sharded_executor(executor, tiny_loss_fn, opt, plan,
                                 mesh).gradients(params, split)
    assert_trees_close(g, g_ref, what=f"{executor}/data={data}/{case} grads")
    assert_scalar_close(l, l_ref, what=f"{executor}/data={data}/{case} loss")


@pytest.mark.parametrize("executor", EXECUTOR_GRID)
def test_sharded_update_matches_single_device_with_clip(executor):
    """Full optimizer step under global-norm clipping on the ragged split:
    params, opt state, loss and grad-norm must all match the single-device
    reference — the clip scale is computed from the globally summed
    gradient, so a wrong sync point shows up here immediately."""
    mesh = host_mesh(4)
    opt = optim.clip_by_global_norm(
        optim.sgd(0.1, momentum=0.9, weight_decay=1e-4), 0.05)
    plan, split = _plan_and_split(10, 4, mesh)
    params = tiny_params()
    ref = make_executor(executor, tiny_loss_fn, opt, plan, donate=False)
    p_ref, s_ref, m_ref = ref.step_split(params, opt.init(params), split)
    ex = make_sharded_executor(executor, tiny_loss_fn, opt, plan, mesh,
                               donate=False)
    p, s, m = ex.step_split(params, opt.init(params), split)
    assert_trees_close(p, p_ref, what=f"{executor} clipped params")
    assert_trees_close(s, s_ref, what=f"{executor} clipped opt state")
    assert_scalar_close(m["loss"], m_ref["loss"], what=f"{executor} loss")
    assert_scalar_close(m["grad_norm"], m_ref["grad_norm"], atol=1e-4,
                        what=f"{executor} grad_norm")


def test_sharded_step_via_host_minibatch():
    """.step() stages the host split with the mesh batch shardings and
    matches .step_split() on pre-staged arrays."""
    mesh = host_mesh(4)
    opt = tiny_optimizer()
    plan = engine.plan_mbs(16, micro_batch_size=8, mesh=mesh)
    params = tiny_params()
    batch = tiny_batch(16)
    ex = make_sharded_executor("compiled", tiny_loss_fn, opt, plan, mesh,
                               donate=False)
    p1, _, m1 = ex.step(params, opt.init(params), dict(batch))
    p2, _, m2 = ex.step_split(params, opt.init(params),
                              plan.device_split(batch))
    assert_trees_close(p1, p2, what="step vs step_split params")
    assert_scalar_close(m1["loss"], m2["loss"])


# ---------------------------------------------------------------------------
# deferred sync: HLO collective counts
# ---------------------------------------------------------------------------

def _compile_step(step_fn, *abstract_args):
    return jax.jit(step_fn).lower(*abstract_args).compile()


@pytest.mark.parametrize("n_micro", [2, 8])
def test_exactly_one_gradient_allreduce_per_minibatch(n_micro):
    """The acceptance criterion: with the scan FULLY UNROLLED (so a rolled
    loop body cannot hide per-iteration collectives) the deferred-sync
    step compiles to exactly one all-reduce regardless of N_Sμ, while the
    per-micro-sync baseline compiles to one per micro-batch plus the
    scalar sync. Both censuses go through the shared analysis rule
    (HLO004) — an empty findings list IS the pass."""
    mesh = host_mesh(4)
    opt = tiny_optimizer()
    plan = engine.plan_mbs(8 * n_micro, num_microbatches=n_micro, mesh=mesh,
                           unroll=n_micro)
    assert plan.num_micro_batches == n_micro
    params = tiny_params()
    split = plan.device_split(tiny_batch(8 * n_micro))
    state = opt.init(params)

    deferred = make_sharded_executor("compiled", tiny_loss_fn, opt, plan,
                                     mesh, donate=False)
    compiled = _compile_step(deferred.make_train_step(), params, state, split)
    findings = analysis.check_gradient_sync(
        compiled, expect="deferred", n_micro=n_micro, context="deferred")
    assert not findings, [f.format() for f in findings]
    assert analysis.allreduce_count(compiled) == 1

    baseline = make_sharded_executor("compiled", tiny_loss_fn, opt, plan,
                                     mesh, donate=False, defer_sync=False)
    compiled = _compile_step(baseline.make_train_step(), params, state, split)
    findings = analysis.check_gradient_sync(
        compiled, expect="per-micro", n_micro=n_micro, context="baseline")
    assert not findings, [f.format() for f in findings]


@pytest.mark.parametrize("executor", [e for e in EXECUTOR_GRID
                                      if e != "streaming"])
def test_one_allreduce_for_every_compiled_inner(executor):
    """The single-collective contract holds for every jittable inner
    strategy (plain scan, Pallas fused accumulate, flat buckets)."""
    mesh = host_mesh(4)
    opt = tiny_optimizer()
    plan = engine.plan_mbs(16, num_microbatches=4, mesh=mesh, unroll=4)
    params = tiny_params()
    split = plan.device_split(tiny_batch(16))
    ex = make_sharded_executor(executor, tiny_loss_fn, opt, plan, mesh,
                               donate=False)
    compiled = _compile_step(ex.make_train_step(), params, opt.init(params),
                             split)
    findings = analysis.check_gradient_sync(
        compiled, expect="deferred", n_micro=4, context=executor)
    assert not findings, [f.format() for f in findings]


# ---------------------------------------------------------------------------
# golden trajectory on a (data=4) mesh
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("executor", EXECUTOR_GRID)
def test_five_step_loss_trajectory_matches_single_device_golden(executor):
    """The PR-4 golden trajectory (recorded on ONE device) must be
    reproduced by sharded execution on a (data=4) mesh — data parallelism
    with deferred sync is a schedule change, never a numerics change."""
    mesh = host_mesh(4)
    plan = engine.plan_mbs(10, micro_batch_size=4, mesh=mesh)
    ds = ToyDataset()
    opt = tiny_optimizer()
    ex = make_sharded_executor(executor, tiny_loss_fn, opt, plan, mesh,
                               donate=False)
    params, state = tiny_params(), opt.init(tiny_params())
    losses = []
    for step in range(5):
        params, state, m = ex.step(params, state, ds.batch(10, step))
        losses.append(float(m["loss"]))
    np.testing.assert_allclose(losses, GOLDEN_LOSSES, atol=5e-4, rtol=0)


# ---------------------------------------------------------------------------
# mesh-aware planning
# ---------------------------------------------------------------------------

def test_plan_records_mesh_geometry_and_divisibility():
    mesh = host_mesh(4)
    plan = engine.plan_mbs(16, micro_batch_size=8, mesh=mesh)
    assert plan.data_parallel == 4
    assert plan.local_micro == 2
    assert plan.micro_batch_size == plan.local_micro * plan.data_parallel
    # pinned sizes that do not divide are rounded UP to the next multiple
    plan = engine.plan_mbs(16, micro_batch_size=6, mesh=mesh)
    assert plan.micro_batch_size == 8 and plan.local_micro == 2
    # ... but never past the largest dp-divisible size <= the mini-batch
    plan = engine.plan_mbs(10, micro_batch_size=7, mesh=mesh)
    assert plan.micro_batch_size == 8 and plan.local_micro == 2
    with pytest.raises(ValueError, match="data-parallel"):
        engine.plan_mbs(3, micro_batch_size=1, mesh=mesh)


def test_sharded_executor_rejects_bad_plans():
    mesh = host_mesh(4)
    opt = tiny_optimizer()
    indivisible = engine.plan_mbs(10, micro_batch_size=5)  # no mesh: 5 % 4
    with pytest.raises(ValueError, match="divide"):
        engine.ShardedExecutor(tiny_loss_fn, opt, indivisible, mesh=mesh)
    ragged_paper = engine.MBSPlan(10, 4, 3, 2, "paper")
    with pytest.raises(ValueError, match="exact"):
        engine.ShardedExecutor(tiny_loss_fn, opt, ragged_paper, mesh=mesh)
    plan = engine.plan_mbs(16, micro_batch_size=8, mesh=mesh)
    with pytest.raises(ValueError, match="defer_sync"):
        engine.ShardedExecutor(tiny_loss_fn, opt, plan, mesh=mesh,
                               inner="flat", defer_sync=False)


def test_admission_grows_with_data_axis():
    """The acceptance criterion: at a FIXED per-device budget the
    mesh-aware planner admits a larger global batch as the data axis
    grows 2 -> 4 -> 8 (local admission is per-device; the global
    micro-batch multiplies it by data_parallel)."""
    cfg = configs.get_reduced("qwen2-1.5b")
    seq = 16
    est = memory_model.estimate(cfg, seq, remat_policy="none")
    budget = est.total(0) + 3 * est.activation_bytes_per_sample
    admitted = []
    for data in (2, 4, 8):
        mesh = host_mesh(data)
        plan = engine.plan_mbs(256, model_cfg=cfg, seq_len=seq,
                               budget_bytes=budget, remat_policy="none",
                               mesh=mesh, fsdp_params=False)
        assert plan.data_parallel == data
        # the plan's own per-device estimate stays inside the budget
        per_dev = memory_model.estimate(cfg, seq, remat_policy="none",
                                        mesh=mesh, fsdp_params=False)
        assert per_dev.total(plan.local_micro) <= budget
        admitted.append(plan.micro_batch_size)
    assert admitted == sorted(admitted)
    assert admitted[-1] > admitted[0], admitted


def test_pipeline_stages_with_mesh_batch_shardings():
    """Pipeline(mesh=...) stages split batches with the mesh's batch
    shardings: the sample dim (dim 1) lands sharded over the data axis,
    the scan dim replicated — the GSPMD launcher path's staging."""
    from jax.sharding import PartitionSpec as P
    mesh = host_mesh(4)
    plan = engine.plan_mbs(16, micro_batch_size=8, mesh=mesh)
    pipe = engine.Pipeline(ToyDataset(), plan, prefetch=0, mesh=mesh)
    batch = next(iter(pipe.batches(1)))
    assert batch["x"].sharding.spec == P(None, "data", None)
    assert batch["sample_weight"].sharding.spec == P(None, "data")
    with pytest.raises(ValueError, match="not both"):
        engine.Pipeline(ToyDataset(), plan, mesh=mesh,
                        sharding=jax.devices()[0])


def test_param_shard_ratio_discounts_fsdp():
    """FSDP sharding discounts the per-device param bytes (divisible dims
    shard; the rest replicate), and the data axis discount disappears for
    a replicating executor (fsdp=False)."""
    cfg = configs.get_reduced("qwen2-1.5b")
    mesh = host_mesh(4)
    r_fsdp = memory_model.param_shard_ratio(cfg, mesh, fsdp=True)
    r_repl = memory_model.param_shard_ratio(cfg, mesh, fsdp=False)
    assert r_fsdp < r_repl <= 1.0
    est_fsdp = memory_model.estimate(cfg, 16, mesh=mesh, fsdp_params=True)
    est_repl = memory_model.estimate(cfg, 16, mesh=mesh, fsdp_params=False)
    assert est_fsdp.params_bytes < est_repl.params_bytes
