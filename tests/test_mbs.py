"""The paper's core claim (eq. 15–17): MBS-accumulated, loss-normalized
gradients equal the full-mini-batch gradients — tested numerically, plus
Algorithm 1 behaviours (ragged tails, N_mu clamp)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import losses, mbs as M
from repro import optim


def tiny_params(key, din=8, dh=16, dout=4):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (din, dh)) * 0.3,
            "w2": jax.random.normal(k2, (dh, dout)) * 0.3}


def loss_fn(p, batch, exact_denom=None):
    h = jnp.tanh(batch["x"] @ p["w1"])
    logits = h @ p["w2"]
    l = losses.cross_entropy(logits, batch["y"],
                             sample_weight=batch.get("sample_weight"),
                             exact_denom=exact_denom)
    return l, {"acc": losses.accuracy(logits, batch["y"])}


def make_batch(n, seed=0):
    rng = np.random.default_rng(seed)
    return {"x": rng.normal(size=(n, 8)).astype(np.float32),
            "y": rng.integers(0, 4, n).astype(np.int32)}


def ref_grads(params, batch):
    return jax.value_and_grad(lambda p: loss_fn(p, batch)[0])(params)


def max_err(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.mark.parametrize("n_b,n_mu", [(12, 4), (16, 8), (16, 2), (9, 3)])
def test_uniform_split_matches_full_batch(n_b, n_mu):
    params = tiny_params(jax.random.PRNGKey(0))
    batch = make_batch(n_b)
    ref_loss, ref_g = ref_grads(params, batch)
    split = {k: jnp.asarray(v) for k, v in M.split_minibatch(batch, n_mu).items()}
    g, loss = M.mbs_gradients(loss_fn, params, split, M.MBSConfig(n_mu, "paper"))
    assert max_err(g, ref_g) < 1e-6
    assert abs(float(loss) - float(ref_loss)) < 1e-6


@pytest.mark.parametrize("n_b,n_mu", [(12, 5), (13, 4), (7, 3), (10, 7)])
def test_ragged_split_exact_mode(n_b, n_mu):
    params = tiny_params(jax.random.PRNGKey(1))
    batch = make_batch(n_b, seed=1)
    _, ref_g = ref_grads(params, batch)
    split = {k: jnp.asarray(v) for k, v in M.split_minibatch(batch, n_mu).items()}
    g, _ = M.mbs_gradients(loss_fn, params, split, M.MBSConfig(n_mu, "exact"))
    assert max_err(g, ref_g) < 1e-6


def test_algorithm1_n_mu_clamp():
    # Algorithm 1 lines 2-4: N_mu <- N_B when N_B < N_mu
    assert M.num_micro_batches(4, 16) == 1
    assert M.num_micro_batches(16, 4) == 4
    assert M.num_micro_batches(17, 4) == 5  # round-up (line 5)
    split = M.split_minibatch(make_batch(4), 16)
    assert split["x"].shape == (1, 4, 8)


def test_split_minibatch_is_partition():
    # eq. (1)-(3): micro-batches partition the mini-batch
    batch = make_batch(13)
    split = M.split_minibatch(batch, 5)
    n_s, n_mu = split["x"].shape[:2]
    assert n_s == 3 and n_mu == 5
    flat = split["x"].reshape(-1, 8)[split["sample_weight"].reshape(-1) > 0]
    np.testing.assert_array_equal(flat, batch["x"])
    assert split["sample_weight"].sum() == 13


def test_compiled_step_matches_baseline_update():
    """One optimizer step via MBS == one step via the no-MBS baseline."""
    params = tiny_params(jax.random.PRNGKey(2))
    batch = make_batch(16, seed=2)
    opt = optim.sgd(0.1, momentum=0.9, weight_decay=1e-4)

    base = M.make_baseline_train_step(loss_fn, opt)
    p1, s1, m1 = jax.jit(base)(params, opt.init(params),
                               {k: jnp.asarray(v) for k, v in batch.items()})

    split = {k: jnp.asarray(v) for k, v in M.split_minibatch(batch, 4).items()}
    step = M.make_mbs_train_step(loss_fn, opt, M.MBSConfig(4, "paper"))
    p2, s2, m2 = jax.jit(step)(params, opt.init(params), split)

    assert max_err(p1, p2) < 1e-6
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-6


def test_without_normalization_grads_differ():
    """eq. (13): raw accumulation (no 1/N_Smu) does NOT equal the mini-batch
    gradient — the loss normalization is load-bearing."""
    params = tiny_params(jax.random.PRNGKey(3))
    batch = make_batch(12, seed=3)
    _, ref_g = ref_grads(params, batch)
    split = {k: jnp.asarray(v) for k, v in M.split_minibatch(batch, 4).items()}
    acc = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    for i in range(3):
        mb = jax.tree.map(lambda x: x[i], split)
        g = jax.grad(lambda p: loss_fn(p, mb)[0])(params)
        acc = jax.tree.map(jnp.add, acc, g)
    assert max_err(acc, ref_g) > 1e-3  # ~3x too large


def test_metrics_averaged_over_microbatches():
    params = tiny_params(jax.random.PRNGKey(4))
    batch = make_batch(16, seed=4)
    opt = optim.sgd(0.0)
    split = {k: jnp.asarray(v) for k, v in M.split_minibatch(batch, 4).items()}
    step = M.make_mbs_train_step(loss_fn, opt, M.MBSConfig(4, "paper"))
    _, _, metrics = jax.jit(step)(params, opt.init(params), split)
    full_acc = loss_fn(params, {k: jnp.asarray(v) for k, v in batch.items()})[1]["acc"]
    assert abs(float(metrics["acc"]) - float(full_acc)) < 1e-6
