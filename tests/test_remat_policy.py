"""Remat-policy axis (engine Layer 5) — graded activation checkpointing
chosen jointly with the micro-batch size:

  * checkpointing is semantically invisible: every policy × executor
    reproduces the ``remat_policy="none"`` gradients on a tiny transformer
    config, ragged tails + exact normalization + global-norm clip included;
  * the planner's policy-aware admission points the right way in reality:
    XLA's own ``compiled.memory_analysis()`` of the train step is monotone
    non-increasing along the lattice (reduced dry-run, one device);
  * ``"auto"`` escalates only when the budget forces it, and buys a
    strictly larger micro-batch than ``"none"`` at a tight budget
    (the PR's acceptance criterion);
  * golden-trajectory regression: a recorded 5-step loss trajectory on a
    fixed seed must be reproduced by all four executors, so engine
    refactors cannot silently drift the training numerics.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import (EXECUTOR_GRID, GOLDEN_LOSSES, ToyDataset,
                      assert_scalar_close, assert_trees_close, make_executor,
                      max_abs_err, tiny_loss_fn, tiny_optimizer, tiny_params)
from repro import configs, engine, optim
from repro.configs.shapes import InputShape
from repro.core import memory_model
from repro.data import LMDataset
from repro.launch import steps
from repro.models import remat, transformer

CFG = configs.get_reduced("qwen2-1.5b")
SEQ = 16


def _lm_split(plan, n_b, seed=0):
    ds = LMDataset(vocab_size=CFG.vocab_size, seq_len=SEQ, seed=seed)
    return plan.device_split(ds.batch(n_b, 0))


def _loss(policy):
    return steps.make_loss_fn(CFG, dtype=jnp.float32, remat_policy=policy)


def _tparams(seed=0):
    return transformer.init_params(CFG, jax.random.PRNGKey(seed))


# ---------------------------------------------------------------------------
# gradient equivalence: every policy == "none", on every executor
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("executor", EXECUTOR_GRID)
@pytest.mark.parametrize("policy", [p for p in remat.POLICIES if p != "none"])
def test_policy_gradients_match_none(executor, policy):
    """Ragged mini-batch (5 % 2 != 0 → exact normalization): checkpointing
    must only change the schedule, never the accumulated gradient."""
    plan = engine.plan_mbs(5, micro_batch_size=2)
    assert plan.normalization == "exact"  # ragged auto-upgrade
    split = _lm_split(plan, 5)
    params = _tparams()
    g_ref, l_ref = make_executor(executor, _loss("none"), optim.sgd(0.1),
                                 plan).gradients(params, split)
    g, l = make_executor(executor, _loss(policy), optim.sgd(0.1),
                         plan).gradients(params, split)
    assert_trees_close(g, g_ref, atol=1e-5,
                       what=f"{executor}/{policy} gradients")
    assert_scalar_close(l, l_ref, atol=1e-5, what=f"{executor}/{policy} loss")


@pytest.mark.parametrize("policy", [p for p in remat.POLICIES if p != "none"])
def test_policy_step_matches_none_with_clip(policy):
    """Global-norm clipping on top: one full optimizer step under a remat
    policy equals the unchecked-pointed step (uniform split, paper mode)."""
    opt = optim.clip_by_global_norm(optim.sgd(0.1, momentum=0.9), 0.05)
    plan = engine.plan_mbs(4, micro_batch_size=2)
    assert plan.normalization == "paper"
    split = _lm_split(plan, 4)
    params = _tparams(1)
    p_ref, _, m_ref = make_executor(
        "compiled", _loss("none"), opt, plan,
        donate=False).step_split(params, opt.init(params), split)
    p, _, m = make_executor(
        "compiled", _loss(policy), opt, plan,
        donate=False).step_split(params, opt.init(params), split)
    assert_trees_close(p, p_ref, atol=1e-5, what=f"clip/{policy} params")
    assert_scalar_close(m["loss"], m_ref["loss"], atol=1e-5)
    assert_scalar_close(m["grad_norm"], m_ref["grad_norm"], atol=1e-4)


# ---------------------------------------------------------------------------
# the analytic model vs XLA's own memory analysis (reduced dry-run)
# ---------------------------------------------------------------------------

def test_memory_analysis_monotone_along_lattice():
    """Compile the real train step at every policy and read
    ``compiled.memory_analysis()``: temp bytes must be monotone
    non-increasing along the lattice — the direction the planner's
    admission model assumes when it trades recompute for batch."""
    shape = InputShape("train_tiny", "train", 256, 8)
    temps = {}
    for policy in remat.POLICIES:
        bundle = steps.build_train_step(CFG, shape, num_microbatches=2,
                                        dtype=jnp.float32,
                                        remat_policy=policy)
        compiled = jax.jit(bundle.fn, donate_argnums=bundle.donate_argnums
                           ).lower(*bundle.arg_shapes).compile()
        temps[policy] = compiled.memory_analysis().temp_size_in_bytes
    for cheap, heavy in zip(remat.POLICIES, remat.POLICIES[1:]):
        assert temps[heavy] <= temps[cheap], (
            f"{heavy} uses MORE temp bytes than {cheap}: {temps}")
    # the end-to-end direction is strict: full remat must beat no remat
    assert temps["full"] < temps["none"], temps
    # and the analytic activation term agrees on the ordering
    acts = [memory_model.activation_bytes_per_sample(CFG, 256, act_bytes=4,
                                                     remat_policy=p)
            for p in remat.POLICIES]
    assert acts == sorted(acts, reverse=True)


# ---------------------------------------------------------------------------
# joint planner: auto escalation buys batch (acceptance criterion)
# ---------------------------------------------------------------------------

def _tight_budget():
    """A budget that fits a few samples without remat but many with it."""
    est = memory_model.estimate(CFG, SEQ, remat_policy="none")
    return est.total(0) + 3 * est.activation_bytes_per_sample


def test_auto_policy_admits_strictly_more_than_none_at_tight_budget():
    cap = _tight_budget()
    plan_none = engine.plan_mbs(64, model_cfg=CFG, seq_len=SEQ,
                                budget_bytes=cap, remat_policy="none")
    plan_auto = engine.plan_mbs(64, model_cfg=CFG, seq_len=SEQ,
                                budget_bytes=cap, remat_policy="auto")
    assert plan_auto.micro_batch_size > plan_none.micro_batch_size
    assert plan_auto.auto_policy and plan_auto.auto_micro
    assert remat.policy_weight(plan_auto.remat_policy) > 0  # escalated
    # the choice satisfies the analytic budget it was admitted under
    est = memory_model.estimate(CFG, SEQ,
                                remat_policy=plan_auto.remat_policy)
    assert est.total(plan_auto.micro_batch_size) <= cap


def test_auto_policy_stays_cheap_when_budget_is_roomy():
    """Escalation only when forced: with a whole HBM for a reduced config,
    the planner keeps the recompute-free policy."""
    plan = engine.plan_mbs(4, model_cfg=CFG, seq_len=SEQ,
                           remat_policy="auto")
    assert plan.remat_policy == "none"
    assert plan.micro_batch_size == 4  # no accumulation needed either


def test_auto_policy_with_pinned_micro_picks_cheapest_fitting():
    cap = _tight_budget()
    # micro-batch 2 fits without remat at this budget -> stay at "none"
    plan = engine.plan_mbs(16, micro_batch_size=2, model_cfg=CFG,
                           seq_len=SEQ, budget_bytes=cap,
                           remat_policy="auto")
    assert plan.remat_policy == "none"
    # micro-batch 8 only fits under remat -> escalate, geometry unchanged
    plan8 = engine.plan_mbs(16, micro_batch_size=8, model_cfg=CFG,
                            seq_len=SEQ, budget_bytes=cap,
                            remat_policy="auto")
    assert plan8.micro_batch_size == 8
    assert remat.policy_weight(plan8.remat_policy) > 0


def test_explicit_policy_and_legacy_bool_resolution():
    plan = engine.plan_mbs(8, micro_batch_size=4, remat_policy="dots")
    assert plan.remat_policy == "dots" and not plan.auto_policy
    assert engine.plan_mbs(8, micro_batch_size=4).remat_policy == "period"
    assert engine.plan_mbs(8, micro_batch_size=4,
                           remat=False).remat_policy == "none"
    with pytest.raises(ValueError, match="remat policy"):
        engine.plan_mbs(8, micro_batch_size=4, remat_policy="everything")


def test_build_train_step_threads_plan_policy_into_loss(monkeypatch):
    """--remat-policy auto end to end: build_train_step must hand the
    *plan's chosen* policy to make_loss_fn — not the "auto" sentinel and
    not the legacy remat bool. Spied rather than smoked, so a regression
    back to the bool threading fails loudly."""
    shape = InputShape("train_tiny", "train", SEQ, 8)
    seen = {}
    real = steps.make_loss_fn

    def spy(cfg, *a, **kw):
        seen["remat_policy"] = kw.get("remat_policy")
        return real(cfg, *a, **kw)

    monkeypatch.setattr(steps, "make_loss_fn", spy)
    # roomy default budget on the reduced config: auto resolves to "none"
    steps.build_train_step(CFG, shape, num_microbatches=2,
                           dtype=jnp.float32, remat_policy="auto")
    assert seen["remat_policy"] == "none"
    # an explicit policy passes through the plan unchanged
    steps.build_train_step(CFG, shape, num_microbatches=2,
                           dtype=jnp.float32, remat_policy="full")
    assert seen["remat_policy"] == "full"
    # and the step built under the heaviest policy actually runs
    bundle = steps.build_train_step(CFG, shape, num_microbatches=2,
                                    dtype=jnp.float32, remat_policy="full")
    params = _tparams(2)
    opt = steps.make_optimizer(CFG)
    split = _lm_split(engine.plan_mbs(8, num_microbatches=2), 8)
    p, _, m = jax.jit(bundle.fn)(params, opt.init(params), split)
    assert np.isfinite(float(m["loss"]))


def test_auto_policy_flag_only_set_when_search_ran():
    """Without a model config there is nothing to search: "auto" falls
    back to the legacy bool and the plan must NOT claim the planner
    validated the choice (describe()/dryrun would otherwise report a
    search that never happened)."""
    plan = engine.plan_mbs(8, micro_batch_size=4, remat_policy="auto")
    assert plan.remat_policy == "period" and not plan.auto_policy
    with_cfg = engine.plan_mbs(8, micro_batch_size=4, model_cfg=CFG,
                               seq_len=SEQ, remat_policy="auto")
    assert with_cfg.auto_policy  # a real admission search ran


# ---------------------------------------------------------------------------
# golden-trajectory regression (all four executors)
# ---------------------------------------------------------------------------

# GOLDEN_LOSSES lives in conftest since the mesh conformance grid
# (test_mesh_engine.py) pins the SAME trajectory on a (data=4) mesh.

@pytest.mark.parametrize("executor", EXECUTOR_GRID)
def test_five_step_loss_trajectory_matches_golden(executor):
    plan = engine.plan_mbs(10, micro_batch_size=4)
    ds = ToyDataset()
    opt = tiny_optimizer()
    ex = make_executor(executor, tiny_loss_fn, opt, plan, donate=False)
    params, state = tiny_params(), opt.init(tiny_params())
    losses = []
    for step in range(5):
        params, state, m = ex.step(params, state, ds.batch(10, step))
        losses.append(float(m["loss"]))
    np.testing.assert_allclose(losses, GOLDEN_LOSSES, atol=5e-4, rtol=0)
