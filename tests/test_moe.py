"""MoE block invariants: capacity behaviour, router normalization, aux
loss, and MBS interaction (aux normalized by the same 1/N_Smu)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, moe


def _cfg(E=4, k=2, cap=10.0):
    return ModelConfig(name="m", family="moe", num_layers=1, d_model=32,
                       num_heads=4, num_kv_heads=4, head_dim=8, d_ff=0,
                       vocab_size=64, num_experts=E, experts_per_token=k,
                       moe_d_ff=48, capacity_factor=cap)


def test_moe_output_shape_and_finite():
    cfg = _cfg()
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    out, aux = moe.moe_block(p, cfg, x)
    assert out.shape == x.shape
    assert np.isfinite(float(aux))
    assert not bool(jnp.isnan(out).any())


def test_moe_aux_loss_uniform_router_is_one():
    """Balanced routing -> aux = E * sum(1/E * 1/E) * E = 1 exactly."""
    cfg = _cfg(E=4, k=1)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    # zero router logits => uniform probs; top-1 ties broken deterministically
    p["router"]["w"] = jnp.zeros_like(p["router"]["w"])
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 32))
    _, aux = moe.moe_block(p, cfg, x)
    # me = 1/E; ce depends on tie-breaking, but E*sum(me*ce) == sum(ce) == 1
    assert abs(float(aux) - 1.0) < 1e-5


def test_moe_capacity_drops_overflow():
    """With capacity factor ~0, (almost) all tokens drop -> output ~ 0
    (plus shared expert if any — none here)."""
    cfg = _cfg(E=4, k=1, cap=1e-6)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32))
    out, _ = moe.moe_block(p, cfg, x)
    # capacity C=1: at most E tokens survive out of 32
    nonzero_rows = jnp.sum(jnp.any(jnp.abs(out[0]) > 1e-9, axis=-1))
    assert int(nonzero_rows) <= 4


def test_moe_grad_flows_to_all_parts():
    cfg = _cfg()
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))

    def loss(p):
        out, aux = moe.moe_block(p, cfg, x)
        return jnp.sum(out ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    for name in ("router", "w_up", "w_down", "w_gate"):
        leaf = g[name]["w"] if isinstance(g[name], dict) else g[name]
        assert float(jnp.max(jnp.abs(leaf))) > 0, name


def test_moe_shared_expert_added():
    cfg_s = ModelConfig(name="m", family="moe", num_layers=1, d_model=32,
                        num_heads=4, num_kv_heads=4, head_dim=8, d_ff=0,
                        vocab_size=64, num_experts=4, experts_per_token=2,
                        moe_d_ff=48, num_shared_experts=1, shared_d_ff=48,
                        capacity_factor=1e-6)  # routed path ~dropped
    p = moe.moe_init(jax.random.PRNGKey(0), cfg_s)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 32))
    out, _ = moe.moe_block(p, cfg_s, x)
    # shared expert output survives even when routed capacity drops tokens
    assert float(jnp.mean(jnp.abs(out))) > 1e-4
