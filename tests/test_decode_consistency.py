"""Serving correctness: prefill + decode reproduces the full-forward
next-token logits exactly, for every block family (attention ring cache
incl. sliding windows, SSD state, RG-LRU state, enc-dec cross cache)."""
import jax
import jax.numpy as jnp
import pytest

from repro.models import ModelConfig, encdec, transformer

CASES = {
    "dense-local-global": dict(
        layer_pattern=("local", "global"), num_layers=2, sliding_window=8,
        use_post_norm=True, attn_softcap=50.0, final_softcap=30.0),
    "dense-gemma3-pattern": dict(
        layer_pattern=("local",) * 5 + ("global",), num_layers=6,
        sliding_window=8, use_qk_norm=True, rope_theta_global=1e6),
    # ample capacity: capacity-bounded token dropping is batch-shape
    # dependent, so exact prefill/forward equality needs no-drop routing
    "moe": dict(layer_pattern=("global",), num_layers=2, num_experts=4,
                experts_per_token=2, moe_d_ff=96, d_ff=0,
                capacity_factor=8.0),
    "ssm": dict(layer_pattern=("ssm",), num_layers=2, ssm_state=16,
                ssm_head_dim=32, ssm_chunk=4, num_heads=0, num_kv_heads=0,
                head_dim=0, d_ff=0),
    "hybrid": dict(layer_pattern=("recurrent", "recurrent", "local"),
                   num_layers=3, sliding_window=8, lru_width=64),
}


@pytest.mark.parametrize("name", list(CASES))
def test_prefill_decode_matches_forward(name):
    kw = dict(name=name, family="t", d_model=64, num_heads=4, num_kv_heads=2,
              head_dim=16, d_ff=128, vocab_size=128)
    kw.update(CASES[name])
    cfg = ModelConfig(**kw)
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 21), 0, 128)

    logits_full, _ = transformer.forward(params, cfg, toks, dtype=jnp.float32,
                                         remat=False)
    # prefill 18 tokens (not window- or chunk-aligned), decode 3 more
    last, cache = transformer.prefill(params, cfg, toks[:, :18], max_len=32,
                                      dtype=jnp.float32)
    assert float(jnp.max(jnp.abs(last - logits_full[:, 17]))) < 1e-4
    for t in range(18, 21):
        lg, cache = transformer.decode_step(
            params, cfg, toks[:, t:t + 1], cache,
            jnp.full((2,), t, jnp.int32), dtype=jnp.float32)
        err = float(jnp.max(jnp.abs(lg[:, 0] - logits_full[:, t])))
        assert err < 1e-4, (name, t, err)


def test_encdec_decode_matches_forward():
    cfg = ModelConfig(name="ed", family="audio", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
                      vocab_size=128, ffn_kind="gelu", encoder_layers=2)
    key = jax.random.PRNGKey(0)
    params = encdec.init_params(cfg, key)
    frames = jax.random.normal(jax.random.PRNGKey(2), (2, 12, 64))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, 128)
    logits_full, _ = encdec.forward(params, cfg, frames, toks,
                                    dtype=jnp.float32, remat=False)
    cache = encdec.init_decode_cache(params, cfg, frames, 16, jnp.float32)
    for t in range(8):
        lg, cache = encdec.decode_step(params, cfg, toks[:, t:t + 1], cache,
                                       jnp.full((2,), t, jnp.int32),
                                       dtype=jnp.float32)
        err = float(jnp.max(jnp.abs(lg[:, 0] - logits_full[:, t])))
        assert err < 1e-4, (t, err)


@pytest.mark.parametrize("name", ["dense-local-global", "ssm", "hybrid"])
def test_ring_wraparound_matches_full_recompute(name):
    """Serving past the window: prompt_len + new_tokens > sliding_window,
    so the local-layer ring wraps (several times) during DECODE, not just
    during prefill. Every decode step's logits must equal the
    full-recompute reference — a fresh full forward over the whole prefix,
    which never uses the ring at all."""
    kw = dict(name=name, family="t", d_model=64, num_heads=4, num_kv_heads=2,
              head_dim=16, d_ff=128, vocab_size=128)
    kw.update(CASES[name])
    cfg = ModelConfig(**kw)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    S = 26  # window is 8 -> the ring wraps 3x over the decode tail
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, S), 0, 128)
    prompt = 6  # prompt shorter than the window; the wrap happens mid-decode
    _, cache = transformer.prefill(params, cfg, toks[:, :prompt], max_len=S,
                                   dtype=jnp.float32)
    for t in range(prompt, S):
        lg, cache = transformer.decode_step(
            params, cfg, toks[:, t:t + 1], cache,
            jnp.full((2,), t, jnp.int32), dtype=jnp.float32)
        ref, _ = transformer.forward(params, cfg, toks[:, :t + 1],
                                     dtype=jnp.float32, remat=False)
        err = float(jnp.max(jnp.abs(lg[:, 0] - ref[:, t])))
        assert err < 1e-4, (name, t, err)


def test_ragged_prefill_matches_exact_per_row():
    """Right-padded ragged prefill (lengths=) must equal per-row
    exact-length prefill — including rows LONGER than the sliding window,
    where a naive padded ring would let pad keys evict real ones — and the
    caches it builds must decode identically afterwards."""
    cfg = ModelConfig(name="rag", family="t", d_model=64, num_heads=4,
                      num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128,
                      layer_pattern=("local", "global"), num_layers=2,
                      sliding_window=4)
    assert transformer.supports_ragged_prefill(cfg)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    lengths = (3, 11, 7)  # row 1 exceeds the window by nearly 2 wraps
    pad_to = 16
    rows = [jax.random.randint(jax.random.PRNGKey(10 + i), (L,), 0, 128)
            for i, L in enumerate(lengths)]
    padded = jnp.stack([jnp.pad(r, (0, pad_to - r.shape[0]),
                                constant_values=99) for r in rows])
    last_r, cache_r = transformer.prefill(
        params, cfg, padded, max_len=32, dtype=jnp.float32,
        lengths=jnp.array(lengths, jnp.int32))
    next_tok = jax.random.randint(jax.random.PRNGKey(20), (3, 1), 0, 128)
    lg_r, _ = transformer.decode_step(
        params, cfg, next_tok, cache_r, jnp.array(lengths, jnp.int32),
        dtype=jnp.float32)
    for i, L in enumerate(lengths):
        last_e, cache_e = transformer.prefill(
            params, cfg, rows[i][None, :], max_len=32, dtype=jnp.float32)
        err = float(jnp.max(jnp.abs(last_r[i] - last_e[0])))
        assert err < 1e-4, ("prefill", i, err)
        lg_e, _ = transformer.decode_step(
            params, cfg, next_tok[i:i + 1], cache_e,
            jnp.array([L], jnp.int32), dtype=jnp.float32)
        err = float(jnp.max(jnp.abs(lg_r[i, 0] - lg_e[0, 0])))
        assert err < 1e-4, ("decode", i, err)


def test_long_context_global_window_variant():
    """gemma3-style long-context serving: global layers under a window cap
    behave identically to full attention while the context fits the cap."""
    base = dict(name="g", family="t", d_model=64, num_heads=4, num_kv_heads=2,
                head_dim=16, d_ff=128, vocab_size=128,
                layer_pattern=("local", "global"), num_layers=2,
                sliding_window=4)
    cfg = ModelConfig(**base)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, 128)
    full, _ = transformer.forward(params, cfg, toks, dtype=jnp.float32,
                                  remat=False)
    capped, _ = transformer.forward(params, cfg, toks, dtype=jnp.float32,
                                    remat=False, global_window=16)
    assert float(jnp.max(jnp.abs(full - capped))) < 1e-5
    # and with a cap < context, the outputs genuinely differ (window active)
    capped2, _ = transformer.forward(params, cfg, toks, dtype=jnp.float32,
                                     remat=False, global_window=4)
    assert float(jnp.max(jnp.abs(full - capped2))) > 1e-4
