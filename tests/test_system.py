"""End-to-end behaviour: the paper's headline result in miniature.

A small LM that CANNOT train at mini-batch 64 under a simulated memory cap
(the "w/o MBS: Failed" column of Table 4) DOES train with MBS at micro-batch
8 — and its loss curve matches the unconstrained full-batch run exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, optim
from repro.core import losses, mbs as M, memory_model
from repro.data import LMDataset
from repro.launch import steps
from repro.models import transformer


def _make(arch="qwen2-1.5b"):
    cfg = configs.get_reduced(arch)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    loss_fn = steps.make_loss_fn(cfg, dtype=jnp.float32, remat=False)
    return cfg, params, loss_fn


def test_mbs_training_curve_matches_full_batch():
    """Fig. 3 of the paper, as an exact statement: per-step losses of the
    MBS run and the full-batch run coincide."""
    cfg, params0, loss_fn = _make()
    ds = LMDataset(vocab_size=cfg.vocab_size, seq_len=16, seed=0)
    opt = optim.sgd(0.3, momentum=0.9)

    # full batch
    base = jax.jit(M.make_baseline_train_step(loss_fn, opt))
    p, s = params0, opt.init(params0)
    full_losses = []
    for i in range(10):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(16, i).items()}
        p, s, m = base(p, s, batch)
        full_losses.append(float(m["loss"]))

    # MBS, micro-batch 4
    mbs_step = jax.jit(M.make_mbs_train_step(loss_fn, opt, M.MBSConfig(4)))
    p, s = params0, opt.init(params0)
    mbs_losses = []
    for i in range(10):
        split = {k: jnp.asarray(v)
                 for k, v in M.split_minibatch(ds.batch(16, i), 4).items()}
        p, s, m = mbs_step(p, s, split)
        mbs_losses.append(float(m["loss"]))

    # the equivalence IS the claim (learning progress is asserted by
    # test_mbs_trains_beyond_simulated_memory_cap with a larger batch)
    np.testing.assert_allclose(mbs_losses, full_losses, rtol=2e-3, atol=2e-3)


def test_mbs_trains_beyond_simulated_memory_cap():
    """Table 4 in miniature: enforce an activation budget below the
    mini-batch requirement; MBS picks a feasible micro-batch and trains."""
    cfg, params, loss_fn = _make()
    seq, mini = 16, 64
    act = memory_model.activation_bytes_per_sample(cfg, seq, act_bytes=4,
                                                   remat=False)
    est = memory_model.estimate(cfg, seq, act_bytes=4, remat=False)
    cap = est.total(0) + act * 8  # room for <= 8 samples of activations
    assert est.total(mini) > cap, "mini-batch must exceed the cap (w/o MBS: Failed)"
    micro = memory_model.suggest_micro_batch_size(cfg, seq, mini,
                                                  budget_bytes=cap,
                                                  act_bytes=4, remat=False)
    assert micro is not None and micro <= 8
    ds = LMDataset(vocab_size=cfg.vocab_size, seq_len=seq, seed=1)
    opt = optim.sgd(0.05, momentum=0.9)
    step = jax.jit(M.make_mbs_train_step(loss_fn, opt, M.MBSConfig(micro)))
    p, s = params, opt.init(params)
    curve = []
    for i in range(4):
        split = {k: jnp.asarray(v)
                 for k, v in M.split_minibatch(ds.batch(mini, i), micro).items()}
        p, s, m = step(p, s, split)
        curve.append(float(m["loss"]))
    assert np.isfinite(curve).all() and curve[-1] < curve[0]
