"""Closed-loop autotuner (engine Layer 7): the tuning cache, the memory
oracle's calibrated admission, and the invariant that tuning changes
speed and admission but NEVER numerics (bit-equality under tuned blocks
and calibrated plans, across the full executor grid)."""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import pytest

from conftest import (EXECUTOR_GRID, assert_trees_close, make_executor,
                      tiny_batch, tiny_loss_fn, tiny_optimizer, tiny_params)
from repro import configs, engine
from repro.core import memory_model
from repro.engine import autotune
from repro.kernels import fused_update as fu
from repro.kernels import grad_accum_kernels as ga

SEQ = 64
MINI = 32
# tight: analytically even micro-batch 1 overflows the fixed-cost pad, so
# the analytic planner falls back to micro 1 — calibration must beat it
BUDGET = 64 * 1024 ** 2

PLAN_KW = dict(seq_len=SEQ, budget_bytes=BUDGET, remat_policy="period",
               act_bytes=4)


@pytest.fixture(autouse=True)
def _reset_active_cache():
    yield
    autotune.set_cache_path(None)


@pytest.fixture(scope="module")
def calibrated_cache(tmp_path_factory):
    """One calibration pass (3 probe compiles), shared by every test that
    needs a real oracle entry."""
    path = str(tmp_path_factory.mktemp("tuning") / "tuning.json")
    cfg = configs.get_reduced("qwen2-1.5b")
    plan = engine.plan_mbs(MINI, model_cfg=cfg, calibrate="force",
                           tuning_cache=path, **PLAN_KW)
    return path, cfg, plan


# ---------------------------------------------------------------------------
# cache round-trip / fallback
# ---------------------------------------------------------------------------

def test_cache_roundtrip_same_plan(calibrated_cache):
    path, cfg, forced = calibrated_cache
    assert forced.calibrated and forced.correction is not None
    # a FRESH cache instance (new load from disk) must reproduce the plan
    # exactly — calibrate="auto" is a pure lookup, no compiles
    reloaded = engine.plan_mbs(MINI, model_cfg=cfg, calibrate="auto",
                               tuning_cache=path + ".copy", **PLAN_KW)
    assert not reloaded.calibrated  # different path: no entry, clean fallback
    import shutil
    shutil.copy(path, path + ".copy")
    autotune._caches.pop(path + ".copy", None)  # force re-load from disk
    again = engine.plan_mbs(MINI, model_cfg=cfg, calibrate="auto",
                            tuning_cache=path + ".copy", **PLAN_KW)
    assert again == forced


def test_cache_entry_roundtrip(tmp_path):
    p = str(tmp_path / "t.json")
    c = autotune.TuningCache(p)
    c.put_memory("k", 1.25, -512.0, [(1, 100, 80)])
    c.put_block("b", 4096, {"4096": 10.0})
    c2 = autotune.TuningCache(p)
    assert c2.memory_correction("k") == (1.25, -512.0)
    assert c2.tuned_block("b") == 4096


@pytest.mark.parametrize("garbage", [
    "{not json at all",
    json.dumps({"version": 999, "memory": {"k": {"a": 1, "b": 2}}}),
    json.dumps({"version": 1, "memory": {"k": "not-a-dict"},
                "blocks": {"b": {"block": "nan"}}}),
    json.dumps({"version": 1, "memory": {"k": {"a": -3.0, "b": 0.0}},
                "blocks": {"b": {"block": -5}}}),
])
def test_corrupted_cache_falls_back_without_raising(tmp_path, garbage):
    p = str(tmp_path / "bad.json")
    with open(p, "w") as f:
        f.write(garbage)
    c = autotune.TuningCache(p)
    assert c.memory_correction("k") is None
    assert c.tuned_block("b") is None
    # the planner must fall back to the pure analytic plan, silently
    cfg = configs.get_reduced("qwen2-1.5b")
    analytic = engine.plan_mbs(MINI, model_cfg=cfg, **PLAN_KW)
    degraded = engine.plan_mbs(MINI, model_cfg=cfg, calibrate="auto",
                               tuning_cache=p, **PLAN_KW)
    assert degraded == analytic and not degraded.calibrated
    # and a kernel launch through the resolver must still work
    autotune.set_cache_path(p)
    out = ga.grad_accum(jnp.zeros(100), jnp.ones(100), 0.5)
    assert float(out[0]) == 0.5


def test_calibrate_mode_validated():
    with pytest.raises(ValueError, match="calibrate"):
        engine.plan_mbs(8, calibrate="yes")


# ---------------------------------------------------------------------------
# oracle-calibrated admission (reduced qwen2)
# ---------------------------------------------------------------------------

def test_calibrated_admission_beats_analytic_within_budget(calibrated_cache):
    path, cfg, calibrated = calibrated_cache
    analytic = engine.plan_mbs(MINI, model_cfg=cfg, **PLAN_KW)
    assert calibrated.micro_batch_size >= analytic.micro_batch_size
    assert calibrated.micro_batch_size > 1  # the tight budget was beaten
    # the admitted micro must hold up against the REAL compiled step
    measured = autotune.measured_step_bytes(
        cfg, SEQ, calibrated.micro_batch_size, remat_policy="period")
    assert measured <= BUDGET, (
        f"calibrated admission overflows: measured {measured} > {BUDGET}")


def test_affine_fit_degeneracies():
    # single probe pins only the offset
    assert autotune._fit_affine([(100.0, 80.0)]) == (1.0, -20.0)
    # two probes pin the line exactly
    a, b = autotune._fit_affine([(100.0, 80.0), (200.0, 130.0)])
    assert a == pytest.approx(0.5) and b == pytest.approx(30.0)
    # pathological negative slope falls back to offset-only
    a, b = autotune._fit_affine([(100.0, 200.0), (200.0, 100.0)])
    assert a == 1.0


def test_corrected_micro_search_matches_direct_scan():
    cfg = configs.get_reduced("qwen2-1.5b")
    est = memory_model.estimate(cfg, SEQ, remat_policy="period", act_bytes=4)
    corr = (0.5, -10 * 1024 ** 2)
    got = autotune.corrected_micro_search(cfg, SEQ, 64, BUDGET, corr,
                                          remat_policy="period", act_bytes=4)
    want = max(m for m in range(1, 65)
               if corr[0] * est.total(m) + corr[1] <= BUDGET)
    assert got == want


# ---------------------------------------------------------------------------
# mesh-keyed entries must not leak into single-device plans
# ---------------------------------------------------------------------------

def test_mesh_keyed_entry_does_not_leak(tmp_path):
    from repro.launch import mesh as mesh_lib
    if jax.device_count() < 2:
        pytest.skip("needs 2 forced host devices")
    mesh = mesh_lib.make_host_mesh(data=2, model=1)
    cfg = configs.get_reduced("qwen2-1.5b")
    p = str(tmp_path / "t.json")
    cache = autotune.TuningCache(p)
    # a correction that halves the modeled bytes, so it admits at the
    # tight budget whenever the planner actually applies it
    cache.put_memory(
        autotune.memory_key(cfg, SEQ, "period", mesh, "sgd", "compiled"),
        0.5, 0.0)
    autotune._caches[p] = cache
    # single-device plan: the mesh-keyed entry must NOT apply
    single = engine.plan_mbs(MINI, model_cfg=cfg, calibrate="auto",
                             tuning_cache=p, **PLAN_KW)
    assert not single.calibrated
    # the mesh plan with the SAME cache does see it
    meshed = engine.plan_mbs(MINI, model_cfg=cfg, calibrate="auto",
                             tuning_cache=p, mesh=mesh, **PLAN_KW)
    assert meshed.calibrated


def test_key_layout_distinguishes_axes():
    cfg = configs.get_reduced("qwen2-1.5b")
    keys = {
        autotune.memory_key(cfg, 64, "period", None, "sgd", "compiled", "cpu"),
        autotune.memory_key(cfg, 128, "period", None, "sgd", "compiled", "cpu"),
        autotune.memory_key(cfg, 64, "full", None, "sgd", "compiled", "cpu"),
        autotune.memory_key(cfg, 64, "period", None, "adam", "compiled", "cpu"),
        autotune.memory_key(cfg, 64, "period", None, "sgd", "flat", "cpu"),
        autotune.memory_key(cfg, 64, "period", None, "sgd", "compiled", "tpu"),
    }
    assert len(keys) == 6
    full = dataclasses.replace(configs.get("qwen2-1.5b"), name=cfg.name)
    assert (autotune.memory_key(full, 64, "period", None, "sgd", "compiled")
            != autotune.memory_key(cfg, 64, "period", None, "sgd", "compiled"))


# ---------------------------------------------------------------------------
# tuned blocks are bit-identical to defaults
# ---------------------------------------------------------------------------

def _tuned_cache(tmp_path, block: int):
    """A cache mapping EVERY fp32 size bucket of both tunable kernels to
    ``block`` (0 = whole buffer)."""
    p = str(tmp_path / "tuned.json")
    cache = autotune.get_cache(p)
    for kind in ("grad_accum", "fused_update"):
        for exp in range(1, 26):
            cache.data["blocks"]["|".join(
                [kind, "float32", f"p{exp}", "cpu+interp"])] = {
                "block": block, "timings_us": {}}
    cache.save()
    return p


@pytest.mark.parametrize("block", [37, 4096, 0])
def test_tuned_blocks_bit_identical(tmp_path, block):
    key = jax.random.PRNGKey(0)
    n = 2_006
    g = jax.random.normal(key, (n,), jnp.float32)
    acc = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float32)
    p0 = jax.random.normal(jax.random.fold_in(key, 2), (n,), jnp.float32)
    m0 = jax.random.normal(jax.random.fold_in(key, 3), (n,), jnp.float32)

    autotune.set_cache_path(None)
    base_acc = ga.grad_accum(acc, g, 0.125, interpret=True)
    base_sgd = fu.fused_sgd(p0, g, m0, 0.01, momentum=0.9,
                            weight_decay=1e-4, interpret=True)

    autotune.set_cache_path(_tuned_cache(tmp_path, block))
    want = n if block == 0 else min(block, n)
    assert ga.resolve_block("grad_accum", jnp.float32, n, True) == want
    tuned_acc = ga.grad_accum(acc, g, 0.125, interpret=True)
    tuned_sgd = fu.fused_sgd(p0, g, m0, 0.01, momentum=0.9,
                             weight_decay=1e-4, interpret=True)
    assert_trees_close(tuned_acc, base_acc, atol=0, what="grad_accum")
    assert_trees_close(list(tuned_sgd), list(base_sgd), atol=0,
                       what="fused_sgd")


def test_default_block_heuristic():
    # interpret mode: whole buffer (grid 1) — the 8x-regression fix
    assert ga.default_block(2_006_560, interpret=True) == 2_006_560
    # TPU: pow2, grid >= NUM_PROGRAMS_MIN, VMEM-capped
    n = 2_006_560
    blk = ga.default_block(n, interpret=False)
    assert blk & (blk - 1) == 0
    assert -(-n // blk) >= ga.NUM_PROGRAMS_MIN
    assert blk <= ga.MAX_BLOCK
    assert ga.default_block(100, interpret=False) == 100  # tiny: one program


def test_bucket_blocks_helper(tmp_path):
    spec = engine.FlatSpec.for_tree(tiny_params())
    autotune.set_cache_path(None)
    assert spec.bucket_blocks("grad_accum", interpret=True) == \
        tuple(spec.bucket_sizes)  # heuristic: whole buffer in interpret
    autotune.set_cache_path(_tuned_cache(tmp_path, 37))
    assert spec.bucket_blocks("grad_accum", interpret=True) == \
        tuple(min(37, n) for n in spec.bucket_sizes)


# ---------------------------------------------------------------------------
# executor conformance: tuned blocks + calibrated plan never change numerics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("executor", EXECUTOR_GRID)
def test_executor_bit_equal_under_tuning(executor, tmp_path):
    params = tiny_params()
    opt = tiny_optimizer()
    batch = tiny_batch(10)
    plan = engine.plan_mbs(10, num_microbatches=3)

    def run(p):
        ex = make_executor(executor, tiny_loss_fn, opt, p, donate=False)
        params2, state2, metrics = ex.step(
            jax.tree.map(jnp.copy, params), opt.init(params), dict(batch))
        return params2, state2, metrics

    autotune.set_cache_path(None)
    base_p, base_s, base_m = run(plan)

    # tuned blocks active + a plan flagged as calibrated: the step must be
    # bit-equal — tuning may only ever change speed and admission
    autotune.set_cache_path(_tuned_cache(tmp_path, 37))
    cal_plan = dataclasses.replace(plan, calibrated=True,
                                   correction=(1.0, 0.0))
    tuned_p, tuned_s, tuned_m = run(cal_plan)

    assert_trees_close(tuned_p, base_p, atol=0, what=f"{executor} params")
    assert_trees_close(tuned_s, base_s, atol=0, what=f"{executor} opt state")
    assert float(tuned_m["loss"]) == float(base_m["loss"])


# ---------------------------------------------------------------------------
# block tuner sweep
# ---------------------------------------------------------------------------

def test_tune_block_sizes_persists_winner(tmp_path):
    p = str(tmp_path / "t.json")
    rec = autotune.tune_block_sizes(5_000, jnp.float32, kind="grad_accum",
                                    candidates=(1024, 0), iters=1,
                                    interpret=True, cache_path=p)
    assert rec["block"] in (1024, 0)
    assert set(rec["timings_us"]) == {"1024", "0"}
    cache = autotune.TuningCache(p)
    key = autotune.block_key("grad_accum", jnp.float32, 5_000, interpret=True)
    assert cache.tuned_block(key) == rec["block"]
    # the resolver now serves it to block=None call sites
    autotune.set_cache_path(p)
    want = 5_000 if rec["block"] == 0 else rec["block"]
    assert ga.resolve_block("grad_accum", jnp.float32, 5_000, True) == want
