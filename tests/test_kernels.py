"""Pallas kernels vs ref.py oracles: shape/dtype sweeps in interpret mode
(the kernel body executes in Python on CPU)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import cross_entropy_kernels as ce_mod
from repro.kernels import flash_attention_kernels as fa_mod
from repro.kernels import grad_accum_kernels as ga_mod
from repro.kernels import ops, ref


@pytest.mark.parametrize("S,hd,H,Hkv", [(128, 64, 4, 4), (256, 64, 4, 2),
                                        (256, 32, 8, 1), (384, 64, 2, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(S, hd, H, Hkv, dtype):
    key = jax.random.PRNGKey(0)
    B = 2
    q = jax.random.normal(key, (B, H, S, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, S, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, S, hd), dtype)
    out = fa_mod.flash_attention(q, k, v, block_q=128, block_k=128)
    expect = ref.attention_ref(q, k, v)
    assert out.dtype == dtype and out.shape == q.shape
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                 - expect.astype(jnp.float32)))) < tol


@pytest.mark.parametrize("window,softcap", [(None, None), (64, None),
                                            (None, 30.0), (96, 50.0)])
def test_flash_attention_window_softcap(window, softcap):
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, 4, 256, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 256, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 256, 64))
    out = fa_mod.flash_attention(q, k, v, window=window, softcap=softcap)
    expect = ref.attention_ref(q, k, v, window=window, softcap=softcap)
    assert float(jnp.max(jnp.abs(out - expect))) < 2e-5


def test_flash_attention_unaligned_seq():
    key = jax.random.PRNGKey(2)
    q = jax.random.normal(key, (1, 2, 200, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 200, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 200, 64))
    out = fa_mod.flash_attention(q, k, v, block_q=128, block_k=128)
    expect = ref.attention_ref(q, k, v)
    assert float(jnp.max(jnp.abs(out - expect))) < 2e-5


def test_flash_attention_vjp_matches_ref():
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 2, 128, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 128, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 128, 32))
    g1 = jax.grad(lambda a, b, c: ops.flash_attention(a, b, c, True, 32, None)
                  .sum(), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda a, b, c: ref.attention_ref(a, b, c, window=32)
                  .sum(), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert float(jnp.max(jnp.abs(a - b))) < 2e-5


@pytest.mark.parametrize("T,V", [(64, 500), (100, 1000), (256, 2048),
                                 (37, 777)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cross_entropy_shapes_dtypes(T, V, dtype):
    key = jax.random.PRNGKey(0)
    logits = (jax.random.normal(key, (T, V)) * 3).astype(dtype)
    labels = jax.random.randint(jax.random.fold_in(key, 1), (T,), 0, V)
    out = ce_mod.cross_entropy(logits, labels, block_t=64, block_v=256)
    expect = ref.cross_entropy_ref(logits, labels)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    assert out.shape == (T,)
    assert float(jnp.max(jnp.abs(out - expect))) < tol


def test_cross_entropy_scale_is_mbs_normalization():
    key = jax.random.PRNGKey(4)
    logits = jax.random.normal(key, (32, 128))
    labels = jax.random.randint(key, (32,), 0, 128)
    n_s = 4
    out = ce_mod.cross_entropy(logits, labels, scale=1.0 / n_s)
    expect = ref.cross_entropy_ref(logits, labels) / n_s  # paper eq. (14)
    assert float(jnp.max(jnp.abs(out - expect))) < 1e-6


def test_cross_entropy_vjp():
    key = jax.random.PRNGKey(5)
    logits = jax.random.normal(key, (16, 64))
    labels = jax.random.randint(key, (16,), 0, 64)
    g1 = jax.grad(lambda l: ops.fused_cross_entropy(l, labels, 0.5).sum())(logits)
    g2 = jax.grad(lambda l: (ref.cross_entropy_ref(l, labels) * 0.5).sum())(logits)
    assert float(jnp.max(jnp.abs(g1 - g2))) < 1e-6


@pytest.mark.parametrize("N", [128, 4096, 5000, 17])
@pytest.mark.parametrize("gdtype", [jnp.float32, jnp.bfloat16])
def test_grad_accum(N, gdtype):
    key = jax.random.PRNGKey(0)
    acc = jax.random.normal(key, (N,), jnp.float32)
    g = jax.random.normal(jax.random.fold_in(key, 1), (N,)).astype(gdtype)
    out = ga_mod.grad_accum(acc, g, 0.125)
    expect = ref.grad_accum_ref(acc, g, 0.125)
    assert out.dtype == jnp.float32
    assert float(jnp.max(jnp.abs(out - expect))) < 1e-6


def test_grad_accum_tree():
    key = jax.random.PRNGKey(1)
    acc = {"a": jnp.zeros((4, 8)), "b": jnp.ones((3,))}
    g = {"a": jax.random.normal(key, (4, 8)), "b": jnp.full((3,), 2.0)}
    out = ga_mod.grad_accum_tree(acc, g, 0.5)
    assert float(jnp.max(jnp.abs(out["a"] - 0.5 * g["a"]))) < 1e-6
    assert float(jnp.max(jnp.abs(out["b"] - 2.0))) < 1e-6
