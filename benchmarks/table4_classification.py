"""Paper Table 4: accuracy + training time for mini-batch sizes BEYOND the
no-MBS memory limit (classification).

A simulated activation-memory cap (from core.memory_model, standing in for
the RTX 3090's 24 GB) marks where the baseline "Fails"; MBS keeps training
with a fixed micro-batch, exactly as in the paper. Also measures the MBS
time overhead at the largest common batch (paper reports 0.3–5%).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses, mbs as M, memory_model
from repro.data import ClassificationDataset
from repro.models import cnn
from repro import optim

from .common import emit

STAGE_SIZES = (1, 1)
WIDTH = 8
IMG = 16
MICRO = 8
# simulated cap: activations for <= 16 samples fit, beyond that "Failed"
MAX_NOMBS_BATCH = 16


def _setup(seed=0):
    key = jax.random.PRNGKey(seed)
    params, state = cnn.resnet_init(key, num_classes=8,
                                    stage_sizes=STAGE_SIZES, width=WIDTH)
    ds = ClassificationDataset(num_classes=8, image_size=IMG, seed=seed)
    opt = optim.sgd(0.01, momentum=0.9, weight_decay=5e-4)

    def loss_fn(p, b, exact_denom=None):
        logits, _ = cnn.resnet_forward(p, state, b["image"],
                                       stage_sizes=STAGE_SIZES, train=True)
        return losses.cross_entropy(
            logits, b["label"], sample_weight=b.get("sample_weight"),
            exact_denom=exact_denom), {}

    return params, state, ds, opt, loss_fn


def _eval_acc(params, state, ds):
    ev = ds.batch(128, 99_999, train=False)
    logits, _ = cnn.resnet_forward(params, state, jnp.asarray(ev["image"]),
                                   stage_sizes=STAGE_SIZES, train=False)
    return float(losses.accuracy(logits, jnp.asarray(ev["label"])))


def run_config(batch: int, use_mbs: bool, steps: int, seed: int = 0):
    params, state, ds, opt, loss_fn = _setup(seed)
    if not use_mbs and batch > MAX_NOMBS_BATCH:
        return None  # "Failed" — exceeds the (simulated) memory limit
    if use_mbs:
        step = jax.jit(M.make_mbs_train_step(
            loss_fn, opt, M.MBSConfig(min(MICRO, batch))))
    else:
        step = jax.jit(M.make_baseline_train_step(loss_fn, opt))
    p, s = params, opt.init(params)
    t0 = None
    for i in range(steps):
        mini = ds.batch(batch, i)
        if use_mbs:
            data = {k: jnp.asarray(v) for k, v in M.split_minibatch(
                mini, min(MICRO, batch)).items()}
        else:
            data = {k: jnp.asarray(v) for k, v in mini.items()}
        p, s, m = step(p, s, data)
        if i == 0:
            jax.block_until_ready(m["loss"])
            t0 = time.perf_counter()  # exclude compile
    jax.block_until_ready(m["loss"])
    dt = (time.perf_counter() - t0) / max(steps - 1, 1)
    return {"acc": _eval_acc(p, state, ds), "s_per_step": dt,
            "loss": float(m["loss"])}


def main(quick: bool = True):
    steps = 12 if quick else 60
    batches = [8, 16, 32, 64] if quick else [8, 16, 32, 64, 128, 256]
    rows = []
    for batch in batches:
        for use_mbs in (False, True):
            tag = "mbs" if use_mbs else "baseline"
            r = run_config(batch, use_mbs, steps)
            if r is None:
                rows.append(emit(f"table4/{tag}_b{batch}", 0.0, "Failed"))
            else:
                rows.append(emit(
                    f"table4/{tag}_b{batch}", r["s_per_step"] * 1e6,
                    f"acc={r['acc']:.3f};loss={r['loss']:.3f}"))
    # time overhead at the largest batch both can run (paper: 0.3-5.1%)
    a = run_config(MAX_NOMBS_BATCH, False, steps)
    b = run_config(MAX_NOMBS_BATCH, True, steps)
    ov = (b["s_per_step"] / a["s_per_step"] - 1) * 100
    rows.append(emit("table4/mbs_time_overhead_pct",
                     b["s_per_step"] * 1e6, f"{ov:.1f}%"))
    return rows


if __name__ == "__main__":
    main(quick=False)
