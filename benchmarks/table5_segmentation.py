"""Paper Table 5: IoU + training time for U-Net at mini-batch sizes beyond
the no-MBS memory limit (segmentation; BCE+Dice, Adam — paper §4.2.4)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import losses, mbs as M
from repro.data import SegmentationDataset
from repro.models import cnn
from repro import optim

from .common import emit

DEPTH = 1
BASE = 4
IMG = 16
MICRO = 4
MAX_NOMBS_BATCH = 8


def _setup(seed=0):
    key = jax.random.PRNGKey(seed)
    params, state = cnn.unet_init(key, base=BASE, depth=DEPTH)
    ds = SegmentationDataset(image_size=IMG, seed=seed)
    opt = optim.adam(1e-2, weight_decay=5e-4)  # paper's U-Net optimizer

    def loss_fn(p, b, exact_denom=None):
        logits, _ = cnn.unet_forward(p, state, b["image"], depth=DEPTH,
                                     train=True)
        return losses.bce_dice_loss(
            logits, b["mask"], sample_weight=b.get("sample_weight"),
            exact_denom=exact_denom), {}

    return params, state, ds, opt, loss_fn


def run_config(batch: int, use_mbs: bool, steps: int):
    params, state, ds, opt, loss_fn = _setup()
    if not use_mbs and batch > MAX_NOMBS_BATCH:
        return None
    if use_mbs:
        step = jax.jit(M.make_mbs_train_step(
            loss_fn, opt, M.MBSConfig(min(MICRO, batch))))
    else:
        step = jax.jit(M.make_baseline_train_step(loss_fn, opt))
    p, s = params, opt.init(params)
    t0 = None
    for i in range(steps):
        mini = ds.batch(batch, i)
        data = ({k: jnp.asarray(v) for k, v in M.split_minibatch(
            mini, min(MICRO, batch)).items()} if use_mbs
            else {k: jnp.asarray(v) for k, v in mini.items()})
        p, s, m = step(p, s, data)
        if i == 0:
            jax.block_until_ready(m["loss"])
            t0 = time.perf_counter()
    jax.block_until_ready(m["loss"])
    dt = (time.perf_counter() - t0) / max(steps - 1, 1)
    ev = ds.batch(32, 99_999)
    logits, _ = cnn.unet_forward(p, state, jnp.asarray(ev["image"]),
                                 depth=DEPTH, train=False)
    iou = float(losses.iou(logits, jnp.asarray(ev["mask"])))
    return {"iou": iou, "s_per_step": dt}


def main(quick: bool = True):
    steps = 10 if quick else 50
    batches = [4, 8, 16, 32] if quick else [4, 8, 16, 32, 64, 128]
    rows = []
    for batch in batches:
        for use_mbs in (False, True):
            tag = "mbs" if use_mbs else "baseline"
            r = run_config(batch, use_mbs, steps)
            if r is None:
                rows.append(emit(f"table5/{tag}_b{batch}", 0.0, "Failed"))
            else:
                rows.append(emit(f"table5/{tag}_b{batch}",
                                 r["s_per_step"] * 1e6,
                                 f"iou={r['iou']:.3f}"))
    return rows


if __name__ == "__main__":
    main(quick=False)
