"""Paper Tables 4/5 "maximum batch size" claim (64×–128× beyond the no-MBS
limit), recomputed analytically for the PAPER'S OWN models under the
paper's 24 GB GPU budget, and for the assigned production LLM configs under
the 16 GB v5e budget — using the core memory model.

derived = max mini-batch w/ MBS ÷ max mini-batch w/o MBS.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro import configs
from repro.core import memory_model

from .common import emit

GB = 1024 ** 3


def _cnn_activation_bytes(image: int, width_factor: float) -> int:
    # crude per-sample activation estimate for the paper's CNNs: feature
    # pyramids sum to ~width_factor * H * W * 4 bytes
    return int(image * image * width_factor * 4)


def main(quick: bool = True):
    rows = []
    # paper's models on the paper's 24 GB GPU (fp32 training)
    paper_models = {
        # (image, act width factor, params)
        "resnet50@224": (224, 64 * 40, 25.6e6),
        "resnet101@224": (224, 64 * 70, 44.5e6),
        "unet@384": (384, 64 * 30, 31.0e6),
    }
    for name, (img, wf, n_params) in paper_models.items():
        fixed = int(n_params) * 4 * 4  # params+grads+mom+workspace, fp32
        act = _cnn_activation_bytes(img, wf)
        budget = 24 * GB
        max_wo = max((budget - fixed) // act, 0)
        # with MBS the mini-batch is unbounded (streamed); the paper bounds
        # it by the dataset size
        dataset = {"resnet50@224": 8189, "resnet101@224": 8189,
                   "unet@384": 5088}[name]
        ratio = dataset / max(max_wo, 1)
        rows.append(emit(f"maxbatch/{name}", 0.0,
                         f"wo_mbs={max_wo};w_mbs={dataset};ratio={ratio:.0f}x"))

    # assigned production configs on v5e (per-chip 16 GB, TP=16, FSDP=16)
    for arch in (configs.ARCHS if not quick else
                 ["qwen2-1.5b", "gemma2-9b", "mixtral-8x22b"]):
        cfg = configs.get(arch)
        max_wo = memory_model.max_minibatch_without_mbs(
            cfg, seq=4096, tp=16, fsdp=16)
        micro = memory_model.suggest_micro_batch_size(
            cfg, seq=4096, mini_batch=1 << 20, tp=16, fsdp=16)
        derived = (f"wo_mbs={max_wo};micro={micro};w_mbs=unbounded"
                   if micro else f"wo_mbs={max_wo};model_does_not_fit")
        rows.append(emit(f"maxbatch/{arch}", 0.0, derived))
    return rows


if __name__ == "__main__":
    main(quick=False)
