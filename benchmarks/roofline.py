"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape), single-pod mesh:
    compute    = HLO_FLOPs_per_device / 197e12      (bf16 peak / chip)
    memory     = HLO_bytes_per_device / 819e9       (HBM bw / chip)
    collective = collective_bytes_per_device / 50e9 (ICI link bw)
HLO FLOPs/bytes are the trip-count-corrected probe values (see
launch/dryrun.py). MODEL_FLOPS = 6·N_active·tokens (train) or
2·N_active·tokens (prefill/decode); the ratio MODEL/HLO exposes
remat + redundancy overhead.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro import configs

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def model_flops_per_device(arch: str, shape_name: str, num_devices: int) -> float:
    cfg = configs.get(arch)
    shape = configs.SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.is_encdec:
            tokens = shape.global_batch * (shape.seq_len
                                           + shape.seq_len // 4)
        return 6.0 * n * tokens / num_devices
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens / num_devices
    tokens = shape.global_batch  # decode: 1 token per sequence
    return 2.0 * n * tokens / num_devices


def _reextrapolate(entry: dict):
    """Recompute corrected cost from the raw probes with the per-period
    slope clamped at >= 0: XLA's 'bytes accessed' is fusion-sensitive, so a
    2-period probe can report FEWER bytes than 1-period (seen on mamba2);
    a negative slope would otherwise drive the total negative."""
    corr = entry["corrected"]
    pr = corr.get("probe_raw")
    if not pr:
        return (corr["flops_per_device"], corr["bytes_per_device"],
                corr["collective_bytes_total"])
    p1, p2 = pr["1"], pr["2"]
    cfg = configs.get(entry["arch"])
    P = cfg.num_periods
    n = entry.get("num_microbatches") or 1
    if entry["kind"] != "train":
        n = 1

    def ext(x1, x2):
        return n * (x1 + (P - 1) * max(x2 - x1, 0.0))

    coll1 = sum(d["bytes"] for d in p1["colls"].values())
    coll2 = sum(d["bytes"] for d in p2["colls"].values())
    return (ext(p1["flops"], p2["flops"]), ext(p1["bytes"], p2["bytes"]),
            ext(coll1, coll2))


def analyze(entry: dict) -> Optional[dict]:
    if entry.get("skipped") or entry.get("failed"):
        return None
    corr = entry.get("corrected")
    if not corr:
        return None
    nd = entry["num_devices"]
    flops, hbytes, cbytes = _reextrapolate(entry)
    t_c = flops / PEAK_FLOPS
    t_m = hbytes / HBM_BW
    t_n = cbytes / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_n, "collective"))[1]
    mf = model_flops_per_device(entry["arch"], entry["shape"], nd)
    return {
        "arch": entry["arch"], "shape": entry["shape"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "dominant": dom,
        "model_flops": mf, "hlo_flops": flops,
        "useful_ratio": mf / flops if flops else 0.0,
        "peak_mem_gb": entry["memory"]["peak_bytes_est"] / 1e9,
        "roofline_frac": (max(t_c, t_m, t_n) and t_c / max(t_c, t_m, t_n)),
    }


HINTS = {
    "compute": "compute-bound: raise MXU utilization (larger tiles, bf16 "
               "throughout, fuse softcap/mask into the attention kernel)",
    "memory": "HBM-bound: fuse/rematerialize to cut bytes (flash-attention "
              "kernel path, fused CE epilogue, wider micro-batch)",
    "collective": "collective-bound: reshard to cut traffic (fewer FSDP "
                  "gathers per micro-batch, expert-parallel all-to-all, "
                  "batch the gradient all-reduce once per mini-batch — MBS)",
}


def load_all(art_dir: str) -> List[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*__single.json"))):
        with open(path) as f:
            e = json.load(f)
        a = analyze(e)
        if a:
            out.append(a)
    return out


def to_markdown(rows: List[dict]) -> str:
    lines = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
             "dominant | model/HLO flops | peak GB/chip |",
             "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['peak_mem_gb']:.1f} |")
    return "\n".join(lines)


def main(art_dir: str = "experiments/dryrun", quick: bool = True):
    from .common import emit
    rows = load_all(art_dir)
    for r in rows:
        dom_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        emit(f"roofline/{r['arch']}/{r['shape']}", dom_s * 1e6,
             f"dom={r['dominant']};useful={r['useful_ratio']:.2f}")
    return rows


if __name__ == "__main__":
    import sys
    rows = main(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun")
    print()
    print(to_markdown(rows))
