"""Paper Table 1: the interaction of batch size × image size on model
quality (ResNet-style classifier; synthetic class-conditioned images stand
in for Flower-102 in this offline container).

Emits max accuracy per (batch, image_size) cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses, mbs as M
from repro.data import ClassificationDataset
from repro.models import cnn
from repro import optim

from .common import emit, time_fn


def train_cell(batch_size: int, image_size: int, *, steps: int = 30,
               micro: int = 8, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    stage_sizes = (1, 1)
    params, state = cnn.resnet_init(key, num_classes=8,
                                    stage_sizes=stage_sizes, width=8)
    ds = ClassificationDataset(num_classes=8, image_size=image_size, seed=seed)
    opt = optim.sgd(0.01, momentum=0.9, weight_decay=5e-4)  # paper §4.2.4

    def loss_fn(p, b, exact_denom=None):
        logits, _ = cnn.resnet_forward(p, state, b["image"],
                                       stage_sizes=stage_sizes, train=True)
        return losses.cross_entropy(
            logits, b["label"], sample_weight=b.get("sample_weight"),
            exact_denom=exact_denom), {"acc": losses.accuracy(logits, b["label"])}

    step = jax.jit(M.make_mbs_train_step(loss_fn, opt,
                                         M.MBSConfig(min(micro, batch_size))))
    p, s = params, opt.init(params)
    best_acc = 0.0
    for i in range(steps):
        split = {k: jnp.asarray(v) for k, v in M.split_minibatch(
            ds.batch(batch_size, i), min(micro, batch_size)).items()}
        p, s, m = step(p, s, split)
        # eval on held-out batch
        if (i + 1) % 10 == 0:
            ev = ds.batch(64, 10_000 + i, train=False)
            logits, _ = cnn.resnet_forward(p, state, jnp.asarray(ev["image"]),
                                           stage_sizes=stage_sizes, train=False)
            best_acc = max(best_acc, float(losses.accuracy(
                logits, jnp.asarray(ev["label"]))))
    return best_acc


def main(quick: bool = True):
    steps = 20 if quick else 80
    rows = []
    for image_size in (8, 16):
        for batch in (2, 16):
            t0 = time_fn(lambda: None) if False else 0.0
            import time as _t
            t0 = _t.perf_counter()
            acc = train_cell(batch, image_size, steps=steps)
            us = (_t.perf_counter() - t0) * 1e6 / steps
            rows.append(emit(f"table1/batch{batch}_img{image_size}",
                             us, f"max_acc={acc:.3f}"))
    return rows


if __name__ == "__main__":
    main(quick=False)
