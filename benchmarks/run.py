"""Benchmark harness: one module per paper table/claim. Prints
``name,us_per_call,derived`` CSV rows.

  table1           batch size × image size interaction      (paper Table 1)
  table4           classification acc/time, w/ vs w/o MBS   (paper Table 4)
  table5           segmentation IoU/time, w/ vs w/o MBS     (paper Table 5)
  maxbatch         max batch beyond the memory limit        (paper §4.3.2)
  mbs_overhead     MBS step-time overhead vs n_micro        (paper §4.3.3)
  kernel           kernel-layer motivation benches
  roofline         three-term roofline per arch × shape     (§Roofline)

Run everything (quick mode):   python -m benchmarks.run
Single module, full size:      python -m benchmarks.table4_classification
"""
from __future__ import annotations

import os
import sys
import traceback


def main() -> None:
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    from . import (kernel_bench, mbs_overhead, roofline,
                   table1_batch_image_size, table4_classification,
                   table5_segmentation, table_maxbatch)

    print("name,us_per_call,derived")
    modules = [
        ("table1", table1_batch_image_size),
        ("table4", table4_classification),
        ("table5", table5_segmentation),
        ("maxbatch", table_maxbatch),
        ("mbs_overhead", mbs_overhead),
        ("kernel", kernel_bench),
    ]
    failures = []
    for name, mod in modules:
        try:
            mod.main(quick=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    # roofline needs the dry-run artifacts; skip quietly if absent
    try:
        if os.path.isdir("experiments/dryrun"):
            roofline.main("experiments/dryrun", quick=True)
    except Exception:
        failures.append("roofline")
        traceback.print_exc()
    if failures:
        print(f"FAILED: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
