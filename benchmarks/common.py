"""Shared benchmark utilities. Every benchmark emits CSV rows
``name,us_per_call,derived`` (derived = the benchmark's headline metric)."""
from __future__ import annotations

import time
from typing import Callable, Iterable

import jax


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call in microseconds (blocks on device results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived) -> str:
    row = f"{name},{us_per_call:.1f},{derived}"
    print(row, flush=True)
    return row
