"""Shared benchmark utilities. Every benchmark emits CSV rows
``name,us_per_call,derived`` (derived = the benchmark's headline metric)."""
from __future__ import annotations

import time
from typing import Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np


def many_leaf_params(num_leaves: int, seed: int = 0):
    """Synthetic many-leaf fp32 param tree with ragged (non-block) sizes —
    the regime where per-leaf update paths pay O(num_leaves) launches."""
    rng = np.random.default_rng(seed)
    sizes = rng.integers(500, 40_000, num_leaves)
    return {f"p{i}": jnp.asarray(rng.normal(size=int(s)), jnp.float32)
            for i, s in enumerate(sizes)}


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call in microseconds (blocks on device results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived) -> str:
    row = f"{name},{us_per_call:.1f},{derived}"
    print(row, flush=True)
    return row
