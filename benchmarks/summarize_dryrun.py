"""Generate EXPERIMENTS.md §Dry-run + §Roofline tables from the artifact
directory.

  PYTHONPATH=src python -m benchmarks.summarize_dryrun experiments/dryrun
writes experiments/dryrun_summary.md and experiments/roofline.md
"""
from __future__ import annotations

import glob
import json
import os
import sys

from . import roofline


def dryrun_table(art_dir: str) -> str:
    lines = ["| arch | shape | mesh | kind | peak GB/chip | compile s | "
             "collectives (GB, once-per-body) |",
             "|---|---|---|---|---|---|---|"]
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            e = json.load(f)
        tag = os.path.basename(path).split("__")[-1].replace(".json", "")
        if e.get("skipped"):
            lines.append(f"| {e['arch']} | {e['shape']} | {tag} | — | — | — | "
                         f"SKIP ({e.get('reason', '')[:40]}…) |")
            continue
        if e.get("failed"):
            lines.append(f"| {e['arch']} | {e['shape']} | {tag} | — | — | — | "
                         f"FAILED |")
            continue
        peak = e["memory"]["peak_bytes_est"] / 1e9
        colls = ", ".join(
            f"{k.replace('collective-', 'c-')}:{v['bytes'] / 1e9:.2f}"
            for k, v in sorted(e.get("collectives_raw_once", {}).items()))
        lines.append(
            f"| {e['arch']} | {e['shape']} | {tag} | {e['kind']} | "
            f"{peak:.1f} | {e.get('compile_s', '?')} | {colls} |")
    return "\n".join(lines)


def main():
    art_dir = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    out_dir = os.path.dirname(art_dir.rstrip("/")) or "."
    with open(os.path.join(out_dir, "dryrun_summary.md"), "w") as f:
        f.write("# Dry-run matrix (generated)\n\n")
        f.write(dryrun_table(art_dir) + "\n")
    rows = roofline.load_all(art_dir)
    with open(os.path.join(out_dir, "roofline.md"), "w") as f:
        f.write("# Roofline (single-pod, per-device, generated)\n\n")
        f.write(roofline.to_markdown(rows) + "\n\n")
        for r in rows:
            f.write(f"* **{r['arch']} × {r['shape']}** — dominant: "
                    f"{r['dominant']}; {roofline.HINTS[r['dominant']]}\n")
    print(f"wrote {out_dir}/dryrun_summary.md and {out_dir}/roofline.md "
          f"({len(rows)} roofline rows)")


if __name__ == "__main__":
    main()
