"""MBS time overhead on the transformer stack (paper §4.3.3): step time at
a fixed global batch as a function of the number of micro-batches. The
paper reports 0.3–5.1% per-epoch overhead; here we measure the compiled
engine step directly, for both the plain-scan and the Pallas fused-
accumulate executors."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import configs, engine, optim
from repro.data import LMDataset
from repro.launch import steps
from repro.models import transformer

from .common import emit


def _time_step(step, params, opt_state, split, iters: int) -> float:
    p2, s2, m = step(params, opt_state, split)  # compile
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for _ in range(iters):
        p2, s2, m = step(params, opt_state, split)
    jax.block_until_ready(m["loss"])
    return (time.perf_counter() - t0) / iters


def main(quick: bool = True):
    cfg = configs.get_reduced("qwen2-1.5b")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    loss_fn = steps.make_loss_fn(cfg, dtype=jnp.float32, remat=False)
    opt = optim.sgd(0.01, momentum=0.9)
    ds = LMDataset(vocab_size=cfg.vocab_size, seq_len=32, seed=0)
    global_batch = 16
    mini = ds.batch(global_batch, 0)
    iters = 3 if quick else 10
    rows = []
    for name in ("compiled", "fused"):
        base_t = None
        for n_micro in (1, 2, 4, 8):
            plan = engine.plan_mbs(global_batch, num_microbatches=n_micro)
            ex = engine.get_executor(name)(loss_fn, opt, plan)
            step = jax.jit(ex.make_train_step())
            split = plan.device_split(mini)
            s = opt.init(params)
            dt = _time_step(step, params, s, split, iters)
            if n_micro == 1:
                base_t = dt
            ov = (dt / base_t - 1) * 100
            rows.append(emit(f"mbs_overhead/{name}/n_micro{n_micro}",
                             dt * 1e6, f"overhead={ov:.1f}%"))
    return rows


if __name__ == "__main__":
    main(quick=False)
