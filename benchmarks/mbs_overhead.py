"""MBS time overhead on the transformer stack (paper §4.3.3): step time at
a fixed global batch as a function of the number of micro-batches. The
paper reports 0.3–5.1% per-epoch overhead; here we measure the compiled
engine step directly, for both the plain-scan and the Pallas fused-
accumulate executors.

``--pipeline`` runs the input-pipeline benchmark instead (paper §3.1 /
Fig. 1): full step-loop time through the synchronous hot loop (inline
``ds.batch`` + blocking per-step metrics readback — what the launcher
used to do) vs. the async ``Pipeline`` + ``Trainer`` path (background
batch synthesis/split, double-buffered device staging, metrics read one
step late). Results land in ``BENCH_pipeline.json`` together with the
pipeline's measured input-wait fraction, so the perf trajectory of the
input path is recorded run over run.

``--update-bench`` benchmarks the update path (paper Fig. 2 steps ❹–❺)
and writes ``BENCH_update.json``: Pallas launches per update (per-leaf
O(num_leaves) vs flat-bucketed O(num_buckets)), step-❺ wall time for the
unfused tree reference vs the fused flat path, and the analytic peak
update-transient bytes each admits into the micro-batch budget.

``--remat-bench`` benchmarks the remat-policy axis (engine Layer 5) and
writes ``BENCH_remat.json``: per policy on the lattice, the measured
compiled-step time (the recompute cost of heavier checkpointing) and the
micro-batch the memory model admits at several HBM budgets — plus the
planner's joint "auto" choice at each budget, showing where escalation
buys batch the cheaper policies cannot.

``--mesh-bench`` benchmarks sharded execution (engine Layer 6) and writes
``BENCH_mesh.json``: at data-parallel 2/4/8 (forced host devices), the
deferred-sync ShardedExecutor step time vs the per-micro-sync baseline,
the all-reduce counts each compiles to on an unrolled scan (1 vs
N_Sμ + 1 — the baseline also pays a scalar loss/valid sync), and the
global batch the mesh-aware planner admits at a fixed per-device budget
as the data axis grows. N_Sμ is recorded per row: the planner's
divisibility rounding can change the schedule as dp grows.

``--tuning-bench`` benchmarks the closed-loop autotuner (engine Layer 7)
and writes ``BENCH_tuning.json``: bucketed grad-accum per-leaf vs legacy
fixed block vs heuristic default vs tuned winner on the 96-leaf config,
plus the admission uplift oracle calibration buys ``plan_mbs`` on reduced
qwen2 at a tight budget (with the XLA-measured peak proving the
calibrated micro still fits).

``--fault-bench`` benchmarks the fault-tolerant runtime (engine Layer 9)
and writes ``BENCH_faults.json``: per injected fault class (OOM at both
degradation rungs, non-finite gradient retry/skip, transient worker,
checkpoint I/O, torn checkpoint write), the supervisor's recovery time,
steps lost/replayed and the plan admission before/after degradation —
plus the steady-state supervision overhead vs the plain Trainer loop.

``--serve-bench`` benchmarks the serving engine (engine Layer 10) and
writes ``BENCH_serve.json``: steady-state decode tokens/s and p50/p99
per-token latency under a synthetic Poisson request stream (warmup/compile
excluded, decode-issued tokens only), TTFT, the admitted-slots-vs-budget
curve from ``plan_serve``, and the XLA-measured decode peak
(``memory_analysis`` on the pool-wide decode step) proving the plan's
admission stays under the budget it was built for.

``--pp-bench`` benchmarks pipeline parallelism (engine Layer 11) and
writes ``BENCH_pp.json``: 1F1B PipelinedExecutor step time on a staged
toy stack at stages 2/4 × dp 1/2 vs the stages=1 baselines
(CompiledScanExecutor / deferred-sync ShardedExecutor), with the
schedule's analytic bubble fraction and tick count per cell — plus the
planner's pipelined admission on reduced qwen2: the local micro-batch
admitted at a fixed per-device budget as the model axis absorbs the
block stack (stage-local activations buy batch the flat layout cannot)."""
from __future__ import annotations

import os
import sys

if ("--mesh-bench" in sys.argv or "--pp-bench" in sys.argv) \
        and "xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    # must land before jax initializes: these benches need >= 8 host devices
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro import configs, engine, optim
from repro.core import memory_model
from repro.data import LMDataset
from repro.engine import exec_core
from repro.kernels import grad_accum_kernels as ga, ref as kref
from repro.launch import steps
from repro.models import transformer

from .common import emit, many_leaf_params, time_fn


def _time_step(step, params, opt_state, split, iters: int) -> float:
    p2, s2, m = step(params, opt_state, split)  # compile
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for _ in range(iters):
        p2, s2, m = step(params, opt_state, split)
    jax.block_until_ready(m["loss"])
    return (time.perf_counter() - t0) / iters


def main(quick: bool = True):
    cfg = configs.get_reduced("qwen2-1.5b")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    loss_fn = steps.make_loss_fn(cfg, dtype=jnp.float32, remat=False)
    opt = optim.sgd(0.01, momentum=0.9)
    ds = LMDataset(vocab_size=cfg.vocab_size, seq_len=32, seed=0)
    global_batch = 16
    mini = ds.batch(global_batch, 0)
    iters = 3 if quick else 10
    rows = []
    for name in ("compiled", "fused", "flat"):
        base_t = None
        for n_micro in (1, 2, 4, 8):
            plan = engine.plan_mbs(global_batch, num_microbatches=n_micro)
            ex = engine.get_executor(name)(loss_fn, opt, plan)
            step = jax.jit(ex.make_train_step())
            split = plan.device_split(mini)
            s = opt.init(params)
            dt = _time_step(step, params, s, split, iters)
            if n_micro == 1:
                base_t = dt
            ov = (dt / base_t - 1) * 100
            rows.append(emit(f"mbs_overhead/{name}/n_micro{n_micro}",
                             dt * 1e6, f"overhead={ov:.1f}%"))
    return rows


def _loop_sync(ex, ds, params, opt_state, mini_batch: int, n_steps: int
               ) -> float:
    """The pre-pipeline launcher hot loop: synchronous batch synthesis,
    host split in the loop, blocking metrics readback every step."""
    p, s = params, opt_state
    t0 = time.perf_counter()
    for i in range(n_steps):
        p, s, m = ex.step(p, s, ds.batch(mini_batch, i))
        float(m["loss"])  # per-step host sync
    jax.block_until_ready(p)
    return (time.perf_counter() - t0) / n_steps


def _loop_overlap(ex, ds, plan, params, opt_state, n_steps: int):
    """Pipeline + Trainer: background synthesis/split, double-buffered
    staging, async metrics readback."""
    device = getattr(ex, "device", None)
    pipeline = engine.Pipeline(ds, plan, prefetch=2, sharding=device)
    trainer = engine.Trainer(ex.step_split, pipeline, log_fn=None)
    t0 = time.perf_counter()
    p, s, _ = trainer.fit(params, opt_state, n_steps)
    jax.block_until_ready(p)
    return (time.perf_counter() - t0) / n_steps, pipeline.stats


def pipeline_main(quick: bool = True, out_path: str = "BENCH_pipeline.json"):
    cfg = configs.get_reduced("qwen2-1.5b")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    loss_fn = steps.make_loss_fn(cfg, dtype=jnp.float32, remat=False)
    opt = optim.sgd(0.01, momentum=0.9)
    ds = LMDataset(vocab_size=cfg.vocab_size, seq_len=64, seed=0)
    mini_batch = 16
    plan = engine.plan_mbs(mini_batch, num_microbatches=4)
    n_steps = 8 if quick else 30

    results = {"benchmark": "pipeline_overlap", "steps": n_steps,
               "mini_batch": mini_batch,
               "num_microbatches": plan.num_micro_batches, "executors": {}}
    for name in ("streaming", "compiled"):
        ex = engine.get_executor(name)(loss_fn, opt, plan)

        def fresh():  # compiled executors donate: never reuse stepped state
            p = jax.tree.map(jnp.copy, params)
            return p, opt.init(p)

        # compile + warm caches outside the timed region
        p, s, m = ex.step(*fresh(), ds.batch(mini_batch, 0))
        jax.block_until_ready(m["loss"])

        sync_s = _loop_sync(ex, ds, *fresh(), mini_batch, n_steps)
        overlap_s, stats = _loop_overlap(ex, ds, plan, *fresh(), n_steps)
        results["executors"][name] = {
            "sync_step_s": sync_s,
            "overlap_step_s": overlap_s,
            "speedup": sync_s / overlap_s,
            "input_wait_fraction": stats.input_wait_fraction,
            "input_wait_s": stats.wait_s,
            "elapsed_s": stats.elapsed_s,
        }
        emit(f"pipeline/{name}/sync", sync_s * 1e6, "per-step, no overlap")
        emit(f"pipeline/{name}/overlap", overlap_s * 1e6,
             f"speedup={sync_s / overlap_s:.2f}x "
             f"input_wait={stats.input_wait_fraction:.3f}")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_path}", flush=True)
    return results


def _bench_update_path(name: str, params, opt, iters: int) -> dict:
    """Launch counts + step-❹/❺ wall times for one param tree."""
    spec = engine.FlatSpec.for_tree(params)
    grads = jax.tree.map(lambda p: jnp.ones_like(p, jnp.float32), params)
    gbufs = spec.flatten(grads, dtype=jnp.float32)
    pbufs = spec.flatten(params)
    state = opt.init(params)
    pbytes = sum(l.size * jnp.dtype(l.dtype).itemsize
                 for l in jax.tree.leaves(params))

    # step ❹: one scaled accumulate over the whole gradient
    t_accum_leaf = time_fn(
        jax.jit(lambda a, g: ga.grad_accum_tree(a, g, 0.125, interpret=True)),
        jax.tree.map(jnp.zeros_like, grads), grads, iters=iters)
    t_accum_bucket = time_fn(
        jax.jit(lambda a, g: ga.grad_accum_buckets(a, g, 0.125,
                                                   interpret=True)),
        spec.zeros(jnp.float32), gbufs, iters=iters)

    # step ❺: unfused tree reference vs the fused flat path. The interpret
    # timing runs the real kernels (dispatch count dominates on this CPU
    # host); the oracle timing is the same one-pass flat arithmetic as a
    # single compiled XLA expression — the compiled-TPU-path proxy.
    t_unfused = time_fn(
        jax.jit(lambda g_, s_, p_: exec_core.apply_update(opt, g_, s_, p_)),
        grads, state, params, iters=iters)
    t_fused = time_fn(
        jax.jit(lambda b_, s_, p_: exec_core.apply_update_flat(
            opt, spec, b_, s_, p_, interpret=True)),
        gbufs, state, params, iters=iters)
    fs = opt.fused
    mbufs = spec.flatten(state["mom"])
    t_fused_oracle = time_fn(
        jax.jit(lambda b_, m_, p_: [kref.fused_sgd_ref(
            p1, g1, m1, 0.01, momentum=fs.momentum,
            weight_decay=fs.weight_decay)
            for p1, g1, m1 in zip(p_, b_, m_)]),
        gbufs, mbufs, pbufs, iters=iters)

    res = {
        "num_leaves": spec.num_leaves,
        "num_buckets": spec.num_buckets,
        "param_bytes": int(pbytes),
        "grad_accum": {
            "per_leaf": {"pallas_launches": spec.num_leaves,
                         "time_s": t_accum_leaf / 1e6},
            "bucketed": {"pallas_launches": spec.num_buckets,
                         "time_s": t_accum_bucket / 1e6},
        },
        "optimizer_update": {
            "unfused": {"pallas_launches": 0,
                        "time_s": t_unfused / 1e6,
                        "transient_bytes": memory_model.update_transient_bytes(
                            int(pbytes))},
            "fused_flat": {"pallas_launches": spec.num_buckets,
                           "time_s_interpret": t_fused / 1e6,
                           "time_s": t_fused_oracle / 1e6,
                           "transient_bytes": 0},
        },
        "step5_speedup_vs_unfused": t_unfused / t_fused_oracle,
        "accum_launch_reduction": spec.num_leaves / spec.num_buckets,
    }
    emit(f"update/{name}/accum_per_leaf", t_accum_leaf,
         f"launches={spec.num_leaves}")
    emit(f"update/{name}/accum_bucketed", t_accum_bucket,
         f"launches={spec.num_buckets}")
    emit(f"update/{name}/step5_unfused", t_unfused,
         f"transient_bytes={res['optimizer_update']['unfused']['transient_bytes']}")
    emit(f"update/{name}/step5_fused_flat", t_fused_oracle,
         f"speedup={res['step5_speedup_vs_unfused']:.2f}x (interpret "
         f"{t_fused:.0f}us)")
    return res


def update_main(quick: bool = True, out_path: str = "BENCH_update.json"):
    """Update-path benchmark (``--update-bench``): per-leaf vs flat-bucketed
    step ❹/❺ on a real (stacked, few-leaf) config and a many-leaf tree,
    plus the memory-model admission delta the fused path buys."""
    iters = 3 if quick else 10
    opt = optim.sgd(0.01, momentum=0.9, weight_decay=5e-4)
    cfg = configs.get_reduced("qwen2-1.5b")
    real = transformer.init_params(cfg, jax.random.PRNGKey(0))
    results = {"benchmark": "update_path", "configs": {}}
    results["configs"]["qwen2-1.5b-reduced"] = _bench_update_path(
        "qwen2-1.5b-reduced", real, opt, iters)
    results["configs"]["synthetic-manyleaf"] = _bench_update_path(
        "synthetic-manyleaf", many_leaf_params(32 if quick else 96),
        opt, iters)

    # what the eliminated transient buys: the largest micro-batch the
    # memory model admits at a budget the unfused update just overflows
    seq, mini = 64, 256
    est = memory_model.estimate(cfg, seq)
    unfused_admit = memory_model.suggest_micro_batch_size(
        cfg, seq, mini, budget_bytes=est.total(8)) or 0
    budget = est.total(2 * max(unfused_admit, 1)) - 1
    results["memory_model"] = {
        "arch": "qwen2-1.5b-reduced", "seq": seq,
        "budget_bytes": int(budget),
        "update_transient_bytes_unfused": est.update_transient_bytes,
        "micro_batch_admitted_unfused": memory_model.suggest_micro_batch_size(
            cfg, seq, mini, budget_bytes=budget) or 0,
        "micro_batch_admitted_fused": memory_model.suggest_micro_batch_size(
            cfg, seq, mini, budget_bytes=budget, fused_update=True) or 0,
    }
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_path}", flush=True)
    return results


def remat_main(quick: bool = True, out_path: str = "BENCH_remat.json"):
    """Remat-policy benchmark (``--remat-bench``): per-policy compiled step
    time on the reduced transformer stack + per-budget admission table."""
    from repro.models import remat as remat_lib

    cfg = configs.get_reduced("qwen2-1.5b")
    seq = 32
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    opt = optim.sgd(0.01, momentum=0.9)
    ds = LMDataset(vocab_size=cfg.vocab_size, seq_len=seq, seed=0)
    mini_batch = 16
    iters = 3 if quick else 10

    results = {"benchmark": "remat_policy", "arch": "qwen2-1.5b-reduced",
               "seq": seq, "mini_batch": mini_batch,
               "policies": {}, "budgets": {}}

    # step time per policy at fixed geometry: the recompute cost axis
    plan = engine.plan_mbs(mini_batch, num_microbatches=4)
    mini = ds.batch(mini_batch, 0)
    split = plan.device_split(mini)
    base_t = None
    for policy in remat_lib.POLICIES:
        loss_fn = steps.make_loss_fn(cfg, dtype=jnp.float32,
                                     remat_policy=policy)
        ex = engine.CompiledScanExecutor(loss_fn, opt, plan)
        step = jax.jit(ex.make_train_step())
        dt = _time_step(step, params, opt.init(params), split, iters)
        if base_t is None:
            base_t = dt
        results["policies"][policy] = {
            "step_time_s": dt,
            "overhead_vs_none": dt / base_t - 1,
            "activation_bytes_per_sample":
                memory_model.activation_bytes_per_sample(
                    cfg, seq, act_bytes=4, remat_policy=policy),
        }
        emit(f"remat/{policy}/step", dt * 1e6,
             f"overhead={100 * (dt / base_t - 1):.1f}%")

    # admission per policy at tight/medium/roomy budgets + the joint choice
    est_none = memory_model.estimate(cfg, seq, act_bytes=4,
                                     remat_policy="none")
    act_none = est_none.activation_bytes_per_sample
    budgets = {
        "tight": est_none.total(0) + 2 * act_none,
        "medium": est_none.total(0) + 6 * act_none,
        "roomy": est_none.total(0) + int(1.5 * mini_batch * act_none),
    }
    for tag, budget in budgets.items():
        admitted = {
            policy: memory_model.suggest_micro_batch_size(
                cfg, seq, mini_batch, budget_bytes=budget, act_bytes=4,
                remat_policy=policy) or 0
            for policy in remat_lib.POLICIES}
        auto_policy, auto_micro = memory_model.suggest_remat_policy_and_micro(
            cfg, seq, mini_batch, budget_bytes=budget, act_bytes=4)
        results["budgets"][tag] = {
            "budget_bytes": int(budget),
            "admitted_micro_batch": admitted,
            "auto": {"policy": auto_policy, "micro_batch": auto_micro or 0},
        }
        emit(f"remat/admission/{tag}", float(auto_micro or 0),
             f"auto={auto_policy} " +
             " ".join(f"{p}:{m}" for p, m in admitted.items()))
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_path}", flush=True)
    return results


def tuning_main(quick: bool = True, out_path: str = "BENCH_tuning.json",
                cache_path: str = None):
    """Closed-loop autotuner benchmark (``--tuning-bench``), the engine
    Layer 7 acceptance numbers, recorded run over run in
    ``BENCH_tuning.json``:

      * **blocks** — bucketed grad-accum on the synthetic-manyleaf 96-leaf
        tree: per-leaf vs the legacy fixed BUCKET_BLOCK=65536 (the 8.1x
        regression) vs the size-aware heuristic default vs the tuner's
        measured winner; the headline ratio is bucketed-default / per-leaf
        (must stay within 1.5x).
      * **calibration** — reduced qwen2 at a tight budget: the analytic
        plan's admitted micro vs the oracle-calibrated plan's, and XLA
        ``memory_analysis()`` of the step at the calibrated micro proving
        it stays under the budget.
    """
    import tempfile

    from repro.engine import autotune
    from repro.kernels.grad_accum import BUCKET_BLOCK

    cache_path = cache_path or os.path.join(tempfile.mkdtemp(), "tuning.json")
    iters = 3 if quick else 10
    results = {"benchmark": "tuning", "blocks": {}, "calibration": {}}

    # -- half 2: kernel block tuning (96-leaf always: the acceptance config)
    params = many_leaf_params(96)
    spec = engine.FlatSpec.for_tree(params)
    grads = jax.tree.map(lambda p: p * 0.5 + 0.1, params)
    acc_tree = jax.tree.map(jnp.zeros_like, params)
    gbufs = spec.flatten(grads, dtype=jnp.float32)

    t_leaf = time_fn(
        jax.jit(lambda a, g: ga.grad_accum_tree(a, g, 0.125, interpret=True)),
        acc_tree, grads, iters=iters)
    t_legacy = time_fn(
        jax.jit(lambda a, g: ga.grad_accum_buckets(
            a, g, 0.125, block=BUCKET_BLOCK, interpret=True)),
        spec.zeros(jnp.float32), gbufs, iters=iters)
    t_default = time_fn(
        jax.jit(lambda a, g: ga.grad_accum_buckets(a, g, 0.125,
                                                   interpret=True)),
        spec.zeros(jnp.float32), gbufs, iters=iters)

    sweep = autotune.tune_for_params(params, iters=iters,
                                     cache_path=cache_path)
    engine.set_cache_path(cache_path)  # block=None now resolves the winners
    try:
        t_tuned = time_fn(
            jax.jit(lambda a, g: ga.grad_accum_buckets(a, g, 0.125,
                                                       interpret=True)),
            spec.zeros(jnp.float32), gbufs, iters=iters)
    finally:
        engine.set_cache_path(None)

    results["blocks"] = {
        "config": "synthetic-manyleaf", "num_leaves": spec.num_leaves,
        "bucket_elems": [int(n) for n in spec.bucket_sizes],
        "per_leaf_s": t_leaf / 1e6,
        "bucketed_legacy_65536_s": t_legacy / 1e6,
        "bucketed_default_s": t_default / 1e6,
        "bucketed_tuned_s": t_tuned / 1e6,
        "default_blocks": [int(b) for b in spec.bucket_blocks(
            "grad_accum", dtype=jnp.float32, interpret=True)],
        "ratio_default_vs_per_leaf": t_default / t_leaf,
        "ratio_legacy_vs_per_leaf": t_legacy / t_leaf,
        "sweep": {k: {kk: vv for kk, vv in r.items() if kk != "key"}
                  for k, r in sweep.items()},
    }
    emit("tuning/blocks/per_leaf", t_leaf, f"launches={spec.num_leaves}")
    emit("tuning/blocks/bucketed_legacy", t_legacy,
         f"block={BUCKET_BLOCK} "
         f"ratio={results['blocks']['ratio_legacy_vs_per_leaf']:.2f}x")
    emit("tuning/blocks/bucketed_default", t_default,
         f"ratio={results['blocks']['ratio_default_vs_per_leaf']:.2f}x "
         "vs per-leaf (acceptance: <= 1.5x)")
    emit("tuning/blocks/bucketed_tuned", t_tuned,
         f"winners={[r['block'] for r in sweep.values()]}")

    # -- half 1: oracle-calibrated admission on reduced qwen2 --------------
    cfg = configs.get_reduced("qwen2-1.5b")
    seq, mini = 128, 64
    budget = 64 * 1024 ** 2  # tight: analytically even micro 1 overflows
    plan_kw = dict(model_cfg=cfg, seq_len=seq, budget_bytes=budget,
                   remat_policy="period", act_bytes=4)
    analytic = engine.plan_mbs(mini, **plan_kw)
    calibrated = engine.plan_mbs(mini, calibrate="force",
                                 tuning_cache=cache_path, **plan_kw)
    measured = autotune.measured_step_bytes(
        cfg, seq, calibrated.micro_batch_size, remat_policy="period")
    results["calibration"] = {
        "arch": "qwen2-1.5b-reduced", "seq": seq, "mini_batch": mini,
        "budget_bytes": budget,
        "analytic_micro": analytic.micro_batch_size,
        "calibrated_micro": calibrated.micro_batch_size,
        "admission_uplift": (calibrated.micro_batch_size
                             / analytic.micro_batch_size),
        "correction": list(calibrated.correction),
        "measured_bytes_at_calibrated_micro": int(measured),
        "under_budget": bool(measured <= budget),
    }
    emit("tuning/calibration/analytic_micro",
         float(analytic.micro_batch_size), f"budget={budget}")
    emit("tuning/calibration/calibrated_micro",
         float(calibrated.micro_batch_size),
         f"measured={measured} under_budget={measured <= budget} "
         f"uplift={results['calibration']['admission_uplift']:.1f}x")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_path}", flush=True)
    return results


def faults_main(quick: bool = True, out_path: str = "BENCH_faults.json"):
    """Fault-tolerance benchmark (``--fault-bench``), the engine Layer 9
    acceptance numbers, recorded run over run in ``BENCH_faults.json``:
    per fault class, the supervisor's recovery time, steps lost/replayed,
    restart count and the plan admission before/after degradation — plus
    the steady-state supervision overhead (the synchronous ``nonfinite``
    readback) vs the plain async ``Trainer`` loop."""
    import tempfile

    from repro.checkpoint import checkpoint as ckpt_lib
    from repro.engine import faults

    cfg = configs.get_reduced("qwen2-1.5b")
    params0 = transformer.init_params(cfg, jax.random.PRNGKey(0))
    loss_fn = steps.make_loss_fn(cfg, dtype=jnp.float32, remat=False)
    opt = optim.sgd(0.01, momentum=0.9)
    ds = LMDataset(vocab_size=cfg.vocab_size, seq_len=32, seed=0)
    mini_batch = 8
    n_steps = 6 if quick else 12
    plan = engine.plan_mbs(mini_batch, num_microbatches=2)

    def fresh():
        p = jax.tree.map(jnp.copy, params0)
        return p, opt.init(p)

    def make_build(guard=True):
        def build(pl):
            ex = engine.get_executor("compiled")(loss_fn, opt, pl,
                                                 guard=guard)
            return ex.step_split, engine.Pipeline(ds, pl, prefetch=0)
        return build

    def admission(pl):
        return {"micro_batch_size": pl.micro_batch_size,
                "num_micro_batches": pl.num_micro_batches,
                "remat_policy": pl.remat_policy}

    def run(specs, *, start_plan=None, sup_kw=None, ckpt: bool = True):
        sup = engine.Supervisor(
            make_build(), start_plan or plan,
            config=engine.SupervisorConfig(**(sup_kw or {})),
            ckpt_dir=tempfile.mkdtemp() if ckpt else None,
            ckpt_every=2, ckpt_keep=3, log_fn=None)
        p, s = fresh()
        crash = None
        t0 = time.perf_counter()
        with faults.inject(faults.FaultPlan(*specs)):
            try:
                sup.fit(p, s, n_steps)
            except faults.InjectedCrash as e:
                crash = str(e)
        return sup, time.perf_counter() - t0, crash

    results = {"benchmark": "faults", "arch": "qwen2-1.5b-reduced",
               "steps": n_steps, "plan": admission(plan), "faults": {}}

    # -- steady-state supervision cost (no faults injected) ----------------
    p, s = fresh()
    trainer = engine.Trainer(*make_build(guard=False)(plan), log_fn=None)
    t0 = time.perf_counter()
    trainer.fit(p, s, n_steps)
    t_plain = (time.perf_counter() - t0) / n_steps
    sup, wall, _ = run((), ckpt=False)
    t_sup = wall / n_steps
    results["supervision_overhead"] = {
        "trainer_step_s": t_plain, "supervised_step_s": t_sup,
        "overhead_frac": t_sup / t_plain - 1}
    emit("faults/overhead/supervised_step", t_sup * 1e6,
         f"vs trainer {t_plain * 1e6:.0f}us "
         f"(+{100 * (t_sup / t_plain - 1):.1f}%: sync nonfinite readback)")

    # -- oom: remat escalation rung (geometry preserved) -------------------
    sup, wall, _ = run([faults.oom_at(2)])
    rec = sup.records[-1]
    results["faults"]["oom_remat"] = {
        "recovery_s": rec.recovery_s, "steps_lost": rec.steps_lost,
        "restarts": sup.restarts, "action": rec.action,
        "admission_before": admission(plan),
        "admission_after": admission(sup.plan)}
    emit("faults/oom_remat/recovery", rec.recovery_s * 1e6,
         f"{rec.action}, {rec.steps_lost} steps replayed")

    # -- oom with remat exhausted: micro-shrink rung -----------------------
    import dataclasses as _dc
    full = _dc.replace(plan, remat_policy="full", auto_policy=False)
    sup, wall, _ = run([faults.oom_at(2)], start_plan=full)
    rec = sup.records[-1]
    results["faults"]["oom_shrink"] = {
        "recovery_s": rec.recovery_s, "steps_lost": rec.steps_lost,
        "restarts": sup.restarts, "action": rec.action,
        "admission_before": admission(full),
        "admission_after": admission(sup.plan)}
    emit("faults/oom_shrink/recovery", rec.recovery_s * 1e6,
         f"{rec.action}, {rec.steps_lost} steps replayed")

    # -- non-finite gradient: bounded clean re-draw retry, then skip -------
    sup, wall, _ = run([faults.nan_at(2)])
    rec = sup.records[-1]
    results["faults"]["nan_retry"] = {
        "recovery_s": rec.recovery_s, "steps_lost": rec.steps_lost,
        "action": rec.action}
    emit("faults/nan_retry/recovery", rec.recovery_s * 1e6, rec.action)
    sup, wall, _ = run([faults.nan_at(2)], sup_kw={"nan_retries": 0})
    rec = sup.records[-1]
    results["faults"]["nan_skip"] = {
        "recovery_s": rec.recovery_s, "steps_lost": rec.steps_lost,
        "action": rec.action}
    emit("faults/nan_skip/recovery", rec.recovery_s * 1e6, rec.action)

    # -- transient worker failure: absorbed by the pipeline's retries ------
    sup, wall, _ = run([faults.worker_at(1)])
    results["faults"]["worker_transient"] = {
        "pipeline_retries": sup.pipeline.stats.retries,
        "steps_lost": 0, "restarts": sup.restarts}
    emit("faults/worker/retries", float(sup.pipeline.stats.retries),
         "absorbed in the producer loop, 0 steps lost")

    # -- checkpoint-I/O failure: bounded save retry ------------------------
    sup, wall, _ = run([faults.ckpt_io_at(2)])
    io_recs = [r for r in sup.records if r.kind == "transient"]
    results["faults"]["ckpt_io"] = {
        "save_retries": len(io_recs), "steps_lost": 0,
        "committed": bool(ckpt_lib.committed_steps(sup.ckpt_dir))}
    emit("faults/ckpt_io/save_retries", float(len(io_recs)),
         "save retried then committed, 0 steps lost")

    # -- torn checkpoint write: crash mid-commit, restore skips it ---------
    sup, wall, crash = run([faults.torn_write_at(2)])
    committed = ckpt_lib.committed_steps(sup.ckpt_dir)
    results["faults"]["torn_write"] = {
        "crashed": crash is not None,
        "committed_steps_on_disk": committed,
        "torn_step_invisible": 2 not in committed}
    emit("faults/torn_write/committed", float(len(committed)),
         f"crash at step-2 commit; committed={committed} (torn invisible)")

    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_path}", flush=True)
    return results


def serve_main(quick: bool = True, out_path: str = "BENCH_serve.json"):
    """Serving benchmark (``--serve-bench``), the engine Layer 10
    acceptance numbers, recorded run over run in ``BENCH_serve.json``."""
    from repro.analysis import serve_checks
    from repro.analysis.hlo_checks import measured_peak_bytes
    from repro.engine import serving

    arch = "qwen2-1.5b"
    cfg = configs.get_reduced(arch)
    max_len = 96
    prefill_micro = 4
    # a budget that admits a bounded slot pool (16 slots exactly) so the
    # admission bound, not the slot cap, shapes the run
    est = memory_model.serve_estimate(cfg, max_len, prefill_len=max_len)
    budget = est.total(16, prefill_micro)
    plan = serving.plan_serve(cfg, budget_bytes=budget, max_len=max_len,
                              prefill_micro=prefill_micro)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    eng = serving.ServingEngine(params, cfg, plan, dtype=jnp.float32)

    n_requests = 24 if quick else 96
    prompt_lens, new_tokens = (8, 16, 32), (4, 8, 16)
    reqs = list(serving.synthetic_traffic(
        n_requests, rate_rps=200.0, prompt_lens=prompt_lens,
        new_tokens=new_tokens, vocab_size=cfg.vocab_size, seed=0))
    eng.run(reqs, warmup_prompt_lens=prompt_lens)
    rep = eng.finished_report(reqs)

    # measured decode peak at the SAME plan geometry, via the analysis layer
    built = serve_checks.build_decode(
        arch, budget_bytes=budget, max_len=max_len,
        max_slots=plan.max_decode_slots, prefill_micro=plan.prefill_micro)
    measured = measured_peak_bytes(built["compiled"])

    # admitted-slots-vs-budget: the serving admission curve
    curve = {}
    for tag, frac in (("half", 0.5), ("planned", 1.0), ("double", 2.0)):
        b = int(budget * frac)
        try:
            p = serving.plan_serve(cfg, budget_bytes=b, max_len=max_len,
                                   prefill_micro=prefill_micro)
            curve[tag] = {"budget_bytes": b, "slots": p.max_decode_slots,
                          "modeled_peak_bytes": p.modeled_peak_bytes()}
        except ValueError as e:
            curve[tag] = {"budget_bytes": b, "slots": 0, "error": str(e)}

    results = {
        "benchmark": "serve", "arch": f"{arch}-reduced",
        "max_len": max_len, "requests": n_requests,
        "prompt_lens": list(prompt_lens), "new_tokens": list(new_tokens),
        "plan": {"budget_bytes": int(budget),
                 "decode_slots": plan.max_decode_slots,
                 "prefill_micro": plan.prefill_micro,
                 "kv_slot_bytes": plan.kv_slot_bytes,
                 "modeled_peak_bytes": plan.modeled_peak_bytes()},
        "report": rep,
        "decode_peak": {"measured_bytes": int(measured),
                        "budget_bytes": int(budget),
                        "under_budget": bool(measured <= budget)},
        "admitted_slots_vs_budget": curve,
    }
    dec = rep["decode"]
    emit("serve/decode/tokens_per_s", dec["tokens_per_s"],
         f"{dec['tokens']} decode-issued tokens over {dec['steps']} steps")
    emit("serve/decode/itl_p50", dec["itl_s"]["p50"] * 1e6,
         f"p99={dec['itl_s']['p99'] * 1e3:.1f}ms")
    emit("serve/prefill/latency_p50", rep["prefill"]["latency_s"]["p50"] * 1e6,
         f"{rep['prefill']['batches']} micro-batches (reported separately "
         "from decode)")
    emit("serve/ttft_p50", rep["ttft_s"]["p50"] * 1e6,
         f"p99={rep['ttft_s']['p99'] * 1e3:.1f}ms")
    emit("serve/slots", float(plan.max_decode_slots),
         f"measured decode peak {measured} <= budget {int(budget)}: "
         f"{measured <= budget}")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_path}", flush=True)
    return results


def _count_allreduce(jitted, *args) -> int:
    import re
    hlo = jitted.lower(*args).compile().as_text()
    return len(re.findall(r"all-reduce(?:-start)?\(", hlo))


def mesh_main(quick: bool = True, out_path: str = "BENCH_mesh.json"):
    """Sharded-execution benchmark (``--mesh-bench``): deferred-sync vs
    per-micro-sync step time + compiled all-reduce counts at data-parallel
    2/4/8, and the mesh-aware planner's admission at a fixed per-device
    budget (the Layer-6 acceptance numbers, recorded run over run)."""
    from repro.launch import mesh as mesh_lib

    cfg = configs.get_reduced("qwen2-1.5b")
    seq = 32
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    loss_fn = steps.make_loss_fn(cfg, dtype=jnp.float32, remat=False)
    opt = optim.sgd(0.01, momentum=0.9)
    ds = LMDataset(vocab_size=cfg.vocab_size, seq_len=seq, seed=0)
    mini_batch, n_micro = 16, 4
    iters = 3 if quick else 10
    # a per-device budget that admits a handful of local samples: the
    # admission axis shows dp * local_micro (the global batch) growing
    est = memory_model.estimate(cfg, seq, act_bytes=4, remat_policy="none")
    budget = est.total(0) + 4 * est.activation_bytes_per_sample

    results = {"benchmark": "mesh_sharded", "arch": "qwen2-1.5b-reduced",
               "seq": seq, "mini_batch": mini_batch,
               "devices": jax.device_count(),
               "data_parallel": {}}
    mini = ds.batch(mini_batch, 0)
    for dp in (2, 4, 8):
        if jax.device_count() < dp:
            results["data_parallel"][str(dp)] = {
                "skipped": f"needs {dp} devices, have {jax.device_count()}"}
            continue
        mesh = mesh_lib.make_host_mesh(data=dp, model=1)
        # unroll the scan so the per-micro baseline's collectives are
        # visible in the HLO text (a rolled loop body appears once)
        plan = engine.plan_mbs(mini_batch, num_microbatches=n_micro,
                               mesh=mesh, unroll=n_micro)
        split = plan.device_split(mini)
        state = opt.init(params)
        # the plan's ACTUAL schedule: dp-divisibility rounding can change
        # the micro size (and so N_Sμ) as the data axis grows
        row = {"local_micro": plan.local_micro,
               "micro_batch_global": plan.micro_batch_size,
               "num_microbatches": plan.num_micro_batches}
        for tag, defer in (("deferred_sync", True), ("per_micro_sync", False)):
            ex = engine.ShardedExecutor(loss_fn, opt, plan, mesh=mesh,
                                        inner="compiled", defer_sync=defer,
                                        donate=False)
            step = jax.jit(ex.make_train_step())
            row[tag] = {
                "step_time_s": _time_step(step, params, state, split, iters),
                "allreduce_ops": _count_allreduce(step, params, state, split),
            }
        row["speedup_deferred"] = (row["per_micro_sync"]["step_time_s"]
                                   / row["deferred_sync"]["step_time_s"])
        # admission at the fixed per-device budget (mesh-aware planner)
        adm = engine.plan_mbs(256, model_cfg=cfg, seq_len=seq,
                              budget_bytes=budget, act_bytes=4,
                              remat_policy="none", mesh=mesh,
                              fsdp_params=False)
        row["admission"] = {"budget_bytes": int(budget),
                            "global_micro_admitted": adm.micro_batch_size,
                            "local_micro": adm.local_micro}
        results["data_parallel"][str(dp)] = row
        emit(f"mesh/dp{dp}/deferred",
             row["deferred_sync"]["step_time_s"] * 1e6,
             f"allreduce={row['deferred_sync']['allreduce_ops']} "
             f"speedup={row['speedup_deferred']:.2f}x vs per-micro "
             f"({row['per_micro_sync']['allreduce_ops']} allreduce)")
        emit(f"mesh/dp{dp}/admission", float(adm.micro_batch_size),
             f"local={adm.local_micro} at fixed per-device budget")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_path}", flush=True)
    return results


def pp_main(quick: bool = True, out_path: str = "BENCH_pp.json"):
    """Pipeline-parallel benchmark (``--pp-bench``), the engine Layer 11
    acceptance numbers, recorded run over run in ``BENCH_pp.json``:

      * **step_times** — the 1F1B PipelinedExecutor on a staged toy stack
        (4 stacked middle layers, the :class:`~repro.engine.StagedLoss`
        contract) at stages 2/4 × dp 1/2, vs the stages=1 baselines at
        the same data parallelism (CompiledScanExecutor at dp=1, the
        deferred-sync ShardedExecutor at dp=2). Each pipelined cell also
        records the closed-form schedule's tick count and bubble fraction
        (S-1)/(M+S-1) — the analytic idle share the measured time should
        track as micro-batches amortize the fill/drain ramps.
      * **admission** — reduced qwen2 at a fixed per-device budget: the
        local micro-batch ``plan_mbs`` admits at stages 1/2 × dp 1/2/4.
        With ``pipeline=True`` the model axis holds stage-LOCAL blocks and
        activations, so the per-device activation term shrinks with the
        stage count and the planner converts the freed bytes into batch.
    """
    from repro.core import losses
    from repro.launch import mesh as mesh_lib

    # staged toy stack: prelude -> NUM_LAYERS stacked tanh blocks ->
    # logits + CE, factored through the StagedLoss contract with a flat
    # single-device twin computing the identical function
    num_layers, d_in, d_h, n_cls = 4, 8, 64, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    params = {
        "w_in": 0.3 * jax.random.normal(ks[0], (d_in, d_h), jnp.float32),
        "mid": 0.3 * jax.random.normal(ks[1], (num_layers, d_h, d_h),
                                       jnp.float32),
        "w_out": 0.3 * jax.random.normal(ks[2], (d_h, n_cls), jnp.float32),
    }
    mini_batch, micro = 16, 4
    batch = {"x": jax.random.normal(ks[3], (mini_batch, d_in), jnp.float32),
             "y": jax.random.randint(ks[4], (mini_batch,), 0, n_cls,
                                     jnp.int32)}

    def flat_loss(p, mb, exact_denom=None):
        x = jnp.tanh(mb["x"] @ p["w_in"])
        for i in range(num_layers):
            x = jnp.tanh(x @ p["mid"][i])
        return losses.cross_entropy(
            x @ p["w_out"], mb["y"], sample_weight=mb.get("sample_weight"),
            exact_denom=exact_denom), {}

    def prelude(shared, mb):
        return jnp.tanh(mb["x"] @ shared["w_in"])

    def stage_fn(stage_p, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, stage_p)[0]

    def finale(shared, x, mb):
        return losses.cross_entropy(
            x @ shared["w_out"], mb["y"],
            sample_weight=mb.get("sample_weight"), exact_denom=1.0), {}

    staged = engine.StagedLoss(num_layers=num_layers, prelude=prelude,
                               stage_fn=stage_fn, finale=finale,
                               stacked_key="mid")
    opt = optim.sgd(0.01, momentum=0.9)
    iters = 3 if quick else 10

    results = {"benchmark": "pipeline_parallel", "devices": jax.device_count(),
               "mini_batch": mini_batch, "micro_batch": micro,
               "toy": {"num_layers": num_layers, "d_hidden": d_h},
               "step_times": {}, "admission": {}}

    base_by_dp = {}
    for stages in (1, 2, 4):
        for dp in (1, 2):
            key = f"s{stages}xd{dp}"
            if jax.device_count() < stages * dp:
                results["step_times"][key] = {
                    "skipped": f"needs {stages * dp} devices, have "
                               f"{jax.device_count()}"}
                continue
            if stages == 1 and dp == 1:
                plan = engine.plan_mbs(mini_batch, micro_batch_size=micro,
                                       normalization="exact", remat=False)
                ex = engine.CompiledScanExecutor(flat_loss, opt, plan)
                split = plan.device_split(batch)
            elif stages == 1:
                mesh = mesh_lib.make_host_mesh(data=dp, model=1)
                plan = engine.plan_mbs(mini_batch, micro_batch_size=micro,
                                       normalization="exact", remat=False,
                                       mesh=mesh)
                ex = engine.ShardedExecutor(flat_loss, opt, plan, mesh=mesh,
                                            inner="compiled",
                                            defer_sync=True, donate=False)
                split = plan.device_split(batch)
            else:
                mesh = mesh_lib.make_host_mesh(data=dp, model=stages)
                plan = engine.plan_mbs(mini_batch, micro_batch_size=micro,
                                       normalization="exact", remat=False,
                                       mesh=mesh, pipeline=True)
                ex = engine.PipelinedExecutor(staged, opt, plan, mesh=mesh,
                                              defer_sync=True)
                split = ex.stage(plan.split(batch))
            step = jax.jit(ex.make_train_step())
            dt = _time_step(step, params, opt.init(params), split, iters)
            row = {"step_time_s": dt,
                   "num_microbatches": plan.num_micro_batches}
            if stages == 1:
                base_by_dp[dp] = dt
            else:
                n_micro = plan.num_micro_batches
                _, _, _, ticks = engine.schedule_1f1b(stages, n_micro)
                row["ticks"] = int(ticks)
                row["bubble_fraction"] = (stages - 1) / (n_micro + stages - 1)
                row["slowdown_vs_flat"] = dt / base_by_dp[dp]
            results["step_times"][key] = row
            extra = (f"bubble={row['bubble_fraction']:.2f} "
                     f"x{row['slowdown_vs_flat']:.2f} vs flat dp{dp}"
                     if stages > 1 else "flat baseline")
            emit(f"pp/{key}/step", dt * 1e6, extra)

    # pipelined admission on the real reduced stack: fixed per-device
    # budget, growing model axis (stages must divide the block stack —
    # the reduced configs have 2 periods, so stages in {1, 2})
    cfg = configs.get_reduced("qwen2-1.5b")
    seq, mini_adm = 64, 256
    est1 = memory_model.estimate(cfg, seq, act_bytes=4, remat_policy="period")
    budget = est1.total(2)
    results["admission"]["arch"] = "qwen2-1.5b-reduced"
    results["admission"]["seq"] = seq
    results["admission"]["budget_bytes"] = int(budget)
    results["admission"]["grid"] = {}
    for stages in (1, 2):
        for dp in (1, 2, 4):
            mesh = mesh_lib.make_host_mesh(data=dp, model=stages)
            plan = engine.plan_mbs(mini_adm, model_cfg=cfg, seq_len=seq,
                                   budget_bytes=budget, act_bytes=4,
                                   remat_policy="period", mesh=mesh,
                                   pipeline=(stages > 1), fsdp_params=False)
            est = memory_model.estimate(cfg, seq, act_bytes=4,
                                        remat_policy="period", mesh=mesh,
                                        pipeline=(stages > 1))
            key = f"s{stages}xd{dp}"
            results["admission"]["grid"][key] = {
                "local_micro": plan.local_micro,
                "global_micro": plan.micro_batch_size,
                "num_microbatches": plan.num_micro_batches,
                "pipeline_stages": getattr(plan, "pipeline_stages", 1),
                "act_bytes_per_sample": int(est.activation_bytes_per_sample),
            }
            emit(f"pp/admission/{key}", float(plan.micro_batch_size),
                 f"local={plan.local_micro} "
                 f"act/sample={est.activation_bytes_per_sample}")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_path}", flush=True)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--pipeline", action="store_true",
                    help="run the input-pipeline overlap benchmark and "
                         "write BENCH_pipeline.json")
    ap.add_argument("--update-bench", action="store_true",
                    help="run the update-path benchmark and write "
                         "BENCH_update.json")
    ap.add_argument("--remat-bench", action="store_true",
                    help="run the remat-policy benchmark and write "
                         "BENCH_remat.json")
    ap.add_argument("--mesh-bench", action="store_true",
                    help="run the sharded-execution benchmark (deferred vs "
                         "per-micro gradient sync at data=2/4/8) and write "
                         "BENCH_mesh.json")
    ap.add_argument("--tuning-bench", action="store_true",
                    help="run the closed-loop autotuner benchmark (tuned "
                         "vs default block times + oracle-calibrated "
                         "admission uplift) and write BENCH_tuning.json")
    ap.add_argument("--tuning-cache", default=None,
                    help="tuning-cache path for --tuning-bench (default: "
                         "a throwaway temp file)")
    ap.add_argument("--fault-bench", action="store_true",
                    help="run the fault-tolerance benchmark (per-fault-class "
                         "recovery time / steps lost / admission "
                         "degradation) and write BENCH_faults.json")
    ap.add_argument("--pp-bench", action="store_true",
                    help="run the pipeline-parallel benchmark (1F1B step "
                         "time at stages 2/4 x dp 1/2 vs the flat "
                         "baselines + pipelined planner admission) and "
                         "write BENCH_pp.json")
    ap.add_argument("--serve-bench", action="store_true",
                    help="run the serving benchmark (decode tok/s, p50/p99 "
                         "per-token latency, admitted-slots-vs-budget, "
                         "measured decode peak) and write BENCH_serve.json")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None)
    a = ap.parse_args()
    if a.pipeline:
        pipeline_main(quick=a.quick, out_path=a.out or "BENCH_pipeline.json")
    elif a.update_bench:
        update_main(quick=a.quick, out_path=a.out or "BENCH_update.json")
    elif a.remat_bench:
        remat_main(quick=a.quick, out_path=a.out or "BENCH_remat.json")
    elif a.mesh_bench:
        mesh_main(quick=a.quick, out_path=a.out or "BENCH_mesh.json")
    elif a.tuning_bench:
        tuning_main(quick=a.quick, out_path=a.out or "BENCH_tuning.json",
                    cache_path=a.tuning_cache)
    elif a.fault_bench:
        faults_main(quick=a.quick, out_path=a.out or "BENCH_faults.json")
    elif a.pp_bench:
        pp_main(quick=a.quick, out_path=a.out or "BENCH_pp.json")
    elif a.serve_bench:
        serve_main(quick=a.quick, out_path=a.out or "BENCH_serve.json")
    else:
        main(quick=a.quick)
