"""MBS time overhead on the transformer stack (paper §4.3.3): step time at
a fixed global batch as a function of the number of micro-batches. The
paper reports 0.3–5.1% per-epoch overhead; here we measure the compiled
engine step directly, for both the plain-scan and the Pallas fused-
accumulate executors.

``--pipeline`` runs the input-pipeline benchmark instead (paper §3.1 /
Fig. 1): full step-loop time through the synchronous hot loop (inline
``ds.batch`` + blocking per-step metrics readback — what the launcher
used to do) vs. the async ``Pipeline`` + ``Trainer`` path (background
batch synthesis/split, double-buffered device staging, metrics read one
step late). Results land in ``BENCH_pipeline.json`` together with the
pipeline's measured input-wait fraction, so the perf trajectory of the
input path is recorded run over run."""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro import configs, engine, optim
from repro.data import LMDataset
from repro.launch import steps
from repro.models import transformer

from .common import emit


def _time_step(step, params, opt_state, split, iters: int) -> float:
    p2, s2, m = step(params, opt_state, split)  # compile
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for _ in range(iters):
        p2, s2, m = step(params, opt_state, split)
    jax.block_until_ready(m["loss"])
    return (time.perf_counter() - t0) / iters


def main(quick: bool = True):
    cfg = configs.get_reduced("qwen2-1.5b")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    loss_fn = steps.make_loss_fn(cfg, dtype=jnp.float32, remat=False)
    opt = optim.sgd(0.01, momentum=0.9)
    ds = LMDataset(vocab_size=cfg.vocab_size, seq_len=32, seed=0)
    global_batch = 16
    mini = ds.batch(global_batch, 0)
    iters = 3 if quick else 10
    rows = []
    for name in ("compiled", "fused"):
        base_t = None
        for n_micro in (1, 2, 4, 8):
            plan = engine.plan_mbs(global_batch, num_microbatches=n_micro)
            ex = engine.get_executor(name)(loss_fn, opt, plan)
            step = jax.jit(ex.make_train_step())
            split = plan.device_split(mini)
            s = opt.init(params)
            dt = _time_step(step, params, s, split, iters)
            if n_micro == 1:
                base_t = dt
            ov = (dt / base_t - 1) * 100
            rows.append(emit(f"mbs_overhead/{name}/n_micro{n_micro}",
                             dt * 1e6, f"overhead={ov:.1f}%"))
    return rows


def _loop_sync(ex, ds, params, opt_state, mini_batch: int, n_steps: int
               ) -> float:
    """The pre-pipeline launcher hot loop: synchronous batch synthesis,
    host split in the loop, blocking metrics readback every step."""
    p, s = params, opt_state
    t0 = time.perf_counter()
    for i in range(n_steps):
        p, s, m = ex.step(p, s, ds.batch(mini_batch, i))
        float(m["loss"])  # per-step host sync
    jax.block_until_ready(p)
    return (time.perf_counter() - t0) / n_steps


def _loop_overlap(ex, ds, plan, params, opt_state, n_steps: int):
    """Pipeline + Trainer: background synthesis/split, double-buffered
    staging, async metrics readback."""
    device = getattr(ex, "device", None)
    pipeline = engine.Pipeline(ds, plan, prefetch=2, sharding=device)
    trainer = engine.Trainer(ex.step_split, pipeline, log_fn=None)
    t0 = time.perf_counter()
    p, s, _ = trainer.fit(params, opt_state, n_steps)
    jax.block_until_ready(p)
    return (time.perf_counter() - t0) / n_steps, pipeline.stats


def pipeline_main(quick: bool = True, out_path: str = "BENCH_pipeline.json"):
    cfg = configs.get_reduced("qwen2-1.5b")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    loss_fn = steps.make_loss_fn(cfg, dtype=jnp.float32, remat=False)
    opt = optim.sgd(0.01, momentum=0.9)
    ds = LMDataset(vocab_size=cfg.vocab_size, seq_len=64, seed=0)
    mini_batch = 16
    plan = engine.plan_mbs(mini_batch, num_microbatches=4)
    n_steps = 8 if quick else 30

    results = {"benchmark": "pipeline_overlap", "steps": n_steps,
               "mini_batch": mini_batch,
               "num_microbatches": plan.num_micro_batches, "executors": {}}
    for name in ("streaming", "compiled"):
        ex = engine.get_executor(name)(loss_fn, opt, plan)
        # compile + warm caches outside the timed region
        p, s, m = ex.step(params, opt.init(params), ds.batch(mini_batch, 0))
        jax.block_until_ready(m["loss"])

        sync_s = _loop_sync(ex, ds, params, opt.init(params),
                            mini_batch, n_steps)
        overlap_s, stats = _loop_overlap(ex, ds, plan, params,
                                         opt.init(params), n_steps)
        results["executors"][name] = {
            "sync_step_s": sync_s,
            "overlap_step_s": overlap_s,
            "speedup": sync_s / overlap_s,
            "input_wait_fraction": stats.input_wait_fraction,
            "input_wait_s": stats.wait_s,
            "elapsed_s": stats.elapsed_s,
        }
        emit(f"pipeline/{name}/sync", sync_s * 1e6, "per-step, no overlap")
        emit(f"pipeline/{name}/overlap", overlap_s * 1e6,
             f"speedup={sync_s / overlap_s:.2f}x "
             f"input_wait={stats.input_wait_fraction:.3f}")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_path}", flush=True)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--pipeline", action="store_true",
                    help="run the input-pipeline overlap benchmark and "
                         "write BENCH_pipeline.json")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_pipeline.json")
    a = ap.parse_args()
    if a.pipeline:
        pipeline_main(quick=a.quick, out_path=a.out)
    else:
        main(quick=a.quick)
