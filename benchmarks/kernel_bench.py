"""Kernel-layer benchmarks (CPU host: the Pallas kernels run in interpret
mode for correctness, so wall-times here compare the pure-JAX reference
paths; the derived column reports the memory-traffic ratio that motivates
each kernel on TPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import optim
from repro.engine import FlatSpec, exec_core
from repro.kernels import ref
from repro.models import attention

from .common import emit, many_leaf_params, time_fn


def main(quick: bool = True):
    rows = []
    key = jax.random.PRNGKey(0)
    B, H, S, hd = 1, 4, 512 if quick else 2048, 64
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    naive = jax.jit(lambda q, k, v: attention.multihead_attention(
        q, k, v, q_pos=pos, k_pos=pos, window=None))
    chunked = jax.jit(lambda q, k, v: attention.chunked_attention(
        q, k, v, q_pos=pos, k_pos=pos, window=None, q_chunk=128))
    t_naive = time_fn(naive, q, k, v)
    t_chunk = time_fn(chunked, q, k, v)
    # bytes of the score tensor avoided by chunking/flash
    avoided = B * H * S * S * 4
    rows.append(emit("kernel/attention_naive", t_naive, f"scores_bytes={avoided}"))
    rows.append(emit("kernel/attention_chunked", t_chunk,
                     f"peak_scores_bytes={avoided * 128 // S}"))

    T, V = (4096, 16384) if quick else (8192, 131072)
    logits = jax.random.normal(key, (T, V))
    labels = jax.random.randint(key, (T,), 0, V)
    ce_ref = jax.jit(lambda l, y: ref.cross_entropy_ref(l, y).mean())
    t_ce = time_fn(ce_ref, logits, labels)
    rows.append(emit("kernel/cross_entropy_ref", t_ce,
                     f"logits_bytes={T * V * 4}"))

    N = 1 << 20
    acc = jnp.zeros((N,))
    g = jax.random.normal(key, (N,))
    accum = jax.jit(lambda a, g: ref.grad_accum_ref(a, g, 0.125))
    t_acc = time_fn(accum, acc, g)
    rows.append(emit("kernel/grad_accum_ref", t_acc, f"bytes={N * 12}"))

    # per-leaf vs bucketed grad-accum (reference arithmetic: one add per
    # leaf vs one add over the contiguous bucket). The derived launch count
    # is the Pallas dispatch knob on TPU: O(num_leaves) -> O(num_buckets).
    params = many_leaf_params(32 if quick else 96)
    spec = FlatSpec.for_tree(params)
    grads = jax.tree.map(lambda p: p * 0.5 + 0.1, params)  # same layout
    acc_tree = jax.tree.map(jnp.zeros_like, params)
    per_leaf = jax.jit(lambda a, g: jax.tree.map(
        lambda a_, g_: ref.grad_accum_ref(a_, g_, 0.125), a, g))
    bucketed = jax.jit(lambda a, g: [ref.grad_accum_ref(a_, g_, 0.125)
                                     for a_, g_ in zip(a, g)])
    t_leafwise = time_fn(per_leaf, acc_tree, grads)
    t_bucket = time_fn(bucketed, spec.zeros(jnp.float32),
                       spec.flatten(grads))
    rows.append(emit("kernel/grad_accum_per_leaf", t_leafwise,
                     f"launches={spec.num_leaves}"))
    rows.append(emit("kernel/grad_accum_bucketed", t_bucket,
                     f"launches={spec.num_buckets}"))

    # block-size regression row: the REAL Pallas bucketed accumulate across
    # launch blocks. The fixed BUCKET_BLOCK=65536 measured 8.1x slower than
    # per-leaf here (interpret mode pays O(N) per grid step for the aliased
    # buffer); the size-aware default (block=None) must not regress again.
    from repro.kernels import grad_accum_kernels as ga
    gbuf = spec.flatten(grads)[0]
    abuf = spec.zeros(jnp.float32)[0]
    n = int(abuf.shape[0])
    for blk in (4096, 16384, 65536, 262144, None):
        if blk is not None and blk >= 2 * n:
            continue
        f = jax.jit(lambda a_, g_, b=blk: ga.grad_accum(
            a_, g_, 0.125, block=b, interpret=True))
        t = time_fn(f, abuf, gbuf)
        tag = "default" if blk is None else str(blk)
        rows.append(emit(f"kernel/grad_accum_block/{tag}", t,
                         f"bucket_elems={n} "
                         f"grid={-(-n // (blk or ga.default_block(n, interpret=True)))}"))

    # fused flat optimizer update vs the unfused tree reference (oracle of
    # the one-pass kernel arithmetic, kernels/fused_update.py), both fed
    # the SAME gradient values: the fused path writes params+state in
    # place — no updates/opt-state transients
    opt = optim.sgd(0.01, momentum=0.9, weight_decay=5e-4)
    fs = opt.fused
    state = opt.init(params)
    unfused = jax.jit(lambda g_, s_, p_: exec_core.apply_update(
        opt, g_, s_, p_))
    fused = jax.jit(lambda g_, m_, p_: [ref.fused_sgd_ref(
        p1, g1, m1, 0.01, momentum=fs.momentum, weight_decay=fs.weight_decay)
        for p1, g1, m1 in zip(p_, g_, m_)])
    pbytes = sum(l.size * 4 for l in jax.tree.leaves(params))
    t_unfused = time_fn(unfused, grads, state, params)
    t_fused = time_fn(fused, spec.flatten(grads),
                      spec.flatten(state["mom"]), spec.flatten(params))
    rows.append(emit("kernel/optimizer_update_unfused", t_unfused,
                     f"transient_bytes={2 * pbytes}"))
    # derived reports the KERNEL path's transient; the timing itself is the
    # donation-less jnp oracle (compiled-TPU proxy; it still allocates its
    # outputs here — see mbs_overhead --update-bench for the kernel timings)
    rows.append(emit("kernel/optimizer_update_fused_flat", t_fused,
                     "kernel_path_transient_bytes=0 (oracle timing)"))
    return rows


if __name__ == "__main__":
    main(quick=False)
