"""Kernel-layer benchmarks (CPU host: the Pallas kernels run in interpret
mode for correctness, so wall-times here compare the pure-JAX reference
paths; the derived column reports the memory-traffic ratio that motivates
each kernel on TPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.models import attention

from .common import emit, time_fn


def main(quick: bool = True):
    rows = []
    key = jax.random.PRNGKey(0)
    B, H, S, hd = 1, 4, 512 if quick else 2048, 64
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    naive = jax.jit(lambda q, k, v: attention.multihead_attention(
        q, k, v, q_pos=pos, k_pos=pos, window=None))
    chunked = jax.jit(lambda q, k, v: attention.chunked_attention(
        q, k, v, q_pos=pos, k_pos=pos, window=None, q_chunk=128))
    t_naive = time_fn(naive, q, k, v)
    t_chunk = time_fn(chunked, q, k, v)
    # bytes of the score tensor avoided by chunking/flash
    avoided = B * H * S * S * 4
    rows.append(emit("kernel/attention_naive", t_naive, f"scores_bytes={avoided}"))
    rows.append(emit("kernel/attention_chunked", t_chunk,
                     f"peak_scores_bytes={avoided * 128 // S}"))

    T, V = (4096, 16384) if quick else (8192, 131072)
    logits = jax.random.normal(key, (T, V))
    labels = jax.random.randint(key, (T,), 0, V)
    ce_ref = jax.jit(lambda l, y: ref.cross_entropy_ref(l, y).mean())
    t_ce = time_fn(ce_ref, logits, labels)
    rows.append(emit("kernel/cross_entropy_ref", t_ce,
                     f"logits_bytes={T * V * 4}"))

    N = 1 << 20
    acc = jnp.zeros((N,))
    g = jax.random.normal(key, (N,))
    accum = jax.jit(lambda a, g: ref.grad_accum_ref(a, g, 0.125))
    t_acc = time_fn(accum, acc, g)
    rows.append(emit("kernel/grad_accum_ref", t_acc, f"bytes={N * 12}"))
    return rows


if __name__ == "__main__":
    main(quick=False)
