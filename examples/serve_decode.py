"""Batched serving: prefill a batch of prompts, then decode tokens against
the ring-buffer KV cache (greedy).

    PYTHONPATH=src python examples/serve_decode.py --arch gemma2-9b \
        --batch 4 --prompt-len 24 --new-tokens 16
(arch ids map to REDUCED variants here so it runs on CPU.)
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b", choices=configs.ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = configs.get_reduced(args.arch)
    if cfg.is_encdec:
        raise SystemExit("use the transformer archs for this example")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.new_tokens

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)

    prefill = jax.jit(lambda p, t: transformer.prefill(
        p, cfg, t, max_len=max_len, dtype=jnp.float32))
    decode = jax.jit(lambda p, tok, c, pos: transformer.decode_step(
        p, cfg, tok, c, pos, dtype=jnp.float32), donate_argnums=(2,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    pos = jnp.full((args.batch,), args.prompt_len, jnp.int32)
    for _ in range(args.new_tokens - 1):
        logits, cache = decode(params, tok, cache, pos)
        tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
        out.append(tok)
        pos = pos + 1
    gen = jnp.concatenate(out, axis=1)
    jax.block_until_ready(gen)
    dt = time.perf_counter() - t0
    tps = args.batch * args.new_tokens / dt
    print(f"arch={cfg.name}  batch={args.batch}  "
          f"prefill {args.prompt_len} + decode {args.new_tokens}")
    print(f"generated shape {gen.shape}  {dt:.2f}s  {tps:.1f} tok/s")
    print("first sequence:", gen[0].tolist())


if __name__ == "__main__":
    main()
