"""Quickstart: train a small LM with Micro-Batch Streaming in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import configs, optim
from repro.core import mbs
from repro.data import LMDataset
from repro.launch import steps
from repro.models import transformer

cfg = configs.get_reduced("qwen2-1.5b")      # any assigned arch id works
params = transformer.init_params(cfg, jax.random.PRNGKey(0))

MINI_BATCH = 32      # what you WANT to train with
MICRO_BATCH = 4      # what fits in memory (paper: the streaming unit)

loss_fn = steps.make_loss_fn(cfg, dtype=jnp.float32, remat=False)
opt = optim.sgd(0.05, momentum=0.9)
train_step = jax.jit(mbs.make_mbs_train_step(
    loss_fn, opt, mbs.MBSConfig(MICRO_BATCH)))

ds = LMDataset(vocab_size=cfg.vocab_size, seq_len=32, seed=0)
opt_state = opt.init(params)
for step in range(20):
    mini = ds.batch(MINI_BATCH, step)                      # host mini-batch
    split = {k: jnp.asarray(v)
             for k, v in mbs.split_minibatch(mini, MICRO_BATCH).items()}
    params, opt_state, metrics = train_step(params, opt_state, split)
    if step % 5 == 0 or step == 19:
        print(f"step {step:3d}  loss {float(metrics['loss']):.4f}  "
              f"|grad| {float(metrics['grad_norm']):.3f}")
print("done — trained a mini-batch 8x larger than the compute unit.")
