"""End-to-end training driver: a ~100M-parameter decoder-only LM trained
with the full production stack — MBS micro-batch streaming, auto
micro-batch sizing from the memory model, LR schedule, checkpointing and
restart.

Default invocation is CPU-sized; pass --full for the ~100M/200-step run.

    PYTHONPATH=src python examples/train_100m.py [--full] [--steps N]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import checkpoint, engine, optim
from repro.core import memory_model
from repro.data import LMDataset
from repro.launch import steps as steps_lib
from repro.models import transformer
from repro.models.config import ModelConfig


def model_100m() -> ModelConfig:
    # ~100M params: 12L, d=768, 12H, ff=2048, vocab 32k (tied)
    return ModelConfig(name="lm-100m", family="dense", num_layers=12,
                       d_model=768, num_heads=12, num_kv_heads=12,
                       head_dim=64, d_ff=2048, vocab_size=32_768,
                       layer_pattern=("global",))


def model_small() -> ModelConfig:
    return ModelConfig(name="lm-4m", family="dense", num_layers=4,
                       d_model=192, num_heads=4, num_kv_heads=4, head_dim=48,
                       d_ff=512, vocab_size=2048, layer_pattern=("global",))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="~100M params")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--mini-batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--executor", choices=sorted(engine.EXECUTORS),
                    default="compiled")
    args = ap.parse_args()

    cfg = model_100m() if args.full else model_small()
    seq = args.seq or (512 if args.full else 64)
    num_steps = args.steps or (200 if args.full else 40)
    print(f"model {cfg.name}: {cfg.param_count() / 1e6:.1f}M params, "
          f"seq {seq}, mini-batch {args.mini_batch}")

    # engine planner: auto micro-batch from the memory model (replaces the
    # paper's experimentally-determined size)
    plan = engine.plan_mbs(args.mini_batch, model_cfg=cfg, seq_len=seq,
                           budget_bytes=memory_model.V5E_HBM_BYTES)
    if not args.full and plan.micro_batch_size > 8:
        plan = engine.plan_mbs(args.mini_batch, micro_batch_size=8)
    print(plan.describe())

    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    loss_fn = steps_lib.make_loss_fn(cfg, dtype=jnp.float32,
                                     remat=bool(args.full))
    opt = optim.sgd(optim.cosine_decay(0.3, num_steps, warmup=10),
                    momentum=0.9, weight_decay=1e-4)
    executor = engine.get_executor(args.executor)(loss_fn, opt, plan)
    opt_state = opt.init(params)

    start = 0
    if checkpoint.latest_step(args.ckpt_dir) is not None:
        start = checkpoint.latest_step(args.ckpt_dir)
        params = checkpoint.restore(args.ckpt_dir, params, start)
        print(f"restored checkpoint at step {start}")

    ds = LMDataset(vocab_size=cfg.vocab_size, seq_len=seq, seed=0)
    t0 = time.perf_counter()
    for i in range(start, num_steps):
        params, opt_state, m = executor.step(params, opt_state,
                                             ds.batch(args.mini_batch, i))
        if i % 10 == 0 or i == num_steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"|g| {float(m['grad_norm']):.3f}  "
                  f"{time.perf_counter() - t0:.1f}s")
        if (i + 1) % args.ckpt_every == 0:
            checkpoint.save(args.ckpt_dir, i + 1, params)
    print("done.")


if __name__ == "__main__":
    main()
