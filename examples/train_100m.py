"""End-to-end training driver: a ~100M-parameter decoder-only LM trained
with the full production stack — MBS micro-batch streaming, auto
micro-batch sizing from the memory model, LR schedule, and the engine's
async input pipeline + resumable Trainer (background batch synthesis,
double-buffered device staging, async metrics readback, periodic
checkpoints of params AND optimizer state).

Default invocation is CPU-sized; pass --full for the ~100M/200-step run.

    PYTHONPATH=src python examples/train_100m.py [--full] [--steps N]
"""
import argparse

import jax
import jax.numpy as jnp

from repro import engine, optim
from repro.core import memory_model
from repro.data import LMDataset
from repro.launch import steps as steps_lib
from repro.models import transformer
from repro.models.config import ModelConfig


def model_100m() -> ModelConfig:
    # ~100M params: 12L, d=768, 12H, ff=2048, vocab 32k (tied)
    return ModelConfig(name="lm-100m", family="dense", num_layers=12,
                       d_model=768, num_heads=12, num_kv_heads=12,
                       head_dim=64, d_ff=2048, vocab_size=32_768,
                       layer_pattern=("global",))


def model_small() -> ModelConfig:
    return ModelConfig(name="lm-4m", family="dense", num_layers=4,
                       d_model=192, num_heads=4, num_kv_heads=4, head_dim=48,
                       d_ff=512, vocab_size=2048, layer_pattern=("global",))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="~100M params")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--mini-batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--executor", choices=sorted(engine.EXECUTORS),
                    default="compiled")
    args = ap.parse_args()

    cfg = model_100m() if args.full else model_small()
    seq = args.seq or (512 if args.full else 64)
    num_steps = args.steps or (200 if args.full else 40)
    print(f"model {cfg.name}: {cfg.param_count() / 1e6:.1f}M params, "
          f"seq {seq}, mini-batch {args.mini_batch}")

    # engine planner: auto micro-batch from the memory model (replaces the
    # paper's experimentally-determined size)
    plan = engine.plan_mbs(args.mini_batch, model_cfg=cfg, seq_len=seq,
                           budget_bytes=memory_model.V5E_HBM_BYTES,
                           remat=bool(args.full))
    if not args.full and plan.micro_batch_size > 8:
        plan = engine.plan_mbs(args.mini_batch, micro_batch_size=8,
                               remat=bool(args.full))
    print(plan.describe())

    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    # the loss compiles under the plan's remat policy (engine Layer 5)
    loss_fn = steps_lib.make_loss_fn(cfg, dtype=jnp.float32,
                                     remat_policy=plan.remat_policy)
    opt = optim.sgd(optim.cosine_decay(0.3, num_steps, warmup=10),
                    momentum=0.9, weight_decay=1e-4)
    executor = engine.get_executor(args.executor)(loss_fn, opt, plan)
    opt_state = opt.init(params)

    ds = LMDataset(vocab_size=cfg.vocab_size, seq_len=seq, seed=0)
    pipeline = engine.Pipeline(ds, plan, prefetch=2)
    trainer = engine.Trainer(executor.step_split, pipeline,
                             ckpt_dir=args.ckpt_dir,
                             ckpt_every=args.ckpt_every, log_every=10)

    start = 0
    restored = trainer.restore(params, opt_state)
    if restored is not None:
        params, opt_state, start = restored
        print(f"restored checkpoint at step {start}")

    trainer.fit(params, opt_state, num_steps, start_step=start)
    stats = pipeline.stats
    print(f"done. input-wait fraction {stats.input_wait_fraction:.3f}")


if __name__ == "__main__":
    main()
