"""Paper Table 5 driver: U-Net semantic segmentation with BCE+Dice loss and
Adam (the paper's exact setup), trained with MBS beyond the no-MBS batch
limit; reports IoU.

    PYTHONPATH=src python examples/train_segmentation.py --batch 32 --steps 40
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import losses, mbs
from repro.data import SegmentationDataset
from repro.models import cnn
from repro import optim


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--micro", type=int, default=4)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--image-size", type=int, default=24)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    params, state = cnn.unet_init(key, base=8, depth=2)
    ds = SegmentationDataset(image_size=args.image_size)
    opt = optim.adam(1e-2, weight_decay=5e-4)  # paper §4.2.4

    def loss_fn(p, b, exact_denom=None):
        logits, _ = cnn.unet_forward(p, state, b["image"], depth=2, train=True)
        return losses.bce_dice_loss(  # paper eq. (20)
            logits, b["mask"], sample_weight=b.get("sample_weight"),
            exact_denom=exact_denom), {}

    micro = min(args.micro, args.batch)
    step = jax.jit(mbs.make_mbs_train_step(loss_fn, opt, mbs.MBSConfig(micro)))
    p, s = params, opt.init(params)
    t0 = time.perf_counter()
    for i in range(args.steps):
        split = {k: jnp.asarray(v) for k, v in mbs.split_minibatch(
            ds.batch(args.batch, i), micro).items()}
        p, s, m = step(p, s, split)
        if i % 10 == 0:
            print(f"step {i:3d}  loss {float(m['loss']):.4f}")
    ev = ds.batch(32, 10 ** 6)
    logits, _ = cnn.unet_forward(p, state, jnp.asarray(ev["image"]), depth=2,
                                 train=False)
    print(f"IoU {float(losses.iou(logits, jnp.asarray(ev['mask']))):.4f}  "
          f"({time.perf_counter() - t0:.1f}s, mini-batch {args.batch}, "
          f"micro {micro})")


if __name__ == "__main__":
    main()
