"""Paper Table 4 driver: image classification with and without MBS across
mini-batch sizes, under a simulated memory cap.

    PYTHONPATH=src python examples/train_classifier.py \
        --batches 8 16 32 64 --steps 30 [--no-mbs]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import losses, mbs
from repro.data import ClassificationDataset
from repro.models import cnn
from repro import optim

STAGE_SIZES = (1, 1)
MEMORY_CAP_BATCH = 16  # simulated no-MBS failure point (paper: 24 GB GPU)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, nargs="+", default=[8, 16, 32, 64])
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--micro", type=int, default=8)
    ap.add_argument("--image-size", type=int, default=16)
    ap.add_argument("--no-mbs", action="store_true")
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    ds = ClassificationDataset(num_classes=8, image_size=args.image_size)
    opt = optim.sgd(0.01, momentum=0.9, weight_decay=5e-4)  # paper §4.2.4

    for batch in args.batches:
        params, state = cnn.resnet_init(key, num_classes=8,
                                        stage_sizes=STAGE_SIZES, width=8)

        def loss_fn(p, b, exact_denom=None):
            logits, _ = cnn.resnet_forward(p, state, b["image"],
                                           stage_sizes=STAGE_SIZES, train=True)
            return losses.cross_entropy(
                logits, b["label"], sample_weight=b.get("sample_weight"),
                exact_denom=exact_denom), {"acc": losses.accuracy(logits, b["label"])}

        use_mbs = not args.no_mbs
        if not use_mbs and batch > MEMORY_CAP_BATCH:
            print(f"batch {batch:4d}  w/o MBS: Failed (exceeds memory cap)")
            continue
        micro = min(args.micro, batch)
        step = jax.jit(mbs.make_mbs_train_step(loss_fn, opt, mbs.MBSConfig(micro))
                       if use_mbs else mbs.make_baseline_train_step(loss_fn, opt))
        p, s = params, opt.init(params)
        t0 = time.perf_counter()
        for i in range(args.steps):
            mini = ds.batch(batch, i)
            data = ({k: jnp.asarray(v)
                     for k, v in mbs.split_minibatch(mini, micro).items()}
                    if use_mbs else {k: jnp.asarray(v) for k, v in mini.items()})
            p, s, m = step(p, s, data)
        jax.block_until_ready(m["loss"])
        ev = ds.batch(128, 10 ** 6, train=False)
        logits, _ = cnn.resnet_forward(p, state, jnp.asarray(ev["image"]),
                                       stage_sizes=STAGE_SIZES, train=False)
        acc = float(losses.accuracy(logits, jnp.asarray(ev["label"])))
        mode = f"w/ MBS (mu={micro})" if use_mbs else "w/o MBS"
        print(f"batch {batch:4d}  {mode:16s}  acc {acc:.3f}  "
              f"loss {float(m['loss']):.3f}  {time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    main()
