"""CLI gate: ``python -m repro.analysis``.

Runs the contract-check suite over a (config × executor × mesh) matrix
and exits with the repo-wide code contract: 0 clean, 1 tool error,
3 contract findings. ``--json``/``--out`` emit the machine-readable
report (the CI job uploads it as an artifact).

Examples::

    python -m repro.analysis --config qwen2_reduced --executor flat --mesh host
    python -m repro.analysis --config qwen2_reduced --config resnet50 \
        --executor flat --executor compiled --mesh host --json --out report.json
    python -m repro.analysis --config qwen2_reduced --mesh 2:2 --force-devices 8
    python -m repro.analysis --lint-only
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback


def _parse(argv):
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static contract checks over traced/compiled train "
                    "steps + repo lint")
    ap.add_argument("--config", action="append", default=None,
                    help="target name (repeatable; default qwen2_reduced). "
                         "Known: see repro.analysis.TARGETS")
    ap.add_argument("--executor", action="append", default=None,
                    help="executor name (repeatable; default flat)")
    ap.add_argument("--mesh", default="single",
                    help="'single' (no mesh), 'host' (all visible devices "
                         "on the data axis — the sharded deferred-sync "
                         "contract; falls back to single on 1 device), or "
                         "'DATA:MODEL' (e.g. '2:2' — a 2-D mesh running "
                         "the pipelined 1F1B contracts JX005/HLO005)")
    ap.add_argument("--remat-policy", default=None,
                    help="override the remat lattice row (default: the "
                         "target's shipped policy)")
    ap.add_argument("--force-devices", type=int, default=0, metavar="N",
                    help="force N XLA host-platform devices (set before "
                         "the first backend call; lets --mesh host "
                         "exercise the collective census on CPU)")
    ap.add_argument("--no-hlo", action="store_true",
                    help="skip the compile-based HLO layer (trace + lint "
                         "only)")
    ap.add_argument("--lint-only", action="store_true",
                    help="run only the AST lint over src/repro")
    ap.add_argument("--serve", action="store_true",
                    help="run the serving decode-step contracts "
                         "(SRV001/SRV002) over the serve matrix instead of "
                         "the training suite; --config picks archs "
                         "(default: repro.analysis.SERVE_TARGETS)")
    ap.add_argument("--memory-tolerance", type=float, default=None,
                    help="HLO003 modeled-vs-measured factor (default 16)")
    ap.add_argument("--json", action="store_true",
                    help="print the machine-readable report to stdout")
    ap.add_argument("--out", default=None,
                    help="also write the JSON report to this path")
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = _parse(argv if argv is not None else sys.argv[1:])
    if args.force_devices:
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{args.force_devices}").strip()

    # import AFTER the device-count env is pinned — jax reads XLA_FLAGS at
    # first backend initialization
    from . import findings as F
    from . import lint as lint_mod
    from . import suite as suite_mod

    reports = []
    tool_error = False
    if args.lint_only:
        try:
            rep = F.Report(context={"mode": "lint-only"})
            rep.extend(lint_mod.lint_repo(), "LINT")
            reports.append(rep)
        except Exception:
            traceback.print_exc()
            return F.EXIT_ERROR
    elif args.serve:
        from . import serve_checks
        for arch in args.config or list(serve_checks.SERVE_TARGETS):
            try:
                kw = {}
                if args.memory_tolerance is not None:
                    kw["tolerance"] = args.memory_tolerance
                reports.append(serve_checks.run_serve_suite(
                    arch, mesh=args.mesh, **kw))
            except Exception:
                traceback.print_exc()
                print(f"ERROR: serve suite crashed on {arch} (see above)",
                      file=sys.stderr)
                tool_error = True
    else:
        kw = {}
        if args.memory_tolerance is not None:
            kw["memory_tolerance"] = args.memory_tolerance
        targets = args.config or ["qwen2_reduced"]
        executors = args.executor or ["flat"]
        lint_once = True
        for t in targets:
            for ex in executors:
                # one combo crashing must not sink the rest of the
                # matrix — record it and keep going (exit 1 at the end)
                try:
                    reports.append(suite_mod.run_suite(
                        t, executor=ex, mesh=args.mesh,
                        remat_policy=args.remat_policy,
                        hlo=not args.no_hlo, lint=lint_once, **kw))
                    lint_once = False  # repo lint is matrix-invariant
                except Exception:
                    traceback.print_exc()
                    print(f"ERROR: suite crashed on {t}/{ex} (see above)",
                          file=sys.stderr)
                    tool_error = True

    payload = {
        "reports": [r.to_dict() for r in reports],
        "total_findings": sum(len(r.findings) for r in reports),
        "ok": not tool_error and all(r.ok for r in reports),
    }
    payload["exit_code"] = (
        F.EXIT_ERROR if tool_error
        else F.EXIT_OK if payload["ok"] else F.EXIT_CONTRACT)
    if args.out:
        with open(args.out, "w", encoding="utf-8") as fh:
            json.dump(payload, fh, indent=2, default=str)
    if args.json:
        print(json.dumps(payload, indent=2, default=str))
    else:
        for r in reports:
            print(r.format())
        print(f"\n{'OK' if payload['ok'] else 'CONTRACT VIOLATIONS'}: "
              f"{payload['total_findings']} finding(s) across "
              f"{len(reports)} run(s)")
    return payload["exit_code"]


if __name__ == "__main__":
    sys.exit(main())
