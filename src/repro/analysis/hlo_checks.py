"""Compiled-HLO contract checks (rules HLO001–HLO004).

Operates on ``jax.jit(step).lower(*abstract).compile()`` artifacts
(``executor.lower_step`` exposes these) — ``memory_analysis()`` for the
byte-level contracts, ``as_text()`` for the op census. This module is
the single source of truth for HLO text queries: ``launch/dryrun.py``
re-exports :func:`collective_bytes` from here, and the mesh/flat test
suites assert their collective/aliasing contracts through this API
instead of hand-parsing HLO strings.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional

import jax

from .findings import Finding, SEVERITY_ERROR, SEVERITY_WARNING

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"^\s*(?:%?[\w.\-]+ = )?(?P<out>\(?[\w\[\],{}\s/#*]*?\)?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute|collective-broadcast)(?:-start|-done)?\(",
    re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def hlo_text(obj) -> str:
    """HLO text from a Compiled / Lowered / already-rendered string."""
    if isinstance(obj, str):
        return obj
    if hasattr(obj, "as_text"):
        return obj.as_text()
    if hasattr(obj, "compile"):  # Lowered
        return obj.compile().as_text()
    raise TypeError(f"cannot extract HLO text from {type(obj)!r}")


def collective_bytes(obj) -> Dict[str, Dict[str, int]]:
    """Per-device output bytes + op count of every collective, by kind.

    ``-start``/``-done`` async halves count once (the ``-done`` arm has no
    shaped output payload in the regex's capture)."""
    out: Dict[str, Dict[str, int]] = {}
    for m in _COLL_RE.finditer(hlo_text(obj)):
        op = m.group("op")
        b = _shape_bytes(m.group("out"))
        d = out.setdefault(op, {"bytes": 0, "count": 0})
        d["bytes"] += b
        d["count"] += 1
    return out


def allreduce_count(obj) -> int:
    """Number of all-reduce launches in the compiled module (async
    ``all-reduce-start`` counted once, ``-done`` ignored)."""
    return len(re.findall(r"all-reduce(?:-start)?\(", hlo_text(obj)))


def tree_bytes(tree) -> int:
    return sum(int(l.size) * jax.numpy.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(tree))


def measured_peak_bytes(compiled) -> int:
    """Per-device peak of a compiled executable — the PR-6 estimator:
    arguments + outputs + temps − aliased (donated buffers counted once)."""
    mem = compiled.memory_analysis()
    return int(mem.argument_size_in_bytes + mem.output_size_in_bytes
               + mem.temp_size_in_bytes
               - getattr(mem, "alias_size_in_bytes", 0))


# ---------------------------------------------------------------------------
# HLO001 — donation aliasing coverage
# ---------------------------------------------------------------------------

def check_aliasing(compiled, state_bytes: int, *,
                   context: str = "") -> List[Finding]:
    """The zero-copy update contract: with params/opt-state donated,
    ``input_output_aliases`` must cover at least the full state footprint
    (every donated state buffer reused in place). ``state_bytes`` is the
    params+opt-state byte total (``tree_bytes``); a donated-but-unaliased
    buffer means XLA is round-tripping the update through a copy."""
    mem = compiled.memory_analysis()
    aliased = int(getattr(mem, "alias_size_in_bytes", 0))
    if aliased < state_bytes:
        return [Finding(
            "HLO001", SEVERITY_ERROR,
            f"input_output_aliases covers {aliased} bytes < state "
            f"footprint {state_bytes} bytes — a donated param/opt/"
            "accumulator buffer is not updated in place",
            location=context,
            details={"alias_bytes": aliased, "state_bytes": state_bytes})]
    return []


# ---------------------------------------------------------------------------
# HLO002 — unexpected collectives at stage boundaries
# ---------------------------------------------------------------------------

def check_unexpected_ops(obj, *, expect_gather: bool = False,
                         context: str = "") -> List[Finding]:
    """A replicated-state (pure-DP) step has no business all-gathering:
    params are already whole on every device, so any ``all-gather`` means
    a sharding boundary is materializing state mid-step. (FSDP launch
    paths DO gather — pass ``expect_gather=True`` there.)"""
    if expect_gather:
        return []
    census = collective_bytes(obj)
    out = []
    for op in ("all-gather",):
        if op in census:
            out.append(Finding(
                "HLO002", SEVERITY_ERROR,
                f"{census[op]['count']} unexpected {op} op(s) "
                f"({census[op]['bytes']} bytes) in a replicated-state "
                "step", location=context,
                details={"op": op, **census[op]}))
    return out


# ---------------------------------------------------------------------------
# HLO003 — memory model cross-check
# ---------------------------------------------------------------------------

def check_memory_model(compiled, modeled_bytes: Optional[int], *,
                       tolerance: float = 16.0,
                       slack_bytes: int = 1 << 30,
                       context: str = "") -> List[Finding]:
    """Tripwire for catastrophic model/compiler divergence: the analytic
    ``core/memory_model`` estimate and the compiled peak must agree within
    ``tolerance``× (plus ``slack_bytes`` absolute headroom for tiny
    configs). The default is deliberately loose — the uncalibrated model
    is conservative by design (PR-6 measured ~4–5× on reduced configs);
    this rule exists to catch order-of-magnitude breaks (a dropped remat,
    a duplicated accumulator), not to re-litigate calibration."""
    if modeled_bytes is None:
        return []
    measured = measured_peak_bytes(compiled)
    hi = modeled_bytes * tolerance + slack_bytes
    lo = max(0.0, modeled_bytes / tolerance - slack_bytes)
    if not (lo <= measured <= hi):
        return [Finding(
            "HLO003", SEVERITY_ERROR,
            f"compiled peak {measured} bytes vs modeled {modeled_bytes} "
            f"bytes — outside {tolerance}x tolerance "
            f"(allowed [{int(lo)}, {int(hi)}])",
            location=context,
            details={"measured_bytes": measured,
                     "modeled_bytes": modeled_bytes,
                     "tolerance": tolerance, "slack_bytes": slack_bytes})]
    return []


# ---------------------------------------------------------------------------
# HLO004 — compiled gradient-sync schedule
# ---------------------------------------------------------------------------

#: all-reduce payloads at or under this byte count are treated as
#: scalar/metric traffic (the grad-norm scalar, XLA-introduced scalar
#: syncs from sharding propagation), not gradient syncs
_SCALAR_ALLREDUCE_BYTES = 64


def _op_payloads(obj, op: str) -> List[int]:
    """Per-instruction output payload bytes for one collective kind
    (async ``-done`` arms skipped — the ``-start`` carries the shape)."""
    out = []
    for m in _COLL_RE.finditer(hlo_text(obj)):
        if m.group("op") != op or m.group(0).rstrip("(").endswith("-done"):
            continue
        out.append(_shape_bytes(m.group("out")))
    return out


def check_pipeline_hlo(obj, *, expect: str, n_micro: int,
                       max_ppermutes: int,
                       context: str = "") -> List[Finding]:
    """HLO005 — the compiled pipelined (1F1B) schedule.

    All-reduces are classified by payload: non-scalar ones are gradient
    syncs (deferred contract: exactly TWO — the stage-local flat data
    psum and the shared (data, model) psum; per-micro baseline: >=
    N_Smu), scalar ones are metric traffic (the grad-norm scalar plus
    whatever scalar syncs XLA's sharding propagation introduces) and
    exempt. The collective-permute count is bounded, not pinned: XLA
    legitimately merges adjacent permutes of the same source/target
    pairs, so the compiled count must be >= 1 and <= the jaxpr
    schedule census (``max_ppermutes``) — more than the schedule means
    boundary traffic the executor never issued."""
    if expect not in ("deferred", "per-micro"):
        raise ValueError(f"bad expect {expect!r}")
    ars = _op_payloads(obj, "all-reduce")
    big = [b for b in ars if b > _SCALAR_ALLREDUCE_BYTES]
    perms = len(_op_payloads(obj, "collective-permute"))
    details = {"nonscalar_allreduces": len(big),
               "scalar_allreduces": len(ars) - len(big),
               "collective_permutes": perms,
               "max_ppermutes": max_ppermutes,
               "n_micro": n_micro, "expect": expect}
    out: List[Finding] = []
    if expect == "deferred" and len(big) != 2:
        out.append(Finding(
            "HLO005", SEVERITY_ERROR,
            f"deferred pipelined step compiled to {len(big)} non-scalar "
            "all-reduce(s), contract is exactly 2 (stage-local data psum "
            "+ shared data-model psum)", location=context, details=details))
    if expect == "per-micro" and len(big) < n_micro:
        out.append(Finding(
            "HLO005", SEVERITY_ERROR,
            f"per-micro pipelined baseline compiled to {len(big)} "
            f"non-scalar all-reduce(s), expected >= {n_micro}",
            location=context, details=details))
    if not (1 <= perms <= max_ppermutes):
        out.append(Finding(
            "HLO005", SEVERITY_ERROR,
            f"{perms} collective-permute(s) in the compiled pipelined "
            f"step, expected between 1 and the jaxpr schedule census "
            f"{max_ppermutes}", location=context, details=details))
    return out


def check_gradient_sync(obj, *, expect: str, n_micro: int,
                        context: str = "") -> List[Finding]:
    """The PR-5 contract at the HLO level: a deferred-sync sharded step
    compiles to exactly ONE all-reduce per mini-batch; the per-micro
    baseline to >= N_Sμ; a mesh-free step to zero. NOTE the compiled
    module keeps rolled loops rolled — pass an UNROLLED plan (or trust
    the jaxpr-level JX004, which multiplies scan trip counts) when the
    micro loop is a scan."""
    if expect not in ("none", "deferred", "per-micro"):
        raise ValueError(f"bad expect {expect!r}")
    count = allreduce_count(obj)
    details = {"all_reduce_count": count, "n_micro": n_micro,
               "expect": expect}
    if expect == "none" and count != 0:
        return [Finding("HLO004", SEVERITY_ERROR,
                        f"{count} all-reduce op(s) in a mesh-free step",
                        location=context, details=details)]
    if expect == "deferred" and count != 1:
        return [Finding(
            "HLO004", SEVERITY_ERROR,
            f"deferred-sync step compiled to {count} all-reduce ops, "
            "contract is exactly 1 per mini-batch",
            location=context, details=details)]
    if expect == "per-micro" and count < n_micro:
        return [Finding(
            "HLO004", SEVERITY_ERROR,
            f"per-micro baseline compiled to {count} all-reduce ops, "
            f"expected >= {n_micro}",
            location=context, details=details)]
    return []
