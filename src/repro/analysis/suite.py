"""The full contract-check suite over real (config × executor × mesh ×
remat-policy) combinations — what ``python -m repro.analysis`` and the CI
``static-analysis`` job run, and what ``launch/dryrun.py --check`` calls
into for its own compiled artifacts.

Targets are REAL shipped configurations at analysis scale (reduced model
configs, short sequences) — the point is to trace/compile the actual
``steps.build_train_step`` machinery, not toy stand-ins. Everything is
allocation-free except the CNN target's tiny concrete init (BN state
must be closed over concretely) and the XLA compiles the HLO layer
needs.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from .. import configs, engine, optim
from ..core import memory_model
from ..launch import mesh as mesh_lib, steps
from .findings import Report
from . import hlo_checks, jaxpr_checks, lint as lint_mod

#: analysis-scale geometry: small enough to trace/compile in seconds,
#: micro size divisible by the forced-8-device test mesh
ANALYSIS_SEQ = 32
ANALYSIS_BATCH = 32
ANALYSIS_MICROS = 4

#: default HLO003 tolerance: the UNCALIBRATED analytic model runs ~4-5x
#: conservative on reduced configs (PR-6 measured a=4.67), so the
#: tripwire is an order-of-magnitude gate, not a calibration test
MEMORY_TOLERANCE = 16.0


def _default_interpret(executor: str) -> Optional[bool]:
    # Pallas-backed executors must interpret off-TPU (same rule as the
    # test harness EXECUTOR_KW)
    if executor in ("fused", "flat") and jax.default_backend() != "tpu":
        return True
    return None


class Target:
    """One analyzable training configuration."""

    def __init__(self, name: str, build: Callable, *, has_memory_model: bool,
                 remat_capable: bool, stageable: bool = False):
        self.name = name
        self.build = build  # (executor, mesh, remat_policy) -> artifacts
        self.has_memory_model = has_memory_model
        self.remat_capable = remat_capable
        #: factors into prelude/stage/finale for the pipelined (Layer 11)
        #: path — dense decoder-only stacks only
        self.stageable = stageable


def _build_transformer(arch: str, executor: str, mesh, remat_policy):
    cfg = configs.get_reduced(arch)
    optimizer = steps.make_optimizer(cfg)
    pipelined = (mesh is not None
                 and mesh_lib.axis_size(mesh, mesh_lib.MODEL_AXIS) > 1)
    plan = engine.plan_mbs(
        ANALYSIS_BATCH, num_microbatches=ANALYSIS_MICROS, model_cfg=cfg,
        seq_len=ANALYSIS_SEQ, remat=remat_policy != "none",
        remat_policy=remat_policy, mesh=mesh, pipeline=pipelined,
        **optim.memory_model_kw(optimizer, fused=executor == "flat"))
    loss_fn = steps.make_loss_fn(cfg, jnp.bfloat16,
                                 remat_policy=plan.remat_policy)
    params = steps.abstract_params(cfg)
    opt_state = steps.abstract_opt_state(optimizer, params)
    batch = steps.abstract_train_batch(cfg, ANALYSIS_SEQ, plan)
    modeled = memory_model.estimate(
        cfg, ANALYSIS_SEQ, remat_policy=plan.remat_policy,
        optimizer=optimizer.name if hasattr(optimizer, "name") else "sgd",
        fused_update=executor == "flat", mesh=mesh, pipeline=pipelined,
    ).total(plan.local_micro if mesh is not None
            else plan.micro_batch_size)
    built = dict(loss_fn=loss_fn, optimizer=optimizer, plan=plan,
                 args=(params, opt_state, batch), modeled_bytes=modeled)
    if pipelined:
        # Layer-11 path: the staged factorization of the same loss
        built["staged"] = steps.make_staged_loss(
            cfg, jnp.bfloat16, remat_policy=plan.remat_policy)
    return built


def _build_resnet(executor: str, mesh, remat_policy):
    from ..configs import resnet50
    from ..models import cnn

    del remat_policy  # the CNN loss has no checkpoint lattice: always none
    rcfg = resnet50.reduced()
    params, state = cnn.resnet_init(
        jax.random.PRNGKey(0), num_classes=rcfg.num_classes,
        stage_sizes=rcfg.stage_sizes, width=rcfg.width)
    optimizer = optim.sgd(1e-2, momentum=0.9, weight_decay=5e-4)
    plan = engine.plan_mbs(ANALYSIS_BATCH, num_microbatches=ANALYSIS_MICROS,
                           remat=False, mesh=mesh)

    def loss_fn(p, b, exact_denom=None):
        from ..core import losses
        # frozen BN (paper §4.2.2 eval-mode semantics): state closed over
        logits, _ = cnn.resnet_forward(p, state, b["image"],
                                       stage_sizes=rcfg.stage_sizes,
                                       train=False)
        return losses.cross_entropy(
            logits, b["label"], sample_weight=b.get("sample_weight"),
            exact_denom=exact_denom), {}

    n, m = plan.num_micro_batches, plan.micro_batch_size
    sds = jax.ShapeDtypeStruct
    batch = {
        "image": sds((n, m, rcfg.image_size, rcfg.image_size,
                      rcfg.in_channels), jnp.float32),
        "label": sds((n, m), jnp.int32),
        "sample_weight": sds((n, m), jnp.float32),
    }
    opt_state = steps.abstract_opt_state(optimizer, params)
    return dict(loss_fn=loss_fn, optimizer=optimizer, plan=plan,
                args=(params, opt_state, batch), modeled_bytes=None)


TARGETS: Dict[str, Target] = {
    "qwen2_reduced": Target(
        "qwen2_reduced",
        functools.partial(_build_transformer, "qwen2-1.5b"),
        has_memory_model=True, remat_capable=True, stageable=True),
    "mamba2_reduced": Target(
        "mamba2_reduced",
        functools.partial(_build_transformer, "mamba2-780m"),
        has_memory_model=True, remat_capable=True, stageable=True),
    "resnet50": Target(
        "resnet50", _build_resnet,
        has_memory_model=False, remat_capable=False),
}


def resolve_mesh(mesh: Any):
    """``None``/``"single"`` -> no mesh; ``"host"`` -> all local devices
    on the data axis (or no mesh when only one device is visible); a
    ``"DATA:MODEL"`` spec -> 2-D host mesh (the pipelined path); a Mesh
    object passes through."""
    if mesh is None or mesh == "single":
        return None
    if mesh == "host":
        n = jax.device_count()
        return mesh_lib.make_host_mesh(data=n) if n >= 2 else None
    if isinstance(mesh, str):
        data, model = mesh_lib.parse_mesh_spec(mesh)
        return mesh_lib.make_host_mesh(data=data, model=model)
    return mesh


def make_executor(target: Dict[str, Any], executor: str, mesh, *,
                  defer_sync: bool = True):
    """The executor instance for one built target (sharded when a mesh is
    given) — the object whose ``trace_step``/``lower_step`` artifacts the
    checks consume."""
    interpret = _default_interpret(executor)
    if target.get("staged") is not None:
        return engine.PipelinedExecutor(
            target["staged"], target["optimizer"], target["plan"],
            mesh=mesh, defer_sync=defer_sync)
    if mesh is not None:
        from ..engine.sharded import ShardedExecutor
        return ShardedExecutor(target["loss_fn"], target["optimizer"],
                               target["plan"], mesh=mesh, inner=executor,
                               defer_sync=defer_sync, interpret=interpret)
    kw = {} if executor == "streaming" else {"interpret": interpret}
    return engine.get_executor(executor)(
        target["loss_fn"], target["optimizer"], target["plan"], **kw)


def run_suite(target: str = "qwen2_reduced", *, executor: str = "flat",
              mesh: Any = None, remat_policy: Optional[str] = None,
              hlo: bool = True, lint: bool = True,
              memory_tolerance: float = MEMORY_TOLERANCE) -> Report:
    """Trace + (optionally) compile one configuration and run every
    applicable contract check. Returns the merged :class:`Report`."""
    spec = TARGETS[target]
    mesh = resolve_mesh(mesh)
    stages = mesh_lib.axis_size(mesh, mesh_lib.MODEL_AXIS) if mesh else 1
    if stages > 1 and not spec.stageable:
        # non-stageable family (CNN): the pipelined mesh simply does not
        # apply — report the skip instead of crashing mid-build, so one
        # CI matrix invocation can sweep every (target x mesh) cell
        return Report(context={
            "target": target, "executor": executor,
            "mesh": f"dp={mesh_lib.data_parallel_size(mesh)},pp={stages}",
            "skipped": "target does not factor into pipeline stages "
                       "(dense decoder-only stacks only)"})
    if remat_policy is None:
        remat_policy = "period" if spec.remat_capable else "none"
    built = spec.build(executor, mesh, remat_policy)
    plan = built["plan"]
    params = built["args"][0]
    pipelined = built.get("staged") is not None
    ex = make_executor(built, executor, mesh)

    report = Report(context={
        "target": target, "executor": executor,
        "mesh": (f"dp={mesh_lib.data_parallel_size(mesh)}"
                 + (f",pp={stages}" if stages > 1 else "")) if mesh
                else "single",
        "remat_policy": plan.remat_policy,
        "num_micro_batches": int(plan.num_micro_batches),
    })

    expect_sync = "deferred" if mesh is not None else "none"
    jaxpr = ex.trace_step(*built["args"])
    if pipelined:
        report.merge(jaxpr_checks.check_pipelined_step(
            jaxpr, plan, stages=stages, expect_sync=expect_sync))
    else:
        report.merge(jaxpr_checks.check_train_step(
            jaxpr, plan, params, expect_sync=expect_sync))

    can_lower = hlo and hasattr(ex, "lower_step") and executor != "streaming"
    if can_lower:
        compiled = ex.lower_step(*built["args"], donate=True).compile()
        ctx = f"{target}/{executor}"
        if pipelined:
            # memory_analysis() reports PER-DEVICE aliasing and the
            # pipelined steady state keeps block leaves model-sharded
            # (state_shardings) — the floor is the per-device shard
            state_bytes = ex.donated_state_bytes(built["args"][0],
                                                 built["args"][1])
        else:
            state_bytes = (hlo_checks.tree_bytes(built["args"][0])
                           + hlo_checks.tree_bytes(built["args"][1]))
        report.extend(hlo_checks.check_aliasing(
            compiled, state_bytes, context=ctx), "HLO001")
        report.extend(hlo_checks.check_unexpected_ops(
            compiled, context=ctx), "HLO002")
        report.extend(hlo_checks.check_memory_model(
            compiled, built["modeled_bytes"], tolerance=memory_tolerance,
            context=ctx), "HLO003")
        if pipelined:
            from ..engine.pipelined import schedule_1f1b
            fwd_tab, bwd_tab, _, _ = schedule_1f1b(
                stages, int(plan.num_micro_batches))
            max_pp = int((fwd_tab >= 0).any(axis=1).sum()
                         + (bwd_tab >= 0).any(axis=1).sum())
            report.extend(hlo_checks.check_pipeline_hlo(
                compiled, expect=expect_sync,
                n_micro=int(plan.num_micro_batches),
                max_ppermutes=max_pp, context=ctx), "HLO005")
        else:
            report.extend(hlo_checks.check_gradient_sync(
                compiled, expect=expect_sync,
                n_micro=int(plan.num_micro_batches), context=ctx), "HLO004")

    if lint:
        report.extend(lint_mod.lint_repo(), "LINT")
    return report


def check_bundle(bundle, *, compiled=None, modeled_bytes: Optional[int] = None,
                 devices: int = 1, lint: bool = False,
                 memory_tolerance: float = MEMORY_TOLERANCE) -> Report:
    """Contract checks over a ``launch/steps.StepBundle`` — the
    ``dryrun --check`` entry. The traced fn is pre-GSPMD (collectives are
    inserted at compile), so the jaxpr census expects none; the HLO
    layer checks aliasing/memory on the caller's own compiled artifact
    (which may legitimately contain FSDP collectives — not censused
    here). ``devices`` is the compile's mesh size: ``memory_analysis()``
    reports PER-DEVICE aliasing, so the donated-state floor is the fully
    sharded (FSDP) per-device shard of the global state footprint."""
    report = Report(context={"kind": bundle.kind,
                             "executor": bundle.executor or "?"})
    if bundle.kind == "train" and bundle.plan is not None:
        jaxpr = jax.make_jaxpr(bundle.fn)(*bundle.arg_shapes)
        report.merge(jaxpr_checks.check_train_step(
            jaxpr, bundle.plan, bundle.arg_shapes[0], expect_sync="none"))
        if compiled is not None:
            state_bytes = (hlo_checks.tree_bytes(bundle.arg_shapes[0])
                           + hlo_checks.tree_bytes(bundle.arg_shapes[1]))
            report.extend(hlo_checks.check_aliasing(
                compiled, state_bytes // max(devices, 1),
                context=bundle.kind), "HLO001")
            report.extend(hlo_checks.check_memory_model(
                compiled, modeled_bytes, tolerance=memory_tolerance,
                context=bundle.kind), "HLO003")
    if lint:
        report.extend(lint_mod.lint_repo(), "LINT")
    return report
