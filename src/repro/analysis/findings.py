"""Structured findings + the rule registry + the process exit-code contract.

Every analyzer layer (jaxpr / HLO / AST lint) reports violations as
:class:`Finding`s — severity, stable rule id, human location, and a
machine-readable ``details`` dict — collected into a :class:`Report`
that renders as text or JSON and maps onto the repo-wide exit-code
contract (shared with ``launch/dryrun.py``):

  * ``EXIT_OK`` (0)       — clean run, no findings.
  * ``EXIT_ERROR`` (1)    — the tool itself failed (bad config, crash).
  * ``EXIT_BUDGET`` (2)   — dryrun memory-budget overrun (PR-6 gate).
  * ``EXIT_CONTRACT`` (3) — one or more contract findings.

(Argparse usage errors also exit 2 by Python convention — scripts that
need to distinguish should check stderr.)

DESIGN.md §Static contracts enumerates every rule; intentional
violations are waived inline with ``# repro: noqa(RULE)`` (AST rules
only — jaxpr/HLO contracts have no legitimate waivers, fix the plan).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Iterable, List, Optional

EXIT_OK = 0
EXIT_ERROR = 1
EXIT_BUDGET = 2
EXIT_CONTRACT = 3

SEVERITY_ERROR = "error"
SEVERITY_WARNING = "warning"

#: rule id -> (layer, one-line contract) — the single source of truth the
#: CLI/docs enumerate. Adding a rule without registering it here raises.
RULES: Dict[str, Dict[str, str]] = {
    "JX001": {"layer": "jaxpr",
              "contract": "micro-gradients accumulate in the plan's "
                          "accum_dtype (fp32 by default)"},
    "JX002": {"layer": "jaxpr",
              "contract": "the remat policy the planner chose is applied "
                          "to the traced step (remat sub-jaxpr census "
                          "matches the MBSPlan lattice row)"},
    "JX003": {"layer": "jaxpr",
              "contract": "no io_callback/debug_callback/host-sync "
                          "primitives inside the jitted train step"},
    "JX004": {"layer": "jaxpr",
              "contract": "collective census: exactly one gradient psum "
                          "per mini-batch when defer_sync, >= N_Smu "
                          "otherwise, zero without a mesh"},
    "JX005": {"layer": "jaxpr",
              "contract": "pipelined (1F1B) collective census: "
                          "stage-boundary ppermute count matches the "
                          "closed-form schedule exactly; deferred sync "
                          "keeps ONE data-axis gradient psum per "
                          "mini-batch plus ONE (data, model) psum for "
                          "shared grads/loss/metrics; the per-micro "
                          "baseline issues >= N_Smu data-axis psums"},
    "HLO001": {"layer": "hlo",
               "contract": "input_output_aliases covers every donated "
                           "param/opt/accumulator buffer (zero-copy "
                           "update)"},
    "HLO002": {"layer": "hlo",
               "contract": "no unexpected all-gather at stage boundaries "
                           "of a replicated-state step"},
    "HLO003": {"layer": "hlo",
               "contract": "compiled peak bytes agree with "
                           "core/memory_model within declared tolerance"},
    "HLO004": {"layer": "hlo",
               "contract": "compiled collective schedule: one all-reduce "
                           "per mini-batch (deferred) / >= N_Smu "
                           "(per-micro baseline)"},
    "HLO005": {"layer": "hlo",
               "contract": "compiled pipelined schedule: exactly two "
                           "non-scalar all-reduces (staged-grad data "
                           "psum + shared data-model psum) when "
                           "deferred, >= N_Smu when per-micro; "
                           "collective-permute count bounded by the "
                           "jaxpr schedule census (XLA may merge "
                           "adjacent permutes, never add them)"},
    "LINT001": {"layer": "ast",
                "contract": "no float()/.item()/jax.device_get host syncs "
                            "in engine hot-loop modules"},
    "LINT002": {"layer": "ast",
                "contract": "no jnp.pad in kernels/ (the PR-3 no-copy "
                            "rule)"},
    "LINT003": {"layer": "ast",
                "contract": "every jax.jit(..., donate_argnums=...) site "
                            "exposes a donate=False opt-out"},
    "LINT004": {"layer": "ast",
                "contract": "every pallas_call plumbs interpret="},
    "LINT005": {"layer": "ast",
                "contract": "production code imports kernels through the "
                            "repro.kernels public surface, not deep "
                            "submodule paths"},
    "LINT006": {"layer": "ast",
                "contract": "bare except Exception in src/repro/engine/ "
                            "routes through the supervisor's fault "
                            "taxonomy (faults.classify/is_oom/...) or "
                            "carries # repro: noqa"},
    "SRV001": {"layer": "hlo",
               "contract": "the compiled decode step aliases the donated "
                           "KV pool in place (input_output_aliases covers "
                           "the full cache footprint — a non-donated path "
                           "keeps two full KV copies live)"},
    "SRV002": {"layer": "hlo",
               "contract": "compiled decode peak agrees with "
                           "core/memory_model.serve_estimate within the "
                           "declared band AND stays under the budget the "
                           "ServePlan was admitted against"},
}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation (or advisory)."""
    rule: str
    severity: str
    message: str
    location: str = ""  # file:line for AST rules; jaxpr/HLO path otherwise
    details: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.rule not in RULES:
            raise ValueError(f"unregistered rule id {self.rule!r}; "
                             f"known: {sorted(RULES)}")
        if self.severity not in (SEVERITY_ERROR, SEVERITY_WARNING):
            raise ValueError(f"bad severity {self.severity!r}")

    def format(self) -> str:
        loc = f" @ {self.location}" if self.location else ""
        return f"[{self.rule}:{self.severity}]{loc} {self.message}"

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Report:
    """Findings from one analysis run + the context it ran under."""
    findings: List[Finding] = dataclasses.field(default_factory=list)
    context: Dict[str, Any] = dataclasses.field(default_factory=dict)
    checks_run: List[str] = dataclasses.field(default_factory=list)

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == SEVERITY_ERROR]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == SEVERITY_WARNING]

    @property
    def ok(self) -> bool:
        """True only when there are NO findings at all — the CI gate is
        strict (warnings fail too; waive intentional ones at the source)."""
        return not self.findings

    def exit_code(self) -> int:
        return EXIT_OK if self.ok else EXIT_CONTRACT

    def extend(self, findings: Iterable[Finding], check: Optional[str] = None
               ) -> "Report":
        self.findings.extend(findings)
        if check is not None and check not in self.checks_run:
            self.checks_run.append(check)
        return self

    def merge(self, other: "Report") -> "Report":
        self.findings.extend(other.findings)
        for c in other.checks_run:
            if c not in self.checks_run:
                self.checks_run.append(c)
        for k, v in other.context.items():
            self.context.setdefault(k, v)
        return self

    def to_dict(self) -> Dict[str, Any]:
        return {
            "ok": self.ok,
            "exit_code": self.exit_code(),
            "context": self.context,
            "checks_run": list(self.checks_run),
            "findings": [f.to_dict() for f in self.findings],
        }

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, default=str)

    def format(self) -> str:
        head = ", ".join(f"{k}={v}" for k, v in self.context.items())
        lines = [f"analysis [{head}]" if head else "analysis",
                 f"  checks: {', '.join(self.checks_run) or '(none)'}"]
        if self.ok:
            lines.append("  OK — zero findings")
        else:
            lines.append(f"  {len(self.errors)} error(s), "
                         f"{len(self.warnings)} warning(s):")
            lines += [f"  {f.format()}" for f in self.findings]
        return "\n".join(lines)
