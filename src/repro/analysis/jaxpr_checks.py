"""Jaxpr-level contract checks (rules JX001–JX004).

Operates on the *traced* train step — ``executor.trace_step(...)`` /
``jax.make_jaxpr`` over ``ShapeDtypeStruct``s — so every check runs
without allocating or executing anything (dryrun-style).

Primitive names are the jax 0.4.x ones: ``jax.checkpoint`` traces to
``remat2``, collectives to ``psum``, host callbacks to
``debug_callback``/``io_callback``, and a ``shard_map``-wrapped body to a
``shard_map`` equation whose body jaxpr hangs off ``eqn.params``. A
``lax.scan`` equation carries ``length``/``num_carry``/``num_consts``, so
the micro-batch loop is analyzable structurally — no unrolling needed:
a collective *inside* a scan of length N executes N times.
"""
from __future__ import annotations

from typing import Any, Iterable, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .findings import Finding, Report, SEVERITY_ERROR, SEVERITY_WARNING

REMAT_PRIMITIVES = frozenset({"remat", "remat2", "checkpoint"})
CALLBACK_PRIMITIVES = frozenset({
    "io_callback", "debug_callback", "pure_callback", "callback",
    "infeed", "outfeed",
})
COLLECTIVE_PRIMITIVES = frozenset({
    "psum", "psum2", "all_gather", "all_to_all", "ppermute",
    "reduce_scatter", "pmax", "pmin",
})
#: primitives whose body executes once per enclosing-trip (not multiplied)
_UNKNOWN_TRIP = frozenset({"while"})


def as_jaxpr(obj):
    """Accept a ClosedJaxpr, a Jaxpr, or anything with ``.jaxpr``."""
    if hasattr(obj, "eqns"):
        return obj
    if hasattr(obj, "jaxpr"):
        return as_jaxpr(obj.jaxpr)
    raise TypeError(f"not a jaxpr: {type(obj)!r}")


def _sub_jaxprs(eqn) -> Iterator[Any]:
    for v in eqn.params.values():
        items = v if isinstance(v, (tuple, list)) else (v,)
        for x in items:
            if hasattr(x, "eqns") or hasattr(x, "jaxpr"):
                yield as_jaxpr(x)


def iter_eqns(jaxpr, _path: Tuple[str, ...] = (),
              _trip: Optional[int] = 1
              ) -> Iterator[Tuple[Any, Tuple[str, ...], Optional[int]]]:
    """Yield ``(eqn, path, trip)`` over every equation, recursively.

    ``path`` is the chain of enclosing primitive names (for locations);
    ``trip`` is how many times the equation executes per call of the
    outermost jaxpr — the product of enclosing ``scan`` lengths, or
    ``None`` once inside a ``while`` (statically unknown)."""
    jaxpr = as_jaxpr(jaxpr)
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        yield eqn, _path, _trip
        if name == "scan":
            length = eqn.params.get("length")
            inner = (None if (_trip is None or length is None)
                     else _trip * int(length))
            tag = f"scan[{length}]"
        elif name in _UNKNOWN_TRIP:
            inner, tag = None, name
        else:
            inner, tag = _trip, name
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub, _path + (tag,), inner)


def count_primitive(jaxpr, names) -> int:
    """Number of equations (not executions) matching ``names``."""
    if isinstance(names, str):
        names = {names}
    return sum(1 for eqn, _, _ in iter_eqns(jaxpr)
               if eqn.primitive.name in names)


def _loc(path: Tuple[str, ...], name: str) -> str:
    return "/".join(path + (name,)) or name


def _param_shape_index(params):
    """(set of param shapes, set of plausible flat-bucket sizes, total
    elements) — what a gradient accumulator can look like: a param-shaped
    leaf (tree accumulators), the same with a leading device dim (the
    sharded streaming carry), or a 1-D per-dtype flat bucket / the full
    concatenation (FlatSpec buffers, psum_flat payloads)."""
    leaves = jax.tree.leaves(params)
    shapes = {tuple(l.shape) for l in leaves}
    by_dtype = {}
    for l in leaves:
        by_dtype[jnp.dtype(l.dtype)] = (by_dtype.get(jnp.dtype(l.dtype), 0)
                                        + int(l.size))
    total = sum(int(l.size) for l in leaves)
    bucket_sizes = set(by_dtype.values()) | {total}
    return shapes, bucket_sizes, total


def _looks_like_accumulator(aval, shapes, bucket_sizes) -> bool:
    if not jnp.issubdtype(aval.dtype, jnp.floating) or aval.ndim < 1:
        return False
    shape = tuple(aval.shape)
    if shape in shapes or (aval.ndim >= 2 and shape[1:] in shapes):
        return True
    return aval.ndim == 1 and int(aval.size) in bucket_sizes


# ---------------------------------------------------------------------------
# JX001 — accumulator dtype
# ---------------------------------------------------------------------------

def check_accum_dtype(jaxpr, plan, params) -> List[Finding]:
    """Every micro-gradient accumulator in the traced step carries
    ``plan.accum_dtype``. Accumulators are located structurally: carries
    of the outermost scan(s) whose length is N_Sμ (the micro-batch loop),
    falling back to accumulator-shaped outputs of per-micro ``pjit``
    dispatches (the eager streaming pipeline)."""
    expected = jnp.dtype(plan.accum_dtype)
    n_s = int(plan.num_micro_batches)
    shapes, bucket_sizes, _ = _param_shape_index(params)
    findings: List[Finding] = []
    candidates = []

    for eqn, path, _ in iter_eqns(jaxpr):
        if eqn.primitive.name != "scan":
            continue
        if any(p.startswith("scan[") for p in path):
            continue  # only the outermost (micro-batch) scans
        if eqn.params.get("length") != n_s:
            continue
        nc, nk = eqn.params.get("num_consts", 0), eqn.params.get("num_carry", 0)
        for v in eqn.invars[nc:nc + nk]:
            aval = getattr(v, "aval", None)
            if aval is not None and _looks_like_accumulator(
                    aval, shapes, bucket_sizes):
                candidates.append((aval, _loc(path, f"scan[{n_s}].carry")))

    if not candidates:
        # eager streaming: one jitted dispatch per micro-batch, the
        # accumulator is threaded through pjit outputs instead of a scan
        for eqn, path, _ in iter_eqns(jaxpr):
            if eqn.primitive.name != "pjit":
                continue
            if any(p.startswith("scan[") or p == "pjit" for p in path):
                continue  # top-level dispatches only
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                if aval is not None and _looks_like_accumulator(
                        aval, shapes, bucket_sizes):
                    candidates.append((aval, _loc(path, "pjit.out")))

    for aval, loc in candidates:
        if jnp.dtype(aval.dtype) != expected:
            findings.append(Finding(
                "JX001", SEVERITY_ERROR,
                f"gradient accumulator is {jnp.dtype(aval.dtype).name}, "
                f"plan.accum_dtype is {expected.name} "
                f"(shape {tuple(aval.shape)})",
                location=loc,
                details={"found_dtype": jnp.dtype(aval.dtype).name,
                         "expected_dtype": expected.name,
                         "shape": tuple(aval.shape)}))
    if not candidates and n_s > 1:
        findings.append(Finding(
            "JX001", SEVERITY_WARNING,
            f"no gradient accumulator located in the traced step "
            f"(N_Smu={n_s}) — dtype contract unverifiable",
            details={"num_micro_batches": n_s}))
    return findings


# ---------------------------------------------------------------------------
# JX002 — remat policy applied
# ---------------------------------------------------------------------------

def check_remat_policy(jaxpr, policy: Optional[str], *,
                       micro_remat: bool = False) -> List[Finding]:
    """The planner's remat lattice row is reflected in the trace: policy
    ``"none"`` (and no micro-step checkpoint) means ZERO remat sub-jaxprs;
    any graded policy means the checkpointed forward actually traced to
    >= 1 ``remat2`` equation (a policy that silently fails to apply is
    exactly the OOM-at-scale failure the planner exists to prevent)."""
    count = count_primitive(jaxpr, REMAT_PRIMITIVES)
    expect_any = micro_remat or (policy is not None and policy != "none")
    if expect_any and count == 0:
        return [Finding(
            "JX002", SEVERITY_ERROR,
            f"plan chose remat_policy={policy!r}"
            f"{' (+remat_micro_step)' if micro_remat else ''} but the "
            "traced step contains no remat/checkpoint sub-jaxpr",
            details={"policy": policy, "remat_eqns": count})]
    if not expect_any and count > 0:
        return [Finding(
            "JX002", SEVERITY_ERROR,
            f"plan chose remat_policy='none' but the traced step contains "
            f"{count} remat sub-jaxpr(s) — paying recompute the planner "
            "did not budget",
            details={"policy": policy, "remat_eqns": count})]
    return []


# ---------------------------------------------------------------------------
# JX003 — no host callbacks / host syncs in the step
# ---------------------------------------------------------------------------

def check_host_callbacks(jaxpr) -> List[Finding]:
    out = []
    for eqn, path, _ in iter_eqns(jaxpr):
        if eqn.primitive.name in CALLBACK_PRIMITIVES:
            out.append(Finding(
                "JX003", SEVERITY_ERROR,
                f"host callback primitive {eqn.primitive.name!r} inside "
                "the jitted train step (forces a device->host sync per "
                "dispatch)",
                location=_loc(path, eqn.primitive.name),
                details={"primitive": eqn.primitive.name}))
    return out


# ---------------------------------------------------------------------------
# JX004 — collective census
# ---------------------------------------------------------------------------

def check_collectives(jaxpr, params, *, n_micro: int,
                      expect: str) -> List[Finding]:
    """Gradient-sync census over the traced step.

    ``expect``:
      * ``"none"``      — single-device step: zero collectives at all.
      * ``"deferred"``  — ShardedExecutor contract: exactly ONE psum whose
        payload covers the gradient buffer per mini-batch, outside the
        micro-batch scan.
      * ``"per-micro"`` — the defer_sync=False baseline: >= N_Sμ gradient
        psums per mini-batch (one inside the scan).

    A psum is counted as a *gradient* sync when its payload is at least
    the total parameter element count (``psum_flat`` concatenates grads +
    loss + metrics + valid-count into one fp32 buffer, so payload >=
    total params); smaller collectives (scalar loss syncs) are censused
    separately and allowed."""
    if expect not in ("none", "deferred", "per-micro"):
        raise ValueError(f"bad expect {expect!r}")
    _, _, total = _param_shape_index(params)
    grad_syncs: List[Tuple[Optional[int], str, int]] = []
    small: List[str] = []
    out: List[Finding] = []

    for eqn, path, trip in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name not in COLLECTIVE_PRIMITIVES:
            continue
        payload = sum(int(v.aval.size) for v in eqn.invars
                      if getattr(v, "aval", None) is not None)
        loc = _loc(path, name)
        if expect == "none":
            out.append(Finding(
                "JX004", SEVERITY_ERROR,
                f"collective {name!r} (payload {payload} elems) in a "
                "single-device step",
                location=loc, details={"primitive": name,
                                       "payload_elems": payload}))
        elif name in ("psum", "psum2") and payload >= total:
            grad_syncs.append((trip, loc, payload))
        else:
            small.append(loc)

    if expect == "none":
        return out

    unknown = [loc for trip, loc, _ in grad_syncs if trip is None]
    effective = sum(trip for trip, _, _ in grad_syncs if trip is not None)
    details = {"gradient_syncs": [
        {"trip": t, "location": l, "payload_elems": p}
        for t, l, p in grad_syncs],
        "effective_count": effective, "n_micro": n_micro,
        "other_collectives": small}
    if unknown:
        out.append(Finding(
            "JX004", SEVERITY_ERROR,
            "gradient psum under a while-loop — per-mini-batch sync count "
            "not statically provable", location=unknown[0], details=details))
    elif expect == "deferred" and effective != 1:
        out.append(Finding(
            "JX004", SEVERITY_ERROR,
            f"deferred-sync step must issue exactly ONE gradient psum per "
            f"mini-batch, found {effective} "
            f"(N_Smu={n_micro}) — the amortization the sharded engine "
            "promises (DESIGN.md §Mesh execution) is broken",
            details=details))
    elif expect == "per-micro" and effective < n_micro:
        out.append(Finding(
            "JX004", SEVERITY_ERROR,
            f"per-micro baseline expected >= {n_micro} gradient psums per "
            f"mini-batch, found {effective}", details=details))
    return out


# ---------------------------------------------------------------------------
# JX005 — pipelined (1F1B) collective census
# ---------------------------------------------------------------------------

def check_pipeline_collectives(jaxpr, plan, *, stages: int,
                               expect: str = "deferred",
                               data_axes: Tuple[str, ...] = ("pod", "data"),
                               model_axis: str = "model") -> List[Finding]:
    """Collective census of a :class:`engine.PipelinedExecutor` step.

    The 1F1B schedule is closed-form (``engine.schedule_1f1b``), so the
    stage-boundary traffic is exactly predictable at trace level: one
    ``ppermute`` per tick in which ANY stage runs a forward, plus one per
    tick in which any stage runs a backward (the executor host-gates the
    rest away). The psum census is the deferred-sync contract composed
    with pipelining: ONE data-axis psum for the stage-local gradient
    accumulator per mini-batch, plus ONE (data, model) psum carrying
    shared-param grads + loss + metrics + the valid count. The per-micro
    baseline (``defer_sync=False``) instead issues a data-axis psum in
    every backward-active tick (>= N_Smu of them).

    ``expect``: ``"deferred"`` | ``"per-micro"``. FSDP steps replace the
    data-axis gradient psum with per-leaf psum_scatter (not censused
    here — gate FSDP artifacts on numerics + HLO002 instead)."""
    if expect not in ("deferred", "per-micro"):
        raise ValueError(f"bad expect {expect!r}")
    from ..engine.pipelined import schedule_1f1b
    n_micro = int(plan.num_micro_batches)
    fwd_tab, bwd_tab, _, _ = schedule_1f1b(stages, n_micro)
    expected_pp = int((fwd_tab >= 0).any(axis=1).sum()
                      + (bwd_tab >= 0).any(axis=1).sum())

    pp = 0
    unknown_trip: List[str] = []
    data_psums: List[str] = []
    mixed_psums: List[str] = []
    model_psums: List[str] = []
    for eqn, path, trip in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name not in COLLECTIVE_PRIMITIVES:
            continue
        loc = _loc(path, name)
        if trip is None:
            unknown_trip.append(loc)
            continue
        if name == "ppermute":
            pp += trip
            continue
        if name in ("psum", "psum2"):
            axes = eqn.params.get("axes") or ()
            if isinstance(axes, str):
                axes = (axes,)
            has_data = any(a in data_axes for a in axes)
            has_model = model_axis in axes
            if has_data and has_model:
                mixed_psums.extend([loc] * trip)
            elif has_data:
                data_psums.extend([loc] * trip)
            elif has_model:
                model_psums.extend([loc] * trip)

    details = {"expected_ppermutes": expected_pp, "found_ppermutes": pp,
               "data_psums": len(data_psums),
               "data_model_psums": len(mixed_psums),
               "model_psums": len(model_psums),
               "stages": stages, "n_micro": n_micro, "expect": expect}
    out: List[Finding] = []
    if unknown_trip:
        out.append(Finding(
            "JX005", SEVERITY_ERROR,
            "pipeline collective under a while-loop — the schedule census "
            "is not statically provable", location=unknown_trip[0],
            details=details))
        return out
    if pp != expected_pp:
        out.append(Finding(
            "JX005", SEVERITY_ERROR,
            f"stage-boundary ppermute count {pp} != {expected_pp} (the "
            f"1F1B closed-form census for stages={stages}, "
            f"N_Smu={n_micro}) — the executor is shuffling activations "
            "outside the schedule", details=details))
    if expect == "deferred":
        if len(data_psums) != 1:
            out.append(Finding(
                "JX005", SEVERITY_ERROR,
                f"deferred pipelined step must issue exactly ONE "
                f"data-axis gradient psum per mini-batch, found "
                f"{len(data_psums)}", details=details))
        if len(mixed_psums) != 1:
            out.append(Finding(
                "JX005", SEVERITY_ERROR,
                f"deferred pipelined step must issue exactly ONE "
                f"(data, model) psum (shared grads + loss + metrics + "
                f"valid count), found {len(mixed_psums)}", details=details))
    elif len(data_psums) < n_micro:
        out.append(Finding(
            "JX005", SEVERITY_ERROR,
            f"per-micro pipelined baseline expected >= {n_micro} "
            f"data-axis psums, found {len(data_psums)}", details=details))
    return out


# ---------------------------------------------------------------------------
# the bundled jaxpr passes
# ---------------------------------------------------------------------------

def check_pipelined_step(jaxpr, plan, *, stages: int,
                         expect_sync: str = "deferred",
                         policy: Optional[str] = "__from_plan__",
                         micro_remat: Optional[bool] = None) -> Report:
    """The jaxpr contracts that survive the pipelined (1F1B)
    factorization: JX002 + JX003 + JX005.

    JX001 and JX004 are structurally N/A here and deliberately skipped:
    the executor accumulates micro-gradients in per-stage masked buffers
    threaded through the tick scan (no micro-batch-length scan carry for
    JX001 to locate), and JX004's payload heuristic (psum >= total param
    elements) never fires because the pipelined step splits gradient
    traffic into a staged flat bucket and a shared bucket, each smaller
    than the whole tree. JX005's schedule-exact census replaces both the
    sync-count and payload-coverage halves of JX004."""
    if policy == "__from_plan__":
        policy = plan.remat_policy
    if micro_remat is None:
        micro_remat = bool(getattr(plan, "remat_micro_step", False))
    rep = Report(context={"layer": "jaxpr", "expect_sync": expect_sync,
                          "policy": policy, "pipelined": True})
    rep.extend(check_remat_policy(jaxpr, policy, micro_remat=micro_remat),
               "JX002")
    rep.extend(check_host_callbacks(jaxpr), "JX003")
    rep.extend(check_pipeline_collectives(jaxpr, plan, stages=stages,
                                          expect=expect_sync), "JX005")
    return rep


def check_train_step(jaxpr, plan, params, *, expect_sync: str = "none",
                     policy: Optional[str] = "__from_plan__",
                     micro_remat: Optional[bool] = None) -> Report:
    """All four jaxpr contracts over one traced train step."""
    if policy == "__from_plan__":
        policy = plan.remat_policy
    if micro_remat is None:
        micro_remat = bool(getattr(plan, "remat_micro_step", False))
    rep = Report(context={"layer": "jaxpr", "expect_sync": expect_sync,
                          "policy": policy})
    rep.extend(check_accum_dtype(jaxpr, plan, params), "JX001")
    rep.extend(check_remat_policy(jaxpr, policy, micro_remat=micro_remat),
               "JX002")
    rep.extend(check_host_callbacks(jaxpr), "JX003")
    rep.extend(check_collectives(jaxpr, params,
                                 n_micro=int(plan.num_micro_batches),
                                 expect=expect_sync), "JX004")
    return rep
