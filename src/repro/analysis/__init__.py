"""Engine contract checker — static analysis over jaxpr / HLO / source.

Three inspection layers, one Finding vocabulary (DESIGN.md §Static
contracts):

  * ``jaxpr_checks`` — contracts on the TRACED train step (no execution):
    accumulator dtype (JX001), remat-policy-applied (JX002), no host
    callbacks (JX003), collective census (JX004), the pipelined 1F1B
    schedule census (JX005).
  * ``hlo_checks``   — contracts on the COMPILED step: donation aliasing
    (HLO001), unexpected all-gathers (HLO002), memory-model cross-check
    (HLO003), the one-all-reduce-per-mini-batch schedule (HLO004), the
    compiled pipelined schedule (HLO005).
  * ``lint``         — AST rules over ``src/repro`` (LINT001–LINT005),
    waivable inline with ``# repro: noqa(RULE)``.
  * ``serve_checks`` — contracts on the COMPILED serving decode step
    (engine Layer 10): KV-pool donation aliasing (SRV001) and the
    decode-peak-vs-serve-model-vs-budget band (SRV002).

``suite.run_suite`` wires them over real reduced configurations;
``python -m repro.analysis`` is the CLI/CI gate and shares the repo
exit-code contract (0 ok / 1 error / 2 budget / 3 contract violation)
with ``launch/dryrun.py``.
"""
from .findings import (EXIT_BUDGET, EXIT_CONTRACT, EXIT_ERROR,  # noqa: F401
                       EXIT_OK, Finding, Report, RULES,
                       SEVERITY_ERROR, SEVERITY_WARNING)
from .jaxpr_checks import (check_accum_dtype, check_collectives,  # noqa: F401
                           check_host_callbacks, check_pipeline_collectives,
                           check_pipelined_step, check_remat_policy,
                           check_train_step, count_primitive, iter_eqns)
from .hlo_checks import (allreduce_count, check_aliasing,  # noqa: F401
                         check_gradient_sync, check_memory_model,
                         check_pipeline_hlo, check_unexpected_ops,
                         collective_bytes, hlo_text, measured_peak_bytes,
                         tree_bytes)
from .lint import (category_for, lint_paths, lint_repo,  # noqa: F401
                   lint_source)
from .suite import TARGETS, check_bundle, run_suite  # noqa: F401
from .serve_checks import (SERVE_TARGETS, build_decode,  # noqa: F401
                           check_decode_aliasing, check_decode_memory,
                           run_serve_suite)
