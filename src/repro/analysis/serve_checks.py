"""Compiled decode-step contracts for the serving engine (SRV001–SRV002).

The serving engine's steady state is ONE jitted decode step over the whole
KV slot pool, so its memory behaviour is decided at compile time by two
facts this module pins:

  * SRV001 — the pool is DONATED back to itself each step. XLA must alias
    every cache leaf (``input_output_aliases`` covering the full cache
    footprint); a non-donated or alias-broken path keeps the old and new
    cache live simultaneously — two full KV copies, which halves the slot
    count ``plan_serve`` could otherwise admit.
  * SRV002 — the compiled peak (``memory_analysis``: args + outs + temps −
    aliased) agrees with ``core/memory_model.serve_estimate``'s decode-time
    picture within a declared band AND stays under the budget the
    :class:`ServePlan` was admitted against — the serving twin of HLO003.

Everything lowers abstractly (``jax.eval_shape`` cache, abstract params) —
no device allocation; the only real work is the XLA compile.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from .. import configs
from ..engine import serving
from ..launch import steps
from ..models import transformer
from .findings import Finding, Report, SEVERITY_ERROR
from .hlo_checks import measured_peak_bytes, tree_bytes

#: the serve matrix: one pure-attention stack (ragged prefill, ring KV) and
#: one state-carrying hybrid (exact-length grouping, ssm state slots) —
#: resnet50 has no decode path and enc-dec is rejected by check_servable
SERVE_TARGETS = ("qwen2-1.5b", "mamba2-780m")

ANALYSIS_MAX_LEN = 64
ANALYSIS_BUDGET = 1 << 30
ANALYSIS_SLOTS = 8  # pinned: matrix compile time, not admission, decides
ANALYSIS_PREFILL = 4

#: SRV002 band: same order-of-magnitude tripwire philosophy as HLO003 but
#: with decode-sized slack (the serve model's fixed term is 64 MiB, not GiB)
SERVE_MEMORY_TOLERANCE = 16.0
SERVE_SLACK_BYTES = 256 << 20


def build_decode(arch: str, *, mesh=None, donate: bool = True,
                 budget_bytes: int = ANALYSIS_BUDGET,
                 max_len: int = ANALYSIS_MAX_LEN,
                 max_slots: Optional[int] = ANALYSIS_SLOTS,
                 prefill_micro: Optional[int] = ANALYSIS_PREFILL
                 ) -> Dict[str, Any]:
    """Plan + abstractly lower + compile one pool-wide decode step, exactly
    as ``engine.serving.ServingEngine`` builds it (same donation contract,
    greedy head)."""
    cfg = configs.get_reduced(arch)
    plan = serving.plan_serve(cfg, budget_bytes=budget_bytes, max_len=max_len,
                              max_slots=max_slots, prefill_micro=prefill_micro,
                              mesh=mesh)
    S = plan.local_slots
    cache = jax.eval_shape(functools.partial(
        transformer.init_cache, cfg, S, max_len, jnp.bfloat16,
        plan.global_window))
    params = steps.abstract_params(cfg)
    tok = jax.ShapeDtypeStruct((S, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((S,), jnp.int32)

    def decode(p, c, t, cur):
        logits, c = transformer.decode_step(p, cfg, t, c, cur,
                                            dtype=jnp.float32,
                                            global_window=plan.global_window)
        return jnp.argmax(logits[:, 0], -1).astype(jnp.int32), c

    jitted = jax.jit(decode, donate_argnums=(1,) if donate else ())
    compiled = jitted.lower(params, cache, tok, pos).compile()
    return dict(cfg=cfg, plan=plan, compiled=compiled,
                cache_bytes=tree_bytes(cache))


# ---------------------------------------------------------------------------
# SRV001 — decode-cache donation aliasing
# ---------------------------------------------------------------------------

def check_decode_aliasing(compiled, cache_bytes: int, *,
                          context: str = "") -> List[Finding]:
    """With the pool donated, ``input_output_aliases`` must cover at least
    the full cache footprint — anything less means XLA round-trips some
    cache leaf through a copy and decode holds two KV generations live."""
    mem = compiled.memory_analysis()
    aliased = int(getattr(mem, "alias_size_in_bytes", 0))
    if aliased < cache_bytes:
        return [Finding(
            "SRV001", SEVERITY_ERROR,
            f"decode step aliases {aliased} bytes < KV pool footprint "
            f"{cache_bytes} bytes — the cache is not updated in place "
            "(two full KV copies live per step)",
            location=context,
            details={"alias_bytes": aliased, "cache_bytes": cache_bytes})]
    return []


# ---------------------------------------------------------------------------
# SRV002 — decode peak vs serve memory model vs budget
# ---------------------------------------------------------------------------

def check_decode_memory(compiled, plan: serving.ServePlan, *,
                        tolerance: float = SERVE_MEMORY_TOLERANCE,
                        slack_bytes: int = SERVE_SLACK_BYTES,
                        context: str = "") -> List[Finding]:
    """Decode-time twin of HLO003, plus the admission promise itself: the
    compiled peak must sit inside the model band around
    ``plan.modeled_peak_bytes(prefill_micro=0)`` (no prefill in flight
    during a pure decode step) and NEVER exceed ``plan.budget_bytes`` —
    the whole point of planned admission."""
    measured = measured_peak_bytes(compiled)
    modeled = plan.modeled_peak_bytes(prefill_micro=0)
    details = {"measured_bytes": measured, "modeled_bytes": modeled,
               "budget_bytes": plan.budget_bytes, "tolerance": tolerance,
               "slack_bytes": slack_bytes,
               "slots": plan.local_slots}
    out = []
    hi = modeled * tolerance + slack_bytes
    lo = max(0.0, modeled / tolerance - slack_bytes)
    if not (lo <= measured <= hi):
        out.append(Finding(
            "SRV002", SEVERITY_ERROR,
            f"compiled decode peak {measured} bytes vs modeled {modeled} "
            f"bytes — outside {tolerance}x band "
            f"(allowed [{int(lo)}, {int(hi)}])",
            location=context, details=details))
    if measured > plan.budget_bytes:
        out.append(Finding(
            "SRV002", SEVERITY_ERROR,
            f"compiled decode peak {measured} bytes exceeds the "
            f"{plan.budget_bytes}-byte budget the plan admitted "
            f"{plan.local_slots} slots against",
            location=context, details=details))
    return out


def run_serve_suite(arch: str = "qwen2-1.5b", *, mesh: Any = None,
                    donate: bool = True,
                    budget_bytes: int = ANALYSIS_BUDGET,
                    max_len: int = ANALYSIS_MAX_LEN,
                    tolerance: float = SERVE_MEMORY_TOLERANCE) -> Report:
    """Compile one serve decode configuration and run both contracts."""
    from .suite import resolve_mesh
    mesh = resolve_mesh(mesh)
    built = build_decode(arch, mesh=mesh, donate=donate,
                         budget_bytes=budget_bytes, max_len=max_len)
    plan: serving.ServePlan = built["plan"]
    report = Report(context={
        "target": arch, "mode": "serve-decode",
        "mesh": (f"dp={plan.data_parallel}" if plan.data_parallel > 1
                 else "single"),
        "slots": plan.local_slots, "max_len": plan.max_len,
        "donate": donate,
    })
    ctx = f"{arch}/serve-decode"
    if donate:
        report.extend(check_decode_aliasing(
            built["compiled"], built["cache_bytes"], context=ctx), "SRV001")
    report.extend(check_decode_memory(
        built["compiled"], plan, tolerance=tolerance, context=ctx), "SRV002")
    return report
