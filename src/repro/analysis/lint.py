"""AST-based repo lint (rules LINT001–LINT005).

Source-level rules over ``src/repro/`` that guard the engine's
performance contracts where jaxpr/HLO inspection cannot see them:

  * LINT001 — no ``float()`` / ``.item()`` / ``jax.device_get`` in the
    engine hot-loop modules (``engine/{executors,exec_core,sharded,
    flat}.py``): a host sync on a tracer-adjacent value serializes the
    dispatch pipeline the streaming executor exists to overlap.
  * LINT002 — no ``jnp.pad``/``np.pad`` inside ``kernels/``: the PR-3
    no-copy rule (padding materializes a fresh buffer; kernels mask the
    ragged tail in-register instead).
  * LINT003 — every ``jax.jit(..., donate_argnums=...)`` site must
    derive the argnums from a donation config (a ``donate`` flag /
    attribute), so callers can opt out; a hard-coded literal strands
    A/B benchmarks that must reuse inputs.
  * LINT004 — every ``pallas_call`` must plumb ``interpret=`` (kernels
    must stay runnable off-TPU; a call that omits it can never be
    forced into interpret mode by the resolver).
  * LINT005 — production code imports kernels through the
    ``repro.kernels`` public surface; deep submodule imports
    (``from ..kernels.grad_accum import ...``) are deprecated.
  * LINT006 — a bare ``except Exception``/``BaseException`` inside
    ``src/repro/engine/`` must route the exception through the
    supervisor's fault taxonomy (reference ``faults`` /
    ``classify`` / ``is_oom`` / ``is_transient`` / a ``*Error`` class
    from ``engine.faults`` in the handler body) or carry
    ``# repro: noqa(LINT006)``: a catch-all that silently swallows
    ``RESOURCE_EXHAUSTED`` hides exactly the failures Layer 9 exists
    to recover from.

Intentional violations are waived inline with ``# repro: noqa(RULE)``
(or a bare ``# repro: noqa`` to waive every rule on that statement).
"""
from __future__ import annotations

import ast
import os
import re
from typing import List, Optional, Sequence

from .findings import Finding, SEVERITY_ERROR

#: engine modules whose bodies are jitted/dispatched per micro-batch
HOT_LOOP_MODULES = frozenset({"executors.py", "exec_core.py", "sharded.py",
                              "flat.py"})

_NOQA_RE = re.compile(r"#\s*repro:\s*noqa(?:\(([A-Za-z0-9_,\s]*)\))?")

_DEEP_KERNEL_RE = re.compile(r"(^|\.)kernels\.\w+")

#: identifiers that count as "routing through the fault taxonomy" when
#: they appear in a bare except-Exception handler body (LINT006)
FAULT_TAXONOMY_NAMES = frozenset({
    "faults", "classify", "is_oom", "is_transient",
    "FaultError", "TransientError", "TransientWorkerError",
    "InjectedIOError", "InjectedCrash", "CheckpointCorruptError",
})


def category_for(path: str) -> str:
    parts = os.path.normpath(path).split(os.sep)
    base = os.path.basename(path)
    if "kernels" in parts:
        return "kernels"
    if "engine" in parts:
        return "engine-hot" if base in HOT_LOOP_MODULES else "engine"
    return "general"


def _noqa_rules(lines: Sequence[str], node: ast.AST) -> Optional[set]:
    """Waived rules for ``node``: None if no marker, empty set == waive
    all. Checks every source line the node spans (multi-line calls)."""
    start = getattr(node, "lineno", None)
    if start is None:
        return None
    end = getattr(node, "end_lineno", start) or start
    for ln in range(start, min(end, len(lines)) + 1):
        m = _NOQA_RE.search(lines[ln - 1])
        if m:
            rules = m.group(1)
            if not rules:
                return set()
            return {r.strip().upper() for r in rules.split(",") if r.strip()}
    return None


def _mentions_donate(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        name = None
        if isinstance(sub, ast.Name):
            name = sub.id
        elif isinstance(sub, ast.Attribute):
            name = sub.attr
        elif isinstance(sub, ast.keyword):
            name = sub.arg
        if name and "donate" in name.lower():
            return True
    return False


def _is_jit_call(call: ast.Call) -> bool:
    f = call.func
    if isinstance(f, ast.Name) and f.id == "jit":
        return True
    return isinstance(f, ast.Attribute) and f.attr == "jit"


def _is_bare_exception_handler(handler: ast.ExceptHandler) -> bool:
    """True for ``except Exception``/``except BaseException`` (possibly
    inside a tuple). ``except:`` with no type is also bare."""
    typ = handler.type
    if typ is None:
        return True
    nodes = typ.elts if isinstance(typ, ast.Tuple) else [typ]
    for n in nodes:
        name = n.id if isinstance(n, ast.Name) else (
            n.attr if isinstance(n, ast.Attribute) else None)
        if name in ("Exception", "BaseException"):
            return True
    return False


def _routes_through_taxonomy(handler: ast.ExceptHandler) -> bool:
    for sub in ast.walk(handler):
        name = None
        if isinstance(sub, ast.Name):
            name = sub.id
        elif isinstance(sub, ast.Attribute):
            name = sub.attr
        if name in FAULT_TAXONOMY_NAMES:
            return True
    return False


def _is_pallas_call(call: ast.Call) -> bool:
    f = call.func
    if isinstance(f, ast.Name) and f.id == "pallas_call":
        return True
    return isinstance(f, ast.Attribute) and f.attr == "pallas_call"


def lint_source(src: str, path: str = "<memory>", *,
                category: Optional[str] = None) -> List[Finding]:
    """Run every applicable AST rule over one source blob."""
    if category is None:
        category = category_for(path)
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:  # surfaced as a finding, not a crash
        return [Finding("LINT005", SEVERITY_ERROR,
                        f"unparseable source: {e.msg}",
                        location=f"{path}:{e.lineno or 0}")]
    lines = src.splitlines()
    findings: List[Finding] = []

    def emit(rule: str, node: ast.AST, message: str, **details):
        waived = _noqa_rules(lines, node)
        if waived is not None and (not waived or rule in waived):
            return
        findings.append(Finding(
            rule, SEVERITY_ERROR, message,
            location=f"{path}:{getattr(node, 'lineno', 0)}",
            details=details or {}))

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            f = node.func
            if category == "engine-hot":
                if isinstance(f, ast.Name) and f.id == "float":
                    emit("LINT001", node,
                         "float(...) in an engine hot-loop module forces "
                         "a host sync when applied to a device value")
                elif isinstance(f, ast.Attribute) and f.attr == "item":
                    emit("LINT001", node,
                         ".item() in an engine hot-loop module is a "
                         "blocking device->host transfer")
                elif ((isinstance(f, ast.Attribute)
                       and f.attr == "device_get")
                      or (isinstance(f, ast.Name)
                          and f.id == "device_get")):
                    emit("LINT001", node,
                         "jax.device_get in an engine hot-loop module is "
                         "a blocking device->host transfer")
            if (category == "kernels" and isinstance(f, ast.Attribute)
                    and f.attr == "pad" and isinstance(f.value, ast.Name)
                    and f.value.id in ("jnp", "np", "numpy")):
                emit("LINT002", node,
                     f"{f.value.id}.pad in kernels/ materializes a padded "
                     "copy — mask the ragged tail in-kernel instead")
            if _is_jit_call(node):
                for kw in node.keywords:
                    if kw.arg == "donate_argnums" and not _mentions_donate(
                            kw.value):
                        emit("LINT003", node,
                             "donate_argnums hard-coded at a jax.jit site "
                             "— derive it from a donate flag so callers "
                             "can opt out (donate=False)")
            if category == "kernels" and _is_pallas_call(node):
                has_splat = any(kw.arg is None for kw in node.keywords)
                if not has_splat and not any(kw.arg == "interpret"
                                             for kw in node.keywords):
                    emit("LINT004", node,
                         "pallas_call without interpret= — kernels must "
                         "plumb interpret mode for off-TPU execution")
        elif (isinstance(node, ast.ExceptHandler)
              and category in ("engine", "engine-hot")
              and _is_bare_exception_handler(node)
              and not _routes_through_taxonomy(node)):
            # the noqa waiver must sit on the ``except`` line itself, not
            # anywhere in the (arbitrarily long) handler body
            marker = ast.Pass()
            marker.lineno = node.lineno
            marker.end_lineno = node.lineno
            emit("LINT006", marker,
                 "bare except Exception in src/repro/engine/ — route the "
                 "exception through the fault taxonomy (faults.classify/"
                 "is_oom/is_transient) or waive with # repro: noqa(LINT006)")
        elif isinstance(node, ast.ImportFrom) and category != "kernels":
            mod = node.module or ""
            if _DEEP_KERNEL_RE.search(mod) or (
                    node.level > 0 and mod.startswith("kernels.")):
                emit("LINT005", node,
                     f"deep kernel import {mod!r} — import from the "
                     "repro.kernels public surface instead",
                     module=mod)
        elif isinstance(node, ast.Import) and category != "kernels":
            for alias in node.names:
                if _DEEP_KERNEL_RE.search(alias.name):
                    emit("LINT005", node,
                         f"deep kernel import {alias.name!r} — import "
                         "from the repro.kernels public surface instead",
                         module=alias.name)
    return findings


def lint_paths(paths: Sequence[str]) -> List[Finding]:
    out: List[Finding] = []
    for p in paths:
        with open(p, "r", encoding="utf-8") as fh:
            out.extend(lint_source(fh.read(), p))
    return out


def repo_root() -> str:
    """The ``src/repro`` package directory this module lives in."""
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def lint_repo(root: Optional[str] = None) -> List[Finding]:
    """Lint every production module under ``src/repro/``."""
    root = root or repo_root()
    targets = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                targets.append(os.path.join(dirpath, fn))
    return lint_paths(targets)
