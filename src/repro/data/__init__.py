from .loader import MBSLoader  # noqa: F401
from .synthetic import (ClassificationDataset, LMDataset,  # noqa: F401
                        SegmentationDataset, minibatch_stream)
