"""Synthetic datasets (offline container: no real corpora).

Each dataset is deterministic in its seed and produces *learnable* structure
(not pure noise) so the training benchmarks show real loss curves:
  * LM: order-2 Markov token chains over the model vocab.
  * Classification: class-conditioned Gaussian blobs rendered as images
    (stand-in for Flower-102).
  * Segmentation: images with random bright shapes; mask = shape support
    (stand-in for Carvana).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class LMDataset:
    vocab_size: int
    seq_len: int
    seed: int = 0
    order: int = 2

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # sparse markov transition: each (prev) state prefers ~8 next tokens
        self._k = min(8, self.vocab_size)
        self._table = rng.integers(
            0, self.vocab_size, size=(min(self.vocab_size, 4096), self._k))

    def batch(self, batch_size: int, seed: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, seed))
        n = self._table.shape[0]
        toks = np.empty((batch_size, self.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab_size, batch_size)
        for t in range(1, self.seq_len + 1):
            prev = toks[:, t - 1] % n
            choice = rng.integers(0, self._k, batch_size)
            nxt = self._table[prev, choice]
            noise = rng.random(batch_size) < 0.05
            nxt = np.where(noise, rng.integers(0, self.vocab_size, batch_size), nxt)
            toks[:, t] = nxt
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].astype(np.int32)}


@dataclasses.dataclass
class ClassificationDataset:
    """Class-conditioned structured images; image_size is the paper's
    batch-size/image-size interaction knob (Table 1)."""
    num_classes: int
    image_size: int
    channels: int = 3
    seed: int = 0
    train_size: int = 2048

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._proto = rng.normal(
            0, 1, (self.num_classes, self.image_size, self.image_size,
                   self.channels)).astype(np.float32)
        self._labels = rng.integers(0, self.num_classes, self.train_size)

    def batch(self, batch_size: int, seed: int, train: bool = True
              ) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, seed, int(train)))
        labels = rng.integers(0, self.num_classes, batch_size)
        x = (self._proto[labels]
             + rng.normal(0, 0.9, (batch_size, self.image_size,
                                   self.image_size, self.channels)
                          ).astype(np.float32))
        return {"image": x, "label": labels.astype(np.int32)}


@dataclasses.dataclass
class SegmentationDataset:
    """Images with a random bright rectangle+disc; mask = its support."""
    image_size: int
    channels: int = 3
    seed: int = 0

    def batch(self, batch_size: int, seed: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, seed))
        s = self.image_size
        x = rng.normal(0, 0.4, (batch_size, s, s, self.channels)).astype(np.float32)
        mask = np.zeros((batch_size, s, s, 1), np.float32)
        yy, xx = np.mgrid[0:s, 0:s]
        for i in range(batch_size):
            cx, cy = rng.integers(s // 4, 3 * s // 4, 2)
            r = rng.integers(max(2, s // 8), max(3, s // 3))
            disc = ((yy - cy) ** 2 + (xx - cx) ** 2) < r * r
            mask[i, disc, 0] = 1.0
            x[i, disc] += 1.5
        return {"image": x, "mask": mask}


def minibatch_stream(dataset, batch_size: int, num_batches: int,
                     start_seed: int = 0, **kw) -> Iterator[Dict[str, np.ndarray]]:
    for i in range(num_batches):
        yield dataset.batch(batch_size, start_seed + i, **kw)
