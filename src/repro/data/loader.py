"""Data loader — thin facade over the engine's async input pipeline.

``MBSLoader`` keeps its historical surface (dataset + mini/micro batch
sizes → iterator of host-side ``(N_Sμ, N_μ, ...)`` splits) but routes
through :func:`repro.engine.plan_mbs` and :class:`repro.engine.Pipeline`,
so it inherits the planner's geometry (ragged tails pad + mask, paper
normalization auto-upgraded to exact) and the pipeline's background
prefetch with proper worker-exception propagation. New code that also
wants device staging should use ``engine.Pipeline`` directly."""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

from ..engine import Pipeline, plan_mbs


class MBSLoader:
    """Yields mini-batches pre-split into ``(N_Sμ, N_μ, ...)`` micro-batch
    stacks ready for the compiled MBS train step."""

    def __init__(self, dataset, mini_batch_size: int, micro_batch_size: int,
                 *, prefetch: int = 2, seed: int = 0,
                 normalization: str = "paper", **batch_kw):
        self.dataset = dataset
        self.mini_batch_size = mini_batch_size
        self.micro_batch_size = micro_batch_size
        self.prefetch = prefetch
        self.seed = seed
        self.batch_kw = batch_kw
        # weighted datasets need normalization="exact" — "paper" cannot
        # weight non-uniform samples correctly and plan.split refuses them
        self.plan = plan_mbs(mini_batch_size,
                             micro_batch_size=micro_batch_size,
                             normalization=normalization)
        self._pipeline = Pipeline(dataset, self.plan, prefetch=prefetch,
                                  stage=False, seed=seed, batch_kw=batch_kw)

    def __call__(self, num_batches: int) -> Iterator[Dict[str, np.ndarray]]:
        return self._pipeline.batches(num_batches)
