"""Data loader: composes a dataset with MBS host-side splitting (paper
Fig. 2 step ❶) and background prefetch."""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

from ..core import mbs as mbs_lib
from ..core.streaming import prefetch_iterator


class MBSLoader:
    """Yields mini-batches pre-split into ``(N_Sμ, N_μ, ...)`` micro-batch
    stacks ready for the compiled MBS train step."""

    def __init__(self, dataset, mini_batch_size: int, micro_batch_size: int,
                 *, prefetch: int = 2, seed: int = 0, **batch_kw):
        self.dataset = dataset
        self.mini_batch_size = mini_batch_size
        self.micro_batch_size = micro_batch_size
        self.prefetch = prefetch
        self.seed = seed
        self.batch_kw = batch_kw

    def __call__(self, num_batches: int) -> Iterator[Dict[str, np.ndarray]]:
        def gen():
            for i in range(num_batches):
                mini = self.dataset.batch(self.mini_batch_size,
                                          self.seed + i, **self.batch_kw)
                yield mbs_lib.split_minibatch(mini, self.micro_batch_size)

        if self.prefetch:
            return prefetch_iterator(gen(), self.prefetch)
        return gen()
