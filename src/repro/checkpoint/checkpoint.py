"""Crash-safe pytree checkpointing: flat-key npz + JSON manifest.

Sharded arrays are gathered to host before writing (fine for the scale we
execute locally; the manifest records the tree structure so restore works
without a template).

Write protocol (engine Layer 9 — a checkpoint must never be half-trusted):

  1. the npz is written to ``<name>.npz.tmp`` and ``os.replace``d into
     place — readers never observe a partially-written archive;
  2. the JSON manifest is written the same way, strictly AFTER the npz:
     the manifest is the **commit record**. A crash between the two
     leaves an orphaned ``ckpt_N.npz`` with no manifest — an uncommitted
     checkpoint that :func:`latest_step`/:func:`committed_steps` simply
     do not see (this also fixes the old bug where the orphan was
     selected as latest and restore then died);
  3. the manifest carries a per-array CRC32 of the stored bytes;
     :func:`restore` verifies it (and maps unreadable archives) into
     :class:`CheckpointCorruptError` so callers can fall back to the
     previous step instead of loading garbage. Manifests from before the
     CRC field restore without verification (legacy).

``save(..., keep=k)`` rotates: only the newest k *committed* checkpoints
survive (manifest deleted first, so a crash mid-rotation can only create
uncommitted orphans, never a manifest pointing at a deleted npz).
"""
from __future__ import annotations

import json
import os
import re
import zlib
import zipfile
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from ..engine import faults

_SEP = "/"


class CheckpointCorruptError(RuntimeError):
    """The checkpoint on disk is unreadable or fails its checksum."""


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"__i{p.idx}"
    return str(p)


def _npz_name(step: int) -> str:
    return f"ckpt_{step:08d}.npz"


def _json_name(step: int) -> str:
    return f"ckpt_{step:08d}.json"


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def save(directory: str, step: int, tree, *,
         keep: Optional[int] = None) -> str:
    """Write a committed checkpoint (see the module doc for the protocol);
    with ``keep``, rotate out all but the newest ``keep`` committed steps."""
    os.makedirs(directory, exist_ok=True)
    faults.on_checkpoint_io(step)
    flat = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    path = os.path.join(directory, _npz_name(step))
    tmp = path + ".tmp"
    # np.savez appends ".npz" to bare string paths — hand it a file object
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)
    faults.on_checkpoint_commit(step)  # the torn-write crash window
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {"step": step, "treedef": str(treedef),
                "keys": sorted(arrays),
                "crc": {k: _crc(v) for k, v in arrays.items()}}
    jpath = os.path.join(directory, _json_name(step))
    jtmp = jpath + ".tmp"
    with open(jtmp, "w") as f:
        json.dump(manifest, f)
    os.replace(jtmp, jpath)  # <-- the commit point
    if keep is not None:
        rotate(directory, keep)
    return path


def committed_steps(directory: str) -> List[int]:
    """Ascending steps with BOTH the npz and its manifest present —
    uncommitted (torn) checkpoints are invisible."""
    if not os.path.isdir(directory):
        return []
    files = set(os.listdir(directory))
    steps = [int(m.group(1)) for f in files
             if (m := re.match(r"ckpt_(\d+)\.json$", f))]
    return sorted(s for s in steps if _npz_name(s) in files)


def latest_step(directory: str) -> Optional[int]:
    steps = committed_steps(directory)
    return steps[-1] if steps else None


def rotate(directory: str, keep: int) -> None:
    """Delete all but the newest ``keep`` committed checkpoints (manifest
    first — mid-rotation crashes leave orphans, never committed garbage)."""
    for step in committed_steps(directory)[:-keep or None]:
        for name in (_json_name(step), _npz_name(step)):
            try:
                os.remove(os.path.join(directory, name))
            except FileNotFoundError:
                pass


def _load_manifest(directory: str, step: int) -> Optional[Dict[str, Any]]:
    try:
        with open(os.path.join(directory, _json_name(step))) as f:
            return json.load(f)
    except FileNotFoundError:
        return None
    except (json.JSONDecodeError, ValueError) as e:
        raise CheckpointCorruptError(
            f"unreadable manifest for step {step}: {e}") from e


def restore(directory: str, template, step: Optional[int] = None, *,
            shardings=None, verify: bool = True):
    """Restore into the structure of ``template`` (shapes must match).

    With ``shardings`` (a pytree of ``jax.sharding.Sharding``/devices
    matching ``template``, or a single sharding), the restored tree is
    placed on device via ``jax.device_put`` instead of being returned as
    bare host numpy arrays — resuming a sharded run must re-apply the
    run's placement, not silently replicate.

    Raises :class:`CheckpointCorruptError` for an uncommitted (no
    manifest), unreadable, or checksum-failing checkpoint — callers fall
    back to an earlier committed step (``Trainer.restore`` does)."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no committed checkpoints in {directory}")
    manifest = _load_manifest(directory, step)
    if manifest is None:
        raise CheckpointCorruptError(
            f"step {step} has no manifest (torn write?) in {directory}")
    crcs = manifest.get("crc") if verify else None  # pre-CRC manifests: skip
    flat_t = _flatten(template)
    npz_path = os.path.join(directory, _npz_name(step))
    try:
        with np.load(npz_path) as data:
            missing = set(flat_t) - set(data.files)
            if missing:
                raise KeyError(
                    f"checkpoint missing keys: {sorted(missing)[:5]}...")
            leaves_by_key = {k: data[k] for k in flat_t}
    except FileNotFoundError as e:
        raise CheckpointCorruptError(
            f"manifest for step {step} exists but {npz_path} is gone") from e
    except (zipfile.BadZipFile, zlib.error, EOFError, ValueError) as e:
        raise CheckpointCorruptError(
            f"unreadable checkpoint archive {npz_path}: {e}") from e
    if crcs:
        for key, arr in leaves_by_key.items():
            want = crcs.get(key)
            if want is not None and _crc(arr) != want:
                raise CheckpointCorruptError(
                    f"checksum mismatch for {key!r} in {npz_path}")
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    new_leaves = []
    for path, leaf in paths:
        key = _SEP.join(_path_str(p) for p in path)
        arr = leaves_by_key[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        new_leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree
