"""Pytree checkpointing: flat-key npz + JSON manifest.

Sharded arrays are gathered to host before writing (fine for the scale we
execute locally; the manifest records the tree structure so restore works
without a template)."""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"__i{p.idx}"
    return str(p)


def save(directory: str, step: int, tree) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    np.savez(path, **arrays)
    treedef = jax.tree_util.tree_structure(tree)
    with open(os.path.join(directory, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump({"step": step, "treedef": str(treedef),
                   "keys": sorted(arrays)}, f)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore(directory: str, template, step: Optional[int] = None, *,
            shardings=None):
    """Restore into the structure of ``template`` (shapes must match).

    With ``shardings`` (a pytree of ``jax.sharding.Sharding``/devices
    matching ``template``, or a single sharding), the restored tree is
    placed on device via ``jax.device_put`` instead of being returned as
    bare host numpy arrays — resuming a sharded run must re-apply the
    run's placement, not silently replicate."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    flat_t = _flatten(template)
    with np.load(os.path.join(directory, f"ckpt_{step:08d}.npz")) as data:
        missing = set(flat_t) - set(data.files)
        if missing:
            raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]}...")
        leaves_by_key = {k: data[k] for k in flat_t}
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    new_leaves = []
    for path, leaf in paths:
        key = _SEP.join(_path_str(p) for p in path)
        arr = leaves_by_key[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        new_leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree
