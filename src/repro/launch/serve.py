"""Memory-planned serving launcher: continuous batching under a synthetic
heavy-traffic stream (engine Layer 10).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --reduced \
      --budget 0.5 --requests 32 --rate 50 --prompt-lens 16,48,96 \
      --new-tokens 8,32 --temperature 0.7

``--budget`` (GiB per device) drives ``engine.plan_serve``: the KV-cache
admission bound (concurrent decode slots) and the prefill micro-batch size
come from ``core/memory_model.serve_estimate``, not from a hand-picked
batch. Prefill latency and steady-state decode throughput are reported
SEPARATELY, after a warmup pass compiles both jits — the old launcher
started its clock before the compiles and counted the prefill-produced
token as decoded, overstating tok/s on both ends.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from .. import configs
from ..core.streaming import prefetch_iterator
from ..engine import serving
from ..models import transformer
from . import mesh as mesh_lib


def _int_list(s: str):
    return tuple(int(x) for x in s.split(",") if x)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCHS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--budget", type=float, default=0.5,
                    help="per-device HBM budget in GiB the serve plan is "
                         "admitted against")
    ap.add_argument("--max-len", type=int, default=128,
                    help="context capacity per slot (prompt + generated)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=50.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--prompt-lens", type=_int_list, default=(16, 48, 96),
                    help="comma-separated prompt-length mix")
    ap.add_argument("--new-tokens", type=_int_list, default=(8, 32),
                    help="comma-separated output-budget mix")
    ap.add_argument("--slots", type=int, default=None,
                    help="pin the decode-slot count (default: memory model)")
    ap.add_argument("--prefill-micro", type=int, default=None,
                    help="pin the prefill micro-batch (default: memory model)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy, >0 = temperature sampling")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-donate", action="store_true",
                    help="do not donate the KV pool at the decode jit "
                         "boundary (keeps it readable across calls; costs a "
                         "second full cache copy — see analysis SRV001)")
    ap.add_argument("--dtype", choices=["float32", "bfloat16"],
                    default="float32")
    ap.add_argument("--json", default=None,
                    help="also write the full report to this path")
    args = ap.parse_args(argv)

    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get(args.arch)
    try:
        serving.check_servable(cfg)
    except ValueError as e:  # per-family message instead of a shape error
        raise SystemExit(str(e))
    if max(args.prompt_lens) >= args.max_len:
        raise SystemExit(f"largest prompt length {max(args.prompt_lens)} "
                         f"leaves no room to generate at --max-len "
                         f"{args.max_len}")
    dtype = jnp.float32 if args.dtype == "float32" else jnp.bfloat16
    budget = int(args.budget * 2**30)
    mesh = mesh_lib.make_host_mesh(data=len(jax.devices()), model=1)

    with mesh:
        plan = serving.plan_serve(
            cfg, budget_bytes=budget, max_len=args.max_len,
            max_slots=args.slots, prefill_micro=args.prefill_micro,
            mesh=mesh, cache_bytes=2 if args.dtype == "bfloat16" else 4)
        print(plan.describe())
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        engine = serving.ServingEngine(
            params, cfg, plan, dtype=dtype, temperature=args.temperature,
            seed=args.seed, donate=not args.no_donate)
        # Poisson stream, staged through the core prefetcher so prompt
        # synthesis overlaps the serve loop
        stream = prefetch_iterator(
            serving.synthetic_traffic(
                args.requests, rate_rps=args.rate,
                prompt_lens=args.prompt_lens, new_tokens=args.new_tokens,
                vocab_size=cfg.vocab_size, seed=args.seed + 1),
            size=8)
        seen = []

        def tee(it):
            for r in it:
                seen.append(r)
                yield r

        engine.run(tee(stream), warmup_prompt_lens=args.prompt_lens)
        rep = engine.finished_report(seen)

    pf, dec = rep["prefill"], rep["decode"]
    print(f"{cfg.name}: {rep['requests']['finished']}/{len(seen)} requests "
          f"finished (warmup/compile {rep['warmup_s']:.2f}s, excluded)")
    print(f"  prefill: {pf['batches']} micro-batches, "
          f"{pf['prompt_tokens']} prompt tokens, latency "
          f"p50 {pf['latency_s']['p50'] * 1e3:.1f}ms "
          f"max {pf['latency_s']['max'] * 1e3:.1f}ms")
    print(f"  decode (steady-state): {dec['tokens']} tokens in "
          f"{dec['time_s']:.2f}s = {dec['tokens_per_s']:.1f} tok/s over "
          f"{dec['steps']} steps (decode-issued only)")
    print(f"  ITL p50 {dec['itl_s']['p50'] * 1e3:.1f}ms "
          f"p99 {dec['itl_s']['p99'] * 1e3:.1f}ms | "
          f"TTFT p50 {rep['ttft_s']['p50'] * 1e3:.1f}ms "
          f"p99 {rep['ttft_s']['p99'] * 1e3:.1f}ms")
    print(f"  slots: {rep['slots']['max_concurrent']} peak of "
          f"{rep['slots']['planned']} planned "
          f"(mean active {rep['slots']['mean_active_per_step']:.1f})")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"arch": cfg.name, "plan": plan.describe(),
                       "report": rep}, f, indent=2)
        print(f"wrote {args.json}")
    return rep


if __name__ == "__main__":
    main()
