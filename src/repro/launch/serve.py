"""Production serving launcher: batched prefill + decode loop on the mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --reduced \
      --batch 4 --prompt-len 16 --new-tokens 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from .. import configs
from ..models import transformer
from . import mesh as mesh_lib, sharding


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCHS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--no-donate", action="store_true",
                    help="do not donate the KV cache at the decode jit "
                         "boundary (keeps it readable across calls)")
    ap.add_argument("--dtype", choices=["float32", "bfloat16"],
                    default="float32")
    args = ap.parse_args()

    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get(args.arch)
    if cfg.is_encdec:
        raise SystemExit("serve.py drives decoder-only archs; see "
                         "examples for the enc-dec loop")
    dtype = jnp.float32 if args.dtype == "float32" else jnp.bfloat16
    mesh = mesh_lib.make_host_mesh(data=len(jax.devices()), model=1)
    max_len = args.prompt_len + args.new_tokens

    with mesh:
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        prefill = jax.jit(lambda p, t: transformer.prefill(
            p, cfg, t, max_len=max_len, dtype=dtype))
        donate = not args.no_donate  # cache is reused in place per step
        decode = jax.jit(lambda p, tok, c, pos: transformer.decode_step(
            p, cfg, tok, c, pos, dtype=dtype),
            donate_argnums=(2,) if donate else ())

        prompts = jax.random.randint(jax.random.PRNGKey(1),
                                     (args.batch, args.prompt_len), 0,
                                     cfg.vocab_size)
        t0 = time.perf_counter()
        logits, cache = prefill(params, prompts)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        pos = jnp.full((args.batch,), args.prompt_len, jnp.int32)
        toks = [tok]
        for _ in range(args.new_tokens - 1):
            logits, cache = decode(params, tok, cache, pos)
            tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
            toks.append(tok)
            pos = pos + 1
        out = jnp.concatenate(toks, axis=1)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        print(f"{cfg.name}: {args.batch}x({args.prompt_len}+{args.new_tokens})"
              f" in {dt:.2f}s = {args.batch * args.new_tokens / dt:.1f} tok/s")


if __name__ == "__main__":
    main()
