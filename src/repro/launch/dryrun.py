import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production mesh, with NO device allocation (ShapeDtypeStruct inputs).

Proves the distribution config is coherent and extracts the roofline inputs:
  * main compile (scan-over-layers): ``memory_analysis()`` (fits HBM?),
    collective schedule, compile proof.
  * cost probes: XLA's cost analysis counts a while-loop body ONCE, so the
    scanned main graph under-reports FLOPs/bytes/collectives by the trip
    counts. We therefore compile two small probes — 1 period and 2 periods
    of the layer pattern, scans fully unrolled, one micro-batch — and
    extrapolate linearly (cost is affine in depth and in the number of
    micro-batches):
        X(P, n) = n * (X1 + (P - 1) * (X2 - X1))
    This is exact for per-layer work; it over-counts the once-per-step
    optimizer update n times (< 0.1% of train FLOPs; noted in
    EXPERIMENTS.md).

Usage:
  python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k \
      [--multi-pod] [--microbatches 8] [--no-probe] [--check] [--json] \
      [--out DIR]

Exit codes (shared with ``python -m repro.analysis`` — see
``repro.analysis.findings``): 0 ok, 1 tool error, 2 budget exceeded
(``--budget``), 3 static-contract findings (``--check``). argparse usage
errors also exit 2 (argparse's own convention; unambiguous in practice
because ``--budget`` is opt-in).
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from .. import configs, engine  # noqa: E402
# the HLO-text census helpers moved to the analysis subsystem (single
# source of truth for dryrun, tests, and the CI contract gate)
from ..analysis.findings import (EXIT_BUDGET, EXIT_CONTRACT,  # noqa: E402
                                 EXIT_OK)
from ..analysis.hlo_checks import collective_bytes  # noqa: E402,F401
from . import mesh as mesh_lib, sharding, steps  # noqa: E402


def _in_specs(bundle, mesh, fsdp_over_pod: bool = False, fsdp: bool = True):
    specs = []
    for i, arg in enumerate(bundle.arg_shapes):
        if bundle.kind == "train":
            spec = (sharding.param_specs(arg, mesh, fsdp=fsdp,
                                         fsdp_over_pod=fsdp_over_pod)
                    if i in (0, 1)
                    else sharding.batch_specs(arg, mesh, batch_dim=1))
        elif bundle.kind == "prefill":
            spec = (sharding.param_specs(arg, mesh) if i == 0
                    else sharding.cache_specs(arg, mesh, stacked=False))
        else:  # decode: (params, token, cache, pos)
            if i == 0:
                spec = sharding.param_specs(arg, mesh)
            elif i == 2:
                spec = sharding.cache_specs(arg, mesh, stacked=True)
            else:
                spec = sharding.cache_specs(arg, mesh, stacked=False)
        specs.append(spec)
    return specs


def _out_specs(bundle, mesh, fsdp_over_pod: bool = False, fsdp: bool = True):
    from jax.sharding import PartitionSpec as P
    out_shapes = jax.eval_shape(bundle.fn, *bundle.arg_shapes)
    if bundle.kind == "train":  # (params, opt_state, metrics)
        return (sharding.param_specs(out_shapes[0], mesh, fsdp=fsdp,
                                     fsdp_over_pod=fsdp_over_pod),
                sharding.param_specs(out_shapes[1], mesh, fsdp=fsdp,
                                     fsdp_over_pod=fsdp_over_pod),
                jax.tree.map(lambda _: P(), out_shapes[2]))
    if isinstance(out_shapes, tuple) and len(out_shapes) == 2:
        logits, cache = out_shapes  # (logits, cache)
        return (sharding.cache_specs(logits, mesh, stacked=False),
                sharding.cache_specs(cache, mesh, stacked=True))
    return sharding.cache_specs(out_shapes, mesh, stacked=False)


def _compile(bundle, mesh, fsdp_over_pod: bool = False, fsdp: bool = True,
             pipelined: bool = False):
    t0 = time.time()
    if pipelined:
        # the Layer-11 step owns its sharding (shard_map over the
        # data x model mesh, specs bound inside) — GSPMD in/out shardings
        # would fight the manual axes, and an ambient mesh context would
        # activate the model's best-effort shard hints INSIDE shard_map
        # (PartitionSpecs naming manual axes are rejected), so lower
        # without either
        jitted = jax.jit(bundle.fn, donate_argnums=bundle.donate_argnums)
        lowered = jitted.lower(*bundle.arg_shapes)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    else:
        with mesh:
            jitted = jax.jit(
                bundle.fn,
                in_shardings=tuple(sharding.named(s, mesh)
                                   for s in _in_specs(bundle, mesh,
                                                      fsdp_over_pod, fsdp)),
                out_shardings=sharding.named(
                    _out_specs(bundle, mesh, fsdp_over_pod, fsdp), mesh),
                donate_argnums=bundle.donate_argnums)
            lowered = jitted.lower(*bundle.arg_shapes)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax returns [dict]
        cost = cost[0] if cost else {}
    cost = dict(cost)
    return compiled, cost, round(t_lower, 2), round(t_compile, 2)


def _probe_cfg(cfg, periods: int):
    kw = {"num_layers": cfg.pattern_len * periods}
    if cfg.is_encdec:
        assert cfg.encoder_layers % cfg.num_periods == 0
        kw["encoder_layers"] = (cfg.encoder_layers // cfg.num_periods) * periods
    return dataclasses.replace(cfg, **kw)


def cost_probes(cfg, shape, mesh, num_microbatches: int, remat: bool = True,
                fsdp: bool = True, executor: str = "compiled",
                remat_policy: str = None):
    """Trip-count-corrected flops/bytes/collective-bytes via two unrolled
    probe compiles (see module docstring)."""
    n = num_microbatches if shape.kind == "train" else 1
    # probe one micro-batch of the planner's (ceil) size — ragged splits pad
    pshape = (dataclasses.replace(
        shape, global_batch=-(-shape.global_batch // num_microbatches))
        if shape.kind == "train" else shape)
    step_kw = ({"remat": remat, "remat_policy": remat_policy,
                "executor": executor}
               if shape.kind == "train" else {})
    probes = {}
    for P in (1, 2):
        bundle = steps.build_step(_probe_cfg(cfg, P), pshape,
                                  num_microbatches=1, scan_unroll=P, **step_kw)
        compiled, cost, tl, tc = _compile(bundle, mesh, fsdp=fsdp)
        probes[P] = {
            "flops": float(cost.get("flops", 0)),
            "bytes": float(cost.get("bytes accessed", 0)),
            "colls": collective_bytes(compiled.as_text()),
            "lower_s": tl, "compile_s": tc,
        }

    P_full = cfg.num_periods

    def extrap(x1, x2):
        return n * (x1 + (P_full - 1) * (x2 - x1))

    kinds = set(probes[1]["colls"]) | set(probes[2]["colls"])
    colls = {k: {
        "bytes": extrap(probes[1]["colls"].get(k, {}).get("bytes", 0),
                        probes[2]["colls"].get(k, {}).get("bytes", 0)),
        "count": extrap(probes[1]["colls"].get(k, {}).get("count", 0),
                        probes[2]["colls"].get(k, {}).get("count", 0)),
    } for k in kinds}
    return {
        "flops_per_device": extrap(probes[1]["flops"], probes[2]["flops"]),
        "bytes_per_device": extrap(probes[1]["bytes"], probes[2]["bytes"]),
        "collectives": colls,
        "collective_bytes_total": sum(d["bytes"] for d in colls.values()),
        "probe_raw": probes,
    }


def run_dryrun(arch: str, shape_name: str, *, multi_pod: bool = False,
               num_microbatches: int = 8, mesh=None, reduced: bool = False,
               probe: bool = True, verbose: bool = True, remat: bool = True,
               remat_policy: str = None, cfg_overrides: dict = None,
               fsdp: bool = True, executor: str = "compiled",
               budget_bytes: int = None, calibrate: str = "off",
               tuning_cache: str = None, check: bool = False,
               mesh_spec: str = None):
    cfg = configs.get_reduced(arch) if reduced else configs.get(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = configs.SHAPES[shape_name]
    if not configs.supports_shape(arch, shape_name):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "long_500k requires sub-quadratic attention "
                          "(DESIGN.md §long_500k applicability)"}
    if mesh is None and mesh_spec:
        data, model = mesh_lib.parse_mesh_spec(mesh_spec)
        mesh = mesh_lib.make_host_mesh(data=data, model=model)
    mesh = mesh or mesh_lib.make_production_mesh(multi_pod=multi_pod)
    # an explicit DATA:MODEL spec with MODEL > 1 dry-runs the Layer-11
    # pipelined step (1F1B over the model axis) instead of the GSPMD path
    pipelined = (shape.kind == "train" and mesh_spec is not None
                 and mesh_lib.axis_size(mesh, mesh_lib.MODEL_AXIS) > 1)
    plan = None
    pinned = None
    if shape.kind == "train":
        # resolve N_Smu through the same planner the step builder uses, so
        # probes/reporting match the compiled step even when the requested
        # count doesn't divide the global batch (<=0 = auto: micro-batch
        # size from the analytic memory model; --remat-policy auto lets
        # the planner pick the checkpoint grade jointly)
        pinned = (num_microbatches if num_microbatches is not None
                  and num_microbatches > 0 else None)
        plan = engine.plan_mbs(shape.global_batch, num_microbatches=pinned,
                               model_cfg=cfg, seq_len=shape.seq_len,
                               remat=remat, remat_policy=remat_policy,
                               mesh=mesh if pipelined else None,
                               pipeline=pipelined)
        num_microbatches = plan.num_micro_batches
        remat_policy = plan.remat_policy  # the chosen grade, for the report
    step_kw = {"remat": remat, "remat_policy": remat_policy,
               "executor": executor} \
        if shape.kind == "train" else {}
    if pipelined:
        step_kw["mesh"] = mesh
    bundle = steps.build_step(cfg, shape, num_microbatches=num_microbatches,
                              **step_kw)
    # multi-pod: extend FSDP over (pod, data) — optimizer-state-bound models
    # (grok-1) only fit per-chip HBM at the 512-chip shard
    compiled, cost, t_lower, t_compile = _compile(bundle, mesh,
                                                  fsdp_over_pod=multi_pod,
                                                  fsdp=fsdp,
                                                  pipelined=pipelined)
    mem = compiled.memory_analysis()
    colls_raw = collective_bytes(compiled.as_text())

    per_device = None
    grad_sync = None
    if shape.kind == "train":
        # engine Layer 6 report: what the mesh-aware planner would run on
        # this mesh (per-device budget, local micro, divisible global
        # micro) and how many all-reduce ops the compiled step actually
        # schedules (a scanned body appears ONCE in the HLO text — the
        # deferred-sync ShardedExecutor keeps the gradient all-reduce
        # outside the scan, so its count is 1 regardless of N_Sμ).
        from ..core import memory_model
        try:
            mesh_plan = engine.plan_mbs(
                shape.global_batch, num_microbatches=pinned,
                model_cfg=cfg, seq_len=shape.seq_len, remat=remat,
                remat_policy=remat_policy, mesh=mesh, pipeline=pipelined)
            est = memory_model.estimate(cfg, shape.seq_len, mesh=mesh,
                                        remat_policy=mesh_plan.remat_policy,
                                        pipeline=pipelined)
            per_device = {
                "data_parallel": mesh_plan.data_parallel,
                "local_micro": mesh_plan.local_micro,
                "micro_batch_global": mesh_plan.micro_batch_size,
                "budget_bytes": memory_model.V5E_HBM_BYTES,
                "analytic_bytes_at_local_micro":
                    est.total(mesh_plan.local_micro),
                "params_bytes": est.params_bytes,
                "activation_bytes_per_local_sample":
                    est.activation_bytes_per_sample,
            }
        except Exception as e:  # report must never sink the compile proof
            per_device = {"error": repr(e)}
        ar = colls_raw.get("all-reduce", {})
        grad_sync = {
            "allreduce_ops_in_hlo": ar.get("count", 0),
            "allreduce_bytes_in_hlo": ar.get("bytes", 0),
            "num_microbatches": num_microbatches,
        }

    pipeline_rep = None
    if pipelined:
        # Layer-11 report: per-stage footprint + the collective census the
        # 1F1B schedule implies. The ppermute count is the schedule's
        # boundary-active tick count (jaxpr-level contract — XLA may merge
        # adjacent collective-permutes in the HLO); the psum census is the
        # deferred-sync contract: ONE data-axis gradient all-reduce per
        # mini-batch + ONE (data, model) psum for shared grads/loss/metrics.
        from ..core import memory_model
        stages = mesh_lib.axis_size(mesh, mesh_lib.MODEL_AXIS)
        M = plan.num_micro_batches
        fwd_tab, bwd_tab, _, ticks = engine.schedule_1f1b(stages, M)
        ppermutes = int((fwd_tab >= 0).any(axis=1).sum()
                        + (bwd_tab >= 0).any(axis=1).sum())
        try:
            est = memory_model.estimate(cfg, shape.seq_len, mesh=mesh,
                                        remat_policy=plan.remat_policy,
                                        pipeline=True)
            per_stage_bytes = {
                "params_bytes": est.params_bytes,
                "activation_bytes_per_sample":
                    est.activation_bytes_per_sample,
                "bytes_at_local_micro": est.total(plan.local_micro),
            }
        except Exception as e:  # report must never sink the compile proof
            per_stage_bytes = {"error": repr(e)}
        pipeline_rep = {
            "stages": stages,
            "data_parallel": plan.data_parallel,
            "periods_per_stage": cfg.num_periods // stages,
            "num_micro_batches": M,
            "ticks": int(ticks),
            "in_flight_micro_batches": min(stages, M),
            "per_stage": per_stage_bytes,
            "expected_collectives": {
                "ppermute": ppermutes,
                "psum_data_axis": 1,
                "psum_data_model_axis": 1,
            },
        }

    measured_peak = (getattr(mem, "argument_size_in_bytes", 0)
                     + getattr(mem, "output_size_in_bytes", 0)
                     + getattr(mem, "temp_size_in_bytes", 0)
                     - getattr(mem, "alias_size_in_bytes", 0))

    oracle = None
    if shape.kind == "train":
        # modeled vs measured vs corrected, side-by-side (no more diffing
        # two tools by hand): the analytic estimate of the per-device step
        # at the compiled local micro size, XLA's measured peak, and — when
        # a calibration entry exists (or --calibrate force just made one) —
        # the oracle-corrected bytes plus the admission delta it buys.
        from ..core import memory_model
        try:
            dp = mesh_lib.data_parallel_size(mesh)
            micro = -(-shape.global_batch // num_microbatches)
            local = max(1, micro // max(dp, 1))
            est = memory_model.estimate(cfg, shape.seq_len, mesh=mesh,
                                        remat_policy=remat_policy,
                                        act_bytes=4)
            modeled = est.total(local)
            oracle = {
                "local_micro": local,
                "modeled_bytes": modeled,
                "measured_bytes": measured_peak,
                "model_error_pct": (
                    round(100.0 * (modeled - measured_peak) / measured_peak, 2)
                    if measured_peak > 0 else None),
            }
            if calibrate != "off":
                from ..engine import autotune
                corr = autotune.planner_correction(
                    cfg, shape.seq_len, remat_policy=remat_policy,
                    mesh=None, optimizer="sgd", executor=executor,
                    mode=calibrate, cache_path=tuning_cache, act_bytes=4)
                if corr is not None:
                    budget = budget_bytes or memory_model.V5E_HBM_BYTES
                    analytic_admit = memory_model.suggest_micro_batch_size(
                        cfg, shape.seq_len, shape.global_batch,
                        budget_bytes=budget, remat_policy=remat_policy,
                        act_bytes=4) or 1
                    corrected_admit = autotune.corrected_micro_search(
                        cfg, shape.seq_len, shape.global_batch, budget, corr,
                        remat_policy=remat_policy, act_bytes=4) or 1
                    oracle.update({
                        "correction": list(corr),
                        "corrected_bytes": corr[0] * modeled + corr[1],
                        "admission": {
                            "budget_bytes": budget,
                            "analytic_micro": analytic_admit,
                            "calibrated_micro": corrected_admit,
                            "delta": corrected_admit - analytic_admit,
                        },
                    })
        except Exception as e:  # report must never sink the compile proof
            oracle = {"error": repr(e)}

    over_budget = (budget_bytes is not None
                   and measured_peak > budget_bytes)

    contract = None
    if check:
        # static contract gate over THIS run's artifacts (no re-lowering):
        # jaxpr contracts on the pre-GSPMD bundle fn, aliasing + memory
        # cross-check on the compiled step we just built
        from .. import analysis
        modeled = (oracle.get("modeled_bytes")
                   if isinstance(oracle, dict) else None)
        contract = analysis.check_bundle(
            bundle, compiled=compiled, modeled_bytes=modeled,
            devices=int(mesh.devices.size)).to_dict()

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": list(mesh.devices.shape), "axes": list(mesh.axis_names),
        "kind": bundle.kind, "num_devices": int(mesh.devices.size),
        "num_microbatches": num_microbatches if bundle.kind == "train" else None,
        "remat_policy": plan.remat_policy if plan is not None else None,
        "remat_policy_auto": plan.auto_policy if plan is not None else None,
        "per_device": per_device,
        "gradient_sync": grad_sync,
        "pipeline": pipeline_rep,
        "oracle": oracle,
        "budget": ({"budget_bytes": budget_bytes,
                    "measured_peak_bytes": measured_peak,
                    "over_budget": over_budget}
                   if budget_bytes is not None else None),
        "contract": contract,
        "raw_cost_analysis": {k: float(v) for k, v in cost.items()
                              if k in ("flops", "bytes accessed",
                                       "transcendentals", "optimal_seconds")},
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", -1),
            "output_bytes": getattr(mem, "output_size_in_bytes", -1),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", -1),
            "peak_bytes_est": measured_peak,
        },
        "collectives_raw_once": colls_raw,
        "lower_s": t_lower, "compile_s": t_compile,
        "skipped": False,
    }
    if probe:
        result["corrected"] = cost_probes(cfg, shape, mesh, num_microbatches,
                                          remat=remat, fsdp=fsdp,
                                          executor=executor,
                                          remat_policy=remat_policy)
    if verbose:
        print(json.dumps(result))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCHS)
    ap.add_argument("--shape", required=True, choices=list(configs.SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mesh", default=None, metavar="DATA:MODEL",
                    help="explicit host-mesh axis spec (e.g. '2:4'); "
                         "MODEL > 1 dry-runs the Layer-11 pipelined step "
                         "(1F1B over the model axis) and adds the "
                         "per-stage bytes + collective-census report "
                         "block (default: the production mesh)")
    ap.add_argument("--microbatches", type=int, default=8,
                    help="N_Smu for train shapes; 0 = auto micro-batch "
                         "size from the analytic memory model")
    ap.add_argument("--executor", choices=["compiled", "fused", "flat"],
                    default="compiled",
                    help="compiled scan vs Pallas fused-accumulate vs "
                         "fused flat-buffer update step")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--no-probe", action="store_true")
    ap.add_argument("--no-remat", action="store_true",
                    help="perf knob: disable per-period activation remat")
    ap.add_argument("--remat-policy",
                    choices=["auto", "none", "dots", "period", "full"],
                    default=None,
                    help="activation-checkpoint grade (overrides "
                         "--no-remat); auto = planner chooses jointly "
                         "with the micro-batch size")
    ap.add_argument("--no-fsdp", action="store_true",
                    help="perf knob: replicate params over the data axis "
                         "(kills per-micro-batch weight all-gathers; only "
                         "for models whose optimizer state fits)")
    ap.add_argument("--capacity-factor", type=float, default=None,
                    help="perf knob: MoE capacity factor override")
    ap.add_argument("--budget", type=float, default=None, metavar="GB",
                    help="per-device HBM budget in GB; exits non-zero when "
                         "the MEASURED peak (memory_analysis) exceeds it")
    ap.add_argument("--calibrate", choices=["off", "auto", "force"],
                    default="off",
                    help="oracle block in the report: auto = use a cached "
                         "memory correction when one exists; force = run "
                         "the probe compiles now and persist the fit")
    ap.add_argument("--tuning-cache", default=None, metavar="PATH",
                    help="tuning-cache JSON path (default: "
                         "$REPRO_TUNING_CACHE or ~/.cache/repro-tuning/)")
    ap.add_argument("--check", action="store_true",
                    help="run the static contract checks "
                         "(repro.analysis.check_bundle) over this run's "
                         "traced/compiled step; findings exit 3")
    ap.add_argument("--json", action="store_true",
                    help="print the full JSON report to stdout (also when "
                         "--out is set)")
    ap.add_argument("--out", default=None, help="directory for JSON artifact")
    args = ap.parse_args()

    overrides = {}
    if args.capacity_factor is not None:
        overrides["capacity_factor"] = args.capacity_factor
    budget_bytes = (int(args.budget * 1024 ** 3)
                    if args.budget is not None else None)
    res = run_dryrun(args.arch, args.shape, multi_pod=args.multi_pod,
                     num_microbatches=args.microbatches, reduced=args.reduced,
                     probe=not args.no_probe,
                     verbose=args.out is None or args.json,
                     remat=not args.no_remat,
                     remat_policy=args.remat_policy,
                     cfg_overrides=overrides or None,
                     fsdp=not args.no_fsdp, executor=args.executor,
                     budget_bytes=budget_bytes, calibrate=args.calibrate,
                     tuning_cache=args.tuning_cache, check=args.check,
                     mesh_spec=args.mesh)
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        tag = "multi" if args.multi_pod else "single"
        path = os.path.join(args.out, f"{args.arch}__{args.shape}__{tag}.json")
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        print(f"wrote {path}")

    # repo-wide exit-code contract (shared with ``python -m repro.analysis``,
    # see analysis/findings.py): 0 ok / 1 tool error / 2 budget / 3 contract
    exit_code = EXIT_OK
    b = res.get("budget") if isinstance(res, dict) else None
    if b and b["over_budget"]:
        print(f"BUDGET EXCEEDED: measured peak "
              f"{b['measured_peak_bytes'] / 1024 ** 3:.2f} GiB > budget "
              f"{b['budget_bytes'] / 1024 ** 3:.2f} GiB "
              f"({args.arch} / {args.shape}) — raise --budget, add model "
              f"parallelism, or shrink the micro-batch", file=sys.stderr)
        exit_code = EXIT_BUDGET
    contract = res.get("contract") if isinstance(res, dict) else None
    if contract and contract.get("findings"):
        for f in contract["findings"]:
            print(f"CONTRACT: [{f.get('rule')}] {f.get('message')}",
                  file=sys.stderr)
        if exit_code == EXIT_OK:
            exit_code = EXIT_CONTRACT
    sys.exit(exit_code)


if __name__ == "__main__":
    main()
