"""Divisibility-aware GSPMD sharding policy.

Parameters: tensor-parallel over ``model`` on the last divisible dim,
FSDP over ``data`` on the first remaining divisible dim (ndim>=2 leaves).
Stacked-per-period leaves (under "blocks"/"enc_layers"/"dec_layers") never
shard their leading (scan) dim. Batch leaves shard dim `batch_dim` over
(pod, data). Anything non-divisible stays replicated on that dim — GSPMD
propagates and inserts collectives as needed, so every (arch × shape ×
mesh) combination lowers.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from . import mesh as mesh_lib

_STACKED_ROOTS = ("blocks", "enc_layers", "dec_layers")


def _leaf_path_root(path) -> str:
    for p in path:
        if hasattr(p, "key"):
            return str(p.key)
    return ""


def _auto_dims(shape: Tuple[int, ...], model_size: int, data_size: int,
               skip_leading: int, fsdp, fsdp_axes) -> list:
    spec = [None] * len(shape)
    dims = range(skip_leading, len(shape))
    if len(shape) - skip_leading < 2:
        return spec  # 1-D leaves (norm scales, biases): replicated
    # model (TP) axis: last dim divisible by the model mesh size
    for i in reversed(list(dims)):
        if model_size > 1 and shape[i] % model_size == 0 and shape[i] >= model_size:
            spec[i] = mesh_lib.MODEL_AXIS
            break
    if fsdp and data_size > 1 and len(shape) - skip_leading >= 2:
        for i in dims:
            if spec[i] is None and shape[i] % data_size == 0 and shape[i] >= data_size:
                spec[i] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
                break
    return spec


def param_specs(params_shapes, mesh, *, fsdp: bool = True,
                fsdp_over_pod: bool = False):
    """PartitionSpec tree for a parameter-like pytree (params, grads,
    optimizer state).

    ``fsdp_over_pod`` extends the FSDP shard to the (pod, data) product —
    needed for optimizer-state-bound models (grok-1: fp32 params+momentum
    = 14.7 GB/chip at 256 chips; 7.4 GB at 512)."""
    msize = mesh_lib.axis_size(mesh, mesh_lib.MODEL_AXIS)
    dsize = mesh_lib.axis_size(mesh, mesh_lib.DATA_AXIS)
    fsdp_axes: Tuple[str, ...] = (mesh_lib.DATA_AXIS,)
    if fsdp_over_pod and mesh_lib.POD_AXIS in mesh.axis_names:
        fsdp_axes = (mesh_lib.POD_AXIS, mesh_lib.DATA_AXIS)
        dsize *= mesh_lib.axis_size(mesh, mesh_lib.POD_AXIS)

    def spec_for(path, leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        keys = [str(p.key) for p in path if hasattr(p, "key")]
        # embedding table: shard the vocab dim (Megatron-style) so the tied
        # LM head emits vocab-sharded logits; fall back to the generic policy
        # when the vocab is not divisible (seamless 256206, mamba2 50280).
        if keys[-2:] == ["embed", "table"] and msize > 1 \
                and shape[0] % msize == 0:
            spec = [mesh_lib.MODEL_AXIS, None]
            if fsdp and dsize > 1 and shape[1] % dsize == 0:
                spec[1] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
            return P(*spec)
        skip = 1 if _leaf_path_root(path) in _STACKED_ROOTS else 0
        return P(*_auto_dims(shape, msize, dsize, skip, fsdp, fsdp_axes))

    return jax.tree_util.tree_map_with_path(spec_for, params_shapes)


def batch_specs(batch_shapes, mesh, *, batch_dim: int = 1):
    """Spec tree for MBS micro-batch stacks ``(N_Sμ, micro, ...)``:
    dim 0 (the scan/stream axis) replicated, ``batch_dim`` sharded over
    (pod, data) when divisible."""
    baxes = mesh_lib.batch_axes(mesh)
    dp = 1
    for a in baxes:
        dp *= mesh_lib.axis_size(mesh, a)

    def spec_for(leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        if len(shape) > batch_dim and dp > 1 and shape[batch_dim] % dp == 0 \
                and shape[batch_dim] >= dp:
            spec[batch_dim] = baxes if len(baxes) > 1 else baxes[0]
        return P(*spec)

    return jax.tree.map(spec_for, batch_shapes)


def cache_specs(cache_shapes, mesh, *, stacked: bool = True):
    """Spec tree for decode caches: leaves are (P, B, ...) — batch over
    (pod, data), model axis on the last divisible dim (kv heads / head_dim /
    state width)."""
    msize = mesh_lib.axis_size(mesh, mesh_lib.MODEL_AXIS)
    baxes = mesh_lib.batch_axes(mesh)
    dp = 1
    for a in baxes:
        dp *= mesh_lib.axis_size(mesh, a)
    bdim = 1 if stacked else 0

    def spec_for(leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        if len(shape) > bdim and dp > 1 and shape[bdim] % dp == 0 and shape[bdim] >= dp:
            spec[bdim] = baxes if len(baxes) > 1 else baxes[0]
        # model axis on the LARGEST divisible dim: for ring KV caches that is
        # the window/sequence dim (sequence-sharded KV — decode attention
        # reduces over it with a sharded softmax), for SSM states the heads.
        cand = [i for i in range(bdim + 1, len(shape))
                if msize > 1 and shape[i] % msize == 0 and shape[i] >= msize]
        if cand:
            spec[max(cand, key=lambda i: shape[i])] = mesh_lib.MODEL_AXIS
        return P(*spec)

    return jax.tree.map(spec_for, cache_shapes)


def named(tree_of_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))


def with_sharding(shapes, specs, mesh):
    """Attach NamedShardings to a ShapeDtypeStruct tree (for .lower())."""
    return jax.tree.map(
        lambda sds, spec: jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                               sharding=NamedSharding(mesh, spec)),
        shapes, specs,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)))
