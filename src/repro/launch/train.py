"""Production training launcher.

Builds the mesh from the actual device topology (falls back to a host mesh
when run off-cluster), shards params/optimizer via the divisibility policy,
and drives an MBS engine executor with the synthetic data pipeline.

Batch geometry comes from the engine planner: ``--microbatches`` pins
N_Sμ; without it the micro-batch size is derived from the analytic memory
model (``--hbm-budget-gb``). Ragged mini-batches (N_B % N_μ != 0) are
padded + masked, not rejected.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
      --reduced --steps 20 --mini-batch 16 [--microbatches 4] \
      [--executor compiled|streaming|fused]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import checkpoint, configs, engine, optim
from ..data import LMDataset
from ..models import encdec, transformer
from . import mesh as mesh_lib, sharding, steps


def build_mesh(args):
    n = len(jax.devices())
    if args.mesh == "production":
        return mesh_lib.make_production_mesh(multi_pod=args.multi_pod)
    # host mesh: all local devices on the data axis
    return mesh_lib.make_host_mesh(data=n, model=1)


def build_plan(cfg, args) -> engine.MBSPlan:
    """The launcher's batch geometry: pinned N_Sμ when given, else the
    memory model picks the micro-batch size (paper §4.3.2, computed)."""
    budget = (int(args.hbm_budget_gb * 1024 ** 3)
              if args.hbm_budget_gb else None)
    dtype_bytes = 4 if args.dtype == "float32" else 2
    return engine.plan_mbs(
        args.mini_batch, num_microbatches=args.microbatches,
        model_cfg=cfg, seq_len=args.seq, budget_bytes=budget,
        normalization=args.normalization,
        act_bytes=dtype_bytes, remat=not args.reduced)


def build_executor(cfg, plan, args, optimizer=None):
    """The step path used by main() — also exercised directly by the
    end-to-end ragged-tail test."""
    dtype = jnp.float32 if args.dtype == "float32" else jnp.bfloat16
    loss_fn = steps.make_loss_fn(cfg, dtype=dtype, remat=not args.reduced)
    opt = optimizer or optim.sgd(args.lr, momentum=0.9, weight_decay=5e-4)
    return engine.get_executor(args.executor)(loss_fn, opt, plan), opt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCHS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--mini-batch", type=int, default=16)
    ap.add_argument("--microbatches", type=int, default=None,
                    help="pin N_Smu (default: auto micro-batch size from "
                         "the memory model)")
    ap.add_argument("--executor", choices=sorted(engine.EXECUTORS),
                    default="compiled")
    ap.add_argument("--normalization", choices=["paper", "exact"],
                    default="paper")
    ap.add_argument("--hbm-budget-gb", type=float, default=None,
                    help="per-device HBM budget for auto micro-batch sizing")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--mesh", choices=["host", "production"], default="host")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--dtype", choices=["float32", "bfloat16"],
                    default="float32")
    args = ap.parse_args()
    if args.executor == "streaming" and (args.mesh != "host" or args.multi_pod):
        ap.error("--executor streaming is the single-device eager pipeline "
                 "(paper Fig. 1); it ignores sharding — use --mesh host, or "
                 "a compiled executor for production meshes")

    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get(args.arch)
    mesh = build_mesh(args)
    plan = build_plan(cfg, args)
    print(plan.describe(), flush=True)
    executor, opt = build_executor(cfg, plan, args)

    init = encdec.init_params if cfg.is_encdec else transformer.init_params
    ds = LMDataset(vocab_size=cfg.vocab_size, seq_len=args.seq, seed=0)

    def run(params, opt_state, do_step):
        t0 = time.perf_counter()
        for i in range(args.steps):
            params, opt_state, m = do_step(params, opt_state,
                                           ds.batch(args.mini_batch, i))
            if i % 5 == 0 or i == args.steps - 1:
                print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                      f"({time.perf_counter() - t0:.1f}s)", flush=True)
        if args.ckpt_dir:
            checkpoint.save(args.ckpt_dir, args.steps, params)
            print(f"checkpointed to {args.ckpt_dir}")

    if args.executor == "streaming":
        # eager paper pipeline: single-device double-buffered streaming
        params = init(cfg, jax.random.PRNGKey(0))
        run(params, opt.init(params), executor.step)
        return

    with mesh:
        pshapes = jax.eval_shape(lambda k: init(cfg, k), jax.random.PRNGKey(0))
        pspecs = sharding.param_specs(pshapes, mesh)
        params = jax.jit(lambda k: init(cfg, k),
                         out_shardings=sharding.named(pspecs, mesh))(
            jax.random.PRNGKey(0))
        opt_state = jax.jit(opt.init, out_shardings=sharding.named(
            sharding.param_specs(jax.eval_shape(opt.init, pshapes), mesh),
            mesh))(params)
        step = jax.jit(executor.make_train_step(), donate_argnums=(0, 1))
        run(params, opt_state,
            lambda p, s, mini: step(p, s, plan.device_split(mini)))


if __name__ == "__main__":
    main()
