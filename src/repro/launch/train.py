"""Production training launcher.

Builds the mesh from the actual device topology (falls back to a host mesh
when run off-cluster), shards params/optimizer via the divisibility policy,
and drives an MBS engine executor through the async input pipeline: the
dataset is batched + plan-split in a background worker (exceptions
propagate), staged host→device with the launcher's batch shardings
(double-buffered at mini-batch granularity), and the ``Trainer`` owns the
step loop — async metrics readback, periodic checkpointing, ``--resume``.

Batch geometry comes from the engine planner: ``--microbatches`` pins
N_Sμ; without it the micro-batch size is derived from the analytic memory
model (``--hbm-budget-gb``). Ragged mini-batches (N_B % N_μ != 0) are
padded + masked, not rejected.

With ``--supervise`` the whole runtime (executor + pipeline) is built
through a rebuild factory and driven by the engine Layer-9
:class:`engine.Supervisor` instead of the bare ``Trainer``: executors run
with the on-device finite-guard, runtime OOM degrades the plan (remat
escalation, then calibrated micro-shrink — the failure is recorded as a
negative bound in the tuning cache) and resumes from the last completed
state, non-finite steps are retried/skipped per ``--on-nan``, and
supervisor give-ups map onto the documented exit codes (40–44,
DESIGN.md §Fault tolerance).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
      --reduced --steps 20 --mini-batch 16 [--microbatches 4] \
      [--executor compiled|streaming|fused] \
      [--ckpt-dir /tmp/ckpt --ckpt-every 10 --resume] \
      [--supervise --max-restarts 3 --on-nan skip --ckpt-keep 3]
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp

from .. import configs, engine, optim
from ..data import LMDataset
from ..models import encdec, transformer
from . import mesh as mesh_lib, sharding, steps


def build_mesh(args):
    n = len(jax.devices())
    if args.mesh == "production":
        return mesh_lib.make_production_mesh(multi_pod=args.multi_pod)
    if args.mesh == "host":
        # host mesh: all local devices on the data axis
        return mesh_lib.make_host_mesh(data=n, model=1)
    # explicit "DATA:MODEL" axis spec — model > 1 routes the step through
    # the Layer-11 pipelined executor (validated in main() at parse time)
    data, model = mesh_lib.parse_mesh_spec(args.mesh, n)
    return mesh_lib.make_host_mesh(data=data, model=model)


def default_optimizer(args) -> optim.Optimizer:
    return optim.sgd(args.lr, momentum=0.9, weight_decay=5e-4)


def build_plan(cfg, args, optimizer=None, mesh=None) -> engine.MBSPlan:
    """The launcher's batch geometry: pinned N_Sμ when given, else the
    memory model picks the micro-batch size (paper §4.3.2, computed) —
    jointly with the remat policy when ``--remat-policy auto`` (the
    default: cheapest recompute that meets the batch target, escalating
    only when the budget forces it). ``optimizer`` (default: the
    launcher's SGD-momentum) feeds the model's state-slot count and
    step-❺ transient: the flat executor updates in place, so its plan
    admits larger auto micro-batches — but only when the optimizer
    actually publishes a fused hook.

    With a ``mesh`` the plan is per-device (engine Layer 6): the budget is
    one worker's HBM, the micro-batch stays divisible by the data axis,
    and the params discount follows the real executor — the host-mesh
    ``ShardedExecutor`` replicates params (``fsdp_params=False``), the
    production GSPMD path FSDP-shards them."""
    budget = (int(args.hbm_budget_gb * 1024 ** 3)
              if args.hbm_budget_gb else None)
    dtype_bytes = 4 if args.dtype == "float32" else 2
    optimizer = optimizer or default_optimizer(args)
    return engine.plan_mbs(
        args.mini_batch, num_microbatches=args.microbatches,
        model_cfg=cfg, seq_len=args.seq, budget_bytes=budget,
        normalization=args.normalization,
        act_bytes=dtype_bytes, remat=not args.reduced,
        remat_policy=getattr(args, "remat_policy", None),
        mesh=mesh, fsdp_params=getattr(args, "mesh", "host") == "production",
        pipeline=(mesh is not None
                  and mesh_lib.axis_size(mesh, mesh_lib.MODEL_AXIS) > 1),
        calibrate=getattr(args, "calibrate", "off"),
        tuning_cache=getattr(args, "tuning_cache", None),
        executor=args.executor,
        **optim.memory_model_kw(optimizer, fused=args.executor == "flat"))


def build_executor(cfg, plan, args, optimizer=None, mesh=None, guard=False):
    """The step path used by main() — also exercised directly by the
    end-to-end ragged-tail test. The loss compiles under the plan's
    chosen remat policy, so the step matches what the planner admitted.
    With a data-parallel ``mesh`` (>1 worker on the batch axes) every
    ``--executor`` routes through the :class:`engine.ShardedExecutor`
    wrapper: per-device accumulation, ONE gradient all-reduce per
    mini-batch. A mesh with a ``model`` axis > 1 routes through the
    Layer-11 :class:`engine.PipelinedExecutor` instead — the block stack
    is split into stages and the plan's micro-batches run 1F1B
    (``--fsdp`` additionally shards params over the data axis with
    just-in-time gathers). ``guard=True`` (the supervised mode) adds the
    on-device finite-check to the update, surfacing a ``nonfinite``
    metric."""
    dtype = jnp.float32 if args.dtype == "float32" else jnp.bfloat16
    opt = optimizer or default_optimizer(args)
    if mesh is not None and mesh_lib.axis_size(mesh, mesh_lib.MODEL_AXIS) > 1:
        staged = steps.make_staged_loss(cfg, dtype=dtype,
                                        remat_policy=plan.remat_policy)
        return engine.PipelinedExecutor(
            staged, opt, plan, mesh=mesh,
            fsdp=getattr(args, "fsdp", False), guard=guard), opt
    loss_fn = steps.make_loss_fn(cfg, dtype=dtype,
                                 remat_policy=plan.remat_policy)
    if mesh is not None and mesh_lib.data_parallel_size(mesh) > 1:
        return engine.ShardedExecutor(loss_fn, opt, plan, mesh=mesh,
                                      inner=args.executor, guard=guard), opt
    return engine.get_executor(args.executor)(loss_fn, opt, plan,
                                              guard=guard), opt


def make_build(cfg, args, ds, mesh, host_dp, opt):
    """``plan -> (step_fn, pipeline)``: one factory for all three runtime
    shapes (host-DP sharded, single-device streaming, GSPMD compiled).
    ``main()`` calls it once for the plain ``Trainer``; the Supervisor
    keeps it as the rebuild hook its OOM path re-invokes after degrading
    the plan — everything plan-dependent (executor, jit, pipeline split
    geometry) is reconstructed from scratch for the new plan."""
    guard = args.supervise

    def build(plan):
        executor, _ = build_executor(cfg, plan, args, optimizer=opt,
                                     mesh=mesh if host_dp else None,
                                     guard=guard)
        if host_dp:
            # data-parallel host mesh (engine Layer 6): per-device
            # accumulation of local_micro samples, ONE deferred gradient
            # all-reduce per mini-batch; the Pipeline stages with the
            # mesh batch shardings
            pipeline = engine.Pipeline(ds, plan, prefetch=args.prefetch,
                                       sharding=executor.batch_shardings)
            return executor.step_split, pipeline
        if args.executor == "streaming":
            # eager paper pipeline: whole split mini-batches staged to the
            # device, micro-batches sliced on device
            pipeline = engine.Pipeline(ds, plan, prefetch=args.prefetch,
                                       sharding=executor.device)
            return executor.step_split, pipeline
        # GSPMD: donate params/opt-state (reused in place) AND the spent
        # split batch (freed for step-❺ temporaries); the loop threads
        # state and never touches a donated buffer again
        donate = not args.no_donate
        jitted = jax.jit(executor.make_train_step(),
                         donate_argnums=(0, 1, 2) if donate else ())

        def step(params, opt_state, batch):
            # tracing is lazy (first call) and the step body resolves
            # PartitionSpecs against the ambient mesh — keep it active at
            # dispatch like the pre-factory `with mesh:` block did
            with mesh:
                return jitted(params, opt_state, batch)

        pipeline = engine.Pipeline(ds, plan, prefetch=args.prefetch,
                                   mesh=mesh)
        return step, pipeline

    return build


def make_plan_ctx(cfg, args, mesh, optimizer):
    """The Supervisor's planning context: everything ``build_plan`` knows,
    so an OOM re-plan goes through the same ``plan_mbs`` the launcher used
    — and the observed failure lands in the same tuning-cache key."""
    budget = (int(args.hbm_budget_gb * 1024 ** 3)
              if args.hbm_budget_gb else None)
    dtype_bytes = 4 if args.dtype == "float32" else 2
    return dict(
        model_cfg=cfg, seq_len=args.seq, budget_bytes=budget, mesh=mesh,
        executor=args.executor, tuning_cache=args.tuning_cache,
        mm_kw=dict(act_bytes=dtype_bytes, remat=not args.reduced,
                   fsdp_params=args.mesh == "production",
                   pipeline=(mesh is not None and
                             mesh_lib.axis_size(mesh, mesh_lib.MODEL_AXIS) > 1),
                   **optim.memory_model_kw(
                       optimizer, fused=args.executor == "flat")))


def run_trainer(trainer, params, opt_state, args):
    """Resume (when asked) + fit; shared by both executor paths."""
    start = 0
    if args.resume:
        restored = trainer.restore(params, opt_state)
        if restored is not None:
            params, opt_state, start = restored
            print(f"resumed from step {start}", flush=True)
        else:
            print("no checkpoint to resume from; starting fresh", flush=True)
    params, opt_state, last = trainer.fit(params, opt_state, args.steps,
                                          start_step=start)
    if args.ckpt_dir:
        print(f"checkpointed to {args.ckpt_dir}", flush=True)
    stats = trainer.pipeline.stats
    print(f"input-wait fraction {stats.input_wait_fraction:.3f} "
          f"({stats.wait_s:.2f}s of {stats.elapsed_s:.2f}s, "
          f"{stats.retries} producer retries)", flush=True)
    return params, opt_state, last


def run_supervised(supervisor, params, opt_state, args):
    """Resume + supervised fit; SupervisorError exit codes (40–44) become
    the process exit status so orchestration can tell "shrink the job"
    (PlanExhausted) from "investigate the data" (NaNCircuitBreaker)."""
    start = 0
    if args.resume:
        restored = supervisor.restore(params, opt_state)
        if restored is not None:
            params, opt_state, start = restored
            print(f"resumed from step {start}", flush=True)
        else:
            print("no checkpoint to resume from; starting fresh", flush=True)
    try:
        params, opt_state, last = supervisor.fit(params, opt_state,
                                                 args.steps, start_step=start)
    except engine.SupervisorError as e:
        print(f"[supervisor] giving up: {e}", flush=True)
        sys.exit(e.exit_code)
    rep = supervisor.report()
    print(f"[supervisor] done: restarts={rep['restarts']} "
          f"steps_lost={rep['steps_lost']} "
          f"plan: micro={rep['plan']['micro_batch_size']} "
          f"remat={rep['plan']['remat_policy']}", flush=True)
    if args.ckpt_dir:
        print(f"checkpointed to {args.ckpt_dir}", flush=True)
    return params, opt_state, last


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCHS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--mini-batch", type=int, default=16)
    ap.add_argument("--microbatches", type=int, default=None,
                    help="pin N_Smu (default: auto micro-batch size from "
                         "the memory model)")
    ap.add_argument("--executor", choices=sorted(engine.EXECUTORS),
                    default="compiled")
    ap.add_argument("--normalization", choices=["paper", "exact"],
                    default="paper")
    ap.add_argument("--remat-policy",
                    choices=["auto", "none", "dots", "period", "full"],
                    default="auto",
                    help="activation-checkpoint grade; auto = planner "
                         "picks it jointly with the micro-batch size "
                         "(cheapest recompute that meets the batch target)")
    ap.add_argument("--hbm-budget-gb", type=float, default=None,
                    help="per-device HBM budget for auto micro-batch sizing")
    ap.add_argument("--calibrate", choices=["off", "auto", "force"],
                    default="auto",
                    help="oracle-calibrated admission (engine.autotune): "
                         "auto = use a cached memory correction when one "
                         "exists (analytic fallback otherwise); force = "
                         "run the probe compiles now and persist the fit; "
                         "off = pure analytic")
    ap.add_argument("--tuning-cache", default=None, metavar="PATH",
                    help="tuning-cache JSON path (default: "
                         "$REPRO_TUNING_CACHE or ~/.cache/repro-tuning/); "
                         "also feeds the kernels' tuned launch blocks")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--mesh", default="host",
                    help="'host' (all devices on the data axis), "
                         "'production', or an explicit 'DATA:MODEL' axis "
                         "spec like '2:4' — MODEL > 1 pipelines the block "
                         "stack over the model axis (1F1B, engine "
                         "Layer 11)")
    ap.add_argument("--fsdp", action="store_true",
                    help="with a pipelined 'DATA:MODEL' mesh, shard "
                         "params over the data axis too (just-in-time "
                         "gathered FSDP forward)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint every N steps (0: only at the end)")
    ap.add_argument("--resume", action="store_true",
                    help="restore params+opt state from the latest "
                         "checkpoint in --ckpt-dir and continue from its step")
    ap.add_argument("--supervise", action="store_true",
                    help="run under the Layer-9 fault-tolerant Supervisor: "
                         "guarded executors, OOM degrade-and-resume, "
                         "bounded retries; give-ups exit 40-44")
    ap.add_argument("--max-restarts", type=int, default=3,
                    help="OOM re-plan budget for the whole run "
                         "(--supervise only)")
    ap.add_argument("--on-nan", choices=["skip", "halt"], default="skip",
                    help="non-finite-gradient policy: bounded retry then "
                         "skip behind a circuit breaker, or halt "
                         "immediately (--supervise only)")
    ap.add_argument("--ckpt-keep", type=int, default=None, metavar="K",
                    help="keep only the newest K committed checkpoints "
                         "(default: keep all)")
    ap.add_argument("--no-donate", action="store_true",
                    help="do not donate params/opt-state/batch at the "
                         "step jit boundary (A/B runs that reuse state)")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="host batches buffered by the input pipeline "
                         "(0: synchronous)")
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--dtype", choices=["float32", "bfloat16"],
                    default="float32")
    args = ap.parse_args()
    if args.mesh not in ("host", "production"):
        try:  # validate the DATA:MODEL spec at parse time — fail fast
            mesh_lib.parse_mesh_spec(args.mesh)
        except ValueError as e:
            ap.error(str(e))
    if args.executor == "streaming" and (args.mesh != "host" or args.multi_pod):
        # fail fast with the actual contract (not a silent warn-and-ignore):
        # streaming composes with data-parallel HOST meshes through the
        # ShardedExecutor; TP/FSDP production meshes need a compiled
        # executor under GSPMD, pipelined meshes the Layer-11 executor
        ap.error("--executor streaming supports single-device and "
                 "data-parallel host meshes (via the ShardedExecutor); "
                 "production/multi-pod/pipelined meshes need a compiled "
                 "executor")
    if args.fsdp and args.mesh in ("host", "production"):
        ap.error("--fsdp applies to the pipelined path: pass an explicit "
                 "'DATA:MODEL' mesh spec with MODEL > 1")
    if args.resume and not args.ckpt_dir:
        ap.error("--resume needs --ckpt-dir")

    if args.tuning_cache:
        # one cache serves both halves: the planner's memory correction
        # (threaded through build_plan) and the kernels' tuned launch
        # blocks (resolved through the process-wide active cache)
        engine.set_cache_path(args.tuning_cache)

    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get(args.arch)
    mesh = build_mesh(args)
    dp = mesh_lib.data_parallel_size(mesh)
    tp = mesh_lib.axis_size(mesh, mesh_lib.MODEL_AXIS)
    # the shard_map paths (ShardedExecutor DP, PipelinedExecutor 1F1B):
    # executor-owned step_split + plan-split pipeline staging
    host_dp = args.mesh != "production" and (dp > 1 or tp > 1)
    opt = default_optimizer(args)
    plan = build_plan(cfg, args, optimizer=opt, mesh=mesh)
    print(plan.describe(), flush=True)

    init = encdec.init_params if cfg.is_encdec else transformer.init_params
    ds = LMDataset(vocab_size=cfg.vocab_size, seq_len=args.seq, seed=0)

    gspmd = not host_dp and args.executor != "streaming"
    if gspmd:
        with mesh:
            pshapes = jax.eval_shape(lambda k: init(cfg, k),
                                     jax.random.PRNGKey(0))
            pspecs = sharding.param_specs(pshapes, mesh)
            params = jax.jit(lambda k: init(cfg, k),
                             out_shardings=sharding.named(pspecs, mesh))(
                jax.random.PRNGKey(0))
            opt_specs = sharding.param_specs(
                jax.eval_shape(opt.init, pshapes), mesh)
            opt_state = jax.jit(opt.init, out_shardings=sharding.named(
                opt_specs, mesh))(params)
        state_shardings = {"params": sharding.named(pspecs, mesh),
                           "opt_state": sharding.named(opt_specs, mesh)}
    else:
        params = init(cfg, jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        state_shardings = None

    build = make_build(cfg, args, ds, mesh, host_dp, opt)

    if args.supervise:
        supervisor = engine.Supervisor(
            build, plan,
            config=engine.SupervisorConfig(max_restarts=args.max_restarts,
                                           on_nan=args.on_nan),
            ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
            ckpt_keep=args.ckpt_keep, log_every=args.log_every,
            state_shardings=state_shardings,
            plan_ctx=make_plan_ctx(cfg, args, mesh, opt))
        run_supervised(supervisor, params, opt_state, args)
        return

    step_fn, pipeline = build(plan)
    trainer = engine.Trainer(step_fn, pipeline, ckpt_dir=args.ckpt_dir,
                             ckpt_every=args.ckpt_every,
                             ckpt_keep=args.ckpt_keep,
                             log_every=args.log_every,
                             state_shardings=state_shardings)
    run_trainer(trainer, params, opt_state, args)


if __name__ == "__main__":
    main()
