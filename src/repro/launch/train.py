"""Production training launcher.

Builds the mesh from the actual device topology (falls back to a host mesh
when run off-cluster), shards params/optimizer via the divisibility policy,
and drives the MBS train step with the synthetic data pipeline.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
      --reduced --steps 20 --mini-batch 16 --microbatches 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import checkpoint, configs, optim
from ..core import mbs as mbs_lib
from ..data import LMDataset
from ..models import encdec, transformer
from . import mesh as mesh_lib, sharding, steps


def build_mesh(args):
    n = len(jax.devices())
    if args.mesh == "production":
        return mesh_lib.make_production_mesh(multi_pod=args.multi_pod)
    # host mesh: all local devices on the data axis
    return mesh_lib.make_host_mesh(data=n, model=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCHS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--mini-batch", type=int, default=16)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--mesh", choices=["host", "production"], default="host")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--dtype", choices=["float32", "bfloat16"],
                    default="float32")
    args = ap.parse_args()

    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get(args.arch)
    mesh = build_mesh(args)
    dtype = jnp.float32 if args.dtype == "float32" else jnp.bfloat16
    micro = args.mini_batch // args.microbatches
    assert micro * args.microbatches == args.mini_batch

    init = encdec.init_params if cfg.is_encdec else transformer.init_params
    opt = optim.sgd(args.lr, momentum=0.9, weight_decay=5e-4)
    loss_fn = steps.make_loss_fn(cfg, dtype=dtype, remat=not args.reduced)
    train_step = mbs_lib.make_mbs_train_step(loss_fn, opt,
                                             mbs_lib.MBSConfig(micro))

    with mesh:
        pshapes = jax.eval_shape(lambda k: init(cfg, k), jax.random.PRNGKey(0))
        pspecs = sharding.param_specs(pshapes, mesh)
        params = jax.jit(lambda k: init(cfg, k),
                         out_shardings=sharding.named(pspecs, mesh))(
            jax.random.PRNGKey(0))
        opt_state = jax.jit(opt.init, out_shardings=sharding.named(
            sharding.param_specs(jax.eval_shape(opt.init, pshapes), mesh),
            mesh))(params)
        step = jax.jit(train_step, donate_argnums=(0, 1))

        ds = LMDataset(vocab_size=cfg.vocab_size, seq_len=args.seq, seed=0)
        t0 = time.perf_counter()
        for i in range(args.steps):
            mini = ds.batch(args.mini_batch, i)
            split = {k: jnp.asarray(v) for k, v in
                     mbs_lib.split_minibatch(mini, micro).items()}
            params, opt_state, m = step(params, opt_state, split)
            if i % 5 == 0 or i == args.steps - 1:
                print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                      f"({time.perf_counter() - t0:.1f}s)", flush=True)
        if args.ckpt_dir:
            checkpoint.save(args.ckpt_dir, args.steps, params)
            print(f"checkpointed to {args.ckpt_dir}")


if __name__ == "__main__":
    main()
