"""Run the full dry-run matrix: every (architecture × input shape) on the
single-pod mesh (with roofline cost probes) AND the 2-pod mesh (compile
proof only). Each combo runs in a subprocess (the 512-device XLA_FLAGS must
be set before jax initializes, and isolation keeps compile memory bounded).

  python -m repro.launch.dryrun_all --out experiments/dryrun [--jobs ...]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from .. import configs

# per-arch micro-batch count for train_4k: 16 → one sample per data shard
# (the MBS knob; chosen from the memory model for the giant models)
TRAIN_MICROBATCHES = {
    "grok-1-314b": 16, "mixtral-8x22b": 16, "qwen2-vl-72b": 16,
}
DEFAULT_MICROBATCHES = 8


def combos():
    for arch in configs.ARCHS:
        for shape in configs.SHAPES:
            for mesh in ("single", "multi"):
                yield arch, shape, mesh


def run_one(arch: str, shape: str, mesh: str, out_dir: str,
            timeout: int = 3000) -> dict:
    tag = f"{arch}__{shape}__{mesh}"
    path = os.path.join(out_dir, f"{tag}.json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    if not configs.supports_shape(arch, shape):
        res = {"arch": arch, "shape": shape, "mesh_tag": mesh, "skipped": True,
               "reason": "long_500k requires sub-quadratic attention"}
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        return res
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--microbatches",
           str(TRAIN_MICROBATCHES.get(arch, DEFAULT_MICROBATCHES)),
           "--out", out_dir]
    if mesh == "multi":
        cmd += ["--multi-pod", "--no-probe"]  # roofline probes: single-pod only
    env = dict(os.environ)
    t0 = time.time()
    proc = subprocess.run(cmd, capture_output=True, text=True,
                          timeout=timeout, env=env)
    if proc.returncode != 0:
        res = {"arch": arch, "shape": shape, "mesh_tag": mesh, "failed": True,
               "stderr_tail": proc.stderr[-3000:], "wall_s": time.time() - t0}
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        return res
    with open(path) as f:
        res = json.load(f)
    res["wall_s"] = round(time.time() - t0, 1)
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--only-mesh", choices=["single", "multi"], default=None)
    ap.add_argument("--only-arch", default=None)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    results = []
    for arch, shape, mesh in combos():
        if args.only_mesh and mesh != args.only_mesh:
            continue
        if args.only_arch and arch != args.only_arch:
            continue
        t0 = time.time()
        try:
            res = run_one(arch, shape, mesh, args.out)
            status = ("SKIP" if res.get("skipped")
                      else "FAIL" if res.get("failed") else "ok")
        except subprocess.TimeoutExpired:
            status, res = "TIMEOUT", {}
        print(f"{arch:24s} {shape:12s} {mesh:6s} {status:7s} "
              f"{time.time() - t0:7.1f}s", flush=True)
        results.append((arch, shape, mesh, status))

    n_ok = sum(1 for r in results if r[3] == "ok")
    n_skip = sum(1 for r in results if r[3] == "SKIP")
    print(f"\n{n_ok} ok / {n_skip} skipped / "
          f"{len(results) - n_ok - n_skip} failed of {len(results)}")


if __name__ == "__main__":
    main()
