"""Step builders + abstract input specs for every (architecture × shape).

  * train:   MBS train step (paper technique, first-class): micro-batch
             scan + loss normalization + single optimizer update.
  * prefill: full-sequence forward building the decode cache.
  * decode:  one new token against a seq_len KV cache.

``input_specs`` returns ShapeDtypeStructs (weak-type-correct, shardable,
no allocation) for everything the step consumes beyond params/opt-state.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .. import engine
from ..configs.shapes import InputShape
from ..core import losses
from ..models import encdec, nn, transformer
from ..models import remat as remat_lib
from ..models.config import ModelConfig
from . import mesh as mesh_lib
from .. import optim

N_VISION_TOKENS = 256  # stubbed patch embeds per sample (qwen2-vl frontend)
AUDIO_TGT_FRACTION = 4  # decoder length = seq / 4 for enc-dec training


@dataclasses.dataclass(frozen=True)
class StepBundle:
    kind: str
    fn: Callable  # the step function to jit
    arg_shapes: Tuple[Any, ...]  # abstract args (ShapeDtypeStruct trees)
    donate_argnums: Tuple[int, ...] = ()
    # traced-artifact context for ``repro.analysis`` (train steps only):
    # the plan the step was built against, the loss/optimizer it closes
    # over, and the executor name — so contract checks can verify the
    # compiled step against what the planner admitted without rebuilding.
    plan: Optional[Any] = None
    optimizer: Optional[Any] = None
    loss_fn: Optional[Callable] = None
    executor: Optional[str] = None


# ---------------------------------------------------------------------------
# abstract params / optimizer state
# ---------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig):
    init = encdec.init_params if cfg.is_encdec else transformer.init_params
    return jax.eval_shape(lambda k: init(cfg, k), jax.random.PRNGKey(0))


def make_optimizer(cfg: ModelConfig, lr: float = 1e-3) -> optim.Optimizer:
    # production default: SGD momentum (the paper's optimizer); examples
    # override with Adam where the paper does (U-Net).
    return optim.sgd(lr, momentum=0.9, weight_decay=5e-4)


def abstract_opt_state(optimizer, params_shapes):
    return jax.eval_shape(optimizer.init, params_shapes)


# ---------------------------------------------------------------------------
# loss / train step
# ---------------------------------------------------------------------------

def make_loss_fn(cfg: ModelConfig, dtype=jnp.bfloat16, remat: bool = True,
                 scan_unroll: int = 1,
                 remat_policy: Optional[str] = None):
    """``remat_policy`` grades activation checkpointing (``models/remat``);
    None keeps the legacy ``remat`` bool mapping (True → "period",
    False → "none"). Pass the *plan's* chosen policy here so the compiled
    loss matches what the planner admitted."""
    policy = remat_lib.resolve(remat, remat_policy)

    def loss_fn(params, mb, exact_denom=None):
        sw = mb.get("sample_weight")
        if cfg.is_encdec:
            logits, aux = encdec.forward(params, cfg, mb["frames"],
                                         mb["tgt_tokens"], dtype=dtype,
                                         remat_policy=policy,
                                         scan_unroll=scan_unroll)
        else:
            logits, aux = transformer.forward(
                params, cfg, mb["tokens"],
                vision_embeds=mb.get("vision_embeds"),
                mrope_positions=mb.get("mrope_positions"),
                dtype=dtype, remat_policy=policy, scan_unroll=scan_unroll)
        loss = losses.cross_entropy(logits, mb["labels"], sample_weight=sw,
                                    exact_denom=exact_denom)
        if cfg.is_moe:
            aux_term = cfg.router_aux_coef * aux / cfg.num_layers
            # exact-mode contract: micro contributions SUM to the mini-batch
            # loss, so additive (non-per-sample) regularizers carry this
            # micro-batch's valid-sample share — Σ_i (valid_i/N_B_valid)·aux_i
            # is the weighted mean over micro-batches (== paper mode's
            # mean when the split is uniform), for every executor.
            if exact_denom is not None:
                n_valid = (jnp.sum(sw) if sw is not None
                           else jnp.asarray(float(jax.tree.leaves(mb)[0].shape[0])))
                aux_term = aux_term * (n_valid / exact_denom)
            loss = loss + aux_term
        return loss, {"aux_loss": aux}

    return loss_fn


def make_staged_loss(cfg: ModelConfig, dtype=jnp.bfloat16, remat: bool = True,
                     scan_unroll: int = 1,
                     remat_policy: Optional[str] = None) -> engine.StagedLoss:
    """Factor the decoder-only transformer loss into the prelude /
    stage_fn / finale triple that :class:`engine.PipelinedExecutor`
    schedules (engine Layer 11).

    The stage boundary is the period axis: ``params["blocks"]`` leaves
    are stacked ``(num_periods, ...)`` and ``StagedLoss.partition``
    reshapes them to ``(stages, periods_per_stage, ...)``; each stage
    scans its local periods exactly like :func:`transformer.forward`
    scans the whole stack, under the same checkpoint lattice. The finale
    emits the RAW loss sum (``exact_denom=1`` semantics) — the executor
    divides by the global valid count after its cross-mesh psum, which
    is what makes pipelined numerics match the single-device exact path.

    Families whose forward does not cut at period boundaries with only a
    ``(B, S, d_model)`` carry are rejected: MoE (router aux loss
    accumulates across periods into the scalar loss), enc-dec (two
    stacks joined by cross-attention), and VLM (the vision frontend
    feeds extra inputs into the embed prelude).
    """
    if cfg.is_encdec or cfg.is_moe or cfg.is_vlm:
        which = ("enc-dec" if cfg.is_encdec else
                 "MoE" if cfg.is_moe else "VLM")
        raise ValueError(
            f"{cfg.name}: pipeline staging supports dense decoder-only "
            f"stacks; {which} forwards do not factor into "
            "prelude/stage_fn/finale with a (B, S, d_model) carry — run "
            "this family on the data axis (ShardedExecutor) instead")
    policy = remat_lib.resolve(remat, remat_policy)

    def _positions(x):
        B, S = x.shape[:2]
        return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def prelude(shared, mb):
        return transformer._embed_inputs(shared, cfg, mb["tokens"], None,
                                         dtype)

    def stage_fn(stage_p, x):
        positions = _positions(x)

        def period_fn(x, slot_params):
            aux = jnp.zeros((), jnp.float32)
            for kind, p in zip(cfg.layer_pattern, slot_params):
                x, a, _ = transformer._apply_slot(p, cfg, kind, x, positions,
                                                  dtype=dtype,
                                                  remat_policy=policy)
                aux = aux + a
            return x, aux

        period_fn = remat_lib.checkpoint_period(period_fn, policy)
        x, _ = jax.lax.scan(period_fn, x, stage_p, unroll=scan_unroll)
        return x

    def finale(shared, x, mb):
        x = nn.rmsnorm(shared["final_norm"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = nn.unembed(shared["embed"], x, jnp.float32)
        else:
            logits = nn.dense(shared["unembed"], x, jnp.float32)
        logits = nn.softcap(logits, cfg.final_softcap)
        loss = losses.cross_entropy(logits, mb["labels"],
                                    sample_weight=mb.get("sample_weight"),
                                    exact_denom=1.0)
        return loss, {}

    return engine.StagedLoss(num_layers=cfg.num_periods, prelude=prelude,
                             stage_fn=stage_fn, finale=finale,
                             stacked_key="blocks")


def abstract_train_batch(cfg: ModelConfig, seq_len: int, plan, *,
                         dtype=jnp.bfloat16) -> Dict[str, Any]:
    """ShapeDtypeStruct tree of a SPLIT ``(N_Sμ, N_μ, ...)`` train batch
    for one (architecture × plan) — what the compiled train step consumes
    beyond params/opt-state. Shared by :func:`build_train_step` and the
    ``repro.analysis`` suite (which traces steps without building data)."""
    s = seq_len
    n, m = plan.num_micro_batches, plan.micro_batch_size
    i32, f32 = jnp.int32, jnp.float32
    sds = jax.ShapeDtypeStruct
    if cfg.is_encdec:
        batch = {
            "frames": sds((n, m, s, cfg.d_model), dtype),
            "tgt_tokens": sds((n, m, s // AUDIO_TGT_FRACTION), i32),
            "labels": sds((n, m, s // AUDIO_TGT_FRACTION), i32),
        }
    else:
        batch = {
            "tokens": sds((n, m, s), i32),
            "labels": sds((n, m, s), i32),
        }
        if cfg.is_vlm:
            batch["vision_embeds"] = sds(
                (n, m, N_VISION_TOKENS, transformer.VISION_EMBED_DIM), dtype)
            batch["mrope_positions"] = sds((n, 3, m, s), i32)
    # the plan's pad-and-mask split always emits the sample-weight mask
    batch["sample_weight"] = sds((n, m), f32)
    return batch


def build_train_step(cfg: ModelConfig, shape: InputShape, *,
                     num_microbatches: Optional[int] = None, optimizer=None,
                     dtype=jnp.bfloat16, remat: bool = True,
                     remat_policy: Optional[str] = None,
                     normalization: str = "paper",
                     scan_unroll: int = 1,
                     executor: str = "compiled",
                     mesh=None, fsdp: bool = False) -> StepBundle:
    """Compiled train step via the MBS engine. ``num_microbatches=None``
    auto-sizes the micro-batch from the analytic memory model (the paper's
    experimentally-determined size, computed — §4.3.2); ragged splits are
    padded + masked rather than asserted away. ``remat_policy`` (incl.
    ``"auto"``) goes through the planner; the loss is built with the
    plan's *chosen* policy. ``mesh`` makes the plan mesh-aware (engine
    Layer 6): per-device budget, micro sizes divisible by the data axis —
    pass the mesh the step will be compiled against.

    When the mesh has a ``model`` axis of size > 1 the step routes
    through engine Layer 11 instead: ``plan_mbs(pipeline=True)`` budgets
    stage-local activations × in-flight depth and the
    :class:`engine.PipelinedExecutor` runs the plan's micro-batches
    through the 1F1B schedule (``fsdp=True`` additionally shards params
    over the data axis with just-in-time gathers)."""
    optimizer = optimizer or make_optimizer(cfg)
    pipeline = (mesh is not None
                and mesh_lib.axis_size(mesh, mesh_lib.MODEL_AXIS) > 1)
    plan = engine.plan_mbs(shape.global_batch,
                           num_microbatches=num_microbatches,
                           model_cfg=cfg, seq_len=shape.seq_len,
                           normalization=normalization, unroll=scan_unroll,
                           act_bytes=jnp.dtype(dtype).itemsize, remat=remat,
                           remat_policy=remat_policy, mesh=mesh,
                           pipeline=pipeline,
                           **optim.memory_model_kw(optimizer,
                                                   fused=executor == "flat"))
    if pipeline:
        staged = make_staged_loss(cfg, dtype, scan_unroll=scan_unroll,
                                  remat_policy=plan.remat_policy)
        step = engine.PipelinedExecutor(staged, optimizer, plan, mesh=mesh,
                                        fsdp=fsdp).make_train_step()
        executor = "pipelined"
        loss_fn = None
    else:
        loss_fn = make_loss_fn(cfg, dtype, scan_unroll=scan_unroll,
                               remat_policy=plan.remat_policy)
        step = engine.get_executor(executor)(
            loss_fn, optimizer, plan).make_train_step()

    batch = abstract_train_batch(cfg, shape.seq_len, plan, dtype=dtype)
    params = abstract_params(cfg)
    opt_state = abstract_opt_state(optimizer, params)
    # donate state AND the split batch: the batch is spent after the scan,
    # freeing its buffers for the update step's temporaries
    return StepBundle("train", step, (params, opt_state, batch),
                      donate_argnums=(0, 1, 2), plan=plan,
                      optimizer=optimizer, loss_fn=loss_fn,
                      executor=executor)


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------

def build_prefill_step(cfg: ModelConfig, shape: InputShape, *,
                       dtype=jnp.bfloat16, scan_unroll: int = 1,
                       remat_policy: str = "none") -> StepBundle:
    """``remat_policy`` defaults to "none" (prefill is forward-only, so
    checkpointing buys nothing when serving alone) but is routed through —
    NOT hardcoded — so eval interleaved with training can compile under
    the training policy when memory is tight."""
    s, b = shape.seq_len, shape.global_batch
    sds = jax.ShapeDtypeStruct
    i32 = jnp.int32
    gw = cfg.long_context_global_window if shape.name == "long_500k" else None

    if cfg.is_encdec:
        def fn(params, frames, tokens):
            # encoder over the audio, then teacher-forced decoder prefill;
            # returns last-position logits (cache built by init_decode_cache
            # in the serving loop).
            logits, _ = encdec.forward(params, cfg, frames, tokens,
                                       dtype=dtype,
                                       remat_policy=remat_policy,
                                       scan_unroll=scan_unroll)
            return logits[:, -1]

        args = (abstract_params(cfg), sds((b, s, cfg.d_model), dtype),
                sds((b, s // AUDIO_TGT_FRACTION), i32))
        return StepBundle("prefill", fn, args)

    def fn(params, tokens, vision_embeds=None, mrope_positions=None):
        return transformer.prefill(params, cfg, tokens, max_len=s,
                                   vision_embeds=vision_embeds,
                                   mrope_positions=mrope_positions,
                                   dtype=dtype, global_window=gw,
                                   scan_unroll=scan_unroll)

    args = [abstract_params(cfg), sds((b, s), i32)]
    if cfg.is_vlm:
        args += [sds((b, N_VISION_TOKENS, transformer.VISION_EMBED_DIM), dtype),
                 sds((3, b, s), i32)]
    return StepBundle("prefill", fn, tuple(args))


def abstract_cache(cfg: ModelConfig, shape: InputShape, dtype=jnp.bfloat16):
    gw = cfg.long_context_global_window if shape.name == "long_500k" else None
    if cfg.is_encdec:
        b, s = shape.global_batch, shape.seq_len
        # built abstractly (matches encdec.init_decode_cache's structure)
        K, hd = cfg.num_kv_heads, cfg.head_dim
        L = cfg.num_layers
        sds = jax.ShapeDtypeStruct
        T = s // AUDIO_TGT_FRACTION  # encoder frames feeding cross-attn
        return {
            "self": {
                "k": sds((L, b, s, K, hd), dtype),
                "v": sds((L, b, s, K, hd), dtype),
                "pos": sds((L, b, s), jnp.int32),
            },
            "cross": {
                "k": sds((L, b, T, K, hd), dtype),
                "v": sds((L, b, T, K, hd), dtype),
            },
        }
    cache = jax.eval_shape(
        functools.partial(transformer.init_cache, cfg, shape.global_batch,
                          shape.seq_len, dtype, global_window=gw))
    return cache


def build_decode_step(cfg: ModelConfig, shape: InputShape, *,
                      dtype=jnp.bfloat16, scan_unroll: int = 1) -> StepBundle:
    b = shape.global_batch
    sds = jax.ShapeDtypeStruct
    i32 = jnp.int32
    gw = cfg.long_context_global_window if shape.name == "long_500k" else None
    cache = abstract_cache(cfg, shape, dtype)

    if cfg.is_encdec:
        def fn(params, token, cache, pos):
            return encdec.decode_step(params, cfg, token, cache, pos,
                                      dtype=dtype, scan_unroll=scan_unroll)
    else:
        def fn(params, token, cache, pos):
            return transformer.decode_step(params, cfg, token, cache, pos,
                                           dtype=dtype, global_window=gw,
                                           scan_unroll=scan_unroll)

    args = (abstract_params(cfg), sds((b, 1), i32), cache, sds((b,), i32))
    return StepBundle("decode", fn, args, donate_argnums=(2,))


def build_step(cfg: ModelConfig, shape: InputShape, *, num_microbatches: int = 8,
               dtype=jnp.bfloat16, scan_unroll: int = 1, **kw) -> StepBundle:
    if shape.kind == "train":
        return build_train_step(cfg, shape, num_microbatches=num_microbatches,
                                dtype=dtype, scan_unroll=scan_unroll, **kw)
    if shape.kind == "prefill":
        # eval/serving compiles under the caller's policy (not a hardcoded
        # remat=False); "auto" has no planner here — use the lattice floor
        policy = kw.get("remat_policy") or "none"
        return build_prefill_step(
            cfg, shape, dtype=dtype, scan_unroll=scan_unroll,
            remat_policy="none" if policy == "auto" else policy)
    return build_decode_step(cfg, shape, dtype=dtype, scan_unroll=scan_unroll)
