"""Production meshes.

Single pod: (data=16, model=16) — 256 chips of TPU v5e.
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the ``pod`` axis is
pure data parallelism so the only inter-pod (DCN) traffic is the gradient
all-reduce, which MBS amortizes to once per mini-batch.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module does not touch jax device state.
"""
from __future__ import annotations

import jax

DATA_AXIS = "data"
MODEL_AXIS = "model"
POD_AXIS = "pod"


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = (POD_AXIS, DATA_AXIS, MODEL_AXIS) if multi_pod else (DATA_AXIS, MODEL_AXIS)
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many (host) devices exist — used by tests."""
    if pod:
        return jax.make_mesh((pod, data, model), (POD_AXIS, DATA_AXIS, MODEL_AXIS))
    return jax.make_mesh((data, model), (DATA_AXIS, MODEL_AXIS))


def parse_mesh_spec(spec: str, device_count: int | None = None):
    """Parse a launcher ``--mesh`` axis spec ``"DATA:MODEL"`` (e.g.
    ``"2:4"``) into ``(data, model)``, validated against the visible
    device count — fail fast at argument-parsing time instead of deep
    inside ``jax.make_mesh``. ``device_count=None`` reads the real
    backend."""
    parts = spec.split(":")
    if len(parts) != 2:
        raise ValueError(
            f"mesh spec {spec!r} is not of the form DATA:MODEL (two "
            "integers, e.g. '2:4' for a 2-way data x 4-stage pipeline "
            "mesh)")
    try:
        data, model = (int(p) for p in parts)
    except ValueError:
        raise ValueError(
            f"mesh spec {spec!r} is not of the form DATA:MODEL (two "
            "integers, e.g. '2:4')") from None
    if data < 1 or model < 1:
        raise ValueError(f"mesh spec {spec!r}: axis sizes must be >= 1")
    n = jax.device_count() if device_count is None else device_count
    if data * model > n:
        raise ValueError(
            f"mesh spec {spec!r} needs {data * model} devices but only "
            f"{n} are visible")
    return data, model


def batch_axes(mesh) -> tuple:
    """Mesh axes the batch dimension is sharded over."""
    return tuple(a for a in (POD_AXIS, DATA_AXIS) if a in mesh.axis_names)


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def data_parallel_size(mesh) -> int:
    """Number of data-parallel workers: the product of the batch axes
    ((pod, data) when the pod axis exists, else data). This is the factor
    the planner divides the global micro-batch by to get the per-device
    ``local_micro`` (engine Layer 6)."""
    dp = 1
    for a in batch_axes(mesh):
        dp *= axis_size(mesh, a)
    return dp
