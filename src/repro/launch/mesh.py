"""Production meshes.

Single pod: (data=16, model=16) — 256 chips of TPU v5e.
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the ``pod`` axis is
pure data parallelism so the only inter-pod (DCN) traffic is the gradient
all-reduce, which MBS amortizes to once per mini-batch.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module does not touch jax device state.
"""
from __future__ import annotations

import jax

DATA_AXIS = "data"
MODEL_AXIS = "model"
POD_AXIS = "pod"


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = (POD_AXIS, DATA_AXIS, MODEL_AXIS) if multi_pod else (DATA_AXIS, MODEL_AXIS)
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many (host) devices exist — used by tests."""
    if pod:
        return jax.make_mesh((pod, data, model), (POD_AXIS, DATA_AXIS, MODEL_AXIS))
    return jax.make_mesh((data, model), (DATA_AXIS, MODEL_AXIS))


def batch_axes(mesh) -> tuple:
    """Mesh axes the batch dimension is sharded over."""
    return tuple(a for a in (POD_AXIS, DATA_AXIS) if a in mesh.axis_names)


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def data_parallel_size(mesh) -> int:
    """Number of data-parallel workers: the product of the batch axes
    ((pod, data) when the pod axis exists, else data). This is the factor
    the planner divides the global micro-batch by to get the per-device
    ``local_micro`` (engine Layer 6)."""
    dp = 1
    for a in batch_axes(mesh):
        dp *= axis_size(mesh, a)
    return dp
