"""The paper's own model families: ResNet (classification) and U-Net
(semantic segmentation), in pure JAX.

BatchNorm statistics are computed per *micro*-batch under MBS — exactly the
semantics of the paper's PyTorch experiments (§4.2.2) — and running
statistics are threaded as explicit state.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import remat as remat_lib


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def conv_init(key, k: int, cin: int, cout: int):
    fan_in = k * k * cin
    return {"w": jax.random.normal(key, (k, k, cin, cout), jnp.float32)
            * math.sqrt(2.0 / fan_in)}


def conv(p, x, stride: int = 1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, p["w"].astype(x.dtype), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def bn_init(c: int):
    return ({"scale": jnp.ones((c,), jnp.float32),
             "bias": jnp.zeros((c,), jnp.float32)},
            {"mean": jnp.zeros((c,), jnp.float32),
             "var": jnp.ones((c,), jnp.float32)})


def batchnorm(p, state, x, train: bool, momentum: float = 0.9):
    if train:
        mu = jnp.mean(x, axis=(0, 1, 2))
        var = jnp.var(x, axis=(0, 1, 2))
        new_state = {"mean": momentum * state["mean"] + (1 - momentum) * mu,
                     "var": momentum * state["var"] + (1 - momentum) * var}
    else:
        mu, var = state["mean"], state["var"]
        new_state = state
    y = (x - mu) * jax.lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# ResNet (bottleneck, ResNet-50-style; depth configurable)
# ---------------------------------------------------------------------------

def _bottleneck_init(key, cin: int, cmid: int, stride: int):
    ks = jax.random.split(key, 4)
    cout = cmid * 4
    p: Dict[str, Any] = {"conv1": conv_init(ks[0], 1, cin, cmid),
                         "conv2": conv_init(ks[1], 3, cmid, cmid),
                         "conv3": conv_init(ks[2], 1, cmid, cout)}
    s: Dict[str, Any] = {}
    for i, c in [(1, cmid), (2, cmid), (3, cout)]:
        p[f"bn{i}"], s[f"bn{i}"] = bn_init(c)
    if stride != 1 or cin != cout:
        p["proj"] = conv_init(ks[3], 1, cin, cout)
        p["bn_proj"], s["bn_proj"] = bn_init(cout)
    return p, s


def _bottleneck(p, s, x, stride: int, train: bool):
    ns = {}
    h = conv(p["conv1"], x)
    h, ns["bn1"] = batchnorm(p["bn1"], s["bn1"], h, train)
    h = jax.nn.relu(h)
    h = conv(p["conv2"], h, stride)
    h, ns["bn2"] = batchnorm(p["bn2"], s["bn2"], h, train)
    h = jax.nn.relu(h)
    h = conv(p["conv3"], h)
    h, ns["bn3"] = batchnorm(p["bn3"], s["bn3"], h, train)
    if "proj" in p:
        x = conv(p["proj"], x, stride)
        x, ns["bn_proj"] = batchnorm(p["bn_proj"], s["bn_proj"], x, train)
    return jax.nn.relu(x + h), ns


def resnet_init(key, *, num_classes: int, stage_sizes: Sequence[int] = (3, 4, 6, 3),
                width: int = 64, in_channels: int = 3):
    """stage_sizes (3,4,6,3) == ResNet-50; (3,4,23,3) == ResNet-101."""
    ks = jax.random.split(key, 3 + sum(stage_sizes))
    params: Dict[str, Any] = {"stem": conv_init(ks[0], 7, in_channels, width)}
    state: Dict[str, Any] = {}
    params["bn_stem"], state["bn_stem"] = bn_init(width)
    cin = width
    ki = 1
    for si, n in enumerate(stage_sizes):
        cmid = width * (2 ** si)
        for bi in range(n):
            stride = 2 if (bi == 0 and si > 0) else 1
            p, s = _bottleneck_init(ks[ki], cin, cmid, stride)
            params[f"s{si}b{bi}"], state[f"s{si}b{bi}"] = p, s
            cin = cmid * 4
            ki += 1
    params["head"] = {"w": jnp.zeros((cin, num_classes), jnp.float32),
                      "b": jnp.zeros((num_classes,), jnp.float32)}
    return params, state


def resnet_forward(params, state, x, *, stage_sizes=(3, 4, 6, 3), train=True,
                   remat_policy: str = "none"):
    """x: (B, H, W, C) -> logits (B, num_classes); returns (logits, new_state).

    The remat unit is one bottleneck block: under MBS the CNNs have no
    period scan, so ``remat_policy`` grades per-block checkpointing
    ("dots" saves the convolutions, "period"/"full" save only block
    boundaries)."""
    ns: Dict[str, Any] = {}
    h = conv(params["stem"], x, stride=2)
    h, ns["bn_stem"] = batchnorm(params["bn_stem"], state["bn_stem"], h, train)
    h = jax.nn.relu(h)
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                              (1, 2, 2, 1), "SAME")
    for si, n in enumerate(stage_sizes):
        for bi in range(n):
            stride = 2 if (bi == 0 and si > 0) else 1
            block = remat_lib.checkpoint_period(
                lambda bp, bs, bh, stride=stride: _bottleneck(
                    bp, bs, bh, stride, train), remat_policy)
            h, ns[f"s{si}b{bi}"] = block(
                params[f"s{si}b{bi}"], state[f"s{si}b{bi}"], h)
    h = jnp.mean(h, axis=(1, 2))
    logits = h.astype(jnp.float32) @ params["head"]["w"] + params["head"]["b"]
    return logits, ns


# ---------------------------------------------------------------------------
# U-Net (paper's segmentation model)
# ---------------------------------------------------------------------------

def _double_conv_init(key, cin: int, cout: int):
    k1, k2 = jax.random.split(key)
    p = {"c1": conv_init(k1, 3, cin, cout), "c2": conv_init(k2, 3, cout, cout)}
    s = {}
    p["bn1"], s["bn1"] = bn_init(cout)
    p["bn2"], s["bn2"] = bn_init(cout)
    return p, s


def _double_conv(p, s, x, train):
    ns = {}
    h = conv(p["c1"], x)
    h, ns["bn1"] = batchnorm(p["bn1"], s["bn1"], h, train)
    h = jax.nn.relu(h)
    h = conv(p["c2"], h)
    h, ns["bn2"] = batchnorm(p["bn2"], s["bn2"], h, train)
    return jax.nn.relu(h), ns


def unet_init(key, *, in_channels: int = 3, out_channels: int = 1,
              base: int = 64, depth: int = 4):
    ks = jax.random.split(key, 2 * depth + 2)
    params: Dict[str, Any] = {}
    state: Dict[str, Any] = {}
    c = in_channels
    for d in range(depth + 1):
        cout = base * (2 ** d)
        params[f"down{d}"], state[f"down{d}"] = _double_conv_init(ks[d], c, cout)
        c = cout
    for d in reversed(range(depth)):
        cout = base * (2 ** d)
        p, s = _double_conv_init(ks[depth + 1 + d], c + cout, cout)
        params[f"up{d}"], state[f"up{d}"] = p, s
        c = cout
    params["head"] = conv_init(ks[-1], 1, c, out_channels)
    return params, state


def unet_forward(params, state, x, *, depth: int = 4, train=True,
                 remat_policy: str = "none"):
    """x: (B, H, W, C) -> logits (B, H, W, out); returns (logits, new_state).

    The remat unit is one double-conv block (see ``resnet_forward``)."""
    block = remat_lib.checkpoint_period(
        lambda bp, bs, bh: _double_conv(bp, bs, bh, train), remat_policy)
    ns: Dict[str, Any] = {}
    skips: List[jnp.ndarray] = []
    h = x
    for d in range(depth + 1):
        h, ns[f"down{d}"] = block(params[f"down{d}"], state[f"down{d}"], h)
        if d < depth:
            skips.append(h)
            h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                      (1, 2, 2, 1), "VALID")
    for d in reversed(range(depth)):
        B, H, W, C = h.shape
        h = jax.image.resize(h, (B, H * 2, W * 2, C), "nearest")
        h = jnp.concatenate([skips[d], h], axis=-1)
        h, ns[f"up{d}"] = block(params[f"up{d}"], state[f"up{d}"], h)
    return conv(params["head"], h).astype(jnp.float32), ns
