"""Mamba2 (SSD — state-space duality) block, TPU-adapted.

The SSD computation is implemented in the *chunked* (block) form: within a
chunk all work is dense matmuls (MXU-friendly — this is the TPU adaptation of
the paper's GPU scan), and a short ``lax.scan`` carries the (H, P, N) state
across chunks. Decode is the O(1) recurrent update.

Shapes: d_inner = expand*d_model, P = head_dim, H = d_inner/P heads,
N = ssm_state, single B/C group (G=1) as in mamba2-780m.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from . import nn
from . import remat as remat_lib
from .config import ModelConfig


def ssm_init(key, cfg: ModelConfig):
    d, di, N, H = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_num_heads
    W = cfg.conv_width
    ks = jax.random.split(key, 4)
    conv_dim = di + 2 * N
    return {
        # fused input projection: [z, x, B, C, dt]
        "in_proj": nn.dense_init(ks[0], d, 2 * di + 2 * N + H),
        "conv_w": jax.random.normal(ks[1], (W, conv_dim), jnp.float32) / math.sqrt(W),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),  # A = -exp(A_log) = -1 init
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "out_norm": nn.rmsnorm_init(di),
        "out_proj": nn.dense_init(ks[2], di, d),
    }


def _split_proj(cfg: ModelConfig, zxbcdt):
    di, N, H = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_num_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:2 * di + 2 * N]
    dt = zxbcdt[..., 2 * di + 2 * N:]
    return z, xBC, dt


def _causal_conv(xBC, conv_w, conv_b):
    """Depthwise causal conv, width W. xBC: (B, S, Cdim)."""
    W = conv_w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * conv_w[i].astype(xBC.dtype)
              for i in range(W))
    return jax.nn.silu(out + conv_b.astype(xBC.dtype))


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD. x: (B,S,H,P); dt: (B,S,H); A: (H,) negative;
    Bm, Cm: (B,S,N) (G=1, shared across heads).
    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    S0 = S
    if S % Q:  # pad tail: dt=0 steps are identity (decay=1, input=0)
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q
    f32 = jnp.float32
    xc = x.astype(f32).reshape(Bsz, nc, Q, H, P)
    dtc = dt.astype(f32).reshape(Bsz, nc, Q, H)
    Bc = Bm.astype(f32).reshape(Bsz, nc, Q, N)
    Cc = Cm.astype(f32).reshape(Bsz, nc, Q, N)

    a = dtc * A  # (B,nc,Q,H) log-decay per step (negative)
    cum = jnp.cumsum(a, axis=2)  # within-chunk inclusive cumsum
    # intra-chunk (diagonal blocks): L[i,j] = exp(cum_i - cum_j) for i>=j
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(seg), 0.0)
    xdt = xc * dtc[..., None]  # (B,nc,Q,H,P)
    G = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # (B,nc,Q,Q)
    y_diag = jnp.einsum("bcij,bcijh,bcjhp->bcihp", G, L, xdt)

    # chunk summary state: S_c = sum_j exp(cum_last - cum_j) B_j (x_j dt_j)^T
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,Q,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bc, decay_to_end, xdt)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,H) total chunk decay

    # carry state across chunks with an associative scan (log-depth, no
    # while loop — keeps the MXU busy and the HLO cost-analyzable)
    s0 = (jnp.zeros((Bsz, H, P, N), f32) if init_state is None
          else init_state.astype(f32))
    dec4 = chunk_decay[..., None, None]  # (B,nc,H,1,1)

    def combine(l, r):
        (dl, sl), (dr, sr) = l, r
        return dl * dr, sl * dr + sr

    _, s_end = jax.lax.associative_scan(combine, (dec4, states), axis=1)
    # state entering chunk c = decayed s0 + inclusive-scan result of chunk c-1
    cumdec = jnp.cumprod(dec4, axis=1)
    s_end = s_end + cumdec * s0[:, None]
    s_in = jnp.concatenate([s0[:, None], s_end[:, :-1]], axis=1)  # (B,nc,H,P,N)
    final = s_end[:, -1]
    # inter-chunk contribution: y_off[i] = exp(cum_i) * C_i . state_in
    y_off = jnp.einsum("bcin,bcih,bchpn->bcihp", Cc, jnp.exp(cum), s_in)
    y = (y_diag + y_off).reshape(Bsz, S, H, P)[:, :S0]
    return y.astype(x.dtype), final


def ssm_block(p, cfg: ModelConfig, x, compute_dtype=None,
              init_state=None, return_cache: bool = False,
              remat_policy: str = "none"
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence Mamba2 block. x: (B, S, D) -> (B, S, D).

    ``remat_policy="full"`` nests a ``jax.checkpoint`` around the block so
    the chunked-scan intermediates are recomputed per block, not per period."""
    fn = remat_lib.checkpoint_block(
        lambda bp, bx: _ssm_block(bp, cfg, bx, compute_dtype, init_state,
                                  return_cache), remat_policy)
    return fn(p, x)


def _ssm_block(p, cfg: ModelConfig, x, compute_dtype=None,
               init_state=None, return_cache: bool = False
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, S, D = x.shape
    di, N, H, P = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_num_heads, cfg.ssm_head_dim
    zxbcdt = nn.dense(p["in_proj"], x, compute_dtype)
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC_raw = xBC
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xs = xBC[..., :di].reshape(B, S, H, P)
    Bm = xBC[..., di:di + N]
    Cm = xBC[..., di + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])  # (H,)
    y, final = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk, init_state)
    y = y + xs * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = nn.rmsnorm(p["out_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = nn.dense(p["out_proj"], y, compute_dtype)
    if return_cache:
        W = cfg.conv_width
        conv_tail = xBC_raw[:, -(W - 1):, :]
        pad = W - 1 - conv_tail.shape[1]
        if pad > 0:
            conv_tail = jnp.pad(conv_tail, ((0, 0), (pad, 0), (0, 0)))
        return out, {"state": final, "conv": conv_tail}
    return out, final


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype):
    di, N, H, P = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_num_heads, cfg.ssm_head_dim
    conv_dim = di + 2 * N
    return {
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
    }


def ssm_decode_step(p, cfg: ModelConfig, x, cache, compute_dtype=None):
    """One-token recurrent update. x: (B, 1, D)."""
    B = x.shape[0]
    di, N, H, P = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_num_heads, cfg.ssm_head_dim
    zxbcdt = nn.dense(p["in_proj"], x[:, 0], compute_dtype)  # (B, ...)
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    # conv over the buffered window
    win = jnp.concatenate([cache["conv"].astype(xBC.dtype),
                           xBC[:, None, :]], axis=1)  # (B, W, Cdim)
    conv_out = jnp.einsum("bwc,wc->bc", win, p["conv_w"].astype(xBC.dtype))
    xBC_c = jax.nn.silu(conv_out + p["conv_b"].astype(xBC.dtype))
    xs = xBC_c[..., :di].reshape(B, H, P)
    Bm = xBC_c[..., di:di + N]  # (B, N)
    Cm = xBC_c[..., di + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, H)
    A = -jnp.exp(p["A_log"])
    dec = jnp.exp(dt * A)  # (B, H)
    xdt = xs.astype(jnp.float32) * dt[..., None]  # (B,H,P)
    new_state = (cache["state"] * dec[..., None, None]
                 + jnp.einsum("bn,bhp->bhpn", Bm.astype(jnp.float32), xdt))
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), new_state)
    y = y.astype(xs.dtype) + xs * p["D"].astype(xs.dtype)[None, :, None]
    y = y.reshape(B, di)
    y = nn.rmsnorm(p["out_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = nn.dense(p["out_proj"], y, compute_dtype)[:, None, :]
    new_cache = {"state": new_state, "conv": win[:, 1:, :]}
    return out, new_cache
