"""Mixture-of-Experts FFN: top-k routing with GShard-style capacity-bounded
one-hot dispatch (dense einsum dispatch/combine — MXU-friendly and shardable:
with experts sharded over the ``model`` mesh axis, GSPMD lowers the dispatch
and combine einsums to all-to-all).

Includes the standard load-balance auxiliary loss; under Micro-Batch
Streaming the aux loss is normalized by the same 1/N_Sμ factor as the task
loss (see repro.core.mbs), so the accumulated total gradient stays exact.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from . import nn
from . import remat as remat_lib
from .config import ModelConfig


def moe_init(key, cfg: ModelConfig):
    d, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    glu = cfg.ffn_kind in ("swiglu", "geglu")
    ks = jax.random.split(key, 5)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(F)
    p = {
        "router": nn.dense_init(ks[0], d, E, scale=0.02),
        "w_up": jax.random.normal(ks[1], (E, d, F), jnp.float32) * s_in,
        "w_down": jax.random.normal(ks[2], (E, F, d), jnp.float32) * s_out,
    }
    if glu:
        p["w_gate"] = jax.random.normal(ks[3], (E, d, F), jnp.float32) * s_in
    if cfg.num_shared_experts:
        p["shared"] = nn.ffn_init(ks[4], d,
                                  cfg.num_shared_experts * (cfg.shared_d_ff or cfg.moe_d_ff),
                                  cfg.ffn_kind)
    return p


def _hints(num_experts: int):
    """Sharding hints for the expert tensors: expert-parallel when E divides
    the ``model`` mesh axis, tensor-parallel on d_ff otherwise. GSPMD alone
    replicates the (E, C, F) hidden (and its gradient) — at grok-1 scale
    that is 2×40 GiB per device, so the hints are load-bearing."""
    msize = nn.mesh_axis_size("model")
    if msize > 1 and num_experts % msize == 0:
        return ("model", None, None), ("model", None, None)
    # capacity-parallel experts (E not divisible by the model axis): shard
    # the token-slot dim C — expert matmuls are then embarrassingly parallel
    # (weights gathered per layer, FSDP-style; gradients reduce-scattered)
    # instead of contracting a sharded F, where GSPMD all-gathers the
    # (E, C, F) hidden (40 GiB/device at grok-1 scale).
    return (None, "model", None), (None, "model", None)


def _expert_ffn(p, x, kind: str, num_experts: int):
    """x: (E, C, D) -> (E, C, D) batched over experts."""
    hid_spec, out_spec = _hints(num_experts)
    x = nn.shard_hint(x, *out_spec)
    up = jnp.einsum("ecd,edf->ecf", x, p["w_up"].astype(x.dtype))
    up = nn.shard_hint(up, *hid_spec)
    if kind == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, p["w_gate"].astype(x.dtype))) * up
    elif kind == "geglu":
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", x, p["w_gate"].astype(x.dtype))) * up
    else:
        h = jax.nn.gelu(up)
    h = nn.shard_hint(h, *hid_spec)
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))
    return nn.shard_hint(out, *out_spec)


def moe_block(p, cfg: ModelConfig, x, compute_dtype=None,
              remat_policy: str = "none") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D). Returns (out (B,S,D), aux_loss scalar fp32).

    ``remat_policy="full"`` nests a ``jax.checkpoint`` around the block
    (inside the per-period one) so the routing/dispatch/expert-FFN
    intermediates are recomputed one block at a time in the backward."""
    fn = remat_lib.checkpoint_block(
        lambda bp, bx: _moe_block(bp, cfg, bx, compute_dtype), remat_policy)
    return fn(p, x)


def _moe_block(p, cfg: ModelConfig, x, compute_dtype=None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x = nn.seq_gathered(x)  # full-S tokens for routing/dispatch
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    xt = x.reshape(T, D)
    if compute_dtype is not None:
        xt = xt.astype(compute_dtype)

    gate_logits = nn.dense(p["router"], xt, jnp.float32)  # router in fp32
    probs = jax.nn.softmax(gate_logits, axis=-1)  # (T, E)
    topv, topi = jax.lax.top_k(probs, k)  # (T, k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)  # renormalize

    # load-balance aux loss (Switch/GShard): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)  # (E,)
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)  # (T, k, E)
    ce = jnp.mean(jnp.sum(onehot, axis=1), axis=0) / k  # fraction routed
    aux = E * jnp.sum(me * ce)

    # capacity-bounded scatter dispatch (avoids the O(T*E*C) one-hot tensor
    # of classic GShard; the expert compute is still a dense batched matmul)
    C = max(1, int(math.ceil(T * k / E * cfg.capacity_factor)))
    C = min(C, T)
    flat_e = topi.reshape(-1)  # (T*k,) expert id, token-major order
    in_e = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*k, E)
    pos_in_e = jnp.cumsum(in_e, axis=0) * in_e - 1  # (T*k, E): queue pos or -1
    pos = jnp.max(pos_in_e, axis=-1)  # (T*k,) position within expert queue
    keep = pos < C
    # destination row in the (E*C,) expert buffer; dropped slots -> trash row
    idx = jnp.where(keep, flat_e * C + pos, E * C)  # (T*k,)
    xs = jnp.repeat(xt, k, axis=0)  # (T*k, D)
    buf = jnp.zeros((E * C + 1, D), xt.dtype).at[idx].add(xs)
    eout = _expert_ffn(p, buf[:E * C].reshape(E, C, D), cfg.ffn_kind,
                       cfg.num_experts)
    # gather back and combine with (renormalized) router weights
    back = jnp.concatenate([eout.reshape(E * C, D),
                            jnp.zeros((1, D), eout.dtype)])[idx]  # (T*k, D)
    w = jnp.where(keep, topv.reshape(-1), 0.0).astype(xt.dtype)
    out = jnp.sum((back * w[:, None]).reshape(T, k, D), axis=1)  # (T, D)

    if cfg.num_shared_experts:
        out = out + nn.ffn(p["shared"], xt, cfg.ffn_kind, compute_dtype)
    out = nn.seq_sharded(out.reshape(B, S, D).astype(x.dtype))
    return out, aux.astype(jnp.float32)
