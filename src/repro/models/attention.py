"""Attention: GQA with causal / sliding-window masks, logit soft-capping,
QK-norm, RoPE / M-RoPE — plus ring-buffer KV-cache decode.

The jnp path here is the reference implementation used for training and for
CPU validation; ``repro.kernels.flash_attention`` provides the Pallas TPU
kernel for the same math (selected via ``use_kernel=True``).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from . import nn
from .config import ModelConfig


def attn_init(key, cfg: ModelConfig):
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    kq, kk, kv, ko, kn = jax.random.split(key, 5)
    p = {
        "wq": nn.dense_init(kq, d, H * hd, bias=cfg.qkv_bias),
        "wk": nn.dense_init(kk, d, K * hd, bias=cfg.qkv_bias),
        "wv": nn.dense_init(kv, d, K * hd, bias=cfg.qkv_bias),
        "wo": nn.dense_init(ko, H * hd, d),
    }
    if cfg.use_qk_norm:
        p["q_norm"] = nn.rmsnorm_init(hd)
        p["k_norm"] = nn.rmsnorm_init(hd)
    return p


def _mask_bias(q_pos, k_pos, window: Optional[int], causal: bool = True):
    """Additive mask bias: (..., S_q, S_k). q_pos/k_pos are int32 arrays
    broadcastable to (..., S_q) and (..., S_k)."""
    dq = q_pos[..., :, None]
    dk = k_pos[..., None, :]
    ok = jnp.ones(jnp.broadcast_shapes(dq.shape, dk.shape), bool)
    if causal:
        ok &= dk <= dq
    if window is not None:
        ok &= dk > dq - window
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def multihead_attention(q, k, v, *, q_pos, k_pos, window=None, causal=True,
                        softcap=None, k_valid=None):
    """q: (B,S,H,hd); k,v: (B,T,K,hd) with H % K == 0 (GQA).

    k_valid: optional bool (B, T) marking valid cache slots.
    Returns (B, S, H, hd)."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qf = q.astype(jnp.float32).reshape(B, S, K, G, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bskgd,btkd->bkgst", qf, kf) / math.sqrt(hd)
    logits = nn.softcap(logits, softcap)
    bias = _mask_bias(q_pos, k_pos, window, causal)  # (B?, S, T)
    while bias.ndim < logits.ndim:
        bias = bias[:, None]
    logits = logits + bias
    if k_valid is not None:
        logits = jnp.where(k_valid[:, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, vf)
    return out.reshape(B, S, H, hd).astype(q.dtype)


def default_q_chunk() -> int:
    """Attention q-chunk size; override with REPRO_Q_CHUNK (perf knob for
    the §Perf hillclimb loop)."""
    import os
    return int(os.environ.get("REPRO_Q_CHUNK", "512"))


def chunked_attention(q, k, v, *, q_pos, k_pos, window=None, causal=True,
                      softcap=None, q_chunk=None, max_chunks=32,
                      align=128):
    """Query-chunked attention: never materializes the (S, S) logits tensor
    (peak extra memory is one (B, H, q_chunk, k_span) block, reused across
    the unrolled chunk loop), and for sliding-window layers each q-chunk
    only reads the k-range it can see — an O(S·W) instead of O(S²) compute
    path. Exact (full softmax row per chunk), not an approximation.

    This is the pure-JAX twin of kernels/flash_attention; it is what the
    production train/prefill steps lower (the Pallas kernel is the TPU
    hot-path for the same math)."""
    if q_chunk is None:
        q_chunk = default_q_chunk()
    B, S, H, hd = q.shape
    if S <= q_chunk:
        return multihead_attention(q, k, v, q_pos=q_pos, k_pos=k_pos,
                                   window=window, causal=causal,
                                   softcap=softcap)
    qc = max(q_chunk, -(-S // max_chunks))
    qc = -(-qc // align) * align
    outs = []
    for c0 in range(0, S, qc):
        c1 = min(c0 + qc, S)
        # static k-span visible to this q chunk (positions are the standard
        # arange; ragged/custom positions still mask correctly inside)
        k1 = c1 if causal else k.shape[1]
        k0 = 0 if window is None else max(0, c0 - window + 1)
        k0 = (k0 // align) * align
        out = multihead_attention(
            q[:, c0:c1], k[:, k0:k1], v[:, k0:k1],
            q_pos=q_pos[:, c0:c1], k_pos=k_pos[:, k0:k1],
            window=window, causal=causal, softcap=softcap)
        outs.append(out)
    return jnp.concatenate(outs, axis=1)


def attn_block(p, cfg: ModelConfig, x, positions, *, window=None,
               rope_theta=None, compute_dtype=None, mrope_positions=None):
    """Full-sequence attention (train / prefill). x: (B, S, D)."""
    B, S, _ = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    x = nn.seq_gathered(x)  # bf16 all-gather at the TP boundary
    q = nn.dense(p["wq"], x, compute_dtype).reshape(B, S, H, hd)
    k = nn.dense(p["wk"], x, compute_dtype).reshape(B, S, K, hd)
    v = nn.dense(p["wv"], x, compute_dtype).reshape(B, S, K, hd)
    if cfg.use_qk_norm:
        q = nn.rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = nn.rmsnorm(p["k_norm"], k, cfg.norm_eps)
    theta = rope_theta if rope_theta is not None else cfg.rope_theta
    if cfg.mrope_sections is not None and mrope_positions is not None:
        q = nn.apply_mrope(q, mrope_positions, theta, cfg.mrope_sections)
        k = nn.apply_mrope(k, mrope_positions, theta, cfg.mrope_sections)
    else:
        q = nn.apply_rope(q, positions, theta)
        k = nn.apply_rope(k, positions, theta)
    # head-sharded attention when the head count divides the model axis;
    # otherwise context-parallel: q ROWS shard over model (valid for any
    # head count; k/v replicated — cheap under GQA) instead of replicating
    # the whole attention computation 16×.
    msize = nn.mesh_axis_size("model")
    heads_div = msize > 1 and H % msize == 0
    qax = "model" if heads_div else None
    kax = "model" if msize > 1 and K % msize == 0 else None
    sax = None
    if not heads_div and msize > 1 and S % msize == 0 and S >= msize:
        sax = "model"  # context parallelism
    batch = ("pod", "data")
    q = nn.shard_hint(q, batch, sax, qax, None)
    k = nn.shard_hint(k, batch, None, kax, None)
    v = nn.shard_hint(v, batch, None, kax, None)
    out = chunked_attention(q, k, v, q_pos=positions, k_pos=positions,
                            window=window, softcap=cfg.attn_softcap)
    out = nn.shard_hint(out, batch, sax, qax, None)
    out = nn.dense(p["wo"], out.reshape(B, S, H * hd), compute_dtype)
    return nn.seq_sharded(out), (k, v)  # reduce-scatter back to S-shards


def cross_attn_block(p, cfg: ModelConfig, x, kv_src=None, kv_cache=None,
                     src_valid=None, compute_dtype=None):
    """Encoder-decoder cross attention. kv_src: encoder output (B, T, D), or
    pass precomputed (k, v) via kv_cache for decode."""
    B, S, _ = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = nn.dense(p["wq"], x, compute_dtype).reshape(B, S, H, hd)
    if kv_cache is None:
        T = kv_src.shape[1]
        k = nn.dense(p["wk"], kv_src, compute_dtype).reshape(B, T, K, hd)
        v = nn.dense(p["wv"], kv_src, compute_dtype).reshape(B, T, K, hd)
    else:
        k, v = kv_cache
        T = k.shape[1]
    q_pos = jnp.zeros((B, S), jnp.int32)
    k_pos = jnp.zeros((B, T), jnp.int32)
    out = multihead_attention(q, k, v, q_pos=q_pos, k_pos=k_pos, causal=False,
                              softcap=cfg.attn_softcap, k_valid=src_valid)
    return nn.dense(p["wo"], out.reshape(B, S, H * hd), compute_dtype), (k, v)


# ---------------------------------------------------------------------------
# Decode: ring-buffer KV cache (bounded to the window for local layers)
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  window: Optional[int], dtype):
    W = max_len if window is None else min(window, max_len)
    K, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, W, K, hd), dtype),
        "v": jnp.zeros((batch, W, K, hd), dtype),
        # absolute position stored in each ring slot; -1 = empty
        "pos": jnp.full((batch, W), -1, jnp.int32),
    }


def ring_cache_from_full(k, v, positions, window, max_len: int,
                         lengths=None):
    """Convert full-sequence prefill (k, v) into the ring-buffer cache layout
    used by ``attn_decode_step``. positions: (B, S) absolute positions
    following the standard arange layout (slot = position % W).

    Implemented as a static gather permutation along the sequence axis (not a
    batch-indexed scatter, which GSPMD replicates — 2×8 GiB/device at
    gemma2 prefill_32k scale).

    ``lengths`` (B,) switches to the RAGGED layout for right-padded prompt
    batches: row ``b``'s ring holds its last ``min(lengths[b], W)`` REAL
    tokens (slot ``p % W`` holds position ``p``) and every other slot is
    empty (pos -1) — padding tokens never enter the cache and, crucially,
    never evict real keys out of a sliding window the way the dense
    layout's tail would. This is a per-row gather (take_along_axis), the
    batch-dynamic generalization of the static permutation below."""
    B, S, K, hd = k.shape
    W = max_len if window is None else min(window, max_len)
    if lengths is not None:
        L = lengths.astype(jnp.int32)[:, None]  # (B, 1)
        j = jnp.arange(W, dtype=jnp.int32)[None]  # (1, W)
        # largest real position p <= L-1 with p ≡ j (mod W); rows shorter
        # than W leave slots j >= L empty
        p = L - 1 - ((L - 1 - j) % W)
        valid = p >= 0
        src = jnp.clip(p, 0, S - 1)[..., None, None]
        ck = jnp.take_along_axis(k, src, axis=1)
        cv = jnp.take_along_axis(v, src, axis=1)
        return {"k": ck, "v": cv, "pos": jnp.where(valid, p, -1)}
    take = min(S, W)
    if take < W:  # short prefill: slots [0, S) filled, the rest empty
        pad = W - take
        ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cpos = jnp.pad(positions.astype(jnp.int32), ((0, 0), (0, pad)),
                       constant_values=-1)
        return {"k": ck, "v": cv, "pos": cpos}
    # slot j holds source index S - W + ((j - S) mod W): a static permutation
    j = jnp.arange(W)
    src = S - W + (j - (S % W)) % W
    ck = jnp.take(k, src, axis=1)
    cv = jnp.take(v, src, axis=1)
    cpos = jnp.take(positions.astype(jnp.int32), src, axis=1)
    return {"k": ck, "v": cv, "pos": cpos}


def attn_decode_step(p, cfg: ModelConfig, x, cache, cur_pos, *, window=None,
                     rope_theta=None, compute_dtype=None):
    """One-token decode. x: (B, 1, D); cur_pos: (B,) absolute position.

    Writes (k, v) into the ring slot ``cur_pos % W`` and attends over valid
    slots. Returns (out (B,1,D), new_cache)."""
    B = x.shape[0]
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    W = cache["k"].shape[1]
    q = nn.dense(p["wq"], x, compute_dtype).reshape(B, 1, H, hd)
    k = nn.dense(p["wk"], x, compute_dtype).reshape(B, 1, K, hd)
    v = nn.dense(p["wv"], x, compute_dtype).reshape(B, 1, K, hd)
    if cfg.use_qk_norm:
        q = nn.rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = nn.rmsnorm(p["k_norm"], k, cfg.norm_eps)
    theta = rope_theta if rope_theta is not None else cfg.rope_theta
    pos2d = cur_pos[:, None]
    q = nn.apply_rope(q, pos2d, theta)
    k = nn.apply_rope(k, pos2d, theta)

    slot = (cur_pos % W).astype(jnp.int32)  # (B,)
    bidx = jnp.arange(B)
    new_k = cache["k"].astype(k.dtype).at[bidx, slot].set(k[:, 0])
    new_v = cache["v"].astype(v.dtype).at[bidx, slot].set(v[:, 0])
    new_pos = cache["pos"].at[bidx, slot].set(cur_pos.astype(jnp.int32))

    k_valid = new_pos >= 0
    if window is not None:
        k_valid &= new_pos > (cur_pos[:, None] - window)
    out = multihead_attention(q, new_k, new_v, q_pos=pos2d, k_pos=new_pos,
                              window=None, causal=True,
                              softcap=cfg.attn_softcap, k_valid=k_valid)
    out = nn.dense(p["wo"], out.reshape(B, 1, H * hd), compute_dtype)
    return out, {"k": new_k, "v": new_v, "pos": new_pos}
