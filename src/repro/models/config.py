"""Model configuration for every architecture family in the framework.

A single dataclass covers dense / MoE / SSM / hybrid / VLM / audio(enc-dec)
families; per-layer behaviour is driven by ``layer_pattern``, a cycle of
block kinds repeated over the depth of the network.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # Block pattern: cycle of kinds, each entry one of
    #   "global"    full causal attention + FFN
    #   "local"     sliding-window causal attention + FFN
    #   "recurrent" RG-LRU block + FFN
    #   "ssm"       Mamba2 (SSD) block, no FFN
    layer_pattern: Tuple[str, ...] = ("global",)
    sliding_window: int = 4096
    # long-context variant: cap "global" layers to this window when serving
    # long_500k (None = true full attention)
    long_context_global_window: Optional[int] = None

    # attention details
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    use_qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_theta_global: Optional[float] = None  # gemma3 uses 1e6 on globals
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl

    # FFN
    ffn_kind: str = "swiglu"  # swiglu | geglu | gelu

    # MoE (active when num_experts > 0; replaces the dense FFN)
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    conv_width: int = 4

    # RG-LRU (recurrentgemma)
    lru_width: int = 0

    # encoder-decoder (audio): encoder_layers > 0 => enc-dec model
    encoder_layers: int = 0

    # VLM
    is_vlm: bool = False

    # norms / embeddings
    use_post_norm: bool = False  # gemma2/3 post-attn + post-ffn norms
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    embed_scale: bool = False  # gemma-style sqrt(d_model) embedding scale

    source: str = ""  # citation for the config

    # ---- derived -----------------------------------------------------------
    @property
    def pattern_len(self) -> int:
        return len(self.layer_pattern)

    @property
    def num_periods(self) -> int:
        assert self.num_layers % self.pattern_len == 0, (
            f"{self.name}: num_layers={self.num_layers} not divisible by "
            f"pattern length {self.pattern_len}")
        return self.num_layers // self.pattern_len

    @property
    def ssm_d_inner(self) -> int:
        return self.d_model * self.ssm_expand

    @property
    def ssm_num_heads(self) -> int:
        assert self.ssm_d_inner % self.ssm_head_dim == 0
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def param_count(self) -> int:
        """Total parameters (analytic; used by the memory model + roofline)."""
        d, hd = self.d_model, self.head_dim
        n_attn = (d * (self.num_heads + 2 * self.num_kv_heads) * hd
                  + self.num_heads * hd * d)
        if self.qkv_bias:
            n_attn += (self.num_heads + 2 * self.num_kv_heads) * hd
        n_ffn_dense = d * self.d_ff * (3 if self.ffn_kind in ("swiglu", "geglu") else 2)
        n_moe = 0
        if self.is_moe:
            per_e = d * self.moe_d_ff * (3 if self.ffn_kind in ("swiglu", "geglu") else 2)
            n_moe = self.num_experts * per_e + d * self.num_experts
            n_moe += self.num_shared_experts * d * (self.shared_d_ff or self.moe_d_ff) * 3
        di, N = self.ssm_d_inner, self.ssm_state
        H = self.ssm_num_heads if self.ssm_state else 0
        n_ssm = (d * (2 * di + 2 * N + H) + self.conv_width * (di + 2 * N)
                 + di * d + 2 * H) if self.ssm_state else 0
        w = self.lru_width
        n_rec = (d * 2 * w + self.conv_width * w + 2 * w * (w // max(self.num_heads, 1))
                 + w * d + 2 * w) if self.lru_width else 0

        total = 0
        for kind in self.layer_pattern:
            if kind in ("global", "local"):
                total += n_attn + (n_moe if self.is_moe else n_ffn_dense) + 4 * d
            elif kind == "recurrent":
                total += n_rec + n_ffn_dense + 4 * d
            elif kind == "ssm":
                total += n_ssm + 2 * d
        total *= self.num_periods
        if self.is_encdec:
            # encoder: same stack non-causal + cross-attn in decoder
            total += self.encoder_layers * (n_attn + n_ffn_dense + 4 * d)
            total += self.num_layers * (n_attn + 2 * d)  # cross attention
        total += self.vocab_size * d  # embedding (tied head)
        if not self.tie_embeddings:
            total += self.vocab_size * d
        total += d  # final norm
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.param_count()
        per_e = self.d_model * self.moe_d_ff * (3 if self.ffn_kind in ("swiglu", "geglu") else 2)
        inactive = (self.num_experts - self.experts_per_token) * per_e * self.num_layers
        return self.param_count() - int(inactive)


def round_up(x: int, m: int) -> int:
    return int(math.ceil(x / m) * m)
