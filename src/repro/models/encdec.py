"""Encoder-decoder transformer (seamless-m4t family).

The modality frontend (mel-spectrogram + conv feature extractor) is stubbed
per the assignment: the encoder consumes precomputed frame embeddings
``(B, S_enc, d_model)``. Everything downstream — the 12L encoder, 12L
decoder with cross-attention, tied LM head — is fully built.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from . import attention, nn
from . import remat as remat_lib
from .config import ModelConfig


def _enc_layer_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {
        "pre_norm": nn.rmsnorm_init(cfg.d_model),
        "attn": attention.attn_init(ks[0], cfg),
        "pre_ffn_norm": nn.rmsnorm_init(cfg.d_model),
        "ffn": nn.ffn_init(ks[1], cfg.d_model, cfg.d_ff, cfg.ffn_kind),
    }


def _dec_layer_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    return {
        "pre_norm": nn.rmsnorm_init(cfg.d_model),
        "self_attn": attention.attn_init(ks[0], cfg),
        "cross_norm": nn.rmsnorm_init(cfg.d_model),
        "cross_attn": attention.attn_init(ks[1], cfg),
        "pre_ffn_norm": nn.rmsnorm_init(cfg.d_model),
        "ffn": nn.ffn_init(ks[2], cfg.d_model, cfg.d_ff, cfg.ffn_kind),
    }


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    ke, kd, kemb = jax.random.split(key, 3)
    enc = [_enc_layer_init(jax.random.fold_in(ke, i), cfg)
           for i in range(cfg.encoder_layers)]
    dec = [_dec_layer_init(jax.random.fold_in(kd, i), cfg)
           for i in range(cfg.num_layers)]
    return {
        "embed": nn.embed_init(kemb, cfg.vocab_size, cfg.d_model),
        "enc_layers": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "enc_norm": nn.rmsnorm_init(cfg.d_model),
        "dec_layers": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "final_norm": nn.rmsnorm_init(cfg.d_model),
    }


def encode(params, cfg: ModelConfig, frames, *, dtype=jnp.bfloat16,
           remat: bool = True, remat_policy: Optional[str] = None,
           scan_unroll: int = 1):
    """frames: (B, S_enc, d_model) stubbed frontend embeddings."""
    policy = remat_lib.resolve(remat, remat_policy)
    B, S, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = frames.astype(dtype)

    def attn_part(p, h):
        B_, S_, _ = h.shape
        H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        q = nn.dense(p["attn"]["wq"], h, dtype).reshape(B_, S_, H, hd)
        k = nn.dense(p["attn"]["wk"], h, dtype).reshape(B_, S_, K, hd)
        v = nn.dense(p["attn"]["wv"], h, dtype).reshape(B_, S_, K, hd)
        q = nn.apply_rope(q, positions, cfg.rope_theta)
        k = nn.apply_rope(k, positions, cfg.rope_theta)
        o = attention.multihead_attention(q, k, v, q_pos=positions,
                                          k_pos=positions, causal=False,
                                          softcap=cfg.attn_softcap)
        return nn.dense(p["attn"]["wo"], o.reshape(B_, S_, H * hd), dtype)

    def layer(x, p):
        h = nn.rmsnorm(p["pre_norm"], x, cfg.norm_eps)
        x = x + remat_lib.checkpoint_block(attn_part, policy)(p, h)
        h = nn.rmsnorm(p["pre_ffn_norm"], x, cfg.norm_eps)
        x = x + remat_lib.checkpoint_block(
            lambda fp, hh: nn.ffn(fp, hh, cfg.ffn_kind, dtype),
            policy)(p["ffn"], h)
        return x, None

    layer = remat_lib.checkpoint_period(layer, policy)
    x, _ = jax.lax.scan(layer, x, params["enc_layers"], unroll=scan_unroll)
    return nn.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def forward(params, cfg: ModelConfig, frames, tgt_tokens, *,
            dtype=jnp.bfloat16, remat: bool = True,
            remat_policy: Optional[str] = None, scan_unroll: int = 1):
    """Teacher-forced forward. Returns (logits (B, S_dec, V), aux=0)."""
    policy = remat_lib.resolve(remat, remat_policy)
    enc_out = encode(params, cfg, frames, dtype=dtype, remat_policy=policy,
                     scan_unroll=scan_unroll)
    B, S = tgt_tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = nn.embed(params["embed"], tgt_tokens, dtype, scale=cfg.embed_scale)

    def self_part(p, h):
        h, _ = attention.attn_block(p["self_attn"], cfg, h, positions,
                                    compute_dtype=dtype)
        return h

    def cross_part(p, h):
        h, _ = attention.cross_attn_block(p["cross_attn"], cfg, h,
                                          kv_src=enc_out, compute_dtype=dtype)
        return h

    def layer(x, p):
        h = nn.rmsnorm(p["pre_norm"], x, cfg.norm_eps)
        x = x + remat_lib.checkpoint_block(self_part, policy)(p, h)
        h = nn.rmsnorm(p["cross_norm"], x, cfg.norm_eps)
        x = x + remat_lib.checkpoint_block(cross_part, policy)(p, h)
        h = nn.rmsnorm(p["pre_ffn_norm"], x, cfg.norm_eps)
        x = x + remat_lib.checkpoint_block(
            lambda fp, hh: nn.ffn(fp, hh, cfg.ffn_kind, dtype),
            policy)(p["ffn"], h)
        return x, None

    layer = remat_lib.checkpoint_period(layer, policy)
    x, _ = jax.lax.scan(layer, x, params["dec_layers"], unroll=scan_unroll)
    x = nn.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = nn.unembed(params["embed"], x, jnp.float32)
    return nn.softcap(logits, cfg.final_softcap), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_decode_cache(params, cfg: ModelConfig, frames, max_len: int,
                      dtype=jnp.bfloat16):
    """Runs the encoder, precomputes per-layer cross-attn K/V, and allocates
    the self-attn ring cache."""
    enc_out = encode(params, cfg, frames, dtype=dtype, remat=False)
    B = frames.shape[0]
    K, hd = cfg.num_kv_heads, cfg.head_dim
    T = enc_out.shape[1]

    def cross_kv(p):
        k = nn.dense(p["cross_attn"]["wk"], enc_out, dtype).reshape(B, T, K, hd)
        v = nn.dense(p["cross_attn"]["wv"], enc_out, dtype).reshape(B, T, K, hd)
        return {"k": k, "v": v}

    cross = jax.lax.map(cross_kv, params["dec_layers"])
    self_cache = attention.init_kv_cache(cfg, B, max_len, None, dtype)
    self_cache = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.num_layers,) + x.shape),
        self_cache)
    return {"self": self_cache, "cross": cross}


def decode_step(params, cfg: ModelConfig, token, cache, cur_pos, *,
                dtype=jnp.bfloat16, scan_unroll: int = 1):
    """One decoder token. token: (B,1); cur_pos: (B,)."""
    x = nn.embed(params["embed"], token, dtype, scale=cfg.embed_scale)

    def layer(x, p, c_self, c_cross):
        h = nn.rmsnorm(p["pre_norm"], x, cfg.norm_eps)
        h, nc = attention.attn_decode_step(p["self_attn"], cfg, h, c_self,
                                           cur_pos, compute_dtype=dtype)
        x = x + h
        h = nn.rmsnorm(p["cross_norm"], x, cfg.norm_eps)
        h, _ = attention.cross_attn_block(p["cross_attn"], cfg, h,
                                          kv_cache=(c_cross["k"], c_cross["v"]),
                                          compute_dtype=dtype)
        x = x + h
        h = nn.rmsnorm(p["pre_ffn_norm"], x, cfg.norm_eps)
        x = x + nn.ffn(p["ffn"], h, cfg.ffn_kind, dtype)
        return x, nc

    # fori_loop with in-place cache update (single live cache copy; see
    # transformer.decode_step)
    L = cfg.num_layers
    if scan_unroll >= L:
        new_self = cache["self"]
        for i in range(L):
            p = jax.tree.map(lambda a: a[i], params["dec_layers"])
            cs = jax.tree.map(lambda a: a[i], new_self)
            cc = jax.tree.map(lambda a: a[i], cache["cross"])
            x, nc = layer(x, p, cs, cc)
            new_self = jax.tree.map(
                lambda full, new: full.at[i].set(new.astype(full.dtype)),
                new_self, nc)
    else:
        def loop_body(i, carry):
            x, self_cache = carry
            p = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
                params["dec_layers"])
            cs = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
                self_cache)
            cc = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
                cache["cross"])
            x, nc = layer(x, p, cs, cc)
            self_cache = jax.tree.map(
                lambda full, new: jax.lax.dynamic_update_index_in_dim(
                    full, new.astype(full.dtype), i, 0),
                self_cache, nc)
            return x, self_cache

        x, new_self = jax.lax.fori_loop(0, L, loop_body, (x, cache["self"]))
    x = nn.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = nn.unembed(params["embed"], x, jnp.float32)
    return (nn.softcap(logits, cfg.final_softcap),
            {"self": new_self, "cross": cache["cross"]})
