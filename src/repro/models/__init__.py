from .config import ModelConfig  # noqa: F401
from . import attention, cnn, encdec, moe, nn, recurrent, remat, ssm, transformer  # noqa: F401
