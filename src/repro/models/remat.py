"""Graded rematerialization policies — the compute↔memory axis the planner
trades against the micro-batch size (engine Layer 5, DESIGN.md §Remat
planner).

The paper fits the micro-batch into "the remaining memory after the model
is uploaded" (§4.3.2); remat *creates* memory by trading compute for
activations, so the two knobs must be chosen jointly. The lattice, in
order of increasing memory savings / increasing recompute:

  ``none``    no checkpointing: every intermediate of every period stays
              live for the backward pass (fastest, heaviest).
  ``dots``    ``jax.checkpoint`` per period with
              ``checkpoint_policies.checkpoint_dots``: matmul outputs are
              saved (the expensive-to-recompute part), elementwise ops are
              recomputed.
  ``period``  plain ``jax.checkpoint`` per period (the repo's historical
              ``remat=True``): only the residual stream at each period
              boundary survives the forward; one period is recomputed at a
              time during the backward.
  ``full``    ``period`` plus a nested ``jax.checkpoint`` around every
              block *inside* the period, so the recompute working set is a
              single block rather than a whole period.

Model forwards take ``remat_policy`` (string) next to the legacy
``remat: bool``; :func:`resolve` maps the bool onto the lattice
(True → "period", False → "none") so existing callers are untouched.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax

# Lattice order == escalation order: the planner prefers the leftmost
# (cheapest-recompute) policy whose admitted micro-batch meets the target.
POLICIES = ("none", "dots", "period", "full")


def validate(policy: str) -> str:
    if policy not in POLICIES:
        raise ValueError(
            f"unknown remat policy {policy!r}; known: {list(POLICIES)} "
            "(or 'auto' at the planner layer)")
    return policy


def policy_weight(policy: str) -> int:
    """Position on the lattice (0 = no remat). Admission is monotone
    non-decreasing in this weight — the property the planner's escalation
    and the hypothesis tests rely on."""
    return POLICIES.index(validate(policy))


def resolve(remat: Optional[bool] = None,
            remat_policy: Optional[str] = None) -> str:
    """Collapse the (legacy bool, graded policy) pair to one policy.

    An explicit ``remat_policy`` wins; otherwise the bool maps to its
    historical meaning (per-period checkpointing or nothing)."""
    if remat_policy is not None:
        return validate(remat_policy)
    if remat is None or remat:
        return "period"
    return "none"


def checkpoint_period(fn: Callable, policy: str) -> Callable:
    """Wrap a period/scan-body function per the policy (outer level)."""
    validate(policy)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    if policy in ("period", "full"):
        return jax.checkpoint(fn)
    return fn


def checkpoint_block(fn: Callable, policy: str) -> Callable:
    """Wrap a single block inside an already-checkpointed period: only the
    ``full`` policy nests a second checkpoint here, shrinking the backward
    recompute working set from one period to one block."""
    validate(policy)
    if policy == "full":
        return jax.checkpoint(fn)
    return fn
