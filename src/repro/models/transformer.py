"""Decoder-only transformer assembly covering the dense / MoE / SSM / hybrid
/ VLM families.

Layers are grouped into *periods* (one cycle of ``cfg.layer_pattern``); the
per-slot parameters are stacked over periods and the depth dimension runs
under ``jax.lax.scan`` — this keeps the HLO size O(pattern) instead of
O(num_layers), which matters for the 512-device dry-run compiles, and gives
the natural remat boundary for Micro-Batch Streaming.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from . import attention, moe, nn, recurrent, ssm
from . import remat as remat_lib
from .config import ModelConfig

VISION_EMBED_DIM = 1280  # stubbed ViT output width (qwen2-vl card)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _slot_init(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    if kind in ("global", "local"):
        p["pre_norm"] = nn.rmsnorm_init(cfg.d_model)
        p["attn"] = attention.attn_init(ks[0], cfg)
        if cfg.use_post_norm:
            p["post_norm"] = nn.rmsnorm_init(cfg.d_model)
        p["pre_ffn_norm"] = nn.rmsnorm_init(cfg.d_model)
        if cfg.is_moe:
            p["moe"] = moe.moe_init(ks[1], cfg)
        else:
            p["ffn"] = nn.ffn_init(ks[1], cfg.d_model, cfg.d_ff, cfg.ffn_kind)
        if cfg.use_post_norm:
            p["post_ffn_norm"] = nn.rmsnorm_init(cfg.d_model)
    elif kind == "recurrent":
        p["pre_norm"] = nn.rmsnorm_init(cfg.d_model)
        p["rec"] = recurrent.recurrent_init(ks[0], cfg)
        p["pre_ffn_norm"] = nn.rmsnorm_init(cfg.d_model)
        p["ffn"] = nn.ffn_init(ks[1], cfg.d_model, cfg.d_ff, cfg.ffn_kind)
    elif kind == "ssm":
        p["pre_norm"] = nn.rmsnorm_init(cfg.d_model)
        p["ssm"] = ssm.ssm_init(ks[0], cfg)
    else:
        raise ValueError(kind)
    return p


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    kemb, kblocks, kvis = jax.random.split(key, 3)
    P = cfg.num_periods
    blocks = []
    for s, kind in enumerate(cfg.layer_pattern):
        per = [_slot_init(jax.random.fold_in(kblocks, s * 1000 + i), cfg, kind)
               for i in range(P)]
        blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per))
    params = {
        "embed": nn.embed_init(kemb, cfg.vocab_size, cfg.d_model),
        "final_norm": nn.rmsnorm_init(cfg.d_model),
        "blocks": tuple(blocks),
    }
    if cfg.is_vlm:
        params["vision_proj"] = nn.dense_init(kvis, VISION_EMBED_DIM, cfg.d_model)
    if not cfg.tie_embeddings:
        params["unembed"] = nn.dense_init(jax.random.fold_in(kemb, 1),
                                          cfg.d_model, cfg.vocab_size)
    return params


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _window_for(cfg: ModelConfig, kind: str, global_window: Optional[int]):
    if kind == "local":
        return cfg.sliding_window
    return global_window  # None => full attention


def _theta_for(cfg: ModelConfig, kind: str):
    if kind == "global" and cfg.rope_theta_global is not None:
        return cfg.rope_theta_global
    return cfg.rope_theta


def _apply_slot(p, cfg: ModelConfig, kind: str, x, positions, *, dtype,
                global_window=None, mrope_positions=None,
                want_cache: bool = False, max_len: Optional[int] = None,
                remat_policy: str = "none", lengths=None):
    """Returns (x, aux_loss, cache_entry). Under ``remat_policy="full"``
    each block (attention / FFN / MoE / SSM / RG-LRU) nests its own
    ``jax.checkpoint`` inside the per-period one, so the backward pass
    recomputes one block at a time instead of a whole period."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("global", "local"):
        window = _window_for(cfg, kind, global_window)

        def attn_part(sp, x):
            h = nn.rmsnorm(sp["pre_norm"], x, cfg.norm_eps)
            h, kv = attention.attn_block(
                sp["attn"], cfg, h, positions, window=window,
                rope_theta=_theta_for(cfg, kind), compute_dtype=dtype,
                mrope_positions=mrope_positions)
            if cfg.use_post_norm:
                h = nn.rmsnorm(sp["post_norm"], h, cfg.norm_eps)
            return h, kv

        h, kv = remat_lib.checkpoint_block(attn_part, remat_policy)(p, x)
        x = x + h
        h = nn.rmsnorm(p["pre_ffn_norm"], x, cfg.norm_eps)
        if cfg.is_moe:
            h, aux = moe.moe_block(p["moe"], cfg, h, compute_dtype=dtype,
                                   remat_policy=remat_policy)
        else:
            h = remat_lib.checkpoint_block(
                lambda fp, hh: nn.ffn(fp, hh, cfg.ffn_kind,
                                      compute_dtype=dtype),
                remat_policy)(p["ffn"], h)
        if cfg.use_post_norm:
            h = nn.rmsnorm(p["post_ffn_norm"], h, cfg.norm_eps)
        x = x + h
        if want_cache:
            kv = attention.ring_cache_from_full(kv[0], kv[1], positions,
                                                window, max_len,
                                                lengths=lengths)
        return x, aux, kv
    if kind == "recurrent":
        h = nn.rmsnorm(p["pre_norm"], x, cfg.norm_eps)
        h, final_h = recurrent.recurrent_block(p["rec"], cfg,
                                               nn.seq_gathered(h),
                                               compute_dtype=dtype,
                                               return_cache=want_cache,
                                               remat_policy=remat_policy)
        x = x + nn.seq_sharded(h)
        h = nn.rmsnorm(p["pre_ffn_norm"], x, cfg.norm_eps)
        x = x + remat_lib.checkpoint_block(
            lambda fp, hh: nn.ffn(fp, hh, cfg.ffn_kind, compute_dtype=dtype),
            remat_policy)(p["ffn"], h)
        return x, aux, final_h
    if kind == "ssm":
        h = nn.rmsnorm(p["pre_norm"], x, cfg.norm_eps)
        h, final = ssm.ssm_block(p["ssm"], cfg, nn.seq_gathered(h),
                                 compute_dtype=dtype,
                                 return_cache=want_cache,
                                 remat_policy=remat_policy)
        return x + nn.seq_sharded(h), aux, final
    raise ValueError(kind)


def _embed_inputs(params, cfg: ModelConfig, tokens, vision_embeds, dtype):
    x = nn.embed(params["embed"], tokens, dtype, scale=cfg.embed_scale)
    if cfg.is_vlm and vision_embeds is not None:
        vis = nn.dense(params["vision_proj"], vision_embeds, dtype)
        if cfg.embed_scale:
            vis = vis * jnp.asarray(cfg.d_model ** 0.5, vis.dtype)
        # prefix-image layout: first n_vis positions are image patches
        n_vis = vis.shape[1]
        x = jnp.concatenate([vis, x[:, n_vis:]], axis=1)
    return x


def forward(params, cfg: ModelConfig, tokens, *, positions=None,
            vision_embeds=None, mrope_positions=None, dtype=jnp.bfloat16,
            global_window=None, remat: bool = True,
            remat_policy: Optional[str] = None, return_hidden=False,
            scan_unroll: int = 1):
    """Full-sequence forward (training / prefill). tokens: (B, S) int32.

    ``remat_policy`` grades activation checkpointing (see ``models/remat``);
    when None the legacy ``remat`` bool maps onto the lattice
    (True → "period", False → "none").

    Returns (logits (B,S,V) fp32, aux_loss scalar)."""
    policy = remat_lib.resolve(remat, remat_policy)
    B, S = tokens.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    # sequence parallelism: measured win for dense/hybrid/ssm, regression
    # for MoE (see nn.set_seq_shard) — gate by family
    nn.set_seq_shard(False if cfg.is_moe else None)
    try:
        x = nn.seq_sharded(_embed_inputs(params, cfg, tokens, vision_embeds,
                                         dtype))

        def period_fn(x, slot_params):
            aux_total = jnp.zeros((), jnp.float32)
            for kind, p in zip(cfg.layer_pattern, slot_params):
                x, aux, _ = _apply_slot(p, cfg, kind, x, positions,
                                        dtype=dtype,
                                        global_window=global_window,
                                        mrope_positions=mrope_positions,
                                        remat_policy=policy)
                aux_total = aux_total + aux
            return x, aux_total

        period_fn = remat_lib.checkpoint_period(period_fn, policy)

        def scan_body(x, slot_params):
            return period_fn(x, slot_params)

        x, aux = jax.lax.scan(scan_body, x, params["blocks"],
                              unroll=scan_unroll)
        x = nn.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if return_hidden:
            return x, jnp.sum(aux)
        logits = _lm_head(params, cfg, x)
        return logits, jnp.sum(aux)
    finally:
        nn.set_seq_shard(None)


def _lm_head(params, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        logits = nn.unembed(params["embed"], x, jnp.float32)
    else:
        logits = nn.dense(params["unembed"], x, jnp.float32)
    # vocab-sharded logits (Megatron-style): with the embedding table sharded
    # on V, the head emits V/TP-sharded logits (batch stays data-sharded) and
    # the CE reduces shardedly — never materializing (or all-reducing) a
    # full-vocab logits tensor.
    spec = [None] * logits.ndim
    spec[0] = ("pod", "data")
    spec[-1] = "model"
    logits = nn.shard_hint(logits, *spec)
    return nn.softcap(logits, cfg.final_softcap)


def supports_ragged_prefill(cfg: ModelConfig) -> bool:
    """True when a right-padded ragged prompt batch prefills EXACTLY: pure
    attention stacks only. Causal attention never lets a real query row see
    the padding appended after it, but state-carrying blocks (ssm /
    recurrent conv+recurrence) run their scan *through* the padded tail,
    and MoE routing competes padded tokens for expert capacity — both
    change real-token outputs, so those families must prefill exact-length
    groups instead (``engine/serving`` enforces this per family)."""
    return (not cfg.is_moe
            and all(k in ("global", "local") for k in cfg.layer_pattern))


def prefill(params, cfg: ModelConfig, tokens, max_len: int, *,
            positions=None, vision_embeds=None, mrope_positions=None,
            dtype=jnp.bfloat16, global_window=None, scan_unroll: int = 1,
            lengths=None):
    """Serving prefill: full-sequence forward that also builds the decode
    cache (ring layout, matching ``init_cache``). Returns
    (last_token_logits (B, V), cache).

    ``lengths`` (B,) serves a RIGHT-PADDED ragged prompt batch: the logits
    are taken at each row's last real token (``lengths[b] - 1``) and the
    ring cache holds only real tokens (padding never evicts real keys from
    a sliding window). Only valid for configs where padding is exact —
    see :func:`supports_ragged_prefill`."""
    B, S = tokens.shape[:2]
    if lengths is not None and not supports_ragged_prefill(cfg):
        raise ValueError(
            f"{cfg.name}: ragged (right-padded) prefill is only exact for "
            "pure-attention stacks; this config has state-carrying or MoE "
            "blocks — prefill exact-length groups instead "
            "(see transformer.supports_ragged_prefill)")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    nn.set_seq_shard(False if cfg.is_moe else None)
    try:
        x = nn.seq_sharded(_embed_inputs(params, cfg, tokens, vision_embeds,
                                         dtype))

        def scan_body(x, slot_params):
            caches = []
            for kind, p in zip(cfg.layer_pattern, slot_params):
                x, _, c = _apply_slot(p, cfg, kind, x, positions, dtype=dtype,
                                      global_window=global_window,
                                      mrope_positions=mrope_positions,
                                      want_cache=True, max_len=max_len,
                                      lengths=lengths)
                caches.append(c)
            return x, tuple(caches)

        x, cache = jax.lax.scan(scan_body, x, params["blocks"],
                                unroll=scan_unroll)
        if lengths is None:
            x_last = x[:, -1:]
        else:
            idx = jnp.clip(lengths.astype(jnp.int32) - 1, 0, S - 1)
            x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
        x = nn.rmsnorm(params["final_norm"], x_last, cfg.norm_eps)
        return _lm_head(params, cfg, x)[:, 0], cache
    finally:
        nn.set_seq_shard(None)


# ---------------------------------------------------------------------------
# serving: prefill -> cache, decode steps
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               global_window: Optional[int] = None):
    """Decode cache pytree: tuple per pattern slot, leaves stacked over
    periods (leading dim P)."""
    P = cfg.num_periods
    caches = []
    for kind in cfg.layer_pattern:
        if kind in ("global", "local"):
            w = _window_for(cfg, kind, global_window)
            c = attention.init_kv_cache(cfg, batch, max_len, w, dtype)
        elif kind == "recurrent":
            c = recurrent.init_recurrent_cache(cfg, batch, dtype)
        elif kind == "ssm":
            c = ssm.init_ssm_cache(cfg, batch, dtype)
        caches.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (P,) + x.shape), c))
    return tuple(caches)


def decode_step(params, cfg: ModelConfig, token, cache, cur_pos, *,
                dtype=jnp.bfloat16, global_window=None, scan_unroll: int = 1):
    """One decode step. token: (B, 1) int32; cur_pos: (B,) absolute position.

    Returns (logits (B, 1, V), new_cache).

    The period loop is a ``fori_loop`` carrying the cache and updating it
    in place with dynamic_update_slice — a scan's xs→ys would hold TWO full
    copies of the KV cache live (new + old), doubling decode HBM."""
    x = nn.embed(params["embed"], token, dtype, scale=cfg.embed_scale)

    def period_body(x, slot_params, slot_cache):
        new_caches = []
        for kind, p, c in zip(cfg.layer_pattern, slot_params, slot_cache):
            if kind in ("global", "local"):
                h = nn.rmsnorm(p["pre_norm"], x, cfg.norm_eps)
                h, nc = attention.attn_decode_step(
                    p["attn"], cfg, h, c, cur_pos,
                    window=_window_for(cfg, kind, global_window),
                    rope_theta=_theta_for(cfg, kind), compute_dtype=dtype)
                if cfg.use_post_norm:
                    h = nn.rmsnorm(p["post_norm"], h, cfg.norm_eps)
                x = x + h
                h = nn.rmsnorm(p["pre_ffn_norm"], x, cfg.norm_eps)
                if cfg.is_moe:
                    h, _ = moe.moe_block(p["moe"], cfg, h, compute_dtype=dtype)
                else:
                    h = nn.ffn(p["ffn"], h, cfg.ffn_kind, compute_dtype=dtype)
                if cfg.use_post_norm:
                    h = nn.rmsnorm(p["post_ffn_norm"], h, cfg.norm_eps)
                x = x + h
            elif kind == "recurrent":
                h = nn.rmsnorm(p["pre_norm"], x, cfg.norm_eps)
                h, nc = recurrent.recurrent_decode_step(p["rec"], cfg, h, c,
                                                        compute_dtype=dtype)
                x = x + h
                h = nn.rmsnorm(p["pre_ffn_norm"], x, cfg.norm_eps)
                x = x + nn.ffn(p["ffn"], h, cfg.ffn_kind, compute_dtype=dtype)
            elif kind == "ssm":
                h = nn.rmsnorm(p["pre_norm"], x, cfg.norm_eps)
                h, nc = ssm.ssm_decode_step(p["ssm"], cfg, h, c,
                                            compute_dtype=dtype)
                x = x + h
            new_caches.append(nc)
        return x, tuple(new_caches)

    P = cfg.num_periods
    if scan_unroll >= P:  # fully unrolled (dry-run cost probes)
        new_cache = cache
        for i in range(P):
            sp = jax.tree.map(lambda a: a[i], params["blocks"])
            sc = jax.tree.map(lambda a: a[i], new_cache)
            x, nc = period_body(x, sp, sc)
            new_cache = jax.tree.map(
                lambda full, new: full.at[i].set(new.astype(full.dtype)),
                new_cache, nc)
    else:
        def loop_body(i, carry):
            x, full_cache = carry
            sp = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
                params["blocks"])
            sc = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
                full_cache)
            x, nc = period_body(x, sp, sc)
            full_cache = jax.tree.map(
                lambda full, new: jax.lax.dynamic_update_index_in_dim(
                    full, new.astype(full.dtype), i, 0),
                full_cache, nc)
            return x, full_cache

        x, new_cache = jax.lax.fori_loop(0, P, loop_body, (x, cache))
    x = nn.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _lm_head(params, cfg, x), new_cache
