"""Minimal functional NN primitives (no flax): params are nested dicts of
jnp arrays; every layer is an ``init_*`` + pure apply function pair.

Master parameters are fp32; compute dtype is configurable (bf16 on TPU).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def current_mesh():
    """The physical mesh of the enclosing ``with mesh:`` context (or None)."""
    try:
        from jax.interpreters import pxla
        mesh = pxla.thread_resources.env.physical_mesh
        return None if mesh.empty else mesh
    except Exception:
        return None


def shard_hint(x, *axes):
    """Best-effort ``with_sharding_constraint``: applies only when a mesh
    context is active; axis names absent from the mesh are dropped from the
    spec (so the same model code runs on any mesh or none at all)."""
    mesh = current_mesh()
    if mesh is None:
        return x

    def filt(a):
        if a is None:
            return None
        names = a if isinstance(a, tuple) else (a,)
        present = tuple(n for n in names if n in mesh.axis_names)
        if not present:
            return None
        return present if len(present) > 1 else present[0]

    from jax.sharding import PartitionSpec
    return jax.lax.with_sharding_constraint(
        x, PartitionSpec(*[filt(a) for a in axes]))


def mesh_axis_size(name: str) -> int:
    mesh = current_mesh()
    if mesh is None or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


_SEQ_STATE = {"enabled": None}  # per-trace override (set by forward())


def set_seq_shard(enabled):
    """Trace-scoped override of sequence parallelism (None = env default).
    Measured: big win for dense/hybrid/ssm stacks (gemma2 train: −58%
    collective, −62% compute), a regression for MoE stacks (mixtral: +170%
    collective from dispatch-buffer reshard churn) — so forward() gates it
    by family."""
    _SEQ_STATE["enabled"] = enabled


def _seq_shard_on() -> bool:
    if _SEQ_STATE["enabled"] is not None:
        return _SEQ_STATE["enabled"]
    import os
    return os.environ.get("REPRO_SEQ_SHARD", "1") != "0"


def _seq_ok(x) -> bool:
    m = mesh_axis_size("model")
    return (_seq_shard_on() and m > 1 and x.ndim >= 3
            and x.shape[1] % m == 0 and x.shape[1] >= m)


def seq_sharded(x):
    """Sequence-parallel residual stream (Korthikanti et al.): between
    blocks, activations are sharded over the ``model`` axis on the SEQUENCE
    dim, so the TP boundary is a bf16 reduce-scatter/all-gather pair instead
    of replicating (B, S, D) in fp32 — the dominant collective in the
    baseline roofline. No-op when S is not divisible (e.g. decode, S=1)."""
    if not _seq_ok(x):
        return x
    spec = [("pod", "data"), "model"] + [None] * (x.ndim - 2)
    return shard_hint(x, *spec)


def seq_gathered(x):
    """Gather the sequence dim before cross-token or TP-weight matmuls
    (emitted as a bf16 all-gather when x is bf16)."""
    if not _seq_ok(x):
        return x
    spec = [("pod", "data")] + [None] * (x.ndim - 1)
    return shard_hint(x, *spec)


def dense_init(key, in_dim: int, out_dim: int, *, bias: bool = False,
               scale: Optional[float] = None, dtype=jnp.float32):
    scale = (1.0 / math.sqrt(in_dim)) if scale is None else scale
    p = {"w": jax.random.normal(key, (in_dim, out_dim), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def dense(p, x, compute_dtype=None):
    w = p["w"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
        x = x.astype(compute_dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def rmsnorm_init(dim: int):
    return {"scale": jnp.zeros((dim,), jnp.float32)}  # gemma-style (1+scale)


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + p["scale"])).astype(dt)


def layernorm_init(dim: int):
    return {"scale": jnp.ones((dim,), jnp.float32),
            "bias": jnp.zeros((dim,), jnp.float32)}


def layernorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(dt)


def softcap(x, cap: Optional[float]):
    """tanh logit soft-capping (gemma2 / grok)."""
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# RoPE (incl. multi-axis M-RoPE for qwen2-vl)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                sections: Tuple[int, int, int]) -> jnp.ndarray:
    """Multi-axis RoPE (qwen2-vl): positions (3, B, S) for (t, h, w) axes;
    ``sections`` gives the per-axis number of frequency pairs and must sum to
    head_dim/2."""
    hd = x.shape[-1]
    assert sum(sections) * 2 == hd, (sections, hd)
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    # per-frequency axis selector: frequencies are split into 3 contiguous
    # sections, each rotated by its own position stream.
    sel = jnp.concatenate([jnp.full((s,), i, jnp.int32)
                           for i, s in enumerate(sections)])  # (hd/2,)
    pos = positions.astype(jnp.float32)[sel]  # (hd/2, B, S)
    ang = pos.transpose(1, 2, 0) * freqs  # (B, S, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------

def ffn_init(key, d_model: int, d_ff: int, kind: str):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_up": dense_init(k1, d_model, d_ff),
         "w_down": dense_init(k2, d_ff, d_model)}
    if kind in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(k3, d_model, d_ff)
    return p


def _ffn_spec(ndim: int, last):
    spec = [None] * ndim
    spec[0] = ("pod", "data")
    spec[-1] = last
    return spec


def ffn(p, x, kind: str, compute_dtype=None):
    x = seq_gathered(x)  # bf16 all-gather at the TP boundary
    up = dense(p["w_up"], x, compute_dtype)
    if kind == "swiglu":
        h = jax.nn.silu(dense(p["w_gate"], x, compute_dtype)) * up
    elif kind == "geglu":
        h = jax.nn.gelu(dense(p["w_gate"], x, compute_dtype)) * up
    elif kind == "gelu":
        h = jax.nn.gelu(up)
    else:
        raise ValueError(kind)
    # hidden stays TP-sharded on d_ff; output reduce-scatters back to the
    # sequence-sharded residual stream (without hints GSPMD all-gathers the
    # (B, S, d_ff) hidden in fp32 at 32k)
    h = shard_hint(h, *_ffn_spec(h.ndim, "model"))
    out = dense(p["w_down"], h, compute_dtype)
    return seq_sharded(out)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d_model: int):
    return {"table": jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02}


def embed(p, tokens, compute_dtype=None, scale: bool = False):
    t = p["table"]
    if compute_dtype is not None:
        t = t.astype(compute_dtype)
    x = jnp.take(t, tokens, axis=0)
    if scale:
        x = x * jnp.asarray(math.sqrt(t.shape[-1]), x.dtype)
    return x


def unembed(p, x, compute_dtype=None):
    t = p["table"]
    if compute_dtype is not None:
        t = t.astype(compute_dtype)
        x = x.astype(compute_dtype)
    return x @ t.T
