"""RG-LRU recurrent block (RecurrentGemma, arXiv:2402.19427).

The gated linear recurrence h_t = a_t*h_{t-1} + sqrt(1-a_t^2)*(i_t*x_t) is
elementwise-linear in h, so full sequences run as a ``lax.associative_scan``
(log-depth — the TPU-friendly formulation); decode is the O(1) update.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from . import nn
from . import remat as remat_lib
from .config import ModelConfig

_C = 8.0  # RG-LRU temperature constant


def recurrent_init(key, cfg: ModelConfig):
    d, w, W = cfg.d_model, cfg.lru_width, cfg.conv_width
    ks = jax.random.split(key, 6)
    # Lambda init so that a = exp(-c*softplus(L)) is in (0.9, 0.999)
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))
    return {
        "in_x": nn.dense_init(ks[1], d, w),
        "in_gate": nn.dense_init(ks[2], d, w),
        "conv_w": jax.random.normal(ks[3], (W, w), jnp.float32) / math.sqrt(W),
        "conv_b": jnp.zeros((w,), jnp.float32),
        "gate_a": nn.dense_init(ks[4], w, w, bias=True),
        "gate_x": nn.dense_init(ks[5], w, w, bias=True),
        "lambda": lam,
        "out": nn.dense_init(jax.random.fold_in(key, 7), w, d),
    }


def _rg_lru_coeffs(p, x):
    """x: (..., w) -> (a, gated_x) both fp32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(nn.dense(p["gate_a"], xf))
    i = jax.nn.sigmoid(nn.dense(p["gate_x"], xf))
    log_a = -_C * jax.nn.softplus(p["lambda"]) * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably via expm1
    mult = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    return a, mult * (i * xf)


def _causal_conv(x, conv_w, conv_b):
    W = conv_w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * conv_w[i].astype(x.dtype)
              for i in range(W))
    return out + conv_b.astype(x.dtype)


def recurrent_block(p, cfg: ModelConfig, x, compute_dtype=None,
                    init_state=None, return_cache: bool = False,
                    remat_policy: str = "none"
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence RG-LRU block. x: (B, S, D) -> ((B, S, D), final_h).

    ``remat_policy="full"`` nests a ``jax.checkpoint`` around the block so
    the associative-scan intermediates are recomputed per block."""
    fn = remat_lib.checkpoint_block(
        lambda bp, bx: _recurrent_block(bp, cfg, bx, compute_dtype,
                                        init_state, return_cache),
        remat_policy)
    return fn(p, x)


def _recurrent_block(p, cfg: ModelConfig, x, compute_dtype=None,
                     init_state=None, return_cache: bool = False
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, S, D = x.shape
    gate = jax.nn.gelu(nn.dense(p["in_gate"], x, compute_dtype))
    xb = nn.dense(p["in_x"], x, compute_dtype)
    xb_raw = xb
    xb = _causal_conv(xb, p["conv_w"], p["conv_b"])
    a, b = _rg_lru_coeffs(p, xb)  # (B, S, w) fp32
    if init_state is not None:
        # fold the initial state in as an extra leading step
        a = jnp.concatenate([jnp.zeros_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([init_state.astype(jnp.float32)[:, None], b], axis=1)

    def combine(l, r):
        (al, bl), (ar, br) = l, r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    if init_state is not None:
        h = h[:, 1:]
    h = h.astype(xb.dtype)
    out = nn.dense(p["out"], h * gate, compute_dtype)
    if return_cache:
        W = cfg.conv_width
        conv_tail = xb_raw[:, -(W - 1):, :]
        pad = W - 1 - conv_tail.shape[1]
        if pad > 0:
            conv_tail = jnp.pad(conv_tail, ((0, 0), (pad, 0), (0, 0)))
        return out, {"h": h[:, -1].astype(jnp.float32), "conv": conv_tail}
    return out, h[:, -1].astype(jnp.float32)


def init_recurrent_cache(cfg: ModelConfig, batch: int, dtype):
    return {
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_width), dtype),
    }


def recurrent_decode_step(p, cfg: ModelConfig, x, cache, compute_dtype=None):
    """One-token update. x: (B, 1, D)."""
    B = x.shape[0]
    gate = jax.nn.gelu(nn.dense(p["in_gate"], x[:, 0], compute_dtype))
    xb = nn.dense(p["in_x"], x[:, 0], compute_dtype)  # (B, w)
    win = jnp.concatenate([cache["conv"].astype(xb.dtype), xb[:, None]], axis=1)
    xb = (jnp.einsum("bwc,wc->bc", win, p["conv_w"].astype(xb.dtype))
          + p["conv_b"].astype(xb.dtype))
    a, b = _rg_lru_coeffs(p, xb)
    h = a * cache["h"] + b
    out = nn.dense(p["out"], h.astype(xb.dtype) * gate, compute_dtype)[:, None]
    return out, {"h": h, "conv": win[:, 1:]}
