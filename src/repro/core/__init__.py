from . import losses, memory_model, mbs, streaming  # noqa: F401
from .mbs import (MBSConfig, make_baseline_train_step, make_mbs_train_step,  # noqa: F401
                  mbs_gradients, num_micro_batches, split_minibatch)
