"""Micro-Batch Streaming (MBS) — the paper's core technique.

A mini-batch that does not fit in device memory is split into ``N_Sμ``
micro-batches (paper §3.2, eq. 1–3); each micro-batch runs forward+backward
with its loss *normalized by 1/N_Sμ* (paper §3.4, eq. 14 / Algorithm 1
line 11); gradients are accumulated in the model-parameter space (paper
Fig. 2 step ❹) and the optimizer applies a single update per mini-batch
(step ❺). Eq. (15)–(17) of the paper prove this equals the full
mini-batch gradient — our property tests assert that equality numerically.

Two normalization modes:
  * ``"paper"``  — Algorithm 1 verbatim: contribution = mean_micro_loss / N_Sμ.
                   Exact when every micro-batch has the same number of valid
                   samples (the paper's setting).
  * ``"exact"``  — contribution = sum(valid per-sample losses) / N_B_valid.
                   Exact for ragged tails (N_B % N_μ != 0) too.

TPU adaptation (see DESIGN.md): inside a compiled step the "stream" is a
``lax.scan`` over the leading micro-batch axis — XLA keeps one micro-batch
of activations live at a time; the fp32 accumulator is sharded like the
parameters so accumulation is communication-free, and the cross-data-parallel
gradient reduction happens once per mini-batch.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class MBSConfig:
    micro_batch_size: int
    normalization: str = "paper"  # "paper" | "exact"
    accum_dtype: Any = jnp.float32
    remat_micro_step: bool = False  # extra jax.checkpoint around each micro step
    unroll: int = 1  # scan unroll factor


def num_micro_batches(mini_batch_size: int, micro_batch_size: int) -> int:
    """Algorithm 1 lines 1–5: N_μ ← min(N_μ, N_B); N_Sμ = ceil(N_B / N_μ)."""
    micro = min(micro_batch_size, mini_batch_size)
    return int(math.ceil(mini_batch_size / micro))


def split_minibatch(batch: Dict[str, np.ndarray], micro_batch_size: int
                    ) -> Dict[str, np.ndarray]:
    """Host-side split (paper Fig. 2 step ❶): reshape every leaf from
    ``(N_B, ...)`` to ``(N_Sμ, N_μ, ...)``, zero-padding the ragged tail and
    emitting a ``sample_weight`` mask (1 = real sample, 0 = padding)."""
    leaves = jax.tree.leaves(batch)
    n_b = leaves[0].shape[0]
    n_mu = min(micro_batch_size, n_b)
    n_s = num_micro_batches(n_b, n_mu)
    pad = n_s * n_mu - n_b

    def split(x):
        if pad:
            x = np.concatenate([x, np.zeros((pad,) + x.shape[1:], x.dtype)])
        return x.reshape(n_s, n_mu, *x.shape[1:])

    out = {k: split(np.asarray(v)) for k, v in batch.items()}
    w = np.ones((n_b,), np.float32)
    if pad:
        w = np.concatenate([w, np.zeros((pad,), np.float32)])
    out["sample_weight"] = w.reshape(n_s, n_mu)
    return out


def _zeros_like_accum(params, dtype):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, dtype), params)


def make_mbs_train_step(
    loss_fn: Callable[..., Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]],
    optimizer,
    mbs: MBSConfig,
) -> Callable:
    """Build the compiled MBS training step.

    ``loss_fn(params, micro_batch, exact_denom=None) -> (loss, metrics)``
    must return the mean per-sample loss of the micro-batch (honouring
    ``micro_batch['sample_weight']`` if present); with ``exact_denom`` it
    must instead divide the summed per-sample loss by that denominator.

    Returns ``train_step(params, opt_state, micro_batches) ->
    (params, opt_state, metrics)`` where every leaf of ``micro_batches`` has
    leading shape ``(N_Sμ, N_μ, ...)``.
    """

    def train_step(params, opt_state, micro_batches):
        n_s = jax.tree.leaves(micro_batches)[0].shape[0]
        if mbs.normalization == "exact":
            w = micro_batches.get("sample_weight")
            total_valid = (jnp.sum(w) if w is not None
                           else jnp.asarray(float(n_s) * jax.tree.leaves(micro_batches)[0].shape[1]))
        accum0 = _zeros_like_accum(params, mbs.accum_dtype)

        def micro_step(carry, mb):
            acc, loss_sum, metric_sum = carry

            def normalized_loss(p):
                if mbs.normalization == "paper":
                    loss, metrics = loss_fn(p, mb)
                    return loss / n_s, metrics  # Algorithm 1 line 11
                loss, metrics = loss_fn(p, mb, exact_denom=total_valid)
                return loss, metrics

            grad_fn = jax.value_and_grad(normalized_loss, has_aux=True)
            if mbs.remat_micro_step:
                grad_fn = jax.checkpoint(grad_fn)
            (lnorm, metrics), grads = grad_fn(params)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(mbs.accum_dtype), acc, grads)
            metric_sum = jax.tree.map(lambda s, m: s + m / n_s, metric_sum, metrics)
            return (acc, loss_sum + lnorm, metric_sum), None

        # probe metrics structure (zeros) for the scan carry
        mb0 = jax.tree.map(lambda x: x[0], micro_batches)
        metrics_shape = jax.eval_shape(
            lambda p: loss_fn(p, mb0)[1] if mbs.normalization == "paper"
            else loss_fn(p, mb0, exact_denom=1.0)[1], params)
        metrics0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), metrics_shape)

        (grads, loss, metric_sum), _ = jax.lax.scan(
            micro_step, (accum0, jnp.zeros((), jnp.float32), metrics0),
            micro_batches, unroll=mbs.unroll)

        updates, new_opt_state = optimizer.update(grads, opt_state, params)
        new_params = jax.tree.map(
            lambda p, u: (p + u.astype(p.dtype)), params, updates)
        out_metrics = dict(metric_sum)
        out_metrics["loss"] = loss  # Σ normalized micro losses == mini-batch mean
        out_metrics["grad_norm"] = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        return new_params, new_opt_state, out_metrics

    return train_step


def make_baseline_train_step(loss_fn, optimizer) -> Callable:
    """The no-MBS reference: one forward/backward over the whole mini-batch
    (what the paper's "w/o MBS" columns do — and what fails beyond the
    memory limit)."""

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        updates, new_opt_state = optimizer.update(grads, opt_state, params)
        new_params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                                  params, updates)
        out = dict(metrics)
        out["loss"] = loss
        out["grad_norm"] = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        return new_params, new_opt_state, out

    return train_step


def mbs_gradients(loss_fn, params, micro_batches, mbs: MBSConfig):
    """Accumulated, normalized MBS gradients only (no optimizer) — the
    quantity eq. (15)–(17) proves equal to the mini-batch gradient. Used by
    the equivalence tests and benchmarks."""
    n_s = jax.tree.leaves(micro_batches)[0].shape[0]
    if mbs.normalization == "exact":
        w = micro_batches.get("sample_weight")
        total_valid = (jnp.sum(w) if w is not None else
                       jnp.asarray(float(n_s * jax.tree.leaves(micro_batches)[0].shape[1])))
    acc = _zeros_like_accum(params, mbs.accum_dtype)
    loss_sum = jnp.zeros((), jnp.float32)
    for i in range(n_s):
        mb = jax.tree.map(lambda x: x[i], micro_batches)

        def normalized_loss(p):
            if mbs.normalization == "paper":
                loss, _ = loss_fn(p, mb)
                return loss / n_s
            loss, _ = loss_fn(p, mb, exact_denom=total_valid)
            return loss

        lnorm, grads = jax.value_and_grad(normalized_loss)(params)
        acc = jax.tree.map(lambda a, g: a + g.astype(mbs.accum_dtype), acc, grads)
        loss_sum = loss_sum + lnorm
    return acc, loss_sum
