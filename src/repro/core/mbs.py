"""Micro-Batch Streaming (MBS) — backward-compatible facade.

The paper's core technique — split a mini-batch into N_Sμ micro-batches
(§3.2, eq. 1–3), normalize each micro loss by 1/N_Sμ (§3.4, eq. 14 /
Algorithm 1 line 11), accumulate gradients (Fig. 2 step ❹) and apply one
optimizer update per mini-batch (step ❺) — now lives in the unified
execution engine (``repro.engine``): one planner (:func:`plan_mbs`) plus
pluggable executors (compiled scan / streaming / Pallas-fused) sharing a
single normalization–accumulation–update core. This module re-exports the
legacy surface; new code should import from ``repro.engine`` directly.
"""
from __future__ import annotations

from typing import Callable

from ..engine import (MBSConfig, MBSPlan, num_micro_batches,  # noqa: F401
                      plan_mbs, split_minibatch)
from ..engine import (CompiledScanExecutor, accumulate_gradients,  # noqa: F401
                      make_baseline_train_step)


def make_mbs_train_step(loss_fn: Callable, optimizer, mbs: MBSConfig
                        ) -> Callable:
    """Legacy builder for the compiled MBS training step — equivalent to
    ``CompiledScanExecutor(loss_fn, optimizer, mbs).make_train_step()``.

    ``loss_fn(params, micro_batch, exact_denom=None) -> (loss, metrics)``
    must return the mean per-sample loss of the micro-batch (honouring
    ``micro_batch['sample_weight']`` if present); with ``exact_denom`` it
    must instead divide the summed per-sample loss by that denominator.

    Returns ``train_step(params, opt_state, micro_batches) ->
    (params, opt_state, metrics)`` where every leaf of ``micro_batches`` has
    leading shape ``(N_Sμ, N_μ, ...)``.
    """
    return CompiledScanExecutor(loss_fn, optimizer, mbs).make_train_step()


def mbs_gradients(loss_fn, params, micro_batches, mbs: MBSConfig):
    """Accumulated, normalized MBS gradients only (no optimizer) — the
    quantity eq. (15)–(17) proves equal to the mini-batch gradient. Used by
    the equivalence tests and benchmarks."""
    return accumulate_gradients(loss_fn, params, micro_batches, mbs)
