"""Loss functions used across the framework.

Every loss returns the *mean per-sample loss over the (micro-)batch* plus a
valid-sample count, which is what the MBS loss-normalization algorithm
(paper §3.4, Algorithm 1) consumes. ``sample_weight`` supports the ragged
tail case (N_B % N_mu != 0): padded samples carry weight 0.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _weighted_mean(per_sample: jnp.ndarray, sample_weight, exact_denom):
    """mean over samples; with ``exact_denom`` set, divide the weighted sum
    by that count instead (used by exact-ragged MBS)."""
    if sample_weight is None:
        if exact_denom is not None:
            return jnp.sum(per_sample) / exact_denom
        return jnp.mean(per_sample)
    total = jnp.sum(per_sample * sample_weight)
    denom = exact_denom if exact_denom is not None else jnp.sum(sample_weight)
    return total / denom


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray, *,
                  token_weight: Optional[jnp.ndarray] = None,
                  sample_weight: Optional[jnp.ndarray] = None,
                  exact_denom=None) -> jnp.ndarray:
    """LM / classification CE. logits: (..., V) fp32; labels int.

    Per-sample loss = mean over valid tokens; batch loss = mean over samples.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold  # (..., ) per-token
    if nll.ndim > 1:  # sequence models: mean over tokens per sample
        if token_weight is not None:
            per_sample = (jnp.sum(nll * token_weight, axis=tuple(range(1, nll.ndim)))
                          / jnp.maximum(jnp.sum(token_weight, axis=tuple(range(1, nll.ndim))), 1))
        else:
            per_sample = jnp.mean(nll, axis=tuple(range(1, nll.ndim)))
    else:
        per_sample = nll
    return _weighted_mean(per_sample, sample_weight, exact_denom)


def bce_with_logits(logits, targets, *, sample_weight=None, exact_denom=None):
    """Binary cross-entropy from logits. logits/targets: (B, H, W, 1)."""
    logits = logits.astype(jnp.float32)
    per_px = jnp.maximum(logits, 0) - logits * targets + jnp.log1p(
        jnp.exp(-jnp.abs(logits)))
    per_sample = jnp.mean(per_px, axis=tuple(range(1, per_px.ndim)))
    return _weighted_mean(per_sample, sample_weight, exact_denom)


def dice_loss(logits, targets, *, sample_weight=None, exact_denom=None,
              eps: float = 1.0):
    """Paper eq. (19): L_dc = 1 - 2|A∩B| / (|A|+|B|), per sample."""
    probs = jax.nn.sigmoid(logits.astype(jnp.float32))
    axes = tuple(range(1, probs.ndim))
    inter = jnp.sum(probs * targets, axis=axes)
    denom = jnp.sum(probs, axis=axes) + jnp.sum(targets, axis=axes)
    per_sample = 1.0 - (2.0 * inter + eps) / (denom + eps)
    return _weighted_mean(per_sample, sample_weight, exact_denom)


def bce_dice_loss(logits, targets, **kw):
    """Paper eq. (20): L_total = L_bce + L_dc (U-Net training loss)."""
    return bce_with_logits(logits, targets, **kw) + dice_loss(logits, targets, **kw)


def iou(logits, targets, thresh: float = 0.5) -> jnp.ndarray:
    """Intersection-over-union metric (paper §4.3.1)."""
    pred = (jax.nn.sigmoid(logits.astype(jnp.float32)) > thresh).astype(jnp.float32)
    axes = tuple(range(1, pred.ndim))
    inter = jnp.sum(pred * targets, axis=axes)
    union = jnp.sum(jnp.maximum(pred, targets), axis=axes)
    return jnp.mean((inter + 1e-6) / (union + 1e-6))


def accuracy(logits, labels) -> jnp.ndarray:
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
