"""Stream-based pipeline (paper §3.1) — host→device micro-batch streaming.

The executor itself now lives in the unified engine
(``repro.engine.executors.StreamingExecutor``); this module keeps the
legacy name plus the host-side prefetch iterator. See DESIGN.md
§Hardware adaptation for how the paper's CUDA-stream pipeline maps onto
the TPU/JAX stack:

  * compiled mode (production): the already-split ``(N_Sμ, N_μ, ...)`` batch
    is consumed by a ``lax.scan`` inside the jitted train step — XLA keeps
    one micro-batch of activations live; used by ``launch/train.py``.

  * streaming mode: the literal paper pipeline — each micro-batch is
    transferred with ``jax.device_put`` while the previous one computes
    (double buffering ≈ CUDA-stream overlap; on TPU, ``device_put`` is
    async so the transfer overlaps compute), and a jitted per-micro-batch
    gradient function accumulates into the on-device accumulator (paper
    Fig. 2 steps ❷–❹). Memory never exceeds model + accumulator +
    2 micro-batches.
"""
from __future__ import annotations

from typing import Iterator

from ..engine.executors import StreamingExecutor

# Legacy name: the eager micro-batch streaming executor (paper Fig. 1).
# Unlike the pre-engine implementation, it honors the full MBS policy —
# normalization="exact" and accum_dtype included.
MBSStreamExecutor = StreamingExecutor


class _WorkerError:
    """Queue sentinel carrying an exception out of the prefetch thread."""

    def __init__(self, exc: BaseException):
        self.exc = exc


def prefetch_iterator(it: Iterator, size: int = 2) -> Iterator:
    """Background-thread prefetch for host data pipelines.

    Exceptions raised by the producer are re-raised in the consumer (with
    the worker's traceback attached) rather than silently ending the
    stream — a failed data pipeline must never truncate an epoch.
    """
    import queue
    import threading

    q: "queue.Queue" = queue.Queue(maxsize=size)
    stop = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        except BaseException as exc:  # noqa: BLE001 — relayed to consumer
            q.put(_WorkerError(exc))
        else:
            q.put(stop)

    threading.Thread(target=worker, daemon=True).start()
    while True:
        item = q.get()
        if item is stop:
            return
        if isinstance(item, _WorkerError):
            raise item.exc
        yield item
