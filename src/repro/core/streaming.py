"""Stream-based pipeline (paper §3.1) — host→device micro-batch streaming.

The paper's MBS streams micro-batches from CPU memory to the GPU
sequentially. The TPU-native analogue (DESIGN.md §Hardware adaptation) is:

  * compiled mode (production): the already-split ``(N_Sμ, N_μ, ...)`` batch
    is consumed by a ``lax.scan`` inside the jitted train step — XLA keeps
    one micro-batch of activations live; used by ``launch/train.py``.

  * streaming mode (this module): the literal paper pipeline — each
    micro-batch is transferred with ``jax.device_put`` while the previous
    one computes (double buffering ≈ CUDA-stream overlap; on TPU,
    ``device_put`` is async so the transfer overlaps compute), and a jitted
    per-micro-batch gradient function accumulates into the on-device
    accumulator (paper Fig. 2 steps ❷–❹). Memory never exceeds
    model + accumulator + 2 micro-batches.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import mbs as mbs_lib


class MBSStreamExecutor:
    """Eager micro-batch streaming executor (the paper's Fig. 1 pipeline)."""

    def __init__(self, loss_fn, optimizer, mbs: mbs_lib.MBSConfig,
                 device: Optional[Any] = None):
        self.loss_fn = loss_fn
        self.optimizer = optimizer
        self.mbs = mbs
        self.device = device or jax.devices()[0]

        @jax.jit
        def _micro_grad(params, mb, inv_n_s):
            def normalized(p):
                loss, metrics = loss_fn(p, mb)
                return loss * inv_n_s, metrics  # Algorithm 1 line 11

            (lnorm, metrics), g = jax.value_and_grad(normalized, has_aux=True)(params)
            return lnorm, g, metrics

        @jax.jit
        def _accumulate(acc, g):  # paper step ❹
            return jax.tree.map(lambda a, x: a + x.astype(a.dtype), acc, g)

        @jax.jit
        def _update(params, opt_state, acc):  # paper step ❺
            updates, new_opt = optimizer.update(acc, opt_state, params)
            new_params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                                      params, updates)
            return new_params, new_opt

        self._micro_grad = _micro_grad
        self._accumulate = _accumulate
        self._update = _update

    def step(self, params, opt_state, minibatch: Dict[str, np.ndarray]
             ) -> Tuple[Any, Any, Dict[str, float]]:
        """One mini-batch update via sequential micro-batch streaming."""
        split = mbs_lib.split_minibatch(minibatch, self.mbs.micro_batch_size)
        n_s = jax.tree.leaves(split)[0].shape[0]
        inv = jnp.asarray(1.0 / n_s, jnp.float32)
        acc = jax.tree.map(
            lambda p: jnp.zeros(p.shape, self.mbs.accum_dtype), params)
        loss = 0.0

        # double buffer: issue transfer of micro-batch i+1 while i computes
        def put(i):
            return jax.device_put(
                jax.tree.map(lambda x: x[i], split), self.device)

        nxt = put(0)
        for i in range(n_s):
            cur, nxt = nxt, (put(i + 1) if i + 1 < n_s else None)
            lnorm, g, _ = self._micro_grad(params, cur, inv)
            acc = self._accumulate(acc, g)
            loss += float(lnorm)
        params, opt_state = self._update(params, opt_state, acc)
        return params, opt_state, {"loss": loss}


def prefetch_iterator(it: Iterator, size: int = 2) -> Iterator:
    """Background-thread prefetch for host data pipelines."""
    import queue
    import threading

    q: "queue.Queue" = queue.Queue(maxsize=size)
    stop = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(stop)

    threading.Thread(target=worker, daemon=True).start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item
