"""Analytic device-memory model → automatic micro-batch sizing.

The paper determines the micro-batch size "experimentally ... the maximum
size that can compute on GPU" (§4.3.2). We replace that search with an
analytic model of per-device bytes as a function of the micro-batch size,
and pick the largest power-of-two that fits the HBM budget — the same
quantity the dry-run's ``compiled.memory_analysis()`` verifies.

The model (per device, for the transformer families):
  params           P/ (tp * fsdp)                       * 4 B (fp32 master)
  grads (accum)    same as params                       * 4 B
  optimizer state  k_opt * params bytes (SGD-m: 1, Adam: 2)
  activations      per-period remat boundary + live period working set,
                   proportional to micro_batch * seq (the MBS knob)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from ..models.config import ModelConfig

V5E_HBM_BYTES = 16 * 1024 ** 3


@dataclasses.dataclass(frozen=True)
class MemoryEstimate:
    params_bytes: int
    grads_bytes: int
    opt_bytes: int
    activation_bytes_per_sample: int  # per micro-batch sample, at given seq
    fixed_bytes: int

    def total(self, micro_batch: int) -> int:
        return (self.params_bytes + self.grads_bytes + self.opt_bytes
                + self.fixed_bytes
                + self.activation_bytes_per_sample * micro_batch)


def activation_bytes_per_sample(cfg: ModelConfig, seq: int,
                                act_bytes: int = 2,
                                remat: bool = True) -> int:
    """Live activation bytes for ONE sample of length ``seq``.

    With per-period remat: residual-stream checkpoints at every period
    boundary (num_periods * seq * d_model) + the recompute working set of a
    single period (~ c * seq * max(d_model, d_ff, moe_active)).
    """
    d = cfg.d_model
    boundary = cfg.num_periods * seq * d * act_bytes
    widths = [d * 6]  # qkv + attn out + residuals
    if cfg.is_moe:
        widths.append(cfg.experts_per_token * cfg.moe_d_ff * 3 * cfg.capacity_factor)
    elif cfg.d_ff:
        widths.append(cfg.d_ff * 3)
    if cfg.ssm_state:
        widths.append(cfg.ssm_d_inner * 4)
    if cfg.lru_width:
        widths.append(cfg.lru_width * 6)
    period_live = seq * int(max(widths)) * act_bytes * cfg.pattern_len
    logits_live = seq * cfg.vocab_size * 4 // 8  # blocked CE kernel: 1/8 vocab
    if not remat:
        period_live *= cfg.num_periods
    return boundary + period_live + logits_live


def estimate(cfg: ModelConfig, seq: int, *, tp: int = 1, fsdp: int = 1,
             opt_slots: int = 1, act_bytes: int = 2,
             remat: bool = True) -> MemoryEstimate:
    p_bytes = cfg.param_count() * 4 // (tp * fsdp)
    return MemoryEstimate(
        params_bytes=p_bytes,
        grads_bytes=p_bytes,
        opt_bytes=opt_slots * p_bytes,
        activation_bytes_per_sample=activation_bytes_per_sample(
            cfg, seq, act_bytes, remat) // tp,
        fixed_bytes=64 * 1024 ** 2,
    )


def suggest_micro_batch_size(cfg: ModelConfig, seq: int, mini_batch: int, *,
                             budget_bytes: int = V5E_HBM_BYTES, tp: int = 1,
                             fsdp: int = 1, opt_slots: int = 1,
                             act_bytes: int = 2,
                             remat: bool = True) -> Optional[int]:
    """Largest power-of-two micro-batch (≤ mini_batch) that fits the budget.
    Returns None if even micro-batch 1 exceeds the budget (the model itself
    does not fit — MBS cannot help; that needs more model parallelism)."""
    est = estimate(cfg, seq, tp=tp, fsdp=fsdp, opt_slots=opt_slots,
                   act_bytes=act_bytes, remat=remat)
    best = None
    m = 1
    while m <= mini_batch:
        if est.total(m) <= budget_bytes:
            best = m
        m *= 2
    return best


def max_minibatch_without_mbs(cfg: ModelConfig, seq: int, *,
                              budget_bytes: int = V5E_HBM_BYTES, tp: int = 1,
                              fsdp: int = 1, opt_slots: int = 1,
                              act_bytes: int = 2,
                              remat: bool = True) -> int:
    """The paper's "w/o MBS" failure point: the largest mini-batch whose
    whole-batch activations fit (beyond it, the run 'Fails')."""
    est = estimate(cfg, seq, tp=tp, fsdp=fsdp, opt_slots=opt_slots,
                   act_bytes=act_bytes, remat=remat)
    m = 0
    while est.total(m + 1) <= budget_bytes:
        m += 1
        if m > 1 << 24:
            break
    return m
