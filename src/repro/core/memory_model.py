"""Analytic device-memory model → automatic micro-batch sizing.

The paper determines the micro-batch size "experimentally ... the maximum
size that can compute on GPU" (§4.3.2). We replace that search with an
analytic model of per-device bytes as a function of the micro-batch size,
and pick the largest power-of-two that fits the HBM budget — the same
quantity the dry-run's ``compiled.memory_analysis()`` verifies.

The model (per device, for the transformer families):
  params           P/ (tp * fsdp)                       * 4 B (fp32 master)
  grads (accum)    same as params                       * 4 B
  optimizer state  k_opt * params bytes (SGD-m: 1, Adam: 2)
  update transient step-❺ peak on top of the steady state: the unfused
                   update materializes the full ``updates`` tree plus
                   fresh momentum/m/v trees that coexist with the old
                   state until the swap — (1 + k_opt) * params bytes.
                   The fused flat path (``kernels/fused_update.py``,
                   in-place aliasing + donation) eliminates it.
  activations      per-period remat boundary + live period working set,
                   proportional to micro_batch * seq (the MBS knob)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from ..models.config import ModelConfig

V5E_HBM_BYTES = 16 * 1024 ** 3

# optimizer-state slots per optimizer (momentum / m+v trees)
OPT_SLOTS = {"sgd": 1, "sgd_plain": 0, "adam": 2, "adamw": 2}


def _resolve_slots(optimizer: str, opt_slots: Optional[int]) -> int:
    if opt_slots is not None:
        return opt_slots
    try:
        return OPT_SLOTS[optimizer]
    except KeyError:
        raise ValueError(
            f"unknown optimizer {optimizer!r}; known: {sorted(OPT_SLOTS)} "
            "(or pass opt_slots explicitly)")


def update_transient_bytes(params_bytes: int, optimizer: str = "sgd",
                           fused: bool = False, *,
                           opt_slots: Optional[int] = None) -> int:
    """Peak transient bytes of paper step ❺ beyond the steady state.

    The unfused reference (``optimizer.update`` + ``apply_update``) holds
    the full fp32 ``updates`` tree plus the freshly built optimizer-state
    trees while the old ones are still live. The fused flat update path
    writes params and opt state in place (``input_output_aliases`` +
    donation), leaving only O(kernel block) scratch — counted as zero.

    Fused-path caveat: the flat step still *gathers* the param/opt-state
    trees into contiguous buckets (and scatters them back), which is a
    copy at the XLA level. Those copies are counted as zero because they
    are live only at step ❺, when the donated split batch and the
    micro-batch activations — whose bytes this model already budgets and
    which dominate them at any admitted micro-batch size — have been
    freed for reuse; keeping state flat *across* steps (eliminating the
    gather entirely) is the noted next step in DESIGN.md §Update path."""
    if fused:
        return 0
    return (1 + _resolve_slots(optimizer, opt_slots)) * params_bytes


@dataclasses.dataclass(frozen=True)
class MemoryEstimate:
    params_bytes: int
    grads_bytes: int
    opt_bytes: int
    activation_bytes_per_sample: int  # per micro-batch sample, at given seq
    fixed_bytes: int
    update_transient_bytes: int = 0  # step-❺ peak (0 for the fused path)

    def total(self, micro_batch: int) -> int:
        """Conservative peak-bytes upper bound: sums the forward/backward
        activation peak and the step-❺ update transient even though the
        two phases do not coexist (activations are freed before the
        update). Summing can under-admit a micro-batch but never
        over-admits one — the safe direction for an OOM model."""
        return (self.params_bytes + self.grads_bytes + self.opt_bytes
                + self.fixed_bytes + self.update_transient_bytes
                + self.activation_bytes_per_sample * micro_batch)


def activation_bytes_per_sample(cfg: ModelConfig, seq: int,
                                act_bytes: int = 2,
                                remat: bool = True) -> int:
    """Live activation bytes for ONE sample of length ``seq``.

    With per-period remat: residual-stream checkpoints at every period
    boundary (num_periods * seq * d_model) + the recompute working set of a
    single period (~ c * seq * max(d_model, d_ff, moe_active)).
    """
    d = cfg.d_model
    boundary = cfg.num_periods * seq * d * act_bytes
    widths = [d * 6]  # qkv + attn out + residuals
    if cfg.is_moe:
        widths.append(cfg.experts_per_token * cfg.moe_d_ff * 3 * cfg.capacity_factor)
    elif cfg.d_ff:
        widths.append(cfg.d_ff * 3)
    if cfg.ssm_state:
        widths.append(cfg.ssm_d_inner * 4)
    if cfg.lru_width:
        widths.append(cfg.lru_width * 6)
    period_live = seq * int(max(widths)) * act_bytes * cfg.pattern_len
    logits_live = seq * cfg.vocab_size * 4 // 8  # blocked CE kernel: 1/8 vocab
    if not remat:
        period_live *= cfg.num_periods
    return boundary + period_live + logits_live


def estimate(cfg: ModelConfig, seq: int, *, tp: int = 1, fsdp: int = 1,
             opt_slots: Optional[int] = None, act_bytes: int = 2,
             remat: bool = True, optimizer: str = "sgd",
             fused_update: bool = False) -> MemoryEstimate:
    """``optimizer`` names the update rule (state-slot count + step-❺
    transient); ``fused_update=True`` models the flat in-place path
    (``--executor flat``) whose update transient is eliminated. An explicit
    ``opt_slots`` overrides the per-optimizer slot count."""
    p_bytes = cfg.param_count() * 4 // (tp * fsdp)
    slots = _resolve_slots(optimizer, opt_slots)
    return MemoryEstimate(
        params_bytes=p_bytes,
        grads_bytes=p_bytes,
        opt_bytes=slots * p_bytes,
        activation_bytes_per_sample=activation_bytes_per_sample(
            cfg, seq, act_bytes, remat) // tp,
        fixed_bytes=64 * 1024 ** 2,
        update_transient_bytes=update_transient_bytes(
            p_bytes, optimizer, fused_update, opt_slots=slots),
    )


def suggest_micro_batch_size(cfg: ModelConfig, seq: int, mini_batch: int, *,
                             budget_bytes: int = V5E_HBM_BYTES, tp: int = 1,
                             fsdp: int = 1, opt_slots: Optional[int] = None,
                             act_bytes: int = 2,
                             remat: bool = True, optimizer: str = "sgd",
                             fused_update: bool = False) -> Optional[int]:
    """Largest power-of-two micro-batch (≤ mini_batch) that fits the budget.
    Returns None if even micro-batch 1 exceeds the budget (the model itself
    does not fit — MBS cannot help; that needs more model parallelism).
    The step-❺ transient term (see :func:`update_transient_bytes`) stops
    this from admitting micro-batches that would OOM at the update; with
    ``fused_update=True`` that headroom is reclaimed for activations."""
    est = estimate(cfg, seq, tp=tp, fsdp=fsdp, opt_slots=opt_slots,
                   act_bytes=act_bytes, remat=remat, optimizer=optimizer,
                   fused_update=fused_update)
    best = None
    m = 1
    while m <= mini_batch:
        if est.total(m) <= budget_bytes:
            best = m
        m *= 2
    return best


def max_minibatch_without_mbs(cfg: ModelConfig, seq: int, *,
                              budget_bytes: int = V5E_HBM_BYTES, tp: int = 1,
                              fsdp: int = 1, opt_slots: Optional[int] = None,
                              act_bytes: int = 2,
                              remat: bool = True, optimizer: str = "sgd",
                              fused_update: bool = False) -> int:
    """The paper's "w/o MBS" failure point: the largest mini-batch whose
    whole-batch activations fit (beyond it, the run 'Fails')."""
    est = estimate(cfg, seq, tp=tp, fsdp=fsdp, opt_slots=opt_slots,
                   act_bytes=act_bytes, remat=remat, optimizer=optimizer,
                   fused_update=fused_update)
    m = 0
    while est.total(m + 1) <= budget_bytes:
        m += 1
        if m > 1 << 24:
            break
    return m
