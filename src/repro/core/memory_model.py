"""Analytic device-memory model → automatic micro-batch sizing.

The paper determines the micro-batch size "experimentally ... the maximum
size that can compute on GPU" (§4.3.2). We replace that search with an
analytic model of per-device bytes as a function of the micro-batch size,
and pick the largest power-of-two that fits the HBM budget — the same
quantity the dry-run's ``compiled.memory_analysis()`` verifies.

The model (per device, for the transformer families):
  params           P/ (tp * fsdp)                       * 4 B (fp32 master)
  grads (accum)    same as params                       * 4 B
  optimizer state  k_opt * params bytes (SGD-m: 1, Adam: 2)
  update transient step-❺ peak on top of the steady state: the unfused
                   update materializes the full ``updates`` tree plus
                   fresh momentum/m/v trees that coexist with the old
                   state until the swap — (1 + k_opt) * params bytes.
                   The fused flat path (``kernels/fused_update.py``,
                   in-place aliasing + donation) eliminates it.
  activations      per-period remat boundary + the live working set the
                   remat policy leaves, proportional to micro_batch * seq
                   (the MBS knob). The graded ``remat_policy`` lattice
                   (``models/remat.POLICIES``) scales the working-set term:
                     none    every period's working set stays live
                     dots    matmul outputs of every period stay saved
                             (~half the working set) + one period recompute
                     period  one period's working set (historical remat=True)
                     full    one block's working set (nested per-block remat)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

from ..models import remat as remat_lib
from ..models.config import ModelConfig

V5E_HBM_BYTES = 16 * 1024 ** 3

# lattice order == the planner's escalation order (cheapest recompute first)
POLICY_ORDER = remat_lib.POLICIES

# fraction of a period's working set that checkpoint_dots keeps saved (the
# matmul outputs; elementwise intermediates are recomputed)
DOTS_SAVED_FRACTION = 0.5

# optimizer-state slots per optimizer (momentum / m+v trees)
OPT_SLOTS = {"sgd": 1, "sgd_plain": 0, "adam": 2, "adamw": 2}


def _resolve_slots(optimizer: str, opt_slots: Optional[int]) -> int:
    if opt_slots is not None:
        return opt_slots
    try:
        return OPT_SLOTS[optimizer]
    except KeyError:
        raise ValueError(
            f"unknown optimizer {optimizer!r}; known: {sorted(OPT_SLOTS)} "
            "(or pass opt_slots explicitly)")


def update_transient_bytes(params_bytes: int, optimizer: str = "sgd",
                           fused: bool = False, *,
                           opt_slots: Optional[int] = None) -> int:
    """Peak transient bytes of paper step ❺ beyond the steady state.

    The unfused reference (``optimizer.update`` + ``apply_update``) holds
    the full fp32 ``updates`` tree plus the freshly built optimizer-state
    trees while the old ones are still live. The fused flat update path
    writes params and opt state in place (``input_output_aliases`` +
    donation), leaving only O(kernel block) scratch — counted as zero.

    Fused-path caveat: the flat step still *gathers* the param/opt-state
    trees into contiguous buckets (and scatters them back), which is a
    copy at the XLA level. Those copies are counted as zero because they
    are live only at step ❺, when the donated split batch and the
    micro-batch activations — whose bytes this model already budgets and
    which dominate them at any admitted micro-batch size — have been
    freed for reuse; keeping state flat *across* steps (eliminating the
    gather entirely) is the noted next step in DESIGN.md §Update path."""
    if fused:
        return 0
    return (1 + _resolve_slots(optimizer, opt_slots)) * params_bytes


@dataclasses.dataclass(frozen=True)
class MemoryEstimate:
    params_bytes: int
    grads_bytes: int
    opt_bytes: int
    activation_bytes_per_sample: int  # per micro-batch sample, at given seq
    fixed_bytes: int
    update_transient_bytes: int = 0  # step-❺ peak (0 for the fused path)

    def total(self, micro_batch: int) -> int:
        """Conservative peak-bytes upper bound: sums the forward/backward
        activation peak and the step-❺ update transient even though the
        two phases do not coexist (activations are freed before the
        update). Summing can under-admit a micro-batch but never
        over-admits one — the safe direction for an OOM model."""
        return (self.params_bytes + self.grads_bytes + self.opt_bytes
                + self.fixed_bytes + self.update_transient_bytes
                + self.activation_bytes_per_sample * micro_batch)

    def affine_coeffs(self) -> tuple:
        """(fixed, per_sample) such that total(m) == fixed + per_sample*m.

        The estimate is exactly affine in the micro-batch size — this is
        the property the engine Layer 7 autotuner relies on: a measured
        XLA peak that is also (approximately) affine in m can be mapped
        onto this model by a single per-key affine correction
        (measured ≈ a*total(m) + b), fit from two or three probe
        compiles (`engine.autotune.calibrate_memory`)."""
        return self.total(0), self.activation_bytes_per_sample


def activation_bytes_per_sample(cfg: ModelConfig, seq: int,
                                act_bytes: int = 2,
                                remat: bool = True,
                                remat_policy: Optional[str] = None) -> int:
    """Live activation bytes for ONE sample of length ``seq``.

    Always present: residual-stream checkpoints at every period boundary
    (num_periods * seq * d_model) and the blocked-CE logits slice. The
    policy scales the live working-set term (one period's intermediates,
    ~ c * seq * max(d_model, d_ff, moe_active) * pattern_len):

      none    all ``num_periods`` working sets live simultaneously;
      dots    ``DOTS_SAVED_FRACTION`` of every period's working set stays
              saved (the dot outputs) + one period recomputing;
      period  exactly one period's working set (the recompute unit);
      full    nested per-block checkpoints shrink the recompute unit to a
              single block: one period's working set / pattern_len.

    ``remat_policy`` overrides the legacy ``remat`` bool (True → "period",
    False → "none") — the mapping lives in ``models/remat.resolve``.
    """
    policy = remat_lib.resolve(remat, remat_policy)
    d = cfg.d_model
    boundary = cfg.num_periods * seq * d * act_bytes
    widths = [d * 6]  # qkv + attn out + residuals
    if cfg.is_moe:
        widths.append(cfg.experts_per_token * cfg.moe_d_ff * 3 * cfg.capacity_factor)
    elif cfg.d_ff:
        widths.append(cfg.d_ff * 3)
    if cfg.ssm_state:
        widths.append(cfg.ssm_d_inner * 4)
    if cfg.lru_width:
        widths.append(cfg.lru_width * 6)
    period_live = seq * int(max(widths)) * act_bytes * cfg.pattern_len
    logits_live = seq * cfg.vocab_size * 4 // 8  # blocked CE kernel: 1/8 vocab
    if policy == "none":
        live = cfg.num_periods * period_live
    elif policy == "dots":
        live = period_live + int(
            DOTS_SAVED_FRACTION * (cfg.num_periods - 1) * period_live)
    elif policy == "period":
        live = period_live
    else:  # "full"
        live = -(-period_live // cfg.pattern_len)
    return boundary + live + logits_live


def pipeline_activation_bytes_per_sample(cfg: ModelConfig, seq: int,
                                         stages: int, act_bytes: int = 2,
                                         remat: bool = True,
                                         remat_policy: Optional[str] = None
                                         ) -> int:
    """Per-device live activation bytes for ONE local sample under the
    1F1B pipelined executor (engine Layer 11) with ``stages`` stages.

    The executor keeps *stage-local activations × the in-flight micro-batch
    count*: 1F1B's warmup depth bounds the number of in-flight micro-batches
    per stage at ``stages``, and each in-flight micro-batch holds exactly
    one stage-INPUT carry (the executor rematerializes the stage forward
    from that carry during the backward tick — stage-level remat). Terms:

      rings        2 depth-``stages`` rings (arriving-activation queue +
                   backward residuals), each slot one residual-stream carry
                   (seq * d_model);
      stage live   ONE stage's forward/backward working set: its share of
                   the period boundaries (num_periods / stages) plus the
                   remat policy's live term — the same lattice scaling as
                   :func:`activation_bytes_per_sample`, with the period
                   count cut to the stage's share;
      logits       the blocked-CE logits slice. Charged on every device:
                   the SPMD-masked schedule traces the (masked) loss head
                   on all stages, so its buffer is live everywhere.
    """
    if stages < 1:
        raise ValueError(f"stages must be >= 1, got {stages}")
    policy = remat_lib.resolve(remat, remat_policy)
    d = cfg.d_model
    carry = seq * d * act_bytes
    rings = 2 * stages * carry
    per_stage = -(-cfg.num_periods // stages)
    widths = [d * 6]
    if cfg.is_moe:
        widths.append(cfg.experts_per_token * cfg.moe_d_ff * 3
                      * cfg.capacity_factor)
    elif cfg.d_ff:
        widths.append(cfg.d_ff * 3)
    if cfg.ssm_state:
        widths.append(cfg.ssm_d_inner * 4)
    if cfg.lru_width:
        widths.append(cfg.lru_width * 6)
    period_live = seq * int(max(widths)) * act_bytes * cfg.pattern_len
    logits_live = seq * cfg.vocab_size * 4 // 8
    if policy == "none":
        live = per_stage * period_live
    elif policy == "dots":
        live = period_live + int(
            DOTS_SAVED_FRACTION * (per_stage - 1) * period_live)
    elif policy == "period":
        live = period_live
    else:  # "full"
        live = -(-period_live // cfg.pattern_len)
    return rings + per_stage * carry + live + logits_live


# ---------------------------------------------------------------------------
# Serving (engine Layer 10): KV-cache admission terms
# ---------------------------------------------------------------------------

# bytes of the per-slot ring-position bookkeeping (``pos`` int32 per entry)
CACHE_POS_BYTES = 4


def kv_bytes_per_token(cfg: ModelConfig, cache_bytes: int = 2) -> int:
    """Decode-cache bytes ONE cached context token costs, summed over every
    attention layer — the serving mirror of
    :func:`activation_bytes_per_sample`. Each (global|local) layer stores a
    K and a V row (``num_kv_heads * head_dim``) plus the ring slot's
    absolute-position bookkeeping (int32); state-carrying layers
    (ssm / recurrent) contribute nothing here because their decode state is
    O(1) in the context length — see :func:`slot_state_bytes`.

    This is the quantity "The Limit of the Batch Size" turns into decode
    throughput: at a fixed HBM budget the admitted concurrent-request
    count is (budget - params - fixed) / (context * kv_bytes_per_token).
    """
    per_layer = 2 * cfg.num_kv_heads * cfg.head_dim * cache_bytes \
        + CACHE_POS_BYTES
    n_attn = sum(1 for k in cfg.layer_pattern if k in ("global", "local"))
    return cfg.num_periods * n_attn * per_layer


def slot_state_bytes(cfg: ModelConfig, cache_bytes: int = 2) -> int:
    """Context-length-independent decode state per request slot: the SSD
    state + conv tail of ``ssm`` slots and the RG-LRU hidden + conv tail of
    ``recurrent`` slots (matching ``models/{ssm,recurrent}.init_*_cache``)."""
    total = 0
    for kind in cfg.layer_pattern:
        if kind == "ssm" and cfg.ssm_state:
            conv_dim = cfg.ssm_d_inner + 2 * cfg.ssm_state
            total += (cfg.ssm_num_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
                      + (cfg.conv_width - 1) * conv_dim * cache_bytes)
        elif kind == "recurrent" and cfg.lru_width:
            total += (cfg.lru_width * 4
                      + (cfg.conv_width - 1) * cfg.lru_width * cache_bytes)
    return cfg.num_periods * total


def kv_slot_bytes(cfg: ModelConfig, max_len: int, cache_bytes: int = 2,
                  global_window: Optional[int] = None) -> int:
    """Total decode-cache bytes ONE request slot holds at context capacity
    ``max_len``, honoring per-layer ring windows: a ``local`` layer's ring
    is bounded to ``sliding_window`` entries and a ``global`` layer to
    ``global_window`` (when serving a capped long-context variant), so a
    slot costs less than ``max_len * kv_bytes_per_token`` whenever any
    window is tighter than the context."""
    per_entry = 2 * cfg.num_kv_heads * cfg.head_dim * cache_bytes \
        + CACHE_POS_BYTES
    total = 0
    for kind in cfg.layer_pattern:
        if kind in ("global", "local"):
            w = cfg.sliding_window if kind == "local" else global_window
            entries = max_len if w is None else min(w, max_len)
            total += entries * per_entry
    return cfg.num_periods * total + slot_state_bytes(cfg, cache_bytes)


def prefill_activation_bytes_per_sample(cfg: ModelConfig, seq: int,
                                        act_bytes: int = 2) -> int:
    """Forward-only (no backward, no checkpoint boundary) live bytes for
    ONE prefill sample of length ``seq``: the residual stream (x plus one
    block output in flight) and one period's working set — under
    ``lax.scan`` period ``i``'s intermediates are freed before ``i+1``
    runs — plus the last-token logits row. The per-sample KV bytes the
    prefill *builds* are accounted by the caller through
    :func:`kv_slot_bytes` (they persist past the prefill)."""
    d = cfg.d_model
    stream = 2 * seq * d * act_bytes
    widths = [d * 6]
    if cfg.is_moe:
        widths.append(cfg.experts_per_token * cfg.moe_d_ff * 3
                      * cfg.capacity_factor)
    elif cfg.d_ff:
        widths.append(cfg.d_ff * 3)
    if cfg.ssm_state:
        widths.append(cfg.ssm_d_inner * 4)
    if cfg.lru_width:
        widths.append(cfg.lru_width * 6)
    period_live = seq * int(max(widths)) * act_bytes * cfg.pattern_len
    logits_live = cfg.vocab_size * 4
    return stream + period_live + logits_live


@dataclasses.dataclass(frozen=True)
class ServeMemoryEstimate:
    """Serving twin of :class:`MemoryEstimate` — affine in the number of
    admitted decode slots (at a fixed prefill micro-batch size), which is
    what :func:`engine.serving.plan_serve` binary-searches against."""
    params_bytes: int
    kv_slot_bytes: int  # decode-cache bytes per admitted request slot
    prefill_bytes_per_sample: int  # activations + the cache being built
    fixed_bytes: int

    def total(self, slots: int, prefill_micro: int = 0) -> int:
        """Peak bytes with ``slots`` admitted decode slots and a prefill
        micro-batch of ``prefill_micro`` in flight. Conservative the same
        way :meth:`MemoryEstimate.total` is: the prefill term is charged
        even though admission could time-slice prefill against decode —
        over-counting never over-admits."""
        return (self.params_bytes + self.fixed_bytes
                + self.kv_slot_bytes * slots
                + self.prefill_bytes_per_sample * prefill_micro)

    def affine_coeffs(self, prefill_micro: int = 0) -> tuple:
        """(fixed, per_slot) with total(s) == fixed + per_slot * s."""
        return self.total(0, prefill_micro), self.kv_slot_bytes


def serve_estimate(cfg: ModelConfig, max_len: int, *,
                   prefill_len: Optional[int] = None,
                   cache_bytes: int = 2, act_bytes: int = 2,
                   global_window: Optional[int] = None,
                   mesh=None, fsdp_params: bool = False
                   ) -> ServeMemoryEstimate:
    """Analytic serving-memory model: params (fp32 inference weights, no
    grads / optimizer state / update transient) + per-slot KV bytes at
    ``max_len`` + per-sample prefill cost at ``prefill_len`` (default
    ``max_len``). ``mesh`` switches to the PER-DEVICE estimate the same
    way :func:`estimate` does — params discounted by the real sharding
    ratio (``fsdp_params=False`` models the replicating data-parallel
    serving replica), cache/activation terms budget the *local* slot and
    prefill counts."""
    if mesh is not None:
        p_bytes = int(cfg.param_count() * 4
                      * param_shard_ratio(cfg, mesh, fsdp=fsdp_params))
    else:
        p_bytes = cfg.param_count() * 4
    pf = max_len if prefill_len is None else prefill_len
    slot = kv_slot_bytes(cfg, max_len, cache_bytes, global_window)
    return ServeMemoryEstimate(
        params_bytes=p_bytes,
        kv_slot_bytes=slot,
        prefill_bytes_per_sample=(
            prefill_activation_bytes_per_sample(cfg, pf, act_bytes)
            + slot),
        fixed_bytes=64 * 1024 ** 2,
    )


class _MeshDims:
    """Axis-name → size view of a mesh — the only part of a mesh the
    sharding policy reads, and a hashable cache key for the ratio below."""

    def __init__(self, dims):
        self.shape = dict(dims)
        self.axis_names = tuple(self.shape)


def param_shard_ratio(cfg: ModelConfig, mesh, *, fsdp: bool = True) -> float:
    """Per-device fraction of the parameter bytes under the REAL sharding
    policy (``launch/sharding.param_specs``), mesh axes and divisibility
    included — leaves whose dims do not divide the mesh stay replicated
    and cost full bytes, which a blanket ``/ (tp * fsdp)`` discount would
    understate. Grads and optimizer state shard with the same specs, so
    one ratio covers all three terms. ``fsdp=False`` models a
    data-parallel-only executor that replicates params (the engine's
    ``ShardedExecutor``): only the model axis discounts. Memoized: one
    auto plan calls ``estimate`` once per lattice policy, and the ratio
    only depends on (config, mesh axis sizes, fsdp)."""
    return _param_shard_ratio(cfg, tuple(mesh.shape.items()), fsdp)


@functools.lru_cache(maxsize=256)
def _param_shard_ratio(cfg: ModelConfig, mesh_dims: tuple,
                       fsdp: bool) -> float:
    import jax  # deferred: keep module import light
    from jax.sharding import PartitionSpec as P
    from ..launch import sharding as sharding_lib  # deferred: no cycle
    from ..models import encdec, transformer

    mesh = _MeshDims(mesh_dims)
    init = encdec.init_params if cfg.is_encdec else transformer.init_params
    shapes = jax.eval_shape(lambda k: init(cfg, k), jax.random.PRNGKey(0))
    specs = sharding_lib.param_specs(shapes, mesh, fsdp=fsdp)

    def shard_factor(spec) -> int:
        f = 1
        for entry in spec:
            if entry is None:
                continue
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                f *= mesh.shape[ax]
        return f

    total = sharded = 0
    for leaf, spec in zip(jax.tree.leaves(shapes),
                          jax.tree.leaves(specs,
                                          is_leaf=lambda x: isinstance(x, P))):
        total += leaf.size
        sharded += -(-leaf.size // shard_factor(spec))
    return sharded / total if total else 1.0


def estimate(cfg: ModelConfig, seq: int, *, tp: int = 1, fsdp: int = 1,
             opt_slots: Optional[int] = None, act_bytes: int = 2,
             remat: bool = True, remat_policy: Optional[str] = None,
             optimizer: str = "sgd",
             fused_update: bool = False, mesh=None,
             fsdp_params: bool = True, pipeline: bool = False
             ) -> MemoryEstimate:
    """``optimizer`` names the update rule (state-slot count + step-❺
    transient); ``fused_update=True`` models the flat in-place path
    (``--executor flat``) whose update transient is eliminated. An explicit
    ``opt_slots`` overrides the per-optimizer slot count; ``remat_policy``
    overrides the legacy ``remat`` bool (see
    :func:`activation_bytes_per_sample`).

    ``mesh`` switches to the PER-DEVICE estimate (engine Layer 6): the
    params/grads/opt-state/update-transient terms are discounted by the
    real sharding policy (:func:`param_shard_ratio` — honors divisibility
    and ``fsdp_params``; the manual ``tp``/``fsdp`` divisors are ignored)
    and the activation term is divided by the model axis only — the data
    axis enters through the *local* micro-batch the caller budgets with,
    not through this estimate.

    ``pipeline=True`` (engine Layer 11) reinterprets the mesh's model axis
    as 1F1B pipeline stages: the activation term becomes
    :func:`pipeline_activation_bytes_per_sample` — stage-local activations
    × the in-flight micro-batch count (warmup depth == stages) — instead
    of the tensor-parallel ``// tp`` discount."""
    if mesh is not None:
        from ..launch import mesh as mesh_lib  # deferred: no cycle
        tp = mesh_lib.axis_size(mesh, mesh_lib.MODEL_AXIS)
        p_bytes = int(cfg.param_count() * 4
                      * param_shard_ratio(cfg, mesh, fsdp=fsdp_params))
    else:
        p_bytes = cfg.param_count() * 4 // (tp * fsdp)
    if pipeline and tp > 1:
        act_per_sample = pipeline_activation_bytes_per_sample(
            cfg, seq, tp, act_bytes, remat, remat_policy)
    else:
        act_per_sample = activation_bytes_per_sample(
            cfg, seq, act_bytes, remat, remat_policy) // tp
    slots = _resolve_slots(optimizer, opt_slots)
    return MemoryEstimate(
        params_bytes=p_bytes,
        grads_bytes=p_bytes,
        opt_bytes=slots * p_bytes,
        activation_bytes_per_sample=act_per_sample,
        fixed_bytes=64 * 1024 ** 2,
        update_transient_bytes=update_transient_bytes(
            p_bytes, optimizer, fused_update, opt_slots=slots),
    )


def suggest_micro_batch_size(cfg: ModelConfig, seq: int, mini_batch: int, *,
                             budget_bytes: int = V5E_HBM_BYTES, tp: int = 1,
                             fsdp: int = 1, opt_slots: Optional[int] = None,
                             act_bytes: int = 2,
                             remat: bool = True,
                             remat_policy: Optional[str] = None,
                             optimizer: str = "sgd",
                             fused_update: bool = False, mesh=None,
                             fsdp_params: bool = True,
                             pipeline: bool = False) -> Optional[int]:
    """Largest power-of-two micro-batch (≤ mini_batch) that fits the budget.
    Returns None if even micro-batch 1 exceeds the budget (the model itself
    does not fit — MBS cannot help; that needs more model parallelism).
    The step-❺ transient term (see :func:`update_transient_bytes`) stops
    this from admitting micro-batches that would OOM at the update; with
    ``fused_update=True`` that headroom is reclaimed for activations.
    With ``mesh`` the estimate is per device and the suggested size is the
    per-device LOCAL micro-batch (``mini_batch`` should then be the local
    share — the planner passes ``mini // data_parallel``)."""
    est = estimate(cfg, seq, tp=tp, fsdp=fsdp, opt_slots=opt_slots,
                   act_bytes=act_bytes, remat=remat,
                   remat_policy=remat_policy, optimizer=optimizer,
                   fused_update=fused_update, mesh=mesh,
                   fsdp_params=fsdp_params, pipeline=pipeline)
    best = None
    m = 1
    while m <= mini_batch:
        if est.total(m) <= budget_bytes:
            best = m
        m *= 2
    return best


def suggest_remat_policy_and_micro(
        cfg: ModelConfig, seq: int, mini_batch: int, *,
        budget_bytes: int = V5E_HBM_BYTES, tp: int = 1, fsdp: int = 1,
        opt_slots: Optional[int] = None, act_bytes: int = 2,
        optimizer: str = "sgd", fused_update: bool = False,
        target_micro: Optional[int] = None, mesh=None,
        fsdp_params: bool = True, pipeline: bool = False
        ) -> Tuple[str, Optional[int]]:
    """Joint (remat policy, micro-batch) choice — engine Layer 5.

    Walks the lattice from cheapest recompute to heaviest, returning the
    FIRST policy whose admitted micro-batch reaches ``target_micro``
    (default: the whole mini-batch — i.e. no gradient accumulation needed).
    When no policy reaches the target the policy admitting the largest
    micro-batch wins, ties broken toward cheaper recompute — heavier remat
    is bought only when it actually converts into batch. Returns
    ``(policy, None)`` with the heaviest policy when even micro-batch 1
    does not fit anywhere (the model needs more parallelism, not MBS).
    """
    target = min(target_micro or mini_batch, mini_batch)
    best_policy, best_micro = POLICY_ORDER[-1], None
    for policy in POLICY_ORDER:
        micro = suggest_micro_batch_size(
            cfg, seq, mini_batch, budget_bytes=budget_bytes, tp=tp,
            fsdp=fsdp, opt_slots=opt_slots, act_bytes=act_bytes,
            remat_policy=policy, optimizer=optimizer,
            fused_update=fused_update, mesh=mesh, fsdp_params=fsdp_params,
            pipeline=pipeline)
        if micro is not None and micro >= target:
            return policy, micro
        if micro is not None and (best_micro is None or micro > best_micro):
            best_policy, best_micro = policy, micro
    return best_policy, best_micro


def max_minibatch_without_mbs(cfg: ModelConfig, seq: int, *,
                              budget_bytes: int = V5E_HBM_BYTES, tp: int = 1,
                              fsdp: int = 1, opt_slots: Optional[int] = None,
                              act_bytes: int = 2,
                              remat: bool = True,
                              remat_policy: Optional[str] = None,
                              optimizer: str = "sgd",
                              fused_update: bool = False) -> int:
    """The paper's "w/o MBS" failure point: the largest mini-batch whose
    whole-batch activations fit (beyond it, the run 'Fails')."""
    est = estimate(cfg, seq, tp=tp, fsdp=fsdp, opt_slots=opt_slots,
                   act_bytes=act_bytes, remat=remat,
                   remat_policy=remat_policy, optimizer=optimizer,
                   fused_update=fused_update)
    m = 0
    while est.total(m + 1) <= budget_bytes:
        m += 1
        if m > 1 << 24:
            break
    return m
