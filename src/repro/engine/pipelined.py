"""Pipeline-parallel MBS execution (engine Layer 11): 1F1B over the mesh's
``model`` axis, composed with the Layer-6 data-parallel path.

The paper's micro-batches are exactly the currency of pipeline schedules:
a 1F1B schedule streams the :class:`~.plan.MBSPlan`'s ``num_micro_batches``
through ``stages`` model shards with at most ``stages`` micro-batches in
flight per device — which is why ``plan_mbs(pipeline=True)`` budgets
stage-local activations × warmup depth instead of whole-model activations.

Schedule (closed form, host-side tables — no device control flow):

    t_f(s, i) = s + i                  i <= S-1-s   (warmup)
              = 2 i + s                otherwise    (steady 1F1B)
    t_b(s, j) = 2 S - 1 - s + 2 j
    ticks     T = 2 (M + S - 1)

Forward and backward never collide on one stage (parity: ``2(i-j)`` is
even, ``2S-1-2s`` is odd), each stage's input for micro ``i`` arrives at
least one tick before ``t_f(s, i)``, and a depth-``S`` ring per buffer is
collision-free (the next same-slot write lands after the consumption).

SPMD realization: every device traces the SAME program — per tick one
*masked* forward and one *masked* backward, selected by indexing the
host-side tables with the traced stage id ``lax.axis_index("model")``.
Masked work runs on clamped/stale-but-finite inputs and is discarded
(forward: ring writes gated off; backward: all-zero cotangents make every
gradient contribution exactly zero by linearity of the VJP). This is the
standard SPMD-masking cost: ~2× the FLOPs of a true MIMD 1F1B, traded for
a single jittable program with no per-stage executables.

Stage function contract (:class:`StagedLoss`): ``prelude`` (embedding) is
traced on every stage but a ``where(stage == 0, prelude(mb), x_in)``
select kills its gradient elsewhere; ``finale`` (head + loss) is traced on
every stage but only the LAST stage's loss cotangent is 1 — autodiff then
routes shared-parameter gradients to exactly one stage each, and the
cross-stage sum happens in the one (data+model) psum below.

Collective structure per mini-batch (``defer_sync=True``, no FSDP):

  * 2 ``ppermute`` rings per tick (activations +1, cotangents −1) — the
    point-to-point stage-boundary traffic, 2 T total;
  * exactly ONE data-axis-only psum (the flat stage-gradient reduction —
    "one gradient all-reduce per mini-batch on the DP axis", the same
    amortization :mod:`engine.sharded` proves for pure DP);
  * exactly ONE (data+model) psum (shared-param grads + loss + metrics +
    valid count, masked by ``is_last`` so nothing is counted ×S).

``defer_sync=False`` is the per-micro-sync baseline (one data-axis psum
per backward tick) that the analysis negative-control asserts against.

``fsdp=True`` additionally shards stage-local parameters over the data
axis per ``launch/sharding.param_specs`` (with the ``model`` entries
stripped — the model axis is spent on the stage dim), gathers them
just-in-time inside the step (``all_gather(tiled=True)``) and reduces
gradients with ``psum_scatter`` — a real FSDP forward, proven by the
equivalence tests rather than the exact-psum-count census.

The optimizer update runs OUTSIDE the ``shard_map`` on the recombined
params-shaped gradient tree, so optimizer state never splits across the
(shared, staged) partition and the Layer-9 guard applies unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from ..launch import mesh as mesh_lib
from ..launch import sharding
from . import exec_core, faults
from .executors import _as_plan
from .plan import MBSPlan
from .sharded import _local_valid_count, batch_partition_specs, psum_flat


def schedule_1f1b(stages: int, micros: int
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Host-side 1F1B tick tables (see module doc for the closed form).

    Returns ``(fwd, bwd, recv, ticks)``: ``fwd[t, s]`` / ``bwd[t, s]`` is
    the micro-batch index stage ``s`` runs forward/backward at tick ``t``
    (−1 = idle); ``recv[t, s]`` is the micro index whose activation stage
    ``s`` receives from ``s−1`` at the END of tick ``t`` (−1 masks the
    ppermute ring wrap into stage 0)."""
    if stages < 1 or micros < 1:
        raise ValueError(f"need stages >= 1 and micros >= 1, got "
                         f"({stages}, {micros})")
    ticks = 2 * (micros + stages - 1)
    fwd = -np.ones((ticks, stages), np.int32)
    bwd = -np.ones((ticks, stages), np.int32)
    for s in range(stages):
        for i in range(micros):
            t = s + i if i <= stages - 1 - s else 2 * i + s
            fwd[t, s] = i
        for j in range(micros):
            bwd[2 * stages - 1 - s + 2 * j, s] = j
    recv = -np.ones((ticks, stages), np.int32)
    recv[:, 1:] = fwd[:, :-1]
    return fwd, bwd, recv, ticks


@dataclasses.dataclass(frozen=True)
class StagedLoss:
    """A loss function split for pipeline execution.

    The params tree must hold ONE subtree (``params[stacked_key]``) whose
    leaves all carry a leading ``num_layers`` scan dim; everything else is
    "shared" (embedding, head, final norm). The three callables factor the
    loss as ``finale(shared, stage_fn^S(.., prelude(shared, mb)), mb)``:

      prelude(shared, mb) -> x        the stage-0 entry (embedding); the
                                      output pytree is the residual carry
                                      every stage maps to itself;
      stage_fn(stage_params, x) -> x  one stage: leaves lead with
                                      ``num_layers // stages`` (scan them);
      finale(shared, x, mb)           -> (raw_loss_sum, metrics): the RAW
                                      per-shard loss SUM (exact_denom=1
                                      semantics — the executor divides by
                                      the global valid count after psum).
    """
    num_layers: int
    prelude: Callable[[Any, Any], Any]
    stage_fn: Callable[[Any, Any], Any]
    finale: Callable[[Any, Any, Any], Tuple[jnp.ndarray, Dict[str, Any]]]
    stacked_key: str = "blocks"

    def partition(self, params, stages: int) -> Tuple[Any, Any]:
        """(shared, staged): staged leaves reshaped (L, ...) ->
        (stages, L/stages, ...) so the stage dim shards over ``model``."""
        if self.num_layers % stages:
            raise ValueError(
                f"pipeline stage count {stages} does not divide the block "
                f"stack ({self.num_layers} layers) — pick a model axis "
                "that divides the layer count evenly")
        per = self.num_layers // stages
        shared = {k: v for k, v in params.items() if k != self.stacked_key}
        staged = jax.tree.map(
            lambda a: a.reshape((stages, per) + a.shape[1:]),
            params[self.stacked_key])
        return shared, staged

    def combine(self, shared, staged):
        """Inverse of :meth:`partition` — rebuilds the params-shaped tree
        (used on gradients, so the optimizer never sees the split)."""
        stacked = jax.tree.map(
            lambda a: a.reshape((a.shape[0] * a.shape[1],) + a.shape[2:]),
            staged)
        out = dict(shared)
        out[self.stacked_key] = stacked
        return out


def _mentions(entry, axis: str) -> bool:
    if entry is None:
        return False
    if isinstance(entry, (tuple, list)):
        return axis in entry
    return entry == axis


def _strip_model(spec: P) -> Tuple:
    """Drop ``model`` mesh-axis entries from a PartitionSpec (the model
    axis is spent on the pipeline stage dim, not tensor parallelism)."""
    out = []
    for e in spec:
        if isinstance(e, (tuple, list)):
            kept = tuple(a for a in e if a != mesh_lib.MODEL_AXIS)
            out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
        else:
            out.append(None if e == mesh_lib.MODEL_AXIS else e)
    return tuple(out)


def _map_specs(fn, spec_tree):
    return jax.tree.map(fn, spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


class PipelinedExecutor:
    """1F1B pipeline + DP executor (see module doc).

    Implements the :class:`engine.executors.Executor` protocol over a 2-D
    ``data × model`` mesh: the model axis runs ``stages`` pipeline stages,
    the (pod, data) axes replicate the schedule over ``local_micro``
    sample shards. ``fsdp=True`` shards stage-local params over ``data``
    per ``launch/sharding.param_specs`` with just-in-time gathers.
    """
    name = "pipelined"

    def __init__(self, staged: StagedLoss, optimizer, plan, *, mesh,
                 defer_sync: bool = True, fsdp: bool = False,
                 donate: bool = True, guard: bool = False):
        self.staged = staged
        self.optimizer = optimizer
        self.plan: MBSPlan = _as_plan(plan)
        self.mesh = mesh
        self.axes = mesh_lib.batch_axes(mesh)
        self.dp = mesh_lib.data_parallel_size(mesh)
        self.stages = mesh_lib.axis_size(mesh, mesh_lib.MODEL_AXIS)
        self.defer_sync = defer_sync
        self.fsdp = fsdp
        self.guard = guard
        self._donate = donate
        self._step_jit = None
        self._grads_jit = None
        if self.stages < 2:
            raise ValueError(
                "PipelinedExecutor needs a mesh model axis of >= 2 stages "
                f"(got {self.stages}); for pure data parallelism use "
                "ShardedExecutor")
        if staged.num_layers % self.stages:
            raise ValueError(
                f"pipeline stage count {self.stages} does not divide the "
                f"block stack ({staged.num_layers} layers) — pick a model "
                "axis that divides the layer count evenly")
        if self.plan.pipeline_stages > 1 \
                and self.plan.pipeline_stages != self.stages:
            raise ValueError(
                f"plan was admitted for {self.plan.pipeline_stages} "
                f"pipeline stages but the mesh's model axis is "
                f"{self.stages} — rebuild the plan with this mesh")
        if self.plan.micro_batch_size % self.dp:
            raise ValueError(
                f"micro-batch {self.plan.micro_batch_size} does not divide "
                f"over {self.dp} data-parallel workers — build the plan "
                "with plan_mbs(mesh=...) so sizes stay divisible")
        if self.plan.normalization == "paper" and self.plan.pad:
            raise ValueError(
                'a ragged "paper" plan cannot be pipelined exactly (the '
                "tail pad lands on one worker's shard) — use "
                'normalization="exact" (plan_mbs auto-upgrades ragged plans)')
        if fsdp and not defer_sync:
            raise ValueError(
                "defer_sync=False is the per-micro-sync comparison baseline "
                "and does not compose with fsdp=True (psum_scatter already "
                "replaces the deferred psum)")

    # -- staging ------------------------------------------------------------

    def batch_shardings(self, split):
        specs = batch_partition_specs(split, self.plan.micro_batch_size,
                                      self.axes)
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs,
                            is_leaf=lambda x: isinstance(x, P))

    def stage(self, split):
        return jax.device_put(split, self.batch_shardings(split))

    # -- parameter partition specs ------------------------------------------

    def _param_specs(self, shared, staged):
        """(shared_specs, staged_specs) PartitionSpec trees. Non-FSDP:
        staged leaves shard ONLY the leading stage dim over ``model``
        (sharding itself does the stage selection — no dynamic indexing of
        params by stage id); shared params replicate. FSDP: stage-LOCAL
        shapes go through the real ``launch/sharding.param_specs`` policy
        (under a stacked root so the layer scan dim is skipped), with
        ``model`` entries stripped."""
        if not self.fsdp:
            staged_specs = jax.tree.map(
                lambda x: P(mesh_lib.MODEL_AXIS, *([None] * (x.ndim - 1))),
                staged)
            shared_specs = jax.tree.map(lambda x: P(), shared)
            return shared_specs, staged_specs
        stage_view = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), staged)
        policy = sharding.param_specs(
            {"blocks": stage_view, "shared": shared}, self.mesh, fsdp=True)
        staged_specs = _map_specs(
            lambda sp: P(mesh_lib.MODEL_AXIS, *_strip_model(sp)),
            policy["blocks"])
        shared_specs = _map_specs(lambda sp: P(*_strip_model(sp)),
                                  policy["shared"])
        return shared_specs, staged_specs

    def _gather_fsdp(self, tree_, specs):
        """Just-in-time parameter gather: undo the data-axis shards so the
        stage computes on full stage-local params."""
        def g(x, spec):
            for d, e in enumerate(spec):
                if _mentions(e, mesh_lib.DATA_AXIS):
                    x = jax.lax.all_gather(x, mesh_lib.DATA_AXIS, axis=d,
                                           tiled=True)
            return x
        return jax.tree.map(g, tree_, specs)

    def _scatter_grads(self, tree_, specs, *, sum_model: bool):
        """Reduce full gradients back to the FSDP layout: ``psum_scatter``
        on sharded dims, plain data psum on unsharded leaves. ``sum_model``
        first sums the stage contributions (shared params only)."""
        def sfn(g, spec):
            if sum_model:
                g = jax.lax.psum(g, mesh_lib.MODEL_AXIS)
            scattered = False
            for d, e in enumerate(spec):
                if _mentions(e, mesh_lib.DATA_AXIS):
                    g = jax.lax.psum_scatter(
                        g, mesh_lib.DATA_AXIS, scatter_dimension=d,
                        tiled=True)
                    scattered = True
            if not scattered:
                g = jax.lax.psum(g, mesh_lib.DATA_AXIS)
            return g
        return jax.tree.map(sfn, tree_, specs)

    # -- the local (per-device) 1F1B program --------------------------------

    def _local_fn(self, n_s: int, shared_specs, staged_specs):
        """The shard_mapped body: returns NORMALIZED (shared grads, staged
        grads [leading stage dim], loss, metrics) for this device."""
        S = self.stages
        fwd_tab, bwd_tab, recv_tab, ticks = schedule_1f1b(S, n_s)
        spec = self.staged
        perm_f = [(i, (i + 1) % S) for i in range(S)]
        perm_b = [(i, (i - 1) % S) for i in range(S)]

        def take_micro(split, idx):
            safe = jnp.maximum(idx, 0)
            return jax.tree.map(
                lambda x: jax.lax.dynamic_index_in_dim(x, safe, 0,
                                                       keepdims=False),
                split)

        def ring_read(ring, idx):
            slot = jnp.maximum(idx, 0) % S
            return jax.tree.map(
                lambda r: jax.lax.dynamic_index_in_dim(r, slot, 0,
                                                       keepdims=False),
                ring)

        def ring_write(ring, val, idx, on):
            slot = jnp.maximum(idx, 0) % S

            def wr(r, v):
                new = jax.lax.dynamic_update_index_in_dim(
                    r, v.astype(r.dtype), slot, 0)
                return jnp.where(on, new, r)
            return jax.tree.map(wr, ring, val)

        def ppermute(tree_, perm):
            return jax.tree.map(
                lambda v: jax.lax.ppermute(v, mesh_lib.MODEL_AXIS, perm),
                tree_)

        def local(shared, staged_block, split):
            if self.fsdp:
                shared = self._gather_fsdp(shared, shared_specs)
                # the block keeps its (size-1) stage dim, so the full spec
                # aligns: entry 0 is `model`, which the gather skips
                staged_block = self._gather_fsdp(staged_block, staged_specs)
            stage_p = jax.tree.map(lambda x: x[0], staged_block)
            s_idx = jax.lax.axis_index(mesh_lib.MODEL_AXIS)
            is_first = s_idx == 0
            is_last = s_idx == S - 1

            def full_stage(sp, sh, x_in, mb):
                x0 = spec.prelude(sh, mb)
                x = jax.tree.map(
                    lambda a, b: jnp.where(is_first, a, b), x0, x_in)
                y = spec.stage_fn(sp, x)
                loss_raw, metrics = spec.finale(sh, y, mb)
                return (y, loss_raw), metrics

            def stage_forward(sp, sh, x_in, mb):
                x0 = spec.prelude(sh, mb)
                x = jax.tree.map(
                    lambda a, b: jnp.where(is_first, a, b), x0, x_in)
                return spec.stage_fn(sp, x)

            mb0 = take_micro(split, jnp.asarray(0, jnp.int32))
            x_abs = jax.eval_shape(spec.prelude, shared, mb0)
            zeros = lambda sds: jnp.zeros(sds.shape, sds.dtype)
            queue = jax.tree.map(
                lambda sds: jnp.zeros((S,) + sds.shape, sds.dtype), x_abs)
            resid = jax.tree.map(
                lambda sds: jnp.zeros((S,) + sds.shape, sds.dtype), x_abs)
            cot = jax.tree.map(zeros, x_abs)
            (_, _), metrics_abs = jax.eval_shape(
                full_stage, stage_p, shared, x_abs, mb0)
            metric_acc = jax.tree.map(zeros, metrics_abs)
            acc_stage = exec_core.init_accum(stage_p, self.plan.accum_dtype)
            acc_shared = exec_core.init_accum(shared, self.plan.accum_dtype)
            loss_acc = jnp.zeros((), jnp.float32)

            for t in range(ticks):
                f_i = jnp.asarray(fwd_tab[t])[s_idx]
                b_j = jnp.asarray(bwd_tab[t])[s_idx]
                r_i = jnp.asarray(recv_tab[t])[s_idx]
                f_on = f_i >= 0
                b_on = b_j >= 0

                if (bwd_tab[t] >= 0).any():
                    # masked backward: recompute the stage from its saved
                    # INPUT (stage-level remat) and pull masked cotangents
                    mb_b = take_micro(split, b_j)
                    x_res = ring_read(resid, b_j)
                    (_, loss_raw), vjp_fn, metrics = jax.vjp(
                        lambda sp_, sh_, xi: full_stage(sp_, sh_, xi, mb_b),
                        stage_p, shared, x_res, has_aux=True)
                    dy_on = jnp.logical_and(b_on, jnp.logical_not(is_last))
                    dy = jax.tree.map(
                        lambda c: jnp.where(dy_on, c, jnp.zeros_like(c)),
                        cot)
                    dl = jnp.where(jnp.logical_and(b_on, is_last),
                                   1.0, 0.0).astype(loss_raw.dtype)
                    d_sp, d_sh, dx = vjp_fn((dy, dl))
                    if not self.defer_sync:
                        # per-micro baseline: sync every backward tick
                        d_sp, d_sh = psum_flat((d_sp, d_sh), self.axes)
                    acc_stage = exec_core.accumulate(acc_stage, d_sp)
                    acc_shared = exec_core.accumulate(acc_shared, d_sh)
                    lmask = jnp.where(jnp.logical_and(b_on, is_last),
                                      1.0, 0.0)
                    loss_acc = loss_acc + loss_raw * lmask
                    metric_acc = jax.tree.map(
                        lambda a, m: a + m.astype(a.dtype) * lmask,
                        metric_acc, metrics)
                    # cotangents flow one stage back (depth-1 buffer: the
                    # receiver consumes it exactly next tick)
                    cot = ppermute(dx, perm_b)

                if (fwd_tab[t] >= 0).any():
                    mb_f = take_micro(split, f_i)
                    x_in = ring_read(queue, f_i)
                    y = stage_forward(stage_p, shared, x_in, mb_f)
                    resid = ring_write(resid, x_in, f_i, f_on)
                    y_recv = ppermute(y, perm_f)
                    queue = ring_write(queue, y_recv, r_i, r_i >= 0)

            valid = _local_valid_count(split) * jnp.where(is_last, 1.0, 0.0)
            if self.defer_sync and not self.fsdp:
                # the ONE gradient all-reduce per mini-batch on the DP axis
                acc_stage = psum_flat(acc_stage, self.axes)
            elif self.fsdp:
                acc_stage = self._scatter_grads(
                    acc_stage,
                    _map_specs(lambda sp: P(*sp[1:]), staged_specs),
                    sum_model=False)
            # shared grads + loss + metrics + valid cross stage boundaries:
            # one (data+model) psum (is_last masking stops ×S counting)
            if self.fsdp:
                acc_shared = self._scatter_grads(acc_shared, shared_specs,
                                                 sum_model=True)
                loss_acc, metric_acc, valid = psum_flat(
                    (loss_acc, metric_acc, valid),
                    self.axes + (mesh_lib.MODEL_AXIS,))
            elif self.defer_sync:
                acc_shared, loss_acc, metric_acc, valid = psum_flat(
                    (acc_shared, loss_acc, metric_acc, valid),
                    self.axes + (mesh_lib.MODEL_AXIS,))
            else:
                # per-micro mode already summed grads over data per tick;
                # only the shared stage contributions still need crossing
                acc_shared = psum_flat(acc_shared, (mesh_lib.MODEL_AXIS,))
                loss_acc, metric_acc, valid = psum_flat(
                    (loss_acc, metric_acc, valid),
                    self.axes + (mesh_lib.MODEL_AXIS,))
            scale = 1.0 / valid
            g_sh = jax.tree.map(lambda g: (g * scale).astype(g.dtype),
                                acc_shared)
            g_st = jax.tree.map(lambda g: ((g * scale).astype(g.dtype))[None],
                                acc_stage)
            loss = loss_acc * scale
            metrics = jax.tree.map(lambda m: m / (self.dp * n_s), metric_acc)
            return g_sh, g_st, loss, metrics

        return local

    def _sharded_grads(self, params, split):
        """(params-shaped normalized grads, loss, metrics) via shard_map."""
        shared, staged = self.staged.partition(params, self.stages)
        shared_specs, staged_specs = self._param_specs(shared, staged)
        split_specs = batch_partition_specs(
            split, self.plan.micro_batch_size, self.axes)
        n_s = jax.tree.leaves(split)[0].shape[0]
        local = self._local_fn(n_s, shared_specs, staged_specs)
        g_sh, g_st, loss, metrics = shard_map(
            local, mesh=self.mesh,
            in_specs=(shared_specs, staged_specs, split_specs),
            out_specs=(shared_specs, staged_specs, P(), P()),
            check_rep=False)(shared, staged, split)
        grads = self.staged.combine(g_sh, g_st)
        return grads, loss, metrics

    # -- the Executor surface -----------------------------------------------

    def make_train_step(self) -> Callable:
        """Pure (params, opt_state, split) -> (params, opt_state, metrics).
        The optimizer update runs outside the shard_map on the recombined
        gradient tree — opt state stays params-shaped and replicated."""
        def train_step(params, opt_state, micro_batches):
            grads, loss, metrics = self._sharded_grads(params, micro_batches)
            ok = None
            if self.guard:
                new_params, new_opt, ok = exec_core.guarded_update(
                    self.optimizer, grads, opt_state, params)
            else:
                new_params, new_opt = exec_core.apply_update(
                    self.optimizer, grads, opt_state, params)
            out = exec_core.finalize_metrics(metrics, loss, grads)
            if ok is not None:
                out["nonfinite"] = 1.0 - ok.astype(jnp.float32)
            return new_params, new_opt, out
        return train_step

    def trace_step(self, params, opt_state, micro_batches):
        """ClosedJaxpr of the full pipelined step (inputs may be
        ``ShapeDtypeStruct``s) for the ``repro.analysis`` jaxpr census."""
        return jax.make_jaxpr(self.make_train_step())(
            params, opt_state, micro_batches)

    def state_shardings(self, params, opt_state):
        """(params, opt_state) NamedSharding trees for the step's steady
        state: stacked block leaves (and their optimizer moments) live
        sharded over the ``model`` axis between steps — each stage owns
        its slice, which is exactly the layout the shard_map consumes and
        produces — while shared params and scalars replicate. Lowering
        with these as BOTH in- and out-shardings keeps the donated state
        fully aliased; left unspecified, GSPMD takes replicated inputs
        but emits model-sharded block outputs, and the layout mismatch
        silently costs one full block-stack copy per step (HLO001)."""
        key = self.staged.stacked_key
        n_layers = self.staged.num_layers
        rep = NamedSharding(self.mesh, P())
        staged_sh = NamedSharding(self.mesh, P(mesh_lib.MODEL_AXIS))

        def one(path, x):
            in_blocks = any(
                getattr(p, "key", getattr(p, "name", None)) == key
                for p in path)
            if (in_blocks and getattr(x, "ndim", 0) >= 1
                    and x.shape[0] == n_layers):
                return staged_sh
            return rep

        return (jax.tree_util.tree_map_with_path(one, params),
                jax.tree_util.tree_map_with_path(one, opt_state))

    def donated_state_bytes(self, params, opt_state) -> int:
        """Per-device bytes of the donated (params, opt_state) buffers
        under :meth:`state_shardings` — the HLO001 aliasing floor (block
        leaves count 1/stages, replicated leaves count whole)."""
        key = self.staged.stacked_key
        n_layers = self.staged.num_layers
        total = 0
        for tree_ in (params, opt_state):
            for path, leaf in jax.tree_util.tree_flatten_with_path(tree_)[0]:
                b = int(leaf.size) * jnp.dtype(leaf.dtype).itemsize
                in_blocks = any(
                    getattr(p, "key", getattr(p, "name", None)) == key
                    for p in path)
                if (in_blocks and getattr(leaf, "ndim", 0) >= 1
                        and leaf.shape[0] == n_layers):
                    b //= self.stages
                total += b
        return total

    def lower_step(self, params, opt_state, micro_batches, *,
                   donate: Optional[bool] = None):
        if donate is None:
            donate = self._donate
        p_sh, o_sh = self.state_shardings(params, opt_state)
        return jax.jit(
            self.make_train_step(),
            in_shardings=(p_sh, o_sh, self.batch_shardings(micro_batches)),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1, 2) if donate else (),
        ).lower(params, opt_state, micro_batches)

    def step_split(self, params, opt_state, micro_batches
                   ) -> Tuple[Any, Any, Dict[str, Any]]:
        faults.on_dispatch(self.plan)
        if self._step_jit is None:
            self._step_jit = jax.jit(
                self.make_train_step(),
                donate_argnums=(0, 1, 2) if self._donate else ())
        return self._step_jit(params, opt_state, micro_batches)

    def step(self, params, opt_state, minibatch
             ) -> Tuple[Any, Any, Dict[str, Any]]:
        return self.step_split(params, opt_state,
                               self.stage(self.plan.split(minibatch)))

    def gradients(self, params, micro_batches):
        """Accumulated NORMALIZED gradients + mini-batch loss under the
        1F1B schedule (params-shaped — comparable leaf-for-leaf with the
        single-device executors)."""
        if self._grads_jit is None:
            def run(p, mb):
                g, loss, _ = self._sharded_grads(p, mb)
                return g, loss
            self._grads_jit = jax.jit(run)
        return self._grads_jit(params, micro_batches)
