"""Slot-pooled decode cache (engine Layer 10, the serving twin of the
training engine's planned activations).

The :class:`KVPool` owns ONE device-resident decode cache sized for the
plan's admitted slot count (``plan_serve`` → ``ServePlan.max_decode_slots``)
and treats the batch dimension as a pool of request *slots*: a request is
admitted by allocating a free slot and scattering its prefill cache rows in,
decodes in place against the ring layout (``attention.attn_decode_step``
writes slot ``pos % W``), and on finish simply returns the slot to the free
list — no zeroing needed, because admission always overwrites the full row
and decode masks validity through the per-entry ``pos`` bookkeeping.

Memory contract: the pool is allocated ONCE at plan time (``slots *
memory_model.kv_slot_bytes`` plus the state-carrying slots' fixed bytes)
and every decode step donates it back to itself (``input_output_aliases``
on every cache leaf — the non-donated path would keep old + new cache live,
doubling decode HBM; ``analysis.serve_checks`` rule SRV001 pins this).
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from ..models import transformer
from ..models.config import ModelConfig


class PoolExhausted(RuntimeError):
    """alloc() with no free slot — the scheduler admitted past the plan."""


class KVPool:
    """Fixed-capacity pool of decode-cache slots.

    ``cache`` is the live pytree (``transformer.init_cache`` layout: tuple
    per pattern slot, leaves stacked over periods with the request-slot
    dimension at axis 1). ``insert`` is a jitted scatter of one prefill
    row into one slot; with ``donate=True`` (default) the pool buffer is
    donated so XLA updates it in place instead of copying the whole pool
    per admission.
    """

    def __init__(self, cfg: ModelConfig, max_slots: int, max_len: int, *,
                 dtype=jnp.bfloat16, global_window: Optional[int] = None,
                 donate: bool = True):
        if max_slots < 1:
            raise ValueError(f"need at least one slot, got {max_slots}")
        self.cfg = cfg
        self.max_slots = int(max_slots)
        self.max_len = int(max_len)
        self.dtype = dtype
        self.global_window = global_window
        self.donate = donate
        self.cache = transformer.init_cache(cfg, self.max_slots, self.max_len,
                                            dtype, global_window)
        # LIFO free list: hot slots are reused first (their rows are most
        # likely still in cache-friendly memory)
        self._free: List[int] = list(range(self.max_slots - 1, -1, -1))
        self._insert = jax.jit(
            self._insert_impl,
            donate_argnums=(0,) if donate else ())

    # -- slot lifecycle -----------------------------------------------------

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def active_count(self) -> int:
        return self.max_slots - len(self._free)

    def alloc(self) -> int:
        """Claim a free slot. Raises :class:`PoolExhausted` when the plan's
        admission bound is already fully used — the scheduler must block
        new work, never grow the pool."""
        if not self._free:
            raise PoolExhausted(
                f"all {self.max_slots} decode slots in use — admission is "
                "bounded by the ServePlan; wait for an eviction")
        return self._free.pop()

    def free(self, slot: int) -> None:
        """Return a finished request's slot to the pool (reusable
        immediately; the next insert overwrites the whole row)."""
        if not 0 <= slot < self.max_slots:
            raise ValueError(f"slot {slot} out of range [0, {self.max_slots})")
        if slot in self._free:
            raise ValueError(f"slot {slot} is already free (double evict)")
        self._free.append(slot)

    # -- data movement ------------------------------------------------------

    @staticmethod
    def _insert_impl(pool, pre, row, slot):
        return jax.tree.map(
            lambda p, c: p.at[:, slot].set(c[:, row].astype(p.dtype)),
            pool, pre)

    def insert(self, prefill_cache: Any, row: int, slot: int) -> None:
        """Scatter prefill-cache row ``row`` into pool slot ``slot``
        (admission). The prefill cache must come from the same config at
        the same ``max_len``/window geometry (leaf shapes match up to the
        batch dim)."""
        self.cache = self._insert(self.cache, prefill_cache,
                                  jnp.int32(row), jnp.int32(slot))

    def bytes(self) -> int:
        """Device bytes the pool holds (all leaves)."""
        return sum(int(l.size) * jnp.dtype(l.dtype).itemsize
                   for l in jax.tree.leaves(self.cache))
