"""Pluggable MBS executors behind one interface.

All three run the same Algorithm 1 through the shared core in
``exec_core.py`` — only the execution strategy differs:

  * :class:`CompiledScanExecutor` — the TPU-native production path: a
    ``lax.scan`` over the micro-batch axis inside one jitted step; XLA keeps
    one micro-batch of activations live (DESIGN.md §Hardware adaptation).
  * :class:`StreamingExecutor` — the paper's literal Fig. 1 pipeline:
    host→device transfer of micro-batch i+1 overlaps compute of i (double
    buffering), one jitted gradient per micro-batch, eager accumulate.
  * :class:`FusedAccumExecutor` — the compiled scan with accumulation
    routed through the Pallas kernel ``kernels/grad_accum.py``: the 1/N_Sμ
    loss-normalization scale is fused into the accumulate (paper Fig. 2
    step ❹ + eq. 14) with in-place aliasing on the fp32 accumulator.
  * :class:`FlatFusedExecutor` — the fused flat-buffer update path: the
    accumulator lives in dtype-bucketed contiguous 1-D buffers
    (``engine/flat.py``) for the whole scan, so step ❹ is one masked
    Pallas launch per *bucket* (not per leaf) and step ❺ runs through the
    in-place fused optimizer kernels (``kernels/fused_update.py``) with no
    ``updates``/opt-state transients (DESIGN.md §Update path).

Compiled executors donate params/opt-state/split-batch buffers at the
``step_split`` jit boundary (construct with ``donate=False`` for callers
that must reuse inputs across calls — see DESIGN.md for the contract).

Kernel block sizes are resolved at trace/build time, not hard-coded:
every Pallas call the executors reach (grad-accum, fused update) takes
``block=None`` and resolves it through the kernel-side hook
(``kernels.grad_accum.resolve_block``), which consults the persistent
tuning cache installed by ``engine/autotune.py`` before falling back to
the size-aware heuristic — so a ``tune_for_params`` sweep changes the
launch geometry of all executors without touching their code, and
never their numerics (DESIGN.md §Autotuning).

New strategies (async multi-device, serving) implement the same
:class:`Executor` surface and register in :data:`EXECUTORS`.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Protocol, Tuple, Type, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from . import exec_core, faults, flat
from .plan import MBSConfig, MBSPlan


def _as_plan(plan) -> MBSPlan:
    if isinstance(plan, MBSConfig):
        return MBSPlan.from_config(plan)
    if isinstance(plan, MBSPlan):
        return plan
    raise TypeError(f"expected MBSPlan or MBSConfig, got {type(plan)!r}")


@runtime_checkable
class Executor(Protocol):
    """One mini-batch-update strategy. ``step`` is the host-level entry
    (splits the raw mini-batch per the plan); compiled strategies also
    expose ``make_train_step`` — a pure function over pre-split batches
    that the launcher jits with shardings/donation; ``gradients`` returns
    the accumulated normalized gradients only (eq. 15–17's quantity)."""
    name: str
    plan: MBSPlan

    def make_train_step(self) -> Callable: ...

    def step(self, params, opt_state, minibatch: Dict[str, np.ndarray]
             ) -> Tuple[Any, Any, Dict[str, Any]]: ...

    def step_split(self, params, opt_state, micro_batches
                   ) -> Tuple[Any, Any, Dict[str, Any]]: ...

    def gradients(self, params, micro_batches) -> Tuple[Any, jnp.ndarray]: ...


def _scan_accumulate(loss_fn, plan: MBSPlan, fused: bool, params,
                     micro_batches, interpret=None, block=None,
                     raw: bool = False):
    """Shared compiled core: scan over the micro-batch axis, accumulating
    normalized gradients + loss + metrics. Returns (grads, loss, metric_sum).

    ``raw=True`` (the ShardedExecutor's per-device half of the mini-batch
    step) defers ALL normalization: each micro loss is the raw SUM of valid
    per-sample losses (``exact_denom=1``), gradients/losses/metrics are
    accumulated as plain sums. The caller divides by the GLOBAL valid count
    after the cross-device reduction — the one place the data-parallel
    denominator is known."""
    n_s, total_valid = exec_core.denominators(micro_batches)
    norm = "exact" if raw else plan.normalization
    accum0 = exec_core.init_accum(params, plan.accum_dtype)
    if raw:
        scale = 1.0 if fused else None  # plain unscaled sums
    else:
        scale = (exec_core.deferred_scale(plan.normalization, n_s, total_valid)
                 if fused else None)
    mb0 = jax.tree.map(lambda x: x[0], micro_batches)
    metrics0 = exec_core.metrics_zeros(loss_fn, norm, params, mb0)
    metric_div = 1 if raw else n_s

    def micro_step(carry, mb):
        acc, loss_sum, metric_sum = carry
        lfn = exec_core.micro_loss_fn(loss_fn, norm, n_s, total_valid, mb,
                                      defer_scale=fused or raw)
        grad_fn = jax.value_and_grad(lfn, has_aux=True)
        if plan.remat_micro_step:
            grad_fn = jax.checkpoint(grad_fn)
        (l, metrics), grads = grad_fn(params)
        acc = exec_core.accumulate(acc, grads, scale=scale, fused=fused,
                                   interpret=interpret, block=block)
        metric_sum = jax.tree.map(lambda s, m: s + m / metric_div,
                                  metric_sum, metrics)
        return (acc, loss_sum + l, metric_sum), None

    (grads, loss, metric_sum), _ = jax.lax.scan(
        micro_step, (accum0, jnp.zeros((), jnp.float32), metrics0),
        micro_batches, unroll=plan.unroll)
    if fused and not raw:
        loss = loss * scale  # normalization was deferred to the accumulate
    return grads, loss, metric_sum


class _CompiledExecutorBase:
    """Common machinery for scan-based (jit-compiled) executors.

    ``donate=True`` (default) donates params/opt-state/split-batch at the
    ``step_split`` jit boundary: callers must thread the returned state
    (the ``Trainer`` does) and never touch a donated buffer again. Pass
    ``donate=False`` when inputs are reused across calls (A/B comparisons,
    benchmarks timing the same state repeatedly).

    ``guard=True`` (engine Layer 9) puts the optimizer update behind an
    on-device finite-check of the accumulated gradient: a non-finite
    accumulator skips step ❺ (state passes through unchanged) and the
    metrics carry a ``nonfinite`` device scalar for the supervisor's
    skip/retry policy. Guard off (the default) compiles the exact same
    program as before — no cond, no extra metric."""
    name = "base"
    fused = False

    def __init__(self, loss_fn, optimizer, plan, *,
                 interpret: Optional[bool] = None, block: Optional[int] = None,
                 donate: bool = True, guard: bool = False):
        self.loss_fn = loss_fn
        self.optimizer = optimizer
        self.plan = _as_plan(plan)
        self._interpret = interpret
        self._block = block
        self._donate = donate
        self.guard = guard
        self._step_jit = None
        self._grads_jit = None

    def _accumulated(self, params, micro_batches):
        return _scan_accumulate(self.loss_fn, self.plan, self.fused, params,
                                micro_batches, self._interpret, self._block)

    def raw_accumulate(self, params, micro_batches):
        """Traceable UN-normalized accumulation over a (local) split batch:
        (grad sums, loss sum, metric sums) with no 1/N anywhere — the
        per-device half of the ShardedExecutor's deferred-sync step, run
        with this executor's own strategy (scan / Pallas accumulate)."""
        return _scan_accumulate(self.loss_fn, self.plan, self.fused, params,
                                micro_batches, self._interpret, self._block,
                                raw=True)

    def make_train_step(self) -> Callable:
        """(params, opt_state, split_batch) -> (params, opt_state, metrics);
        pure — the launcher jits it with shardings and donation."""
        def train_step(params, opt_state, micro_batches):
            grads, loss, metric_sum = self._accumulated(params, micro_batches)
            if self.guard:
                new_params, new_opt, ok = exec_core.guarded_update(
                    self.optimizer, grads, opt_state, params)
                metrics = exec_core.finalize_metrics(metric_sum, loss, grads)
                metrics["nonfinite"] = 1.0 - ok.astype(jnp.float32)
                return new_params, new_opt, metrics
            new_params, new_opt = exec_core.apply_update(
                self.optimizer, grads, opt_state, params)
            return new_params, new_opt, exec_core.finalize_metrics(
                metric_sum, loss, grads)
        return train_step

    def gradients(self, params, micro_batches):
        if self._grads_jit is None:
            self._grads_jit = jax.jit(
                lambda p, mb: self._accumulated(p, mb)[:2])
        return self._grads_jit(params, micro_batches)

    def trace_step(self, params, opt_state, micro_batches):
        """ClosedJaxpr of the full mini-batch train step — traced, never
        executed (inputs may be ``ShapeDtypeStruct``s). This is the
        canonical artifact the ``repro.analysis`` jaxpr contract checks
        consume, instead of every caller re-tracing ad hoc."""
        return jax.make_jaxpr(self.make_train_step())(
            params, opt_state, micro_batches)

    def lower_step(self, params, opt_state, micro_batches, *,
                   donate: Optional[bool] = None):
        """``jax.stages.Lowered`` of the jitted step with this executor's
        donation contract (override via ``donate=``); ``.compile()`` it for
        the HLO-level checks (aliasing coverage, ``memory_analysis``)."""
        if donate is None:
            donate = self._donate
        return jax.jit(
            self.make_train_step(),
            donate_argnums=(0, 1, 2) if donate else (),
        ).lower(params, opt_state, micro_batches)

    def step_split(self, params, opt_state, micro_batches):
        """Jitted step over an already-split ``(N_Sμ, N_μ, ...)`` batch —
        the entry used by the ``Trainer``/``Pipeline`` pair (staging done
        upstream). Metrics come back as device scalars (no host sync).
        Inputs are donated (unless constructed with ``donate=False``): the
        params/opt-state buffers are reused in place for the new state and
        the spent split batch is freed for step-❺ temporaries."""
        faults.on_dispatch(self.plan)
        if self._step_jit is None:
            self._step_jit = jax.jit(
                self.make_train_step(),
                donate_argnums=(0, 1, 2) if self._donate else ())
        return self._step_jit(params, opt_state, micro_batches)

    def step(self, params, opt_state, minibatch):
        return self.step_split(params, opt_state,
                               self.plan.device_split(minibatch))


class CompiledScanExecutor(_CompiledExecutorBase):
    """Today's production path: jitted ``lax.scan`` + plain fp32 add."""
    name = "compiled"
    fused = False


class FusedAccumExecutor(_CompiledExecutorBase):
    """Compiled scan with the Pallas fused scaled-accumulate (step ❹).
    ``interpret`` defaults to True off-TPU (set explicitly for tests)."""
    name = "fused"
    fused = True


class FlatFusedExecutor(_CompiledExecutorBase):
    """Fused flat-buffer update path (DESIGN.md §Update path).

    The gradient accumulator is kept as dtype-bucketed contiguous 1-D
    buffers (``engine/flat.py``) across the whole micro-batch scan, so the
    scaled accumulate (step ❹, normalization deferred into the kernel) is
    one masked Pallas launch per *bucket* instead of one per leaf; the
    optimizer update (step ❺) reads the fp32 accumulator and writes params
    + opt state in one in-place pass through ``kernels/fused_update.py``
    (``input_output_aliases`` everywhere, global-norm clip carried in as a
    scalar). Combined with ``step_split``'s donation this eliminates the
    ``updates`` tree and all optimizer-state transients — see
    ``core/memory_model.update_transient_bytes``. ``interpret`` defaults
    to True off-TPU."""
    name = "flat"
    fused = True  # raw micro losses; normalization fused into the accumulate

    def _accumulated_flat(self, params, micro_batches, raw: bool = False):
        """Like ``_scan_accumulate`` but the carry holds flat buckets.
        ``raw=True`` defers all normalization to the caller (sharded
        execution) — unscaled sums, same flat-bucket strategy."""
        plan = self.plan
        norm = "exact" if raw else plan.normalization
        spec = flat.FlatSpec.for_tree(params)  # static at trace time
        n_s, total_valid = exec_core.denominators(micro_batches)
        scale = (1.0 if raw else
                 exec_core.deferred_scale(plan.normalization, n_s, total_valid))
        mb0 = jax.tree.map(lambda x: x[0], micro_batches)
        metrics0 = exec_core.metrics_zeros(self.loss_fn, norm, params, mb0)
        metric_div = 1 if raw else n_s

        def micro_step(carry, mb):
            acc, loss_sum, metric_sum = carry
            lfn = exec_core.micro_loss_fn(self.loss_fn, norm,
                                          n_s, total_valid, mb,
                                          defer_scale=True)
            grad_fn = jax.value_and_grad(lfn, has_aux=True)
            if plan.remat_micro_step:
                grad_fn = jax.checkpoint(grad_fn)
            (l, metrics), grads = grad_fn(params)
            acc = exec_core.accumulate_flat(acc, spec, grads, scale=scale,
                                            interpret=self._interpret,
                                            block=self._block)
            metric_sum = jax.tree.map(lambda s, m: s + m / metric_div,
                                      metric_sum, metrics)
            return (acc, loss_sum + l, metric_sum), None

        (acc, loss, metric_sum), _ = jax.lax.scan(
            micro_step,
            (spec.zeros(plan.accum_dtype), jnp.zeros((), jnp.float32),
             metrics0),
            micro_batches, unroll=plan.unroll)
        return spec, acc, (loss if raw else loss * scale), metric_sum

    def raw_accumulate(self, params, micro_batches):
        """Un-normalized flat-bucket accumulation (see the base class doc);
        returns the gradient sums as a TREE (unflattened, accum dtype)."""
        spec, acc, loss, metric_sum = self._accumulated_flat(
            params, micro_batches, raw=True)
        return spec.unflatten(acc, cast=False), loss, metric_sum

    def make_train_step(self) -> Callable:
        def train_step(params, opt_state, micro_batches):
            spec, acc, loss, metric_sum = self._accumulated_flat(
                params, micro_batches)
            if self.guard:
                # finite-check runs directly on the dtype buckets — the
                # FlatSpec composition the guard contract promises
                new_params, new_opt, ok = exec_core.guarded_update_flat(
                    self.optimizer, spec, acc, opt_state, params,
                    interpret=self._interpret, block=self._block)
                metrics = exec_core.finalize_metrics(metric_sum, loss, acc)
                metrics["nonfinite"] = 1.0 - ok.astype(jnp.float32)
                return new_params, new_opt, metrics
            new_params, new_opt = exec_core.apply_update_flat(
                self.optimizer, spec, acc, opt_state, params,
                interpret=self._interpret, block=self._block)
            # grad_norm straight off the flat buffers (a tuple is a pytree)
            return new_params, new_opt, exec_core.finalize_metrics(
                metric_sum, loss, acc)
        return train_step

    def gradients(self, params, micro_batches):
        if self._grads_jit is None:
            def run(p, mb):
                spec, acc, loss, _ = self._accumulated_flat(p, mb)
                return spec.unflatten(acc, cast=False), loss
            self._grads_jit = jax.jit(run)
        return self._grads_jit(params, micro_batches)


class StreamingExecutor:
    """Eager host→device micro-batch streaming (the paper's Fig. 1
    pipeline): double-buffered transfers, one jitted micro step per
    micro-batch. Honors the full plan — ``normalization="exact"`` and
    ``accum_dtype`` route through the same shared core as the compiled
    executors.

    Loss and metrics stay on device for the whole loop (the jitted micro
    step carries them alongside the gradient accumulator) and the step
    returns device scalars, so nothing forces a host sync between
    micro-batches and the double buffer actually overlaps transfer with
    compute. Callers read metrics back when they need them (the
    ``Trainer`` does so asynchronously, one step late)."""
    name = "streaming"

    def __init__(self, loss_fn, optimizer, plan, device: Optional[Any] = None,
                 *, guard: bool = False):
        self.loss_fn = loss_fn
        self.optimizer = optimizer
        self.plan = _as_plan(plan)
        self.device = device or jax.devices()[0]
        self.guard = guard
        norm = self.plan.normalization

        @jax.jit
        def _micro_grad_accum(params, acc, loss_sum, mb, n_s, total_valid):
            # grad + accumulate in ONE dispatch (the gradients-only analogue
            # of _micro_step; a separate _accumulate launch per micro-batch
            # used to double the dispatch count)
            lfn = exec_core.micro_loss_fn(loss_fn, norm, n_s, total_valid, mb)
            (l, _), g = jax.value_and_grad(lfn, has_aux=True)(params)
            return exec_core.accumulate(acc, g), loss_sum + l

        @jax.jit
        def _micro_step(params, carry, mb, n_s, total_valid):
            # grad + accumulate + on-device loss/metric sums in ONE dispatch
            # (paper Fig. 2 steps ❷–❹); no host value ever materializes here.
            acc, loss_sum, metric_sum = carry
            lfn = exec_core.micro_loss_fn(loss_fn, norm, n_s, total_valid, mb)
            (l, metrics), g = jax.value_and_grad(lfn, has_aux=True)(params)
            acc = exec_core.accumulate(acc, g)
            metric_sum = jax.tree.map(jnp.add, metric_sum, metrics)
            return acc, loss_sum + l, metric_sum

        @jax.jit
        def _update(params, opt_state, acc):  # paper step ❺
            return exec_core.apply_update(optimizer, acc, opt_state, params)

        @jax.jit
        def _guarded_update(params, opt_state, acc):  # step ❺ behind the guard
            return exec_core.guarded_update(optimizer, acc, opt_state, params)

        self._micro_grad_accum = _micro_grad_accum
        self._micro_step = _micro_step
        self._update = _update
        self._guarded_update = _guarded_update

    def make_train_step(self) -> Callable:
        raise NotImplementedError(
            "StreamingExecutor is an eager host pipeline; use .step() "
            "(or a compiled executor for a jittable train step)")

    def trace_step(self, params, opt_state, micro_batches):
        """ClosedJaxpr of one whole mini-batch of the eager pipeline (the
        per-micro jitted dispatches + the update), stitched into a single
        traceable function. Production never compiles this — the pipeline
        stays eager — but it gives ``repro.analysis`` the same step
        semantics to inspect (each jitted dispatch shows up as a ``pjit``
        equation)."""
        def whole(p, o, split):
            n_s = jax.tree.leaves(split)[0].shape[0]
            micro_iter = (jax.tree.map(lambda x, i=i: x[i], split)
                          for i in range(n_s))
            return self._run(p, o, micro_iter, n_s, split)
        return jax.make_jaxpr(whole)(params, opt_state, micro_batches)

    def _denoms(self, split) -> Tuple[jnp.ndarray, jnp.ndarray]:
        n_s, total_valid = exec_core.denominators(split)
        return jnp.asarray(n_s, jnp.float32), total_valid

    def gradients(self, params, micro_batches):
        """Eager accumulation over an already-split batch (device arrays) —
        one jitted dispatch per micro-batch (grad + accumulate fused)."""
        n_s = jax.tree.leaves(micro_batches)[0].shape[0]
        n_s_f, total_valid = self._denoms(micro_batches)
        acc = exec_core.init_accum(params, self.plan.accum_dtype)
        loss = jnp.zeros((), jnp.float32)
        for i in range(n_s):
            mb = jax.tree.map(lambda x: x[i], micro_batches)
            acc, loss = self._micro_grad_accum(params, acc, loss, mb,
                                               n_s_f, total_valid)
        return acc, loss

    def _run(self, params, opt_state, micro_iter, n_s: int, split
             ) -> Tuple[Any, Any, Dict[str, Any]]:
        n_s_f, total_valid = self._denoms(split)
        mb0 = jax.tree.map(lambda x: x[0], split)
        carry = (exec_core.init_accum(params, self.plan.accum_dtype),
                 jnp.zeros((), jnp.float32),
                 exec_core.metrics_zeros(self.loss_fn,
                                         self.plan.normalization, params, mb0))
        for cur in micro_iter:
            carry = self._micro_step(params, carry, cur, n_s_f, total_valid)
        acc, loss, metric_sum = carry
        out: Dict[str, Any] = {k: v / n_s for k, v in metric_sum.items()}
        out["loss"] = loss  # Σ normalized micro losses == mini-batch loss
        out["grad_norm"] = exec_core.global_grad_norm(acc)
        if self.guard:
            params, opt_state, ok = self._guarded_update(params, opt_state, acc)
            out["nonfinite"] = 1.0 - ok.astype(jnp.float32)
        else:
            params, opt_state = self._update(params, opt_state, acc)
        return params, opt_state, out

    def step_split(self, params, opt_state, micro_batches
                   ) -> Tuple[Any, Any, Dict[str, Any]]:
        """Streaming update over a pre-split (and typically pre-staged)
        ``(N_Sμ, N_μ, ...)`` batch — the ``Pipeline`` overlaps the
        mini-batch transfer, so micro-batches are sliced on device."""
        faults.on_dispatch(self.plan)
        n_s = jax.tree.leaves(micro_batches)[0].shape[0]
        micro_iter = (jax.tree.map(lambda x, i=i: x[i], micro_batches)
                      for i in range(n_s))
        return self._run(params, opt_state, micro_iter, n_s, micro_batches)

    def step(self, params, opt_state, minibatch: Dict[str, np.ndarray]
             ) -> Tuple[Any, Any, Dict[str, Any]]:
        """One mini-batch update via sequential micro-batch streaming."""
        split = self.plan.split(minibatch)
        n_s = jax.tree.leaves(split)[0].shape[0]

        # double buffer: issue transfer of micro-batch i+1 while i computes
        def put(i):
            return jax.device_put(
                jax.tree.map(lambda x: x[i], split), self.device)

        def micro_iter():
            nxt = put(0)
            for i in range(n_s):
                cur, nxt = nxt, (put(i + 1) if i + 1 < n_s else None)
                yield cur

        return self._run(params, opt_state, micro_iter(), n_s, split)


EXECUTORS: Dict[str, Type] = {
    CompiledScanExecutor.name: CompiledScanExecutor,
    StreamingExecutor.name: StreamingExecutor,
    FusedAccumExecutor.name: FusedAccumExecutor,
    FlatFusedExecutor.name: FlatFusedExecutor,
}


def get_executor(name: str) -> Type:
    try:
        return EXECUTORS[name]
    except KeyError:
        raise ValueError(
            f"unknown executor {name!r}; available: {sorted(EXECUTORS)}")


def accumulate_gradients(loss_fn, params, micro_batches, plan,
                         *, fused: bool = False,
                         interpret: Optional[bool] = None):
    """Eager (python-loop) accumulated, normalized MBS gradients — the
    quantity eq. (15)–(17) proves equal to the mini-batch gradient. Used by
    the equivalence tests, benchmarks and the legacy ``mbs_gradients``."""
    plan = _as_plan(plan)
    n_s, total_valid = exec_core.denominators(micro_batches)
    scale = (exec_core.deferred_scale(plan.normalization, n_s, total_valid)
             if fused else None)
    acc = exec_core.init_accum(params, plan.accum_dtype)
    loss_sum = jnp.zeros((), jnp.float32)
    for i in range(n_s):
        mb = jax.tree.map(lambda x: x[i], micro_batches)
        lfn = exec_core.micro_loss_fn(loss_fn, plan.normalization, n_s,
                                      total_valid, mb, defer_scale=fused)
        (l, _), grads = jax.value_and_grad(lfn, has_aux=True)(params)
        acc = exec_core.accumulate(acc, grads, scale=scale, fused=fused,
                                   interpret=interpret)
        loss_sum = loss_sum + l
    if fused:
        loss_sum = loss_sum * scale
    return acc, loss_sum


def make_baseline_train_step(loss_fn, optimizer) -> Callable:
    """The no-MBS reference: one forward/backward over the whole mini-batch
    (what the paper's "w/o MBS" columns do — and what fails beyond the
    memory limit)."""
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        new_params, new_opt_state = exec_core.apply_update(
            optimizer, grads, opt_state, params)
        return new_params, new_opt_state, exec_core.finalize_metrics(
            metrics, loss, grads)
    return train_step
