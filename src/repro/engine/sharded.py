"""Mesh-aware MBS execution (engine Layer 6): data-parallel micro-batch
accumulation with DEFERRED gradient synchronization.

The paper fits a large global batch into ONE device's memory by splitting
it into micro-batches; data parallelism multiplies that across workers.
The cost to control is the gradient all-reduce: naive DP gradient
accumulation syncs every micro-batch (N_Sμ collectives per step), while
Algorithm 1 only *needs* the sum of all micro gradients — so the sync can
happen once per MINI-batch (``launch/mesh.py``'s amortization promise).

:class:`ShardedExecutor` wraps any executor from ``engine/executors.py``
and runs its accumulation strategy inside ``shard_map``:

  * every batch leaf is sharded on its sample dim over the mesh's batch
    axes ((pod, data)), so each device scans its ``local_micro`` =
    ``micro / data_parallel`` slice of every micro-batch;
  * the inner executor's ``raw_accumulate`` produces UN-normalized local
    sums (gradients, loss, metrics — no 1/N anywhere), using its own
    strategy: ``lax.scan`` (compiled), Pallas fused accumulate (fused),
    flat dtype buckets (flat), or an eager per-micro dispatch loop
    (streaming, see below);
  * all local sums — gradient leaves, loss, metrics, and the local valid-
    sample count — are raveled into ONE fp32 buffer and reduced with a
    single ``lax.psum``: exactly one all-reduce per mini-batch in the
    compiled HLO, independent of N_Sμ (the conformance test asserts this
    against a fully unrolled scan);
  * normalization divides by the GLOBAL valid count after the reduction
    (exact semantics — identical to "paper" mode for the uniform splits
    paper mode is valid for), then the optimizer update runs replicated
    on every device.

``defer_sync=False`` is the comparison baseline (one flat psum per
micro-batch, inner="compiled" only) used by ``--mesh-bench`` and the HLO
conformance test — it is what the deferred path saves.

The streaming inner keeps its eager character: one jitted shard_mapped
dispatch per micro-batch (no collective inside — the local partial sums
carry a leading ``data_parallel`` dim so they stay device-local between
dispatches), then one jitted sync+update dispatch per mini-batch.

Scope: pure data parallelism — params/opt state replicated inside the
step (``plan_mbs(mesh=..., fsdp_params=False)`` budgets accordingly).
TP/FSDP production meshes keep the launcher's GSPMD jit path. MoE router
aux follows the exact-mode contract per *local* micro-batch: router
statistics are per-device (standard DP-MoE semantics), so sharded MoE
losses are not bitwise-comparable to single-device runs.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from ..launch import mesh as mesh_lib
from . import exec_core, faults, flat as flat_lib
from .executors import EXECUTORS, _as_plan, get_executor
from .plan import MBSPlan


def _axis_entry(axes: Tuple[str, ...]):
    return axes if len(axes) > 1 else axes[0]


def psum_flat(tree, axis_names):
    """One collective for a whole pytree: ravel every leaf into a single
    fp32 buffer, ``lax.psum`` it once, unpack. This is why the deferred
    step's HLO contains exactly ONE all-reduce — and it is the bucketing
    optimization (one large collective beats many small ones) for free."""
    leaves, treedef = jax.tree.flatten(tree)
    if not leaves:
        return tree
    flat = jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])
    flat = jax.lax.psum(flat, axis_names)
    out, off = [], 0
    for l in leaves:
        out.append(flat[off:off + l.size].reshape(l.shape).astype(l.dtype))
        off += l.size
    return jax.tree.unflatten(treedef, out)


def batch_partition_specs(batch, micro: int, axes: Tuple[str, ...],
                          sample_dim_from: int = 1):
    """Per-leaf PartitionSpec sharding the SAMPLE dim — the first dim (at
    index >= ``sample_dim_from``; dim 0 is the scan axis of a split batch)
    whose size equals the global micro-batch size — over the batch axes.
    Every leaf must have such a dim: a replicated leaf inside shard_map
    would be double-counted by every worker's local accumulation."""
    entry = _axis_entry(axes)

    def spec_for(leaf):
        shape = leaf.shape
        for d in range(sample_dim_from, len(shape)):
            if shape[d] == micro:
                spec = [None] * len(shape)
                spec[d] = entry
                return P(*spec)
        raise ValueError(
            f"cannot shard batch leaf of shape {shape}: no dim (>= "
            f"{sample_dim_from}) equals the global micro-batch size {micro}"
            " — ShardedExecutor requires every leaf to carry the sample dim")

    return jax.tree.map(spec_for, batch)


def _local_valid_count(mb, sample_dims: int = 2) -> jnp.ndarray:
    """This shard's valid-sample weight (padding carries 0) — summed into
    the flat psum so the normalization denominator is the GLOBAL count.
    ``sample_dims`` is 2 for a split ``(N_Sμ, N_μ, ...)`` batch, 1 for a
    single micro-batch (the streaming per-micro dispatch)."""
    w = mb.get("sample_weight") if hasattr(mb, "get") else None
    if w is not None:
        return jnp.sum(w).astype(jnp.float32)
    leaf = jax.tree.leaves(mb)[0]
    n = 1.0
    for d in leaf.shape[:sample_dims]:
        n *= d
    return jnp.asarray(n, jnp.float32)


class ShardedExecutor:
    """Data-parallel wrapper around an inner MBS executor (see module doc).

    Implements the :class:`engine.executors.Executor` protocol; the
    ``inner`` name selects the local accumulation strategy ("compiled" |
    "streaming" | "fused" | "flat"). ``donate=False`` for callers that
    reuse params/opt-state across calls (A/B tests, benchmarks).

    ``guard=True`` (engine Layer 9) finite-checks the globally-reduced
    gradient inside ``_finalize`` — after the one psum, so the flag is
    replicated and every device takes the same skip/update branch — and
    surfaces a ``nonfinite`` metric for the supervisor."""
    name = "sharded"

    def __init__(self, loss_fn, optimizer, plan, *, mesh,
                 inner: str = "compiled", defer_sync: bool = True,
                 donate: bool = True, interpret: Optional[bool] = None,
                 block: Optional[int] = None, guard: bool = False):
        self.loss_fn = loss_fn
        self.optimizer = optimizer
        self.plan: MBSPlan = _as_plan(plan)
        self.mesh = mesh
        self.axes = mesh_lib.batch_axes(mesh)
        self.dp = mesh_lib.data_parallel_size(mesh)
        self.defer_sync = defer_sync
        self._donate = donate
        self._interpret = interpret
        self._block = block
        self.guard = guard
        if not self.axes or self.dp < 2:
            raise ValueError(
                "ShardedExecutor needs a mesh with a (pod, data) extent of "
                f">= 2 (got {self.dp}); on one device use the inner "
                "executor directly")
        if self.plan.micro_batch_size % self.dp:
            raise ValueError(
                f"micro-batch {self.plan.micro_batch_size} does not divide "
                f"over {self.dp} data-parallel workers — build the plan "
                "with plan_mbs(mesh=...) so sizes stay divisible")
        if self.plan.normalization == "paper" and self.plan.pad:
            raise ValueError(
                'a ragged "paper" plan cannot be sharded exactly (the tail '
                "pad lands on one worker's shard) — use "
                'normalization="exact" (plan_mbs auto-upgrades ragged plans)')
        if not isinstance(inner, str):
            inner = getattr(inner, "name", inner)
        if inner not in EXECUTORS:
            raise ValueError(
                f"unknown inner executor {inner!r}; available: "
                f"{sorted(EXECUTORS)}")
        if not defer_sync and inner != "compiled":
            raise ValueError(
                "defer_sync=False is the per-micro-sync comparison baseline "
                "and only supports inner='compiled'")
        self.inner_name = inner
        self.inner = (None if inner == "streaming" else
                      get_executor(inner)(loss_fn, optimizer, self.plan,
                                          interpret=interpret, block=block,
                                          donate=False))
        self._step_jit = None
        self._grads_jit = None
        self._stream_micro = None
        self._stream_update = None
        self._stream_grads = None

    # -- staging ------------------------------------------------------------

    def batch_shardings(self, split):
        """NamedSharding tree for a split ``(N_Sμ, N_μ, ...)`` batch — what
        the ``Pipeline`` stages with (``sharding=executor.batch_shardings``)."""
        specs = batch_partition_specs(split, self.plan.micro_batch_size,
                                      self.axes)
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs,
                            is_leaf=lambda x: isinstance(x, P))

    def stage(self, split):
        return jax.device_put(split, self.batch_shardings(split))

    # -- the local (per-device) halves of the step --------------------------

    def _raw_local(self, params, mb):
        """UN-normalized local sums via the inner executor's own strategy."""
        return self.inner.raw_accumulate(params, mb)

    def _per_micro_synced(self, params, mb):
        """The baseline being amortized away: one flat psum per micro-batch
        inside the scan (N_Sμ collectives per step). Returns grads already
        globally summed; loss/metrics still local."""
        plan = self.plan
        n_s, _ = exec_core.denominators(mb)
        accum0 = exec_core.init_accum(params, plan.accum_dtype)
        mb0 = jax.tree.map(lambda x: x[0], mb)
        metrics0 = exec_core.metrics_zeros(self.loss_fn, "exact", params, mb0)

        def micro_step(carry, m):
            acc, loss_sum, metric_sum = carry
            lfn = exec_core.micro_loss_fn(self.loss_fn, "exact", n_s, 1.0, m,
                                          defer_scale=True)
            (l, metrics), g = jax.value_and_grad(lfn, has_aux=True)(params)
            g = psum_flat(g, self.axes)  # <-- the per-micro sync
            acc = exec_core.accumulate(acc, g)
            metric_sum = jax.tree.map(jnp.add, metric_sum, metrics)
            return (acc, loss_sum + l, metric_sum), None

        (grads, loss, metric_sum), _ = jax.lax.scan(
            micro_step, (accum0, jnp.zeros((), jnp.float32), metrics0),
            mb, unroll=plan.unroll)
        return grads, loss, metric_sum

    def _finalize(self, params, opt_state, grads, loss, metric_sum, valid,
                  n_s: int):
        """Post-sync: normalize by the global valid count, update
        (replicated — identical on every device), package metrics."""
        scale = 1.0 / valid
        grads = jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads)
        loss = loss * scale
        # metrics were summed over every (device, micro-batch) pair
        metrics = jax.tree.map(lambda m: m / (self.dp * n_s), metric_sum)
        ok = None
        if self.inner_name == "flat":
            spec = flat_lib.FlatSpec.for_tree(params)
            bufs = spec.flatten(grads, dtype=jnp.float32)
            if self.guard:
                new_params, new_opt, ok = exec_core.guarded_update_flat(
                    self.optimizer, spec, bufs, opt_state, params,
                    interpret=self._interpret, block=self._block)
            else:
                new_params, new_opt = exec_core.apply_update_flat(
                    self.optimizer, spec, bufs, opt_state, params,
                    interpret=self._interpret, block=self._block)
        elif self.guard:
            new_params, new_opt, ok = exec_core.guarded_update(
                self.optimizer, grads, opt_state, params)
        else:
            new_params, new_opt = exec_core.apply_update(
                self.optimizer, grads, opt_state, params)
        out = exec_core.finalize_metrics(metrics, loss, grads)
        if ok is not None:
            out["nonfinite"] = 1.0 - ok.astype(jnp.float32)
        return new_params, new_opt, out

    # -- compiled path ------------------------------------------------------

    def make_train_step(self) -> Callable:
        """Pure (params, opt_state, split_batch) -> (params, opt_state,
        metrics) with the shard_map applied at trace time — the launcher
        jits it with donation exactly like the single-device executors."""
        if self.inner_name == "streaming":
            raise NotImplementedError(
                "the streaming inner is an eager per-micro pipeline; use "
                ".step()/.step_split() (or a compiled inner for a jittable "
                "train step)")

        def train_step(params, opt_state, micro_batches):
            specs = batch_partition_specs(
                micro_batches, self.plan.micro_batch_size, self.axes)
            n_s = jax.tree.leaves(micro_batches)[0].shape[0]

            def local_step(params, opt_state, mb):
                if self.defer_sync:
                    grads, loss, msum = self._raw_local(params, mb)
                    grads, loss, msum, valid = psum_flat(
                        (grads, loss, msum, _local_valid_count(mb)),
                        self.axes)  # the ONE all-reduce per mini-batch
                else:
                    grads, loss, msum = self._per_micro_synced(params, mb)
                    loss, msum, valid = psum_flat(
                        (loss, msum, _local_valid_count(mb)), self.axes)
                return self._finalize(params, opt_state, grads, loss,
                                      msum, valid, n_s)

            return shard_map(local_step, mesh=self.mesh,
                             in_specs=(P(), P(), specs),
                             out_specs=(P(), P(), P()),
                             check_rep=False)(params, opt_state, micro_batches)

        return train_step

    def trace_step(self, params, opt_state, micro_batches):
        """ClosedJaxpr of the full sharded mini-batch step (traced, never
        run; inputs may be ``ShapeDtypeStruct``s) — the artifact the
        ``repro.analysis`` jaxpr checks (collective census, accumulator
        dtype) consume. For the eager streaming inner the per-micro jitted
        dispatches and the sync+update dispatch are stitched into one
        traceable function (each shows up as a ``pjit`` equation)."""
        if self.inner_name != "streaming":
            return jax.make_jaxpr(self.make_train_step())(
                params, opt_state, micro_batches)
        self._ensure_stream_fns()

        def whole(p, o, split):
            n_s = jax.tree.leaves(split)[0].shape[0]
            mb0 = jax.tree.map(lambda x: x[0], split)
            carry = self._carry_zeros(p, mb0)
            for i in range(n_s):
                mb = jax.tree.map(lambda x, i=i: x[i], split)
                carry = self._stream_micro(p, carry, mb)
            return self._stream_update(p, o, carry, n_s)

        return jax.make_jaxpr(whole)(params, opt_state, micro_batches)

    def lower_step(self, params, opt_state, micro_batches, *,
                   donate: Optional[bool] = None):
        """``jax.stages.Lowered`` of the jitted sharded step (donation as
        configured unless overridden) for the HLO-level contract checks —
        one all-reduce per mini-batch, aliasing, ``memory_analysis``."""
        if self.inner_name == "streaming":
            raise NotImplementedError(
                "the streaming inner has no single jittable step to lower; "
                "use trace_step for jaxpr-level analysis")
        if donate is None:
            donate = self._donate
        return jax.jit(
            self.make_train_step(),
            donate_argnums=(0, 1, 2) if donate else (),
        ).lower(params, opt_state, micro_batches)

    def step_split(self, params, opt_state, micro_batches
                   ) -> Tuple[Any, Any, Dict[str, Any]]:
        faults.on_dispatch(self.plan)
        if self.inner_name == "streaming":
            return self._stream_step_split(params, opt_state, micro_batches)
        if self._step_jit is None:
            self._step_jit = jax.jit(
                self.make_train_step(),
                donate_argnums=(0, 1, 2) if self._donate else ())
        return self._step_jit(params, opt_state, micro_batches)

    def step(self, params, opt_state, minibatch
             ) -> Tuple[Any, Any, Dict[str, Any]]:
        return self.step_split(params, opt_state,
                               self.stage(self.plan.split(minibatch)))

    def gradients(self, params, micro_batches):
        """Accumulated NORMALIZED gradients + mini-batch loss (eq. 15–17's
        quantity) under the deferred-sync sharded schedule."""
        if self.inner_name == "streaming":
            return self._stream_gradients(params, micro_batches)
        if self._grads_jit is None:
            def run(p, mb):
                specs = batch_partition_specs(
                    mb, self.plan.micro_batch_size, self.axes)

                def local(p, mb):
                    g, l, _ = self._raw_local(p, mb)
                    g, l, valid = psum_flat((g, l, _local_valid_count(mb)),
                                            self.axes)
                    scale = 1.0 / valid
                    return (jax.tree.map(
                        lambda x: (x * scale).astype(x.dtype), g), l * scale)

                return shard_map(local, mesh=self.mesh,
                                 in_specs=(P(), specs),
                                 out_specs=(P(), P()),
                                 check_rep=False)(p, mb)
            self._grads_jit = jax.jit(run)
        return self._grads_jit(params, micro_batches)

    # -- streaming path -----------------------------------------------------
    #
    # Local partial sums carry a leading data_parallel dim (sharded over the
    # batch axes) so they stay device-local across eager dispatches — a
    # global array cannot otherwise hold per-device state.

    def _carry_zeros(self, params, mb0):
        dp = self.dp
        acc = jax.tree.map(
            lambda p: jnp.zeros((dp,) + p.shape, self.plan.accum_dtype),
            params)
        mshape = exec_core.metrics_zeros(self.loss_fn, "exact", params, mb0)
        metrics = jax.tree.map(
            lambda m: jnp.zeros((dp,) + m.shape, m.dtype), mshape)
        return (acc, jnp.zeros((dp,), jnp.float32), metrics,
                jnp.zeros((dp,), jnp.float32))

    def _ensure_stream_fns(self):
        if self._stream_micro is not None:
            return
        entry = _axis_entry(self.axes)
        carry_spec = P(entry)
        micro = self.plan.micro_batch_size

        def local_micro(params, carry, mb):
            # one raw grad+accumulate dispatch, NO collective (deferred)
            acc, loss_sum, metric_sum, valid = carry  # local: leading dim 1
            lfn = exec_core.micro_loss_fn(self.loss_fn, "exact", 1, 1.0, mb,
                                          defer_scale=True)
            (l, metrics), g = jax.value_and_grad(lfn, has_aux=True)(params)
            acc = jax.tree.map(lambda a, gg: a + gg.astype(a.dtype)[None],
                               acc, g)
            metric_sum = jax.tree.map(lambda s, m: s + m[None],
                                      metric_sum, metrics)
            return (acc, loss_sum + l[None], metric_sum,
                    valid + _local_valid_count(mb, sample_dims=1)[None])

        def local_update(params, opt_state, carry, n_s):
            local = jax.tree.map(lambda x: x[0], carry)
            grads, loss, msum, valid = psum_flat(local, self.axes)
            return self._finalize(params, opt_state, grads, loss, msum,
                                  valid, n_s)

        def local_grads(carry):
            acc, loss_sum, _, valid = jax.tree.map(lambda x: x[0], carry)
            g, l, v = psum_flat((acc, loss_sum, valid), self.axes)
            scale = 1.0 / v
            return (jax.tree.map(lambda x: (x * scale).astype(x.dtype), g),
                    l * scale)

        def micro_specs(mb):
            return batch_partition_specs(mb, micro, self.axes,
                                         sample_dim_from=0)

        def wrap_micro(params, carry, mb):
            return shard_map(local_micro, mesh=self.mesh,
                             in_specs=(P(), carry_spec, micro_specs(mb)),
                             out_specs=carry_spec,
                             check_rep=False)(params, carry, mb)

        def wrap_update(params, opt_state, carry, n_s):
            return shard_map(lambda p, s, c: local_update(p, s, c, n_s),
                             mesh=self.mesh,
                             in_specs=(P(), P(), carry_spec),
                             out_specs=(P(), P(), P()),
                             check_rep=False)(params, opt_state, carry)

        def wrap_grads(carry):
            return shard_map(local_grads, mesh=self.mesh,
                             in_specs=(carry_spec,), out_specs=(P(), P()),
                             check_rep=False)(carry)

        self._stream_micro = jax.jit(
            wrap_micro, donate_argnums=(1,) if self._donate else ())
        self._stream_update = jax.jit(wrap_update, static_argnums=(3,))
        self._stream_grads = jax.jit(wrap_grads)

    def _stream_accumulate(self, params, micro_batches):
        self._ensure_stream_fns()
        n_s = jax.tree.leaves(micro_batches)[0].shape[0]
        mb0 = jax.tree.map(lambda x: x[0], micro_batches)
        carry = self._carry_zeros(params, mb0)
        for i in range(n_s):
            mb = jax.tree.map(lambda x, i=i: x[i], micro_batches)
            carry = self._stream_micro(params, carry, mb)
        return n_s, carry

    def _stream_step_split(self, params, opt_state, micro_batches):
        n_s, carry = self._stream_accumulate(params, micro_batches)
        return self._stream_update(params, opt_state, carry, n_s)

    def _stream_gradients(self, params, micro_batches):
        _, carry = self._stream_accumulate(params, micro_batches)
        return self._stream_grads(carry)
