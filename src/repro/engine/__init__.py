"""Unified MBS execution engine: one planner + pluggable executors.

Layer 1 — planner (``plan.py``): :func:`plan_mbs` turns (mini-batch size,
optional pins, model config, HBM budget) into an :class:`MBSPlan` — micro
size N_μ, N_Sμ, ragged-tail padding + sample-weight mask, normalization
mode, accumulator dtype. When the micro-batch size is not pinned it is
derived from the analytic memory model (``core/memory_model.py``) instead
of the paper's experimental search (§4.3.2).

Layer 2 — executors (``executors.py``): compiled scan / eager streaming /
Pallas-fused accumulate, all sharing one normalization–accumulation–update
core (``exec_core.py``). See DESIGN.md §Engine architecture.

Layer 3 — input pipeline + loop (``pipeline.py`` / ``trainer.py``): the
:class:`Pipeline` turns (dataset, plan) into pre-split, device-staged
batches with background prefetch and double buffering; the
:class:`Trainer` owns the step loop — async metrics readback, periodic
checkpointing, sharding-aware resume. See DESIGN.md §Input pipeline.

Layer 4 — fused flat update path (``flat.py`` + ``kernels/fused_update.py``):
:class:`FlatSpec` buckets the param/grad/opt-state trees into contiguous
per-dtype 1-D buffers so step ❹ accumulates with one Pallas launch per
bucket and step ❺ runs through in-place fused optimizer kernels with
donation — no ``updates``/opt-state transients. See DESIGN.md §Update path.

Layer 5 — remat planner (``models/remat.py`` + the joint search in
``core/memory_model.suggest_remat_policy_and_micro``): a graded
activation-checkpointing lattice (none | dots | period | full) chosen
jointly with the micro-batch size — ``plan_mbs(remat_policy="auto")``
escalates to heavier recompute only when it buys batch the budget would
otherwise refuse. See DESIGN.md §Remat planner.

Layer 6 — mesh-aware execution (``sharded.py``): ``plan_mbs(mesh=...)``
plans against the PER-DEVICE budget (params discounted by the real
sharding policy, micro sizes divisible by the data axis, ``local_micro``
per worker) and :class:`ShardedExecutor` wraps any executor's
accumulation strategy in ``shard_map`` so the cross-device gradient
all-reduce happens ONCE per mini-batch — one flat fp32 psum of
gradients+loss+metrics — instead of once per micro-batch. See DESIGN.md
§Sharded execution.

Layer 7 — closed-loop autotuner (``autotune.py``): one persistent on-disk
tuning cache feeds measurement back into the two places the stack above
guesses. The memory oracle compiles the REAL train step at probe micro
sizes, reads XLA ``memory_analysis()`` and fits a per-key affine
correction so ``plan_mbs(calibrate="auto"|"force")`` admits against
corrected bytes (``MBSPlan.calibrated``); the kernel block tuner sweeps
launch block sizes for the accumulate/fused-update kernels and installs a
resolver so ``block=None`` call sites pick the measured winner. Tuning
changes speed and admission, never numerics. See DESIGN.md §Autotuning.

Layer 9 — fault-tolerant runtime (``supervisor.py`` + ``faults.py``): the
:class:`Supervisor` wraps the Trainer's step loop with a recovery state
machine — runtime ``RESOURCE_EXHAUSTED`` degrades the plan (remat
escalation, then micro-shrink with a negative calibration bound fed back
into the Layer-7 cache), rebuilds the runtime and resumes from the last
completed state; executors built with ``guard=True`` finite-check the
gradient accumulator on device so non-finite steps are skipped/retried
behind a circuit breaker; transient pipeline/checkpoint-I/O failures get
bounded jittered retries. ``faults.py`` is the deterministic seeded
fault-injection harness (+ the fault taxonomy) that makes every recovery
path provable in CI on CPU. See DESIGN.md §Fault tolerance.

Layer 10 — serving (``serving.py`` + ``kv.py``): the same admission idea
applied to inference, where the per-unit memory cost is the KV-cache slot
(``memory_model.kv_slot_bytes``) instead of per-sample activations.
:func:`plan_serve` bounds concurrent decode slots + the prefill
micro-batch against the HBM budget (``ServePlan``), and
:class:`ServingEngine` runs the request lifecycle (arrive → prefill →
decode → finish/evict) as continuous batching over a fixed-shape
:class:`KVPool` — per-step admit/evict without recompilation, donated
in-place decode cache, ragged-padded prefill for pure-attention stacks
and exact-length grouping for state-carrying/MoE families. See DESIGN.md
§Serving.

Layer 11 — pipeline parallelism (``pipelined.py``): the plan's
micro-batches become the currency of a 1F1B schedule over the mesh's
``model`` axis. :class:`StagedLoss` factors a loss into prelude /
stage_fn / finale; :class:`PipelinedExecutor` runs the closed-form
schedule (host-side tick tables, traced ring buffers, per-tick masked
forward+backward with stage-input remat) under ``shard_map`` on a 2-D
``data × model`` mesh, composing with the Layer-6 DP path: still exactly
ONE data-axis gradient psum per mini-batch, plus one (data+model) psum
for shared params/loss/metrics and two ppermutes per tick at the stage
boundaries. ``plan_mbs(pipeline=True)`` budgets stage-local activations
× in-flight depth (``memory_model.pipeline_activation_bytes_per_sample``)
and ``fsdp=True`` adds just-in-time gathered parameter sharding per
``launch/sharding.param_specs``. See DESIGN.md §Pipeline parallelism.
"""
from .plan import (MBSConfig, MBSPlan, num_micro_batches,  # noqa: F401
                   plan_mbs, split_minibatch)
from .autotune import (TuningCache, calibrate_memory,  # noqa: F401
                       get_cache, set_cache_path, tune_block_sizes,
                       tune_for_params)
from .flat import FlatSpec, LeafSlot  # noqa: F401
from .executors import (EXECUTORS, CompiledScanExecutor, Executor,  # noqa: F401
                        FlatFusedExecutor, FusedAccumExecutor,
                        StreamingExecutor, accumulate_gradients,
                        get_executor, make_baseline_train_step)
from .sharded import ShardedExecutor, batch_partition_specs, psum_flat  # noqa: F401
from .pipelined import (PipelinedExecutor, StagedLoss,  # noqa: F401
                        schedule_1f1b)
from .pipeline import Pipeline, PipelineStats  # noqa: F401
from .trainer import Trainer  # noqa: F401
from . import faults  # noqa: F401
from .supervisor import (FaultRecord, NaNCircuitBreaker, NaNHalt,  # noqa: F401
                         PlanExhausted, RestartBudgetExceeded, Supervisor,
                         SupervisorConfig, SupervisorError, degrade_plan)
from .kv import KVPool, PoolExhausted  # noqa: F401
from .serving import (Request, ServePlan, ServingEngine,  # noqa: F401
                      check_servable, plan_serve, synthetic_traffic)
