"""Closed-loop autotuner (engine Layer 7): measured feedback for the
planner and the Pallas kernels behind one persistent on-disk cache.

Two coupled halves, both keyed into the same JSON cache
(``~/.cache/repro-tuning/tuning.json``, overridable via
``REPRO_TUNING_CACHE`` / ``set_cache_path`` / ``--tuning-cache``):

**Half 1 — memory oracle.** ``core/memory_model`` is open-loop analytic:
it has never been corrected against what XLA really allocates, so
``plan_mbs`` stays conservative and leaves admitted batch on the table
(the fixed 64 MB ``fixed_bytes`` pad, the summed step-❺ transient that
never actually coexists with the activation peak).
:func:`calibrate_memory` closes the loop: compile the REAL train step at
2–3 probe micro-batch sizes, read ``compiled.memory_analysis()`` (the
same machinery the remat lattice was validated against), fit a per-key
affine correction ``measured ≈ a·modeled + b`` and persist it. A
calibrated ``plan_mbs(calibrate="auto"|"force")`` then binary-searches
admission (all integers, not just powers of two) against *corrected*
bytes, recording ``MBSPlan.calibrated``/``correction``; with no cache
entry it falls back to the analytic model cleanly.

The correction is affine *per key* because both sides are affine in the
micro-batch size: the analytic total is ``fixed + act_per_sample·m`` and
XLA's peak for the scanned step is steady-state + one micro-batch of
live activations — two lines, so two probes pin the map exactly and a
third (least-squares) absorbs allocator noise. One global correction
would conflate per-(arch, seq, policy, mesh, optimizer, executor)
slopes; the key keeps each line its own.

**Half 2 — kernel block tuner.** ``BENCH_update.json`` proved the fixed
``BUCKET_BLOCK = 65536`` was a guess, not a measurement: 8.1× SLOWER
than per-leaf on the 96-leaf bucket. :func:`tune_block_sizes` /
:func:`tune_for_params` run a timed sweep over candidate blocks for the
``grad_accum`` and ``fused_update`` kernels and persist the winner per
(kernel, dtype, buffer-size-bucket, backend); kernels called with
``block=None`` look the winner up through the resolver this module
installs into ``kernels/grad_accum.py`` at import, falling back to the
size-aware ``default_block`` heuristic.

Invariant (tested): tuning may change *speed and admission*, never
numerics — every tuned block is bit-identical to the default, and a
calibrated plan runs the exact same step arithmetic as an analytic plan
of the same geometry.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
import warnings
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..kernels import fused_sgd, grad_accum, set_block_resolver
from .flat import FlatSpec

CACHE_VERSION = 1

# candidate 1-D launch blocks for the timed sweep; 0 = the whole buffer
# (grid 1 — the interpret-mode winner, see grad_accum.default_block)
CANDIDATE_BLOCKS = (4096, 16384, 65536, 262144, 0)


# ---------------------------------------------------------------------------
# cache keys
# ---------------------------------------------------------------------------

def mesh_tag(mesh) -> str:
    """Stable axis-name/size fingerprint of a mesh ("none" single-device).
    Part of every memory key so a mesh-calibrated correction can never
    leak into single-device plans (and vice versa)."""
    if mesh is None:
        return "none"
    return "x".join(f"{ax}{n}" for ax, n in mesh.shape.items())


def arch_tag(cfg) -> str:
    """Config fingerprint: the name alone collides between full and
    --reduced variants, so the dimensions that move the memory model are
    baked in."""
    dims = [f"L{getattr(cfg, 'num_layers', 0)}"]
    for short, attr in (("d", "d_model"), ("ff", "d_ff"), ("v", "vocab_size")):
        val = getattr(cfg, attr, None)
        if val:
            dims.append(f"{short}{val}")
    return "-".join([cfg.name] + dims)


def memory_key(cfg, seq: int, remat_policy: str, mesh, optimizer: str,
               executor: str, backend: Optional[str] = None) -> str:
    backend = backend or jax.default_backend()
    return "|".join([arch_tag(cfg), f"s{seq}", str(remat_policy),
                     f"mesh:{mesh_tag(mesh)}", str(optimizer),
                     str(executor), backend])


def size_bucket(n: int) -> str:
    """Power-of-two ceiling bucket: one tuned entry covers every buffer
    within a factor of two of the measured size."""
    n = max(int(n), 1)
    return f"p{(n - 1).bit_length()}"


def block_key(kind: str, dtype, n: int, *, interpret: Optional[bool] = None,
              backend: Optional[str] = None) -> str:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    backend = backend or jax.default_backend()
    mode = f"{backend}+interp" if interpret else backend
    return "|".join([kind, str(jnp.dtype(dtype)), size_bucket(n), mode])


# ---------------------------------------------------------------------------
# the persistent cache
# ---------------------------------------------------------------------------

def default_cache_path() -> str:
    env = os.environ.get("REPRO_TUNING_CACHE")
    if env:
        return os.path.expanduser(env)
    return os.path.join(os.path.expanduser("~"), ".cache", "repro-tuning",
                        "tuning.json")


def _empty() -> Dict[str, Any]:
    return {"version": CACHE_VERSION, "memory": {}, "blocks": {}}


class TuningCache:
    """Tolerant JSON store for both tuner halves.

    Corrupted files, wrong versions, and malformed entries are treated as
    *absent* — the planner falls back to the analytic model and the
    kernels to the heuristic block; nothing ever raises out of a lookup.
    Writes are atomic (tmp + rename) and best-effort: an unwritable cache
    degrades to in-memory-only with a warning.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = os.path.expanduser(path) if path else default_cache_path()
        self._data: Optional[Dict[str, Any]] = None

    # -- load / save --------------------------------------------------------

    @property
    def data(self) -> Dict[str, Any]:
        if self._data is None:
            self._data = self._load()
        return self._data

    def _load(self) -> Dict[str, Any]:
        try:
            with open(self.path) as f:
                raw = json.load(f)
        except (OSError, ValueError):
            return _empty()
        if not isinstance(raw, dict) or raw.get("version") != CACHE_VERSION:
            return _empty()  # stale schema: recalibrate rather than misread
        out = _empty()
        mem = raw.get("memory")
        if isinstance(mem, dict):
            out["memory"] = mem
        blocks = raw.get("blocks")
        if isinstance(blocks, dict):
            out["blocks"] = blocks
        return out

    def save(self) -> None:
        try:
            os.makedirs(os.path.dirname(self.path), exist_ok=True)
            tmp = self.path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(self.data, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except OSError as e:
            warnings.warn(f"tuning cache not persisted to {self.path}: {e}")

    # -- memory-oracle entries ----------------------------------------------

    def memory_correction(self, key: str) -> Optional[Tuple[float, float]]:
        entry = self.data["memory"].get(key)
        if not isinstance(entry, dict):
            return None
        try:
            a, b = float(entry["a"]), float(entry["b"])
        except (KeyError, TypeError, ValueError):
            return None  # malformed/stale entry == no entry
        if not (a > 0.0 and jnp.isfinite(a) and jnp.isfinite(b)):
            return None
        return a, b

    def put_memory(self, key: str, a: float, b: float,
                   probes: Sequence[Sequence[float]] = ()) -> None:
        self.data["memory"][key] = {
            "a": float(a), "b": float(b),
            "probes": [[int(m), int(mod), int(meas)]
                       for m, mod, meas in probes],
        }
        self.save()

    # -- tuned-block entries ------------------------------------------------

    def tuned_block(self, key: str) -> Optional[int]:
        entry = self.data["blocks"].get(key)
        if not isinstance(entry, dict):
            return None
        try:
            block = int(entry["block"])
        except (KeyError, TypeError, ValueError):
            return None
        return block if block >= 0 else None  # 0 = whole buffer

    def put_block(self, key: str, block: int,
                  timings_us: Optional[Dict[str, float]] = None) -> None:
        self.data["blocks"][key] = {"block": int(block),
                                    "timings_us": timings_us or {}}
        self.save()


_active_path: Optional[str] = None
_caches: Dict[str, TuningCache] = {}


def set_cache_path(path: Optional[str]) -> None:
    """Point the process-wide active cache (planner lookups with no
    explicit path + the kernel block resolver) at ``path`` (None resets
    to the ``REPRO_TUNING_CACHE`` / ``~/.cache/repro-tuning`` default)."""
    global _active_path
    _active_path = os.path.expanduser(path) if path else None


def get_cache(path: Optional[str] = None) -> TuningCache:
    p = os.path.expanduser(path) if path else (_active_path
                                               or default_cache_path())
    if p not in _caches:
        _caches[p] = TuningCache(p)
    return _caches[p]


# ---------------------------------------------------------------------------
# Half 1 — memory oracle
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MemoryCorrection:
    """``measured ≈ a · modeled + b`` for one cache key."""
    a: float
    b: float
    probes: Tuple[Tuple[int, int, int], ...] = ()  # (micro, modeled, measured)

    @property
    def correction(self) -> Tuple[float, float]:
        return (self.a, self.b)

    def corrected(self, modeled_bytes: float) -> float:
        return self.a * modeled_bytes + self.b


def _probe_optimizer(name: str):
    """A concrete optimizer whose state tree matches the named rule (the
    hyperparameters are irrelevant to the memory profile; the slots are
    not)."""
    from .. import optim
    if name == "sgd_plain":
        return optim.sgd(0.01)
    if name == "adam":
        return optim.adam(0.01)
    if name == "adamw":
        return optim.adam(0.01, weight_decay=0.01, decoupled=True)
    return optim.sgd(0.01, momentum=0.9)


def measured_step_bytes(cfg, seq: int, micro: int, *,
                        remat_policy: str = "period",
                        optimizer: str = "sgd", executor: str = "compiled",
                        act_bytes: int = 4,
                        num_probe_microbatches: int = 2) -> int:
    """Peak device bytes of the REAL compiled train step at one pinned
    micro-batch size: lower + compile abstractly (no allocation, dry-run
    style) and read XLA ``memory_analysis()``. The peak counts arguments
    + outputs + temps − donation-aliased bytes — the quantity admission
    must keep under the HBM budget."""
    from ..configs.shapes import InputShape
    from ..launch import steps

    # streaming has no jittable whole-mini-batch step; its per-micro
    # memory profile matches the compiled scan (one micro live), so probe
    # that. The key still records the requested executor.
    probe_exec = "compiled" if executor == "streaming" else executor
    dtype = jnp.float32 if act_bytes >= 4 else jnp.bfloat16
    shape = InputShape(f"calibrate_m{micro}", "train", seq,
                       micro * num_probe_microbatches)
    bundle = steps.build_train_step(
        cfg, shape, num_microbatches=num_probe_microbatches,
        optimizer=_probe_optimizer(optimizer), dtype=dtype,
        remat_policy=remat_policy, executor=probe_exec)
    compiled = jax.jit(bundle.fn, donate_argnums=bundle.donate_argnums
                       ).lower(*bundle.arg_shapes).compile()
    mem = compiled.memory_analysis()
    return int(getattr(mem, "argument_size_in_bytes", 0)
               + getattr(mem, "output_size_in_bytes", 0)
               + getattr(mem, "temp_size_in_bytes", 0)
               - getattr(mem, "alias_size_in_bytes", 0))


def _fit_affine(points: Sequence[Tuple[float, float]]) -> Tuple[float, float]:
    """Least-squares ``y ≈ a·x + b`` with safe degeneracies: one probe (or
    identical modeled values) pins only the offset; a non-positive or
    non-finite slope falls back to offset-only (a=1)."""
    xs = [float(x) for x, _ in points]
    ys = [float(y) for _, y in points]
    n = len(xs)
    if n == 0:
        return 1.0, 0.0
    mx, my = sum(xs) / n, sum(ys) / n
    var = sum((x - mx) ** 2 for x in xs)
    if n < 2 or var == 0.0:
        return 1.0, my - mx
    a = sum((x - mx) * (y - my) for x, y in zip(xs, ys)) / var
    if not (a > 0.0 and jnp.isfinite(a)):
        return 1.0, my - mx
    return a, my - a * mx


def calibrate_memory(cfg, seq: int, *, remat_policy: str = "period",
                     optimizer: str = "sgd", executor: str = "compiled",
                     mesh=None, probe_micros: Sequence[int] = (1, 2, 4),
                     act_bytes: int = 4, tp: int = 1, fsdp: int = 1,
                     opt_slots: Optional[int] = None,
                     fused_update: bool = False, fsdp_params: bool = True,
                     cache: Optional[TuningCache] = None,
                     cache_path: Optional[str] = None) -> MemoryCorrection:
    """Run the calibration pass for one key and persist the correction.

    Probes compile the single-worker step (for a mesh plan that is the
    per-device view the planner budgets — exact for replicated-param
    data parallelism, the host-mesh ``ShardedExecutor``); the entry is
    still keyed by the mesh shape so it never serves a different
    topology.
    """
    from ..core import memory_model
    cache = cache or get_cache(cache_path)
    est = memory_model.estimate(
        cfg, seq, tp=tp, fsdp=fsdp, opt_slots=opt_slots, act_bytes=act_bytes,
        remat_policy=remat_policy, optimizer=optimizer,
        fused_update=fused_update, mesh=mesh, fsdp_params=fsdp_params)
    probes = []
    for m in dict.fromkeys(int(m) for m in probe_micros if m >= 1):
        modeled = est.total(m)
        measured = measured_step_bytes(
            cfg, seq, m, remat_policy=remat_policy, optimizer=optimizer,
            executor=executor, act_bytes=act_bytes)
        probes.append((m, modeled, measured))
    a, b = _fit_affine([(mod, meas) for _, mod, meas in probes])
    key = memory_key(cfg, seq, remat_policy, mesh, optimizer, executor)
    cache.put_memory(key, a, b, probes)
    return MemoryCorrection(a, b, tuple(probes))


def planner_correction(cfg, seq: int, *, remat_policy: str, mesh,
                       optimizer: str, executor: str, mode: str,
                       cache_path: Optional[str] = None,
                       probe_micros: Sequence[int] = (1, 2, 4),
                       **mm_kw) -> Optional[Tuple[float, float]]:
    """The planner's entry: ``mode="auto"`` is a pure cache lookup (no
    entry → None → analytic fallback); ``"force"`` runs the probe
    compiles now and returns the fresh fit."""
    if mode == "force":
        return calibrate_memory(
            cfg, seq, remat_policy=remat_policy, optimizer=optimizer,
            executor=executor, mesh=mesh, probe_micros=probe_micros,
            cache_path=cache_path, **mm_kw).correction
    cache = get_cache(cache_path)
    return cache.memory_correction(
        memory_key(cfg, seq, remat_policy, mesh, optimizer, executor))


def corrected_micro_search(cfg, seq: int, local_mini: int, budget: int,
                           correction: Tuple[float, float], *,
                           remat_policy: str, **mm_kw) -> Optional[int]:
    """Largest micro-batch (ANY integer ≤ local_mini, not just powers of
    two — corrected bytes are trusted, so the pow-of-two safety margin is
    dropped) whose corrected bytes fit the budget; None when even 1 does
    not fit."""
    from ..core import memory_model
    est = memory_model.estimate(cfg, seq, remat_policy=remat_policy, **mm_kw)
    a, b = correction
    fixed, per_sample = est.affine_coeffs()  # total(m) == fixed + per_sample*m

    def fits(m: int) -> bool:
        return a * (fixed + per_sample * m) + b <= budget

    if not fits(1):
        return None
    lo, hi = 1, max(int(local_mini), 1)
    while lo < hi:  # binary search the admission frontier (monotone in m)
        mid = (lo + hi + 1) // 2
        if fits(mid):
            lo = mid
        else:
            hi = mid - 1
    return lo


def record_oom_bound(cfg, seq: int, micro: int, budget: int, *,
                     remat_policy: str, mesh=None, optimizer: str = "sgd",
                     executor: str = "compiled",
                     cache: Optional[TuningCache] = None,
                     cache_path: Optional[str] = None,
                     **mm_kw) -> Tuple[float, float]:
    """Feed an OBSERVED runtime OOM back into the calibration cache as a
    negative bound (engine Layer 9): micro-batch ``micro`` provably does
    NOT fit ``budget`` under this key, yet the current correction (cached
    fit, or the identity for a pure-analytic plan) claims it does — so
    raise the offset ``b`` until ``corrected(modeled(micro)) = budget + 1``.
    Since corrected bytes are strictly increasing in the micro-batch size,
    the next ``corrected_micro_search`` under this key admits strictly
    less than ``micro``. A correction that already rejects ``micro`` is
    left untouched (the OOM came from elsewhere — fragmentation, a
    co-tenant — and clamping would double-penalize admission)."""
    from ..core import memory_model
    cache = cache or get_cache(cache_path)
    key = memory_key(cfg, seq, remat_policy, mesh, optimizer, executor)
    a, b = cache.memory_correction(key) or (1.0, 0.0)
    est = memory_model.estimate(cfg, seq, remat_policy=remat_policy, **mm_kw)
    fixed, per_sample = est.affine_coeffs()
    modeled = fixed + per_sample * max(int(micro), 1)
    if a * modeled + b <= budget:  # the correction wrongly admits micro
        b = float(budget) - a * modeled + 1.0
        cache.put_memory(key, a, b)
    return a, b


# ---------------------------------------------------------------------------
# Half 2 — kernel block tuner
# ---------------------------------------------------------------------------

def _time_us(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def _sweep_fn(kind: str, n: int, dtype, block: int, interpret: bool):
    """(compiled thunk, operands) timing one candidate block. block==0
    sweeps the whole-buffer launch."""
    blk = n if block == 0 else min(block, n)
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (n,), jnp.float32)
    if kind == "grad_accum":
        acc = jnp.zeros((n,), jnp.float32)
        fn = jax.jit(lambda a_, g_: grad_accum(
            a_, g_, 0.125, block=blk, interpret=interpret))
        return fn, (acc, g)
    if kind == "fused_update":
        p = jax.random.normal(jax.random.fold_in(key, 1), (n,), dtype)
        m = jnp.zeros((n,), dtype)
        fn = jax.jit(lambda p_, g_, m_: fused_sgd(
            p_, g_, m_, 0.01, momentum=0.9, block=blk, interpret=interpret))
        return fn, (p, g, m)
    raise ValueError(f"unknown tunable kernel kind {kind!r}")


def tune_block_sizes(n: int, dtype=jnp.float32, *, kind: str = "grad_accum",
                     candidates: Sequence[int] = CANDIDATE_BLOCKS,
                     iters: int = 3, interpret: Optional[bool] = None,
                     cache: Optional[TuningCache] = None,
                     cache_path: Optional[str] = None) -> Dict[str, Any]:
    """Timed sweep over candidate launch blocks for one (kernel, dtype,
    buffer size); persists the winner under the size bucket so every
    buffer within 2× reuses it. Returns the sweep record."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    cache = cache or get_cache(cache_path)
    n = int(n)
    timings: Dict[str, float] = {}
    best_block, best_t = None, None
    for cand in dict.fromkeys(candidates):
        if cand != 0 and cand >= 2 * n:
            continue  # indistinguishable from the whole-buffer candidate
        fn, args = _sweep_fn(kind, n, dtype, cand, interpret)
        t = _time_us(fn, *args, iters=iters)
        timings[str(cand)] = t
        if best_t is None or t < best_t:
            best_block, best_t = cand, t
    key = block_key(kind, dtype, n, interpret=interpret)
    cache.put_block(key, best_block, timings)
    return {"key": key, "n": n, "block": best_block,
            "time_us": best_t, "timings_us": timings}


def tune_for_params(params, *, kinds: Sequence[str] = ("grad_accum",
                                                       "fused_update"),
                    iters: int = 3, interpret: Optional[bool] = None,
                    cache: Optional[TuningCache] = None,
                    cache_path: Optional[str] = None) -> Dict[str, Any]:
    """Tune every dtype bucket of a model's :class:`FlatSpec` — the
    buffers the flat executor actually launches over."""
    spec = FlatSpec.for_tree(params)
    out = {}
    for n, dt in zip(spec.bucket_sizes, spec.bucket_dtypes):
        for kind in kinds:
            rec = tune_block_sizes(n, dt, kind=kind, iters=iters,
                                   interpret=interpret, cache=cache,
                                   cache_path=cache_path)
            out[rec["key"]] = rec
    return out


# ---------------------------------------------------------------------------
# kernel-side resolver: installed once at import so any kernel entry
# called with block=None sees the active cache's winners
# ---------------------------------------------------------------------------

def _tuned_block_resolver(kind: str, dtype_str: str, n: int,
                          interpret: bool) -> Optional[int]:
    try:
        tuned = get_cache().tuned_block(
            block_key(kind, dtype_str, n, interpret=interpret))
    except Exception:  # repro: noqa(LINT006) - degrade, never sink a launch
        return None  # a broken cache must never sink a kernel launch
    if tuned is None:
        return None
    return n if tuned == 0 else tuned  # 0 = whole-buffer winner


set_block_resolver(_tuned_block_resolver)
