"""Flat-buffer layer: pytrees ⇄ contiguous dtype-bucketed 1-D buffers.

The update step (paper Fig. 2 steps ❹–❺) is leaf-count-bound, not
byte-bound: ``grad_accum_tree`` pays one ``pallas_call`` per parameter
leaf and the unfused optimizer materializes per-leaf transients. A
:class:`FlatSpec` collapses the param/grad/opt-state trees into one
contiguous 1-D buffer **per dtype** ("bucket"), so the accumulate and the
fused optimizer kernels launch O(num_buckets) times per step instead of
O(num_leaves).

Contract:

  * **stable leaf ordering** — buckets follow ``jax.tree.flatten`` order
    (deterministic for a fixed tree structure); a spec built from one tree
    round-trips any tree with the same structure/shapes/dtypes.
  * **dtype bucketing** — leaves sharing a dtype share a bucket (buckets
    ordered by first appearance). Gradient/accumulator buffers reuse the
    *param* bucket partitioning but may carry a different dtype
    (``flatten(grads, dtype=accum_dtype)``), so offsets always line up
    with the param buffers inside the fused kernels.
  * **no padded copies** — buckets are exact-sized; the kernels mask the
    ragged final block through the grid (``kernels/grad_accum.py``)
    instead of ``jnp.pad``-ing operands.

All methods are trace-safe: a spec is built from abstract shapes/dtypes
(at trace time when called on tracers) and holds only Python ints.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Where one pytree leaf lives inside the flat buffers."""
    bucket: int
    offset: int
    size: int
    shape: Tuple[int, ...]
    dtype: Any


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Layout of one pytree as dtype-bucketed contiguous 1-D buffers."""
    treedef: Any
    slots: Tuple[LeafSlot, ...]
    bucket_sizes: Tuple[int, ...]
    bucket_dtypes: Tuple[Any, ...]

    @classmethod
    def for_tree(cls, tree) -> "FlatSpec":
        leaves, treedef = jax.tree.flatten(tree)
        buckets: dict = {}  # canonical dtype -> bucket index (first appearance)
        fill: list = []  # bytes filled per bucket so far (in elements)
        slots = []
        for leaf in leaves:
            dt = jnp.dtype(leaf.dtype)
            if dt not in buckets:
                buckets[dt] = len(fill)
                fill.append(0)
            b = buckets[dt]
            size = int(leaf.size) if hasattr(leaf, "size") else 1
            slots.append(LeafSlot(b, fill[b], size, tuple(leaf.shape), dt))
            fill[b] += size
        return cls(treedef, tuple(slots), tuple(fill),
                   tuple(buckets))  # dict preserves insertion order

    @property
    def num_buckets(self) -> int:
        return len(self.bucket_sizes)

    @property
    def num_leaves(self) -> int:
        return len(self.slots)

    def zeros(self, dtype) -> Tuple[jnp.ndarray, ...]:
        """Zero accumulator buffers: param bucket partitioning, one dtype."""
        return tuple(jnp.zeros((n,), dtype) for n in self.bucket_sizes)

    def bucket_blocks(self, kind: str = "grad_accum", *,
                      dtype: Optional[Any] = None,
                      interpret: Optional[bool] = None) -> Tuple[int, ...]:
        """Per-bucket 1-D launch blocks, resolved at build time through the
        tuning cache (when ``engine.autotune`` has an entry for this
        (kernel, dtype, size-bucket, backend)) or the size-aware heuristic.
        ``dtype`` overrides the bucket dtype for the lookup (accumulator
        buffers carry ``accum_dtype``, not the param dtype)."""
        from ..kernels import resolve_block
        return tuple(
            resolve_block(kind, dtype if dtype is not None else dt, n,
                          interpret)
            for n, dt in zip(self.bucket_sizes, self.bucket_dtypes))

    def flatten(self, tree, dtype: Optional[Any] = None
                ) -> Tuple[jnp.ndarray, ...]:
        """Tree → bucketed 1-D buffers. ``dtype`` casts every leaf (used to
        route gradients into the ``accum_dtype`` buffers); default keeps
        each bucket in its own dtype."""
        leaves = jax.tree.flatten(tree)[0]
        if len(leaves) != len(self.slots):
            raise ValueError(
                f"tree has {len(leaves)} leaves, spec expects {len(self.slots)}")
        parts: list = [[] for _ in self.bucket_sizes]
        for leaf, slot in zip(leaves, self.slots):
            flat = jnp.asarray(leaf).reshape(-1)
            parts[slot.bucket].append(
                flat if dtype is None else flat.astype(dtype))
        return tuple(p[0] if len(p) == 1 else jnp.concatenate(p)
                     for p in parts)

    def unflatten(self, buffers: Sequence[jnp.ndarray], *,
                  cast: bool = True):
        """Bucketed buffers → tree. ``cast=False`` keeps the buffer dtype
        on every leaf (for gradient trees held in ``accum_dtype``)."""
        leaves = []
        for slot in self.slots:
            leaf = buffers[slot.bucket][
                slot.offset:slot.offset + slot.size].reshape(slot.shape)
            leaves.append(leaf.astype(slot.dtype) if cast else leaf)
        return jax.tree.unflatten(self.treedef, leaves)
