"""Deterministic fault-injection harness + fault taxonomy (engine Layer 9).

MBP trains at the *edge* of device memory, so every recovery path in the
:class:`engine.supervisor.Supervisor` must be provable in CI on CPU — a
real ``RESOURCE_EXHAUSTED`` cannot be staged deterministically, a real
torn checkpoint needs a kill -9 mid-write. This module makes each fault
class a first-class, *seeded and replayable* event:

  * a :class:`FaultPlan` is a list of :class:`FaultSpec`s — fault kind,
    the hook index at which to fire, and how many times;
  * production code carries cheap **hook points** (``on_dispatch`` in the
    executors' ``step_split``, ``on_host_batch``/``corrupt_batch`` in the
    ``Pipeline`` worker, ``on_checkpoint_io``/``on_checkpoint_commit`` in
    ``checkpoint.save``, ``on_replan`` in the supervisor) that are a
    single ``is None`` check when no plan is active — zero cost in
    unsupervised production;
  * the same plan replays the same faults at the same indices every run
    (the only state is per-spec fire counters), so the recovery tests can
    assert exact trajectories.

Fault classes (``FaultSpec.kind``):

  ``oom``           ``XlaRuntimeError("RESOURCE_EXHAUSTED: ...")`` raised
                    at executor dispatch — fires on every dispatch with
                    index >= ``step`` while charges remain, and only while
                    the active plan's micro-batch is >= ``min_micro``
                    (models "this size genuinely does not fit": the fault
                    clears once the supervisor degrades the plan below it).
  ``nan``           non-finite poison written into micro-batch ``micro``'s
                    ``sample_weight`` of global step ``step``'s split
                    batch (works for any input dtype — every split batch
                    carries a float mask).
  ``worker``        :class:`TransientWorkerError` raised inside the
                    ``Pipeline``'s background producer for global step
                    ``step``.
  ``torn_write``    :class:`InjectedCrash` raised between the npz rename
                    and the manifest write in ``checkpoint.save`` — the
                    crash window that leaves an orphaned ``ckpt_N.npz``
                    with no commit record.
  ``ckpt_io``       :class:`InjectedIOError` (an ``OSError``) raised
                    before the checkpoint write — the transient-I/O class
                    the supervisor retries with backoff.
  ``corrupt_cache`` deterministic garbage written over the tuning-cache
                    file at the supervisor's re-plan hook — proves the
                    PR-6 tolerant load degrades to analytic instead of
                    sinking recovery.

``step`` is the hook's own index space: the global *training step* for
``nan``/``worker`` (the pipeline knows it), the *save step* for the
checkpoint kinds, and the *dispatch counter* (number of ``step_split``
calls since activation) for ``oom``. ``step=None`` is a wildcard.
"""
from __future__ import annotations

import contextlib
import dataclasses
import re
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

try:  # the real class jitted dispatch raises on device OOM
    from jax._src.lib import xla_client as _xla_client
    XlaRuntimeError = _xla_client.XlaRuntimeError
except Exception:  # pragma: no cover - very old/new jaxlib # repro: noqa(LINT006)
    XlaRuntimeError = RuntimeError


# ---------------------------------------------------------------------------
# fault taxonomy — the vocabulary the supervisor's recovery paths dispatch on
# ---------------------------------------------------------------------------

class FaultError(Exception):
    """Base class for injected faults (never raised by real failures)."""


class TransientError(Exception):
    """Marker mixin: a retryable failure (bounded retry + backoff)."""


class TransientWorkerError(FaultError, TransientError):
    """Injected transient failure in the input-pipeline producer."""


class InjectedIOError(FaultError, TransientError, OSError):
    """Injected transient checkpoint-I/O failure."""


class InjectedCrash(FaultError):
    """Simulated process death (e.g. mid-checkpoint-write). NOT retryable:
    in production this is the process disappearing; the harness raises it
    so tests can assert on the on-disk state it leaves behind."""


_OOM_RE = re.compile(
    r"RESOURCE_EXHAUSTED|OUT_OF_MEMORY|[Oo]ut of memory|[Rr]esource exhausted")

KINDS = ("oom", "nan", "worker", "torn_write", "ckpt_io", "corrupt_cache")

#: classification labels (the supervisor's recovery state machine keys)
OOM, TRANSIENT, CRASH, FATAL = "oom", "transient", "crash", "fatal"


def is_oom(exc: BaseException) -> bool:
    """True for a device out-of-memory failure (real or injected)."""
    return isinstance(exc, (XlaRuntimeError, RuntimeError)) \
        and _OOM_RE.search(str(exc)) is not None


def is_transient(exc: BaseException) -> bool:
    """True for failures worth a bounded retry: the explicit transient
    taxonomy plus plain I/O errors (never an OOM — that needs a re-plan,
    retrying the same dispatch would fail identically)."""
    if is_oom(exc):
        return False
    return isinstance(exc, (TransientError, OSError))


def classify(exc: BaseException) -> str:
    """Map any exception onto the supervisor's fault taxonomy."""
    if is_oom(exc):
        return OOM
    if isinstance(exc, InjectedCrash):
        return CRASH
    if is_transient(exc):
        return TRANSIENT
    return FATAL


def injected_oom(detail: str = "") -> XlaRuntimeError:
    """An exception indistinguishable (by :func:`is_oom`) from the real
    allocator failure the supervisor must recover from."""
    return XlaRuntimeError(
        "RESOURCE_EXHAUSTED: injected OOM (repro.engine.faults)"
        + (f": {detail}" if detail else ""))


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FaultSpec:
    """One scheduled fault. See the module doc for the ``step`` index
    space per kind; ``times`` is the number of firings (a large value
    models a persistent fault), ``micro`` the poisoned micro-batch for
    ``nan``, ``min_micro`` the admission threshold below which an ``oom``
    stops firing (0 = always)."""
    kind: str
    step: Optional[int] = 0
    micro: int = 0
    times: int = 1
    min_micro: int = 0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"known: {list(KINDS)}")


def oom_at(step: int, *, times: int = 1, min_micro: int = 0) -> FaultSpec:
    return FaultSpec("oom", step, times=times, min_micro=min_micro)


def nan_at(step: Optional[int], *, micro: int = 0, times: int = 1
           ) -> FaultSpec:
    return FaultSpec("nan", step, micro=micro, times=times)


def worker_at(step: int, *, times: int = 1) -> FaultSpec:
    return FaultSpec("worker", step, times=times)


def torn_write_at(step: int) -> FaultSpec:
    return FaultSpec("torn_write", step)


def ckpt_io_at(step: int, *, times: int = 1) -> FaultSpec:
    return FaultSpec("ckpt_io", step, times=times)


def corrupt_cache() -> FaultSpec:
    return FaultSpec("corrupt_cache", None)


class FaultPlan:
    """A seeded, replayable schedule of injected faults.

    The plan is pure bookkeeping: per-spec remaining-charge counters, a
    dispatch counter for the ``oom`` index space, and a ``fired`` log
    ``(kind, index)`` the tests assert against. ``seed`` keys any
    randomness a fault payload needs (the harness itself is deterministic
    by construction)."""

    def __init__(self, *specs: FaultSpec, seed: int = 0):
        self.specs: List[FaultSpec] = list(specs)
        self.seed = seed
        self._remaining = [s.times for s in self.specs]
        self.dispatches = 0
        self.fired: List[Tuple[str, int]] = []

    def _take(self, kind: str, index: int, *,
              at_least: bool = False) -> Optional[FaultSpec]:
        for i, s in enumerate(self.specs):
            if s.kind != kind or self._remaining[i] <= 0:
                continue
            if s.step is not None:
                if at_least:
                    if index < s.step:
                        continue
                elif index != s.step:
                    continue
            self._remaining[i] -= 1
            self.fired.append((kind, index))
            return s
        return None

    def fired_kinds(self) -> List[str]:
        return [k for k, _ in self.fired]


# ---------------------------------------------------------------------------
# activation + the hook points production code calls
# ---------------------------------------------------------------------------

_ACTIVE: Optional[FaultPlan] = None


def active() -> Optional[FaultPlan]:
    return _ACTIVE


def activate(plan: FaultPlan) -> FaultPlan:
    global _ACTIVE
    _ACTIVE = plan
    return plan


def deactivate() -> None:
    global _ACTIVE
    _ACTIVE = None


@contextlib.contextmanager
def inject(plan: FaultPlan):
    """``with faults.inject(FaultPlan(oom_at(2))): ...`` — activation is
    process-global (the hooks live in module scope), scoped by this
    context manager."""
    activate(plan)
    try:
        yield plan
    finally:
        deactivate()


def on_dispatch(plan_geometry: Any = None) -> None:
    """Executor hook: called at every ``step_split`` dispatch (see
    ``executors.py`` / ``sharded.py``). Raises an injected OOM when an
    armed ``oom`` spec matches the current dispatch index and the active
    plan's micro-batch has not been degraded below ``min_micro``."""
    if _ACTIVE is None:
        return
    idx = _ACTIVE.dispatches
    _ACTIVE.dispatches += 1
    micro = getattr(plan_geometry, "micro_batch_size", None)
    for i, s in enumerate(_ACTIVE.specs):
        if (s.kind == "oom" and _ACTIVE._remaining[i] > 0
                and (s.step is None or idx >= s.step)
                and (micro is None or micro >= s.min_micro)):
            _ACTIVE._remaining[i] -= 1
            _ACTIVE.fired.append(("oom", idx))
            raise injected_oom(f"dispatch {idx}, micro={micro}")


def on_host_batch(step: int) -> None:
    """Pipeline producer hook (background thread): transient worker
    failure for global step ``step``."""
    if _ACTIVE is None:
        return
    if _ACTIVE._take("worker", step) is not None:
        raise TransientWorkerError(f"injected worker fault at step {step}")


def corrupt_batch(split: Dict[str, np.ndarray], step: int
                  ) -> Dict[str, np.ndarray]:
    """Pipeline producer hook: poison micro-batch ``micro`` of global step
    ``step``'s split batch with a NaN in its ``sample_weight`` (present on
    every split batch, float for every input dtype) — the gradient
    accumulator goes non-finite and the executors' on-device guard must
    catch it."""
    if _ACTIVE is None:
        return split
    spec = _ACTIVE._take("nan", step)
    if spec is None or "sample_weight" not in split:
        return split
    w = np.array(split["sample_weight"], np.float32, copy=True)
    j = min(spec.micro, w.shape[0] - 1)
    w[j, 0] = np.nan
    out = dict(split)
    out["sample_weight"] = w
    return out


def on_checkpoint_io(step: int) -> None:
    """checkpoint.save hook, before any file is touched: transient I/O
    failure (the retryable class)."""
    if _ACTIVE is None:
        return
    if _ACTIVE._take("ckpt_io", step) is not None:
        raise InjectedIOError(f"injected checkpoint I/O fault at step {step}")


def on_checkpoint_commit(step: int) -> None:
    """checkpoint.save hook, between the npz rename and the manifest
    write: simulated crash leaving a torn (uncommitted) checkpoint."""
    if _ACTIVE is None:
        return
    if _ACTIVE._take("torn_write", step) is not None:
        raise InjectedCrash(
            f"injected crash before manifest commit at step {step}")


def on_replan(cache_path: Optional[str]) -> None:
    """Supervisor hook, fired when OOM recovery is about to consult/update
    the tuning cache: a ``corrupt_cache`` spec overwrites the cache file
    with garbage — the PR-6 tolerant load must degrade to analytic."""
    if _ACTIVE is None or cache_path is None:
        return
    if _ACTIVE._take("corrupt_cache", 0, at_least=True) is not None:
        try:
            with open(cache_path, "w") as f:
                f.write('{"version": "garbage", "memory": [corrupt')
        except OSError:
            pass  # nothing to corrupt — the lookup already degrades
