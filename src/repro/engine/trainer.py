"""Resumable training loop over a (step_fn, Pipeline) pair.

The :class:`Trainer` owns everything the launcher's hot loop used to do
inline, with the synchronization bugs designed out:

  * **async metrics readback** — the step functions return *device*
    scalars; the Trainer holds step i's metrics while dispatching step
    i+1 and only converts to host floats afterwards (and only on log
    steps), so printing a loss never serializes the pipeline;
  * **periodic checkpointing** — ``{"params", "opt_state"}`` saved every
    ``ckpt_every`` steps (plus a final save), tagged with the *next*
    step index so resume knows where to pick up;
  * **resume** — :meth:`restore` reads the latest checkpoint and
    re-applies the run's shardings via ``jax.device_put`` (the launcher
    passes ``sharding.param_specs``-derived NamedShardings) instead of
    handing the step function bare host numpy arrays.

Combined with the Pipeline's step-indexed seeding, a save → resume
round-trip replays the identical data stream and op sequence, so it
matches an uninterrupted run bitwise (the regression test asserts this).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax

from ..checkpoint import checkpoint
from .pipeline import Pipeline


def _default_log(step: int, metrics: Dict[str, float], elapsed: float):
    extra = (f"  |g| {metrics['grad_norm']:.3f}"
             if "grad_norm" in metrics else "")
    print(f"step {step:4d}  loss {metrics['loss']:.4f}{extra}"
          f"  ({elapsed:.1f}s)", flush=True)


class Trainer:
    """Drives ``step_fn(params, opt_state, split_batch)`` over a
    :class:`Pipeline`. ``step_fn`` is an executor's ``step_split`` (or the
    launcher's sharded jit of ``make_train_step``)."""

    def __init__(self, step_fn: Callable, pipeline: Pipeline, *,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
                 ckpt_keep: Optional[int] = None, log_every: int = 5,
                 state_shardings: Any = None, log_fn: Callable = _default_log):
        self.step_fn = step_fn
        self.pipeline = pipeline
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.ckpt_keep = ckpt_keep
        self.log_every = log_every
        self.state_shardings = state_shardings
        self.log_fn = log_fn

    # -- checkpointing ------------------------------------------------------

    def save(self, step: int, params, opt_state) -> Optional[str]:
        if not self.ckpt_dir:
            return None
        return checkpoint.save(self.ckpt_dir, step,
                               {"params": params, "opt_state": opt_state},
                               keep=self.ckpt_keep)

    def restore(self, params_template, opt_state_template
                ) -> Optional[Tuple[Any, Any, int]]:
        """(params, opt_state, start_step) from the newest *loadable*
        committed checkpoint in ``ckpt_dir``, placed per
        ``state_shardings`` (default device when none) — or ``None`` when
        there is nothing to resume from. Torn writes are invisible
        (uncommitted — no manifest) and checksum-failing checkpoints are
        skipped in favor of the previous committed step."""
        if not self.ckpt_dir:
            return None
        for step in reversed(checkpoint.committed_steps(self.ckpt_dir)):
            try:
                tree = self._restore_step(step, params_template,
                                          opt_state_template)
            except checkpoint.CheckpointCorruptError:
                continue  # fall back to the previous committed step
            if self.state_shardings is None:
                tree = jax.device_put(tree)
            return tree["params"], tree["opt_state"], step
        return None

    def _restore_step(self, step: int, params_template, opt_state_template):
        template = {"params": params_template,
                    "opt_state": opt_state_template}
        try:
            return checkpoint.restore(self.ckpt_dir, template, step,
                                      shardings=self.state_shardings)
        except KeyError:
            # legacy params-only checkpoint: restore what is there and
            # keep the caller's (fresh) optimizer state. A non-dict
            # state_shardings (one sharding for every leaf) applies as-is —
            # dropping it would hand the step bare host numpy arrays and
            # silently re-place them with default sharding.
            pshard = (self.state_shardings.get("params")
                      if isinstance(self.state_shardings, dict)
                      else self.state_shardings)
            params = checkpoint.restore(self.ckpt_dir, params_template,
                                        step, shardings=pshard)
            return {"params": params, "opt_state": opt_state_template}

    # -- the loop -----------------------------------------------------------

    def fit(self, params, opt_state, num_steps: int, *, start_step: int = 0
            ) -> Tuple[Any, Any, Dict[str, float]]:
        """Run steps ``start_step .. num_steps``; returns the final state
        and the last step's metrics (as host floats)."""
        t0 = time.perf_counter()
        pending: Optional[Tuple[int, Dict[str, Any]]] = None
        last: Dict[str, float] = {}
        stream = self.pipeline.batches(num_steps - start_step,
                                       start=start_step)
        # drive iteration from the stream (not a zip'd range) so the
        # generator runs to completion and finalizes pipeline.stats
        for offset, batch in enumerate(stream):
            step = start_step + offset
            params, opt_state, metrics = self.step_fn(params, opt_state, batch)
            # read back the PREVIOUS step's metrics now that this step is
            # in flight — the readback overlaps compute instead of gating it
            if pending is not None:
                self._flush(pending, t0)
            pending = (step, metrics)
            if self.ckpt_every and (step + 1) % self.ckpt_every == 0 \
                    and step + 1 < num_steps:
                self.save(step + 1, params, opt_state)
        if pending is not None:
            last = self._readback(pending[1])
            if self.log_fn:
                self.log_fn(pending[0], last, time.perf_counter() - t0)
        if self.ckpt_dir and num_steps > start_step:
            self.save(num_steps, params, opt_state)
        return params, opt_state, last

    def _flush(self, pending: Tuple[int, Dict[str, Any]], t0: float):
        step, metrics = pending
        if self.log_fn and self.log_every and step % self.log_every == 0:
            self.log_fn(step, self._readback(metrics),
                        time.perf_counter() - t0)

    @staticmethod
    def _readback(metrics: Dict[str, Any]) -> Dict[str, float]:
        return {k: float(v) for k, v in metrics.items()}
