"""MBS planner — batch geometry + normalization/accumulation policy.

The paper determines the micro-batch size "experimentally ... the maximum
size that can compute on GPU" (§4.3.2) and assumes N_B % N_μ == 0. The
planner replaces both:

  * when the caller does not pin a micro-batch size, ``plan_mbs`` asks the
    analytic memory model (``core/memory_model.suggest_micro_batch_size``)
    for the largest micro-batch that fits the HBM budget;
  * ragged mini-batches (N_B % N_μ != 0) are handled by zero-padding the
    tail micro-batch and carrying a ``sample_weight`` mask (1 = real
    sample, 0 = padding) instead of asserting. Because Algorithm 1's
    ``"paper"`` normalization is only exact for uniform splits, a ragged
    plan auto-upgrades to ``"exact"`` (eq. 15–17 hold for any split there).

The resulting :class:`MBSPlan` is consumed by every executor in
``engine/executors.py``; see DESIGN.md §Engine architecture.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class MBSConfig:
    """Legacy per-step policy (kept for backward compatibility; new code
    should build an :class:`MBSPlan` via :func:`plan_mbs`)."""
    micro_batch_size: int
    normalization: str = "paper"  # "paper" | "exact"
    accum_dtype: Any = jnp.float32
    remat_micro_step: bool = False  # extra jax.checkpoint around each micro step
    unroll: int = 1  # scan unroll factor


def num_micro_batches(mini_batch_size: int, micro_batch_size: int) -> int:
    """Algorithm 1 lines 1–5: N_μ ← min(N_μ, N_B); N_Sμ = ceil(N_B / N_μ)."""
    micro = min(micro_batch_size, mini_batch_size)
    return int(math.ceil(mini_batch_size / micro))


def split_minibatch(batch: Dict[str, np.ndarray], micro_batch_size: int
                    ) -> Dict[str, np.ndarray]:
    """Host-side split (paper Fig. 2 step ❶): reshape every leaf from
    ``(N_B, ...)`` to ``(N_Sμ, N_μ, ...)``, zero-padding the ragged tail and
    emitting a ``sample_weight`` mask (1 = real sample, 0 = padding).

    A dataset-provided per-sample ``sample_weight`` is composed with the
    padding mask (weight × mask) rather than clobbered, so weighted
    datasets keep their weighting through the MBS split."""
    existing_w = batch.get("sample_weight")
    rest = {k: v for k, v in batch.items() if k != "sample_weight"}
    leaves = jax.tree.leaves(rest or batch)
    n_b = leaves[0].shape[0]
    n_mu = min(micro_batch_size, n_b)
    n_s = num_micro_batches(n_b, n_mu)
    pad = n_s * n_mu - n_b

    def split(x):
        if pad:
            x = np.concatenate([x, np.zeros((pad,) + x.shape[1:], x.dtype)])
        return x.reshape(n_s, n_mu, *x.shape[1:])

    out = {k: split(np.asarray(v)) for k, v in rest.items()}
    w = np.ones((n_b,), np.float32)
    if existing_w is not None:
        w = w * np.asarray(existing_w, np.float32).reshape(n_b)
    if pad:
        w = np.concatenate([w, np.zeros((pad,), np.float32)])
    out["sample_weight"] = w.reshape(n_s, n_mu)
    return out


@dataclasses.dataclass(frozen=True)
class MBSPlan:
    """Complete batch-geometry + accumulation policy for one training setup.

    Geometry (host side): ``mini_batch_size`` samples are split into
    ``num_micro_batches`` micro-batches of ``micro_batch_size`` each, with
    ``pad`` zero samples appended to the tail (masked via sample_weight).

    Policy (device side): ``normalization`` picks Algorithm 1 verbatim
    ("paper": micro mean / N_Sμ) vs. the ragged-exact variant ("exact":
    Σ valid per-sample losses / N_B_valid); ``accum_dtype`` is the gradient
    accumulator precision; ``remat_micro_step``/``unroll`` tune the
    compiled scan.

    Remat (model side): ``remat_policy`` is the graded activation-
    checkpointing policy (``models/remat.POLICIES``) the loss function must
    be built with — chosen jointly with the micro-batch size when the
    caller asks for ``"auto"`` (``auto_policy=True`` then records that the
    planner, not the caller, picked it).
    """
    mini_batch_size: int
    micro_batch_size: int
    num_micro_batches: int  # N_Sμ
    pad: int  # zero samples appended to the last micro-batch
    normalization: str = "paper"  # "paper" | "exact"
    accum_dtype: Any = jnp.float32
    remat_micro_step: bool = False
    unroll: int = 1
    auto_micro: bool = False  # micro size chosen by the memory model
    auto_normalization: bool = False  # "paper" upgraded to "exact" (ragged)
    remat_policy: str = "period"  # none | dots | period | full
    auto_policy: bool = False  # policy chosen by the planner ("auto")
    # -- mesh geometry (engine Layer 6) -----------------------------------
    # data_parallel workers each process local_micro samples of every
    # micro-batch (micro_batch_size = local_micro * data_parallel); the
    # cross-device gradient sync happens once per MINI-batch (deferred).
    data_parallel: int = 1
    local_micro: Optional[int] = None  # = micro_batch_size when dp == 1
    # -- measured-feedback admission (engine Layer 7) ----------------------
    # True when the micro size was admitted against oracle-corrected bytes
    # (engine/autotune memory calibration); ``correction`` records the
    # (a, b) affine map ``measured ~= a*modeled + b`` that was applied.
    calibrated: bool = False
    correction: Optional[tuple] = None
    # -- pipeline geometry (engine Layer 11) --------------------------------
    # > 1 when the plan was admitted pipeline-aware (plan_mbs(pipeline=True)
    # on a mesh with a model axis): the mesh's model axis runs this many
    # 1F1B stages and the activation budget charged stage-local activations
    # × the in-flight depth (== stages) instead of the // tp discount.
    pipeline_stages: int = 1

    def __post_init__(self):
        if self.local_micro is None:
            object.__setattr__(self, "local_micro",
                               self.micro_batch_size // self.data_parallel)

    @property
    def has_ragged_tail(self) -> bool:
        return self.pad > 0

    def split(self, batch: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        """Pad-and-mask split of a host mini-batch (paper Fig. 2 step ❶).

        Non-uniform dataset sample weights are only normalized correctly
        by "exact" mode (Algorithm 1 averages micro means with equal
        1/N_Sμ weight, which mis-weights unequal micro totals exactly
        like a ragged tail does) — refuse loudly rather than corrupt the
        gradient silently."""
        w = batch.get("sample_weight") if hasattr(batch, "get") else None
        if w is not None and self.normalization == "paper":
            w = np.asarray(w)
            if w.size and not np.all(w == w.flat[0]):
                raise ValueError(
                    'batch carries a non-uniform sample_weight, which '
                    '"paper" normalization cannot weight correctly — '
                    'build the plan with normalization="exact"')
        return split_minibatch(batch, self.micro_batch_size)

    def device_split(self, batch: Dict[str, np.ndarray]) -> Dict[str, jnp.ndarray]:
        return {k: jnp.asarray(v) for k, v in self.split(batch).items()}

    def as_config(self) -> MBSConfig:
        return MBSConfig(self.micro_batch_size, self.normalization,
                         self.accum_dtype, self.remat_micro_step, self.unroll)

    @classmethod
    def from_config(cls, cfg: MBSConfig,
                    mini_batch_size: Optional[int] = None) -> "MBSPlan":
        """Adapt a legacy MBSConfig. Without a mini-batch size the geometry
        fields are degenerate (executors derive N_Sμ from the data at trace
        time; only the policy fields matter)."""
        mini = mini_batch_size if mini_batch_size is not None else cfg.micro_batch_size
        micro = min(cfg.micro_batch_size, mini)
        n_s = num_micro_batches(mini, micro)
        return cls(mini, micro, n_s, n_s * micro - mini, cfg.normalization,
                   cfg.accum_dtype, cfg.remat_micro_step, cfg.unroll)

    def describe(self) -> str:
        src = ("calibrated memory model" if self.calibrated
               else "memory model" if self.auto_micro else "pinned")
        norm = self.normalization + (" (auto)" if self.auto_normalization else "")
        pol = self.remat_policy + (" (auto)" if self.auto_policy else "")
        mesh = (f", data-parallel {self.data_parallel} x local {self.local_micro}"
                if self.data_parallel > 1 else "")
        if self.pipeline_stages > 1:
            mesh += f", pipeline {self.pipeline_stages} stages"
        return (f"MBSPlan: mini-batch {self.mini_batch_size} -> "
                f"{self.num_micro_batches} x micro-batch {self.micro_batch_size}"
                f" (pad {self.pad}, micro {src}, normalization {norm}, "
                f"remat {pol}, accum {jnp.dtype(self.accum_dtype).name}{mesh})")


def plan_mbs(mini_batch_size: int, *,
             micro_batch_size: Optional[int] = None,
             num_microbatches: Optional[int] = None,
             model_cfg=None, seq_len: Optional[int] = None,
             budget_bytes: Optional[int] = None,
             normalization: str = "paper",
             accum_dtype: Any = jnp.float32,
             remat_micro_step: bool = False, unroll: int = 1,
             tp: int = 1, fsdp: int = 1, opt_slots: Optional[int] = None,
             act_bytes: int = 2, remat: bool = True,
             remat_policy: Optional[str] = None,
             optimizer: str = "sgd", fused_update: bool = False,
             mesh=None, fsdp_params: bool = True,
             calibrate: str = "off", tuning_cache: Optional[str] = None,
             executor: str = "compiled",
             pipeline: bool = False) -> MBSPlan:
    """Produce an :class:`MBSPlan` for one training setup.

    Micro-batch size resolution, in priority order:
      1. ``micro_batch_size`` pinned by the caller;
      2. ``num_microbatches`` pinned by the caller → ceil(N_B / N_Sμ);
      3. the analytic memory model (needs ``model_cfg`` + ``seq_len``):
         largest power-of-two micro-batch fitting ``budget_bytes``
         (default: one v5e HBM) — the paper's "experimentally determined"
         size (§4.3.2), computed instead of searched. The model includes a
         step-❺ transient term for ``optimizer`` (see
         ``memory_model.update_transient_bytes``); ``fused_update=True``
         (the ``flat`` executor's in-place kernels) drops it, admitting
         micro-batches the unfused update would OOM on. Falls back to
         micro-batch 1 when even that does not fit (more model parallelism
         is needed; MBS cannot shrink the model itself);
      4. no model config at all → one micro-batch (no MBS).

    ``remat_policy`` grades activation checkpointing (engine Layer 5):
      * an explicit policy ("none"|"dots"|"period"|"full") is used as-is —
        for auto micro sizing the memory model's activation term is scaled
        by it;
      * ``"auto"`` chooses the policy jointly with the micro-batch size
        (``memory_model.suggest_remat_policy_and_micro``): the cheapest-
        recompute policy whose admitted N_μ meets the target (the whole
        mini-batch), escalating to heavier remat only when the budget
        forces it. With a *pinned* micro size, ``"auto"`` picks the
        cheapest policy that admits the pinned size. Without a model
        config there is nothing to search — the legacy ``remat`` bool
        decides (True → "period", False → "none");
      * ``None`` (default) preserves the legacy ``remat`` bool behavior.
    The choice is recorded in ``MBSPlan.remat_policy`` and must be threaded
    into the loss function (``steps.make_loss_fn(remat_policy=...)``).

    ``mesh`` makes the plan mesh-aware (engine Layer 6): the budget is read
    as PER-DEVICE bytes (params/opt-state discounted by the real sharding
    policy via ``memory_model.param_shard_ratio``; ``fsdp_params=False``
    models a replicating data-parallel executor), the memory model sizes
    the per-device *local* micro-batch, and the global micro-batch size is
    kept divisible by the data-axis size (pinned sizes are rounded UP to
    the next multiple) so every worker gets an equal
    ``local_micro = micro / data_parallel`` slice of each micro-batch.

    ``calibrate`` closes the loop against XLA (engine Layer 7, only when
    the planner itself sizes the micro-batch — resolution path 3):
      * ``"off"`` (default): pure analytic admission, no cache I/O;
      * ``"auto"``: if the tuning cache (``tuning_cache`` path or the
        active/default cache) holds a calibration entry for this
        (arch, seq, policy, mesh, optimizer, executor, backend) key, the
        admission search runs against *corrected* bytes
        (``a*modeled + b``, any integer micro — not just powers of two);
        no entry → clean analytic fallback, nothing raises;
      * ``"force"``: run the probe compiles NOW (2–3 real train-step
        compilations + ``memory_analysis()``), persist the fit, then
        admit against it.
    A calibrated plan records ``calibrated=True`` and the correction used.
    ``executor`` only keys the cache entry; it does not change geometry.

    ``pipeline=True`` (engine Layer 11) reinterprets the mesh's model axis
    as 1F1B pipeline stages: micro-batch admission charges stage-local
    activations × the in-flight micro-batch count (warmup depth == stages,
    ``memory_model.pipeline_activation_bytes_per_sample``) instead of the
    tensor-parallel ``// tp`` discount, and the plan records
    ``pipeline_stages``. Stage counts that do not divide the model's block
    stack are rejected here, before any executor is built.
    """
    if calibrate not in ("off", "auto", "force"):
        raise ValueError(
            f'calibrate must be "off", "auto" or "force", got {calibrate!r}')
    if mini_batch_size < 1:
        raise ValueError(f"mini_batch_size must be >= 1, got {mini_batch_size}")
    from ..core import memory_model  # deferred: core imports this module
    from ..models import remat as remat_lib
    dp = 1
    stages = 1
    if mesh is not None:
        from ..launch import mesh as mesh_lib  # deferred: no cycle
        dp = mesh_lib.data_parallel_size(mesh)
        if pipeline:
            stages = mesh_lib.axis_size(mesh, mesh_lib.MODEL_AXIS)
    if pipeline and stages > 1 and model_cfg is not None \
            and model_cfg.num_periods % stages:
        raise ValueError(
            f"pipeline stage count {stages} (the mesh's model axis) does "
            f"not divide the block stack ({model_cfg.num_periods} periods) "
            "— pick a model axis that divides num_periods evenly")
    if mini_batch_size < dp:
        raise ValueError(
            f"mini-batch {mini_batch_size} is smaller than the mesh's "
            f"data-parallel size {dp}; every worker needs at least one "
            "sample per micro-batch — shrink the data axis or grow the batch")
    auto_policy_requested = remat_policy == "auto"
    policy = (None if auto_policy_requested
              else remat_lib.resolve(remat, remat_policy))
    can_search = model_cfg is not None and seq_len is not None
    budget = budget_bytes or memory_model.V5E_HBM_BYTES
    mm_kw = dict(tp=tp, fsdp=fsdp, opt_slots=opt_slots, act_bytes=act_bytes,
                 optimizer=optimizer, fused_update=fused_update,
                 mesh=mesh, fsdp_params=fsdp_params, pipeline=pipeline)
    # the memory model budgets what ONE device holds: local samples
    local_mini = mini_batch_size // dp

    def cheapest_policy_admitting(local: int) -> str:
        for p in memory_model.POLICY_ORDER:
            est = memory_model.estimate(model_cfg, seq_len, remat_policy=p,
                                        **mm_kw)
            if est.total(local) <= budget:
                return p
        return memory_model.POLICY_ORDER[-1]

    auto = False
    policy_searched = False
    calibrated = False
    correction = None
    if micro_batch_size is not None:
        micro = micro_batch_size
    elif num_microbatches is not None:
        if num_microbatches < 1:
            raise ValueError(f"num_microbatches must be >= 1, got {num_microbatches}")
        micro = int(math.ceil(mini_batch_size / num_microbatches))
    elif model_cfg is not None:
        if seq_len is None:
            raise ValueError("auto micro-batch sizing needs seq_len")
        if auto_policy_requested:
            # analytic joint search picks the policy; calibration (below)
            # then refines the micro size for THAT policy only, so "force"
            # costs one probe set, not one per lattice point
            policy, local = memory_model.suggest_remat_policy_and_micro(
                model_cfg, seq_len, local_mini, budget_bytes=budget,
                **mm_kw)
            policy_searched = True
        else:
            local = memory_model.suggest_micro_batch_size(
                model_cfg, seq_len, local_mini, budget_bytes=budget,
                remat_policy=policy, **mm_kw)
        if calibrate != "off":
            from . import autotune
            corr = autotune.planner_correction(
                model_cfg, seq_len, remat_policy=policy, mesh=mesh,
                optimizer=optimizer, executor=executor, mode=calibrate,
                cache_path=tuning_cache,
                **{k: v for k, v in mm_kw.items()
                   if k not in ("optimizer", "mesh", "pipeline")})
            if corr is not None:
                cal_local = autotune.corrected_micro_search(
                    model_cfg, seq_len, local_mini, budget, corr,
                    remat_policy=policy, **mm_kw)
                if cal_local is not None:
                    local = cal_local
                    calibrated = True
                    correction = (float(corr[0]), float(corr[1]))
        micro = (local or 1) * dp
        auto = True
    else:
        micro = mini_batch_size

    micro = max(1, min(micro, mini_batch_size))  # Algorithm 1 lines 2–4
    if dp > 1:
        # divisibility against the data axis: round UP to the next multiple
        # (per-device load ceil(micro/dp) never exceeds the pinned intent),
        # capped at the largest dp-divisible size <= the mini-batch
        micro = min(dp * -(-micro // dp), dp * local_mini)
    if policy is None:  # "auto" with a pinned micro size (or no model cfg)
        if can_search:
            policy = cheapest_policy_admitting(micro // dp)
            policy_searched = True
        else:
            # nothing to search against: the legacy bool decides, and the
            # plan must NOT claim the planner validated the choice
            policy = remat_lib.resolve(remat, None)
    n_s = num_micro_batches(mini_batch_size, micro)
    pad = n_s * micro - mini_batch_size
    auto_norm = False
    if pad and normalization == "paper":
        # Algorithm 1 divides each micro mean by N_Sμ, which over-weights a
        # short tail; "exact" reproduces the mini-batch gradient for any split.
        normalization, auto_norm = "exact", True
    return MBSPlan(mini_batch_size, micro, n_s, pad, normalization,
                   accum_dtype, remat_micro_step, unroll,
                   auto_micro=auto, auto_normalization=auto_norm,
                   remat_policy=policy,
                   auto_policy=auto_policy_requested and policy_searched,
                   data_parallel=dp, local_micro=micro // dp,
                   calibrated=calibrated, correction=correction,
                   pipeline_stages=stages)
