"""Fault-tolerant training runtime (engine Layer 9): the Supervisor.

MBP admission plans to the *edge* of device memory, so a production run
must assume the plan will sometimes be wrong at runtime — allocator
fragmentation, a co-tenant, a calibration miss — and that long huge-batch
runs will hit non-finite gradients and flaky I/O. The
:class:`Supervisor` wraps the ``Trainer``'s step loop with a recovery
state machine over the ``faults`` taxonomy:

  ``oom``        (``RESOURCE_EXHAUSTED`` out of executor dispatch)
                 → **degrade + re-plan + resume**: escalate the remat
                 policy one rung up the Layer-5 lattice first (recompute
                 is cheaper than losing batch — the paper's whole point
                 is keeping N_B), then shrink the micro-batch and
                 re-derive the plan via ``plan_mbs``, feeding the
                 observed failure back into the Layer-7 tuning cache as
                 a negative calibration bound
                 (``autotune.record_oom_bound``) so the re-plan — and
                 every future plan under this key — admits strictly less
                 than what just OOMed. Rebuild executor + pipeline for
                 the new plan, restore the last completed state (PR-2
                 resume machinery: committed checkpoints, else the
                 in-memory anchor), replay from there. The Pipeline's
                 step-indexed seeding makes the post-recovery trajectory
                 equal an uninterrupted run at the degraded plan.
  ``nonfinite``  (the executors' ``guard=True`` on-device finite-check)
                 → skip-step + bounded retry: the guarded update already
                 left params/opt-state untouched, so the supervisor
                 re-draws the same seeded batch (``pipeline.rebatch`` —
                 donation consumed the poisoned buffers) up to
                 ``nan_retries`` times, then skips; ``max_consecutive_nan``
                 skipped steps in a row trip the circuit breaker
                 (``on_nan="halt"`` raises on the first one instead).
  ``transient``  (``faults.TransientError`` / ``OSError`` escaping the
                 Pipeline's own bounded retries, or checkpoint-I/O
                 failures) → bounded retry with jittered backoff; a
                 checkpoint that still fails after ``io_retries`` is
                 logged and *skipped* — training goes on, durability
                 catches up at the next cadence.
  ``crash``      (``faults.InjectedCrash``) → NOT handled: it models the
                 process dying (e.g. mid-checkpoint-write); the harness
                 lets it propagate so tests can assert the on-disk state
                 a real crash would leave.
  ``fatal``      everything else → propagate unchanged. A real bug must
                 not be retried into silence.

Degradation order — remat before micro-shrink — because escalating remat
preserves the planned batch geometry (same N_μ/N_Sμ, only more
recompute), while shrinking the micro-batch re-pads/re-masks the split
and costs throughput; and because the remat lattice is bounded (4 rungs)
whereas micro-shrink is where the real admission give-back happens, it
is the escape hatch once recompute is exhausted.

Supervision cost: when the guard is active the supervisor reads the
``nonfinite`` flag synchronously every step (one scalar readback) —
without it, step i+1's dispatch would consume state before step i's
skip decision is known. Unsupervised runs keep the Trainer's fully
async readback.
"""
from __future__ import annotations

import dataclasses
import math
import random as _random
import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from ..checkpoint import checkpoint
from ..models import remat as remat_lib
from . import autotune, faults
from .plan import MBSPlan, plan_mbs
from .trainer import _default_log


class SupervisorError(RuntimeError):
    """Base class for supervisor give-ups (recovery budget exhausted)."""
    exit_code = 40


class RestartBudgetExceeded(SupervisorError):
    """More OOM restarts than ``max_restarts``."""
    exit_code = 41


class PlanExhausted(SupervisorError):
    """OOM with nothing left to degrade (remat full, micro-batch 1)."""
    exit_code = 42


class NaNCircuitBreaker(SupervisorError):
    """``max_consecutive_nan`` skipped steps in a row."""
    exit_code = 43


class NaNHalt(SupervisorError):
    """Non-finite step under ``on_nan="halt"``."""
    exit_code = 44


@dataclasses.dataclass
class SupervisorConfig:
    """Recovery budgets + policies (all deterministic; ``seed`` keys only
    the backoff jitter)."""
    max_restarts: int = 3  # OOM re-plan budget for the whole fit
    on_nan: str = "skip"  # "skip" (bounded retry then skip) | "halt"
    nan_retries: int = 1  # same-step clean re-draw attempts before skipping
    max_consecutive_nan: int = 3  # skipped-in-a-row circuit breaker
    io_retries: int = 3  # checkpoint-I/O attempts per save
    stream_retries: int = 2  # transient failures escaping the Pipeline
    backoff_s: float = 0.02  # base backoff (jittered, doubling)
    seed: int = 0

    def __post_init__(self):
        if self.on_nan not in ("skip", "halt"):
            raise ValueError(f"on_nan must be 'skip'|'halt', "
                             f"got {self.on_nan!r}")


@dataclasses.dataclass
class FaultRecord:
    """One recovery event for the report / ``BENCH_faults.json``."""
    kind: str  # faults taxonomy label
    step: int  # global step at which the fault surfaced
    action: str  # what the supervisor did
    recovery_s: float = 0.0  # fault caught -> ready to dispatch again
    steps_lost: int = 0  # completed steps replayed (OOM) or skipped (NaN)


def degrade_plan(plan: MBSPlan, ctx: Optional[Dict[str, Any]] = None
                 ) -> Tuple[MBSPlan, str]:
    """One deterministic rung down the degradation ladder; returns
    ``(new_plan, action)``.

    Rungs: escalate ``remat_policy`` up the Layer-5 lattice (micro size
    pinned — geometry preserved) until "full", then shrink the
    micro-batch: with a plan ``ctx`` (the launcher's model/budget view)
    re-derive via ``plan_mbs(calibrate="auto")`` so the Layer-7 negative
    bound recorded for the OOM drives the new admission; without one,
    halve (keeping data-parallel divisibility). Raises
    :class:`PlanExhausted` at the bottom of the ladder."""
    lattice = remat_lib.POLICIES
    i = lattice.index(plan.remat_policy)
    if i + 1 < len(lattice):
        nxt = lattice[i + 1]
        action = f"remat {plan.remat_policy}->{nxt}"
        if ctx and ctx.get("model_cfg") is not None:
            new = plan_mbs(plan.mini_batch_size,
                           micro_batch_size=plan.micro_batch_size,
                           remat_policy=nxt, **_ctx_kw(plan, ctx))
        else:
            new = dataclasses.replace(plan, remat_policy=nxt,
                                      auto_policy=False)
        return new, action

    dp = max(plan.data_parallel, 1)
    if plan.micro_batch_size <= max(1, dp):
        raise PlanExhausted(
            f"OOM at remat=full, micro={plan.micro_batch_size}, dp={dp}: "
            "nothing left to degrade (the model itself does not fit — "
            "MBS cannot shrink it; add model parallelism)")
    if ctx and ctx.get("model_cfg") is not None \
            and ctx.get("budget_bytes") is not None:
        new = plan_mbs(plan.mini_batch_size,
                       budget_bytes=ctx["budget_bytes"],
                       remat_policy=plan.remat_policy, calibrate="auto",
                       **_ctx_kw(plan, ctx))
        if new.micro_batch_size < plan.micro_batch_size:
            return new, (f"replan micro {plan.micro_batch_size}->"
                         f"{new.micro_batch_size} (calibrated)")
        # bound didn't move admission (e.g. corrupted cache degraded the
        # lookup to analytic) — fall through to the deterministic halving
    new_micro = (plan.micro_batch_size // 2 // dp) * dp if dp > 1 \
        else plan.micro_batch_size // 2
    if new_micro < max(1, dp):
        raise PlanExhausted(
            f"cannot halve micro={plan.micro_batch_size} below the "
            f"data-parallel extent {dp}")
    action = f"halve micro {plan.micro_batch_size}->{new_micro}"
    if ctx and ctx.get("model_cfg") is not None:
        return plan_mbs(plan.mini_batch_size, micro_batch_size=new_micro,
                        remat_policy=plan.remat_policy,
                        **_ctx_kw(plan, ctx)), action
    n_s = math.ceil(plan.mini_batch_size / new_micro)
    pad = n_s * new_micro - plan.mini_batch_size
    norm = ("exact" if (pad and plan.normalization == "paper")
            else plan.normalization)
    return dataclasses.replace(
        plan, micro_batch_size=new_micro, num_micro_batches=n_s, pad=pad,
        normalization=norm,
        auto_normalization=plan.auto_normalization or norm != plan.normalization,
        local_micro=new_micro // dp if dp > 1 else new_micro,
        auto_micro=False, calibrated=False, correction=None), action


def _ctx_kw(plan: MBSPlan, ctx: Dict[str, Any]) -> Dict[str, Any]:
    """The ``plan_mbs`` kwargs a launcher-style plan context carries."""
    kw = dict(model_cfg=ctx.get("model_cfg"), seq_len=ctx.get("seq_len"),
              normalization=plan.normalization,
              accum_dtype=plan.accum_dtype, mesh=ctx.get("mesh"),
              optimizer=ctx.get("optimizer", "sgd"),
              executor=ctx.get("executor", "compiled"),
              tuning_cache=ctx.get("tuning_cache"))
    kw.update(ctx.get("mm_kw") or {})
    return kw


class Supervisor:
    """Wraps a ``(step_fn, pipeline)`` runtime with the Layer-9 recovery
    state machine (see the module doc).

    ``build(plan) -> (step_fn, pipeline)`` is the rebuild factory the OOM
    path calls after degrading the plan — the launcher's executor/pipeline
    construction, closed over model/optimizer; executors should be built
    with ``guard=True`` so the NaN path has its on-device flag.

    ``plan_ctx`` (optional) is the launcher's planning context
    (``model_cfg``, ``seq_len``, ``budget_bytes``, ``mesh``, ``optimizer``,
    ``executor``, ``tuning_cache``, ``mm_kw``): with it, OOM degradation
    re-derives plans through ``plan_mbs`` and records the negative
    calibration bound; without it, degradation is purely geometric
    (remat escalation, then halving).
    """

    def __init__(self, build: Callable[[MBSPlan], Tuple[Callable, Any]],
                 plan: MBSPlan, *,
                 config: Optional[SupervisorConfig] = None,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
                 ckpt_keep: Optional[int] = None, log_every: int = 5,
                 log_fn: Callable = _default_log,
                 state_shardings: Any = None,
                 plan_ctx: Optional[Dict[str, Any]] = None):
        self.build = build
        self.plan = plan
        self.config = config or SupervisorConfig()
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.ckpt_keep = ckpt_keep
        self.log_every = log_every
        self.log_fn = log_fn
        self.state_shardings = state_shardings
        self.plan_ctx = plan_ctx
        self.step_fn, self.pipeline = build(plan)
        self.restarts = 0
        self.records: List[FaultRecord] = []
        self.history: Dict[int, float] = {}  # step -> loss (completed steps)
        self._rng = _random.Random(self.config.seed ^ 0x0F0F)
        self._snapshot: Optional[Tuple[Any, Any, int]] = None
        self._templates = None

    # -- state anchoring / restore ------------------------------------------

    def _anchor(self, params, opt_state, step: int) -> None:
        """Host-side copy of the completed state at ``step`` — the restore
        source of last resort (donation invalidates the device buffers the
        moment the next step dispatches). Refreshed at checkpoint cadence,
        so its sync cost amortizes like a save."""
        self._snapshot = (jax.device_get(params), jax.device_get(opt_state),
                          step)

    def _save(self, params, opt_state, step: int) -> None:
        """Checkpoint with bounded transient-I/O retry; a save that still
        fails is skipped (training continues, durability catches up next
        cadence). ``InjectedCrash`` propagates — it models process death."""
        self._anchor(params, opt_state, step)
        if not self.ckpt_dir:
            return
        for attempt in range(self.config.io_retries + 1):
            try:
                checkpoint.save(self.ckpt_dir, step,
                                {"params": params, "opt_state": opt_state},
                                keep=self.ckpt_keep)
                return
            except faults.InjectedCrash:
                raise
            except OSError as e:
                if attempt >= self.config.io_retries:
                    warnings.warn(f"checkpoint at step {step} failed after "
                                  f"{attempt + 1} attempts ({e}); continuing")
                    return
                self.records.append(FaultRecord(
                    "transient", step, f"ckpt-io retry {attempt + 1}"))
                self._backoff(attempt)

    def _restore(self):
        """(params, opt_state, step) of the newest recoverable completed
        state: the newest loadable committed checkpoint, else the
        in-memory anchor."""
        if self.ckpt_dir:
            for step in reversed(checkpoint.committed_steps(self.ckpt_dir)):
                try:
                    tree = checkpoint.restore(self.ckpt_dir, self._templates,
                                              step,
                                              shardings=self.state_shardings)
                except checkpoint.CheckpointCorruptError:
                    continue
                if self.state_shardings is None:
                    tree = jax.device_put(tree)
                if self._snapshot is None or step >= self._snapshot[2]:
                    return tree["params"], tree["opt_state"], step
                break  # the anchor is newer
        params, opt_state, step = self._snapshot
        placed = {"params": params, "opt_state": opt_state}
        placed = jax.device_put(
            placed, self.state_shardings) if self.state_shardings is not None \
            else jax.device_put(placed)
        return placed["params"], placed["opt_state"], step

    def restore(self, params, opt_state):
        """Trainer-compatible initial resume: ``(params, opt_state, step)``
        from the newest *loadable* committed checkpoint in ``ckpt_dir``
        (torn / checksum-failing ones are skipped), or ``None``."""
        if not self.ckpt_dir:
            return None
        self._templates = jax.eval_shape(
            lambda p, o: {"params": p, "opt_state": o}, params, opt_state)
        for step in reversed(checkpoint.committed_steps(self.ckpt_dir)):
            try:
                tree = checkpoint.restore(self.ckpt_dir, self._templates,
                                          step, shardings=self.state_shardings)
            except checkpoint.CheckpointCorruptError:
                continue
            if self.state_shardings is None:
                tree = jax.device_put(tree)
            return tree["params"], tree["opt_state"], step
        return None

    def _backoff(self, attempt: int) -> None:
        time.sleep(self.config.backoff_s * (1 + self._rng.random())
                   * (2 ** attempt))

    # -- the recovery state machine -----------------------------------------

    def _recover_oom(self, exc: BaseException, failed_step: int
                     ) -> Tuple[Any, Any, int]:
        """Degrade → re-plan (negative bound) → rebuild → restore."""
        t0 = time.perf_counter()
        self.restarts += 1
        if self.restarts > self.config.max_restarts:
            raise RestartBudgetExceeded(
                f"{self.restarts - 1} restarts exhausted (last OOM at step "
                f"{failed_step}: {exc})") from exc
        ctx = self.plan_ctx
        cache_path = (ctx or {}).get("tuning_cache")
        faults.on_replan(cache_path or
                         (autotune.get_cache().path if ctx else None))
        if ctx and ctx.get("model_cfg") is not None \
                and ctx.get("budget_bytes") is not None:
            # the observed failure becomes a negative calibration bound
            # BEFORE re-planning, so plan_mbs(calibrate="auto") sees it
            autotune.record_oom_bound(
                ctx["model_cfg"], ctx["seq_len"], self.plan.micro_batch_size,
                ctx["budget_bytes"], remat_policy=self.plan.remat_policy,
                mesh=ctx.get("mesh"), optimizer=ctx.get("optimizer", "sgd"),
                executor=ctx.get("executor", "compiled"),
                cache_path=cache_path,
                **(ctx.get("mm_kw") or {}))
        old = self.plan
        self.plan, action = degrade_plan(old, ctx)
        self.step_fn, self.pipeline = self.build(self.plan)
        params, opt_state, resume_step = self._restore()
        rec = FaultRecord("oom", failed_step, action,
                          recovery_s=time.perf_counter() - t0,
                          steps_lost=failed_step - resume_step)
        self.records.append(rec)
        if self.log_fn:
            print(f"[supervisor] OOM at step {failed_step}: {action}; "
                  f"resuming from step {resume_step} "
                  f"({rec.recovery_s:.2f}s, {rec.steps_lost} steps replayed)",
                  flush=True)
        return params, opt_state, resume_step

    def _handle_nonfinite(self, params, opt_state, metrics, step: int):
        """Bounded same-batch (clean re-draw) retry, then skip. The guarded
        update already passed state through untouched, so the returned
        buffers ARE the pre-step state."""
        if self.config.on_nan == "halt":
            raise NaNHalt(f"non-finite gradient at step {step} "
                          "(on_nan='halt')")
        t0 = time.perf_counter()
        for attempt in range(self.config.nan_retries):
            batch = self.pipeline.rebatch(step)
            params, opt_state, metrics = self.step_fn(params, opt_state,
                                                      batch)
            if not float(metrics.get("nonfinite", 0.0)):
                self.records.append(FaultRecord(
                    "nonfinite", step, f"retried ok (attempt {attempt + 1})",
                    recovery_s=time.perf_counter() - t0))
                return params, opt_state, metrics, False
        self.records.append(FaultRecord(
            "nonfinite", step, "skipped", steps_lost=1,
            recovery_s=time.perf_counter() - t0))
        return params, opt_state, metrics, True

    # -- the loop -----------------------------------------------------------

    def fit(self, params, opt_state, num_steps: int, *, start_step: int = 0
            ) -> Tuple[Any, Any, Dict[str, float]]:
        """Supervised ``Trainer.fit``: same contract (final state + last
        step's metrics as host floats), plus ``self.records`` /
        ``self.history`` / ``self.report()`` describing every recovery."""
        cfg = self.config
        t_fit = time.perf_counter()
        self._templates = jax.eval_shape(
            lambda p, o: {"params": p, "opt_state": o}, params, opt_state)
        self._anchor(params, opt_state, start_step)
        step = start_step
        consecutive_nan = 0
        stream_failures = 0
        last: Dict[str, float] = {}
        while step < num_steps:
            stream = self.pipeline.batches(num_steps - step, start=step)
            try:
                for batch in stream:
                    params, opt_state, metrics = self.step_fn(
                        params, opt_state, batch)
                    if float(metrics.get("nonfinite", 0.0)):
                        params, opt_state, metrics, skipped = \
                            self._handle_nonfinite(params, opt_state,
                                                   metrics, step)
                        if skipped:
                            consecutive_nan += 1
                            if consecutive_nan >= cfg.max_consecutive_nan:
                                raise NaNCircuitBreaker(
                                    f"{consecutive_nan} consecutive "
                                    f"non-finite steps ending at {step}")
                        else:
                            consecutive_nan = 0
                    else:
                        consecutive_nan = 0
                    last = {k: float(v) for k, v in metrics.items()}
                    self.history[step] = last.get("loss", float("nan"))
                    if self.log_fn and self.log_every \
                            and step % self.log_every == 0:
                        self.log_fn(step, last, time.perf_counter() - t_fit)
                    step += 1
                    if self.ckpt_every and step % self.ckpt_every == 0 \
                            and step < num_steps:
                        self._save(params, opt_state, step)
            except Exception as exc:
                if faults.is_oom(exc):
                    params, opt_state, step = self._recover_oom(exc, step)
                    continue
                if faults.is_transient(exc):
                    stream_failures += 1
                    if stream_failures > cfg.stream_retries:
                        raise
                    self.records.append(FaultRecord(
                        "transient", step, "stream restart"))
                    self._backoff(stream_failures - 1)
                    continue  # re-open the stream at the current step
                raise  # fatal (and InjectedCrash): propagate unchanged
        if num_steps > start_step:
            self._save(params, opt_state, num_steps)
        return params, opt_state, last

    def report(self) -> Dict[str, Any]:
        return {
            "restarts": self.restarts,
            "plan": {"micro_batch_size": self.plan.micro_batch_size,
                     "num_micro_batches": self.plan.num_micro_batches,
                     "remat_policy": self.plan.remat_policy},
            "faults": [dataclasses.asdict(r) for r in self.records],
            "steps_lost": sum(r.steps_lost for r in self.records),
            "completed_steps": len(self.history),
        }
