"""Memory-planned serving engine (engine Layer 10): continuous batching
with KV-cache admission.

The training stack plans micro-batches against an activation memory model
(``plan_mbs`` / ``activation_bytes_per_sample``). Serving is the same MBP
admission problem with a different per-unit cost: a decoding request's
footprint is its KV-cache slot (``memory_model.kv_slot_bytes``), not its
activations, so :func:`plan_serve` bounds the number of CONCURRENTLY
decoding requests and the prefill micro-batch size against the HBM budget
the same way ``plan_mbs`` bounds the micro-batch size.

Request lifecycle (state machine, DESIGN.md §Serving):

    QUEUED --admit (free slot + prefill micro-batch)--> PREFILL
    PREFILL --first token sampled, cache row scattered--> DECODE
    DECODE --max_new_tokens reached--> FINISHED (slot evicted → reusable)

Continuous batching: every decode step runs the jitted ``decode_step``
over the ENTIRE fixed-shape slot pool (``kv.KVPool``); inactive slots
compute garbage that is masked host-side, so admissions and evictions
never retrigger compilation. Prefill is micro-batched through the same
pad-and-mask idiom as the training planner: pure-attention stacks take
RIGHT-PADDED ragged groups (``transformer.prefill(lengths=...)`` — exact,
because causal attention never lets a real query see the padding), while
state-carrying (ssm / recurrent) and MoE families group EXACT-LENGTH
prompts instead (padding would run through their scans / expert routing
and change real-token outputs — ``transformer.supports_ragged_prefill``).
Encoder-decoder configs are rejected up front with a clear message.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Any, Dict, Iterable, Iterator, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer
from ..models.config import ModelConfig
from .kv import KVPool

# request lifecycle states
QUEUED = "queued"
PREFILL = "prefill"
DECODE = "decode"
FINISHED = "finished"

_FAMILY_NOTES = {
    "encdec": ("encoder-decoder configs are not servable by the decoder-only "
               "serving engine (no cross-attention cache in init_cache/"
               "decode_step); serve a decoder-only arch instead"),
    "state": ("state-carrying layers (ssm/recurrent) decode through "
              "init_cache/decode_step but prefill EXACT-LENGTH groups — "
              "ragged padding would run the scan through the padded tail"),
    "moe": ("MoE routing competes padded tokens for expert capacity, so "
            "prompts prefill in exact-length groups"),
}


def check_servable(cfg: ModelConfig) -> None:
    """Fail fast, per family, before any array is allocated (the old
    ``launch/serve.py`` only guarded enc-dec and let every other
    unsupported combination surface as a shape error mid-loop)."""
    if cfg.is_encdec:
        raise ValueError(f"{cfg.name}: {_FAMILY_NOTES['encdec']}")
    for kind in cfg.layer_pattern:
        if kind not in ("global", "local", "ssm", "recurrent"):
            raise ValueError(
                f"{cfg.name}: layer kind {kind!r} has no decode-cache slot "
                "in transformer.init_cache — cannot serve this pattern")


@dataclasses.dataclass
class Request:
    """One generation request moving through the lifecycle."""
    rid: int
    prompt: np.ndarray  # (L,) int32 token ids
    max_new_tokens: int
    arrival_s: float = 0.0  # offset from stream start

    # filled in by the engine
    state: str = QUEUED
    slot: Optional[int] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    queued_s: Optional[float] = None
    first_token_s: Optional[float] = None  # TTFT = first_token_s - arrival_s
    finish_s: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))


@dataclasses.dataclass(frozen=True)
class ServePlan:
    """Admission geometry for one serving setup — the serving sibling of
    :class:`engine.plan.MBSPlan`.

    ``max_decode_slots`` bounds concurrent decoding requests (the KV pool's
    batch dimension); ``prefill_micro`` bounds how many prompts prefill
    together. Both were admitted against ``budget_bytes`` via
    ``memory_model.serve_estimate``: the modeled peak
    ``base_bytes + kv_slot_bytes * slots + prefill_bytes_per_sample * micro``
    never exceeds the budget.
    """
    max_decode_slots: int
    prefill_micro: int
    max_len: int  # context capacity per slot (prompt + generated)
    budget_bytes: int
    # memory-model coefficients the admission was computed from
    kv_slot_bytes: int
    base_bytes: int  # params + fixed overhead (slot-count independent)
    prefill_bytes_per_sample: int
    cache_bytes: int = 2
    global_window: Optional[int] = None
    ragged_prefill: bool = True  # False → exact-length prompt grouping
    auto_slots: bool = True  # slot count chosen by the memory model
    # mesh geometry: budget was per device; the pool is local_slots per
    # data-parallel worker, max_decode_slots = local_slots * data_parallel
    data_parallel: int = 1
    local_slots: Optional[int] = None

    def __post_init__(self):
        if self.local_slots is None:
            object.__setattr__(self, "local_slots",
                               self.max_decode_slots // self.data_parallel)

    def modeled_peak_bytes(self, slots: Optional[int] = None,
                           prefill_micro: Optional[int] = None) -> int:
        """Memory-model peak for ``slots`` active decode slots and a
        ``prefill_micro`` prefill in flight (defaults: the plan's bounds),
        per data-parallel worker."""
        s = self.local_slots if slots is None else slots
        m = self.prefill_micro if prefill_micro is None else prefill_micro
        return (self.base_bytes + self.kv_slot_bytes * s
                + self.prefill_bytes_per_sample * m)

    def describe(self) -> str:
        src = "memory model" if self.auto_slots else "pinned"
        group = "ragged-pad" if self.ragged_prefill else "exact-length"
        mesh = (f", data-parallel {self.data_parallel} x local "
                f"{self.local_slots}" if self.data_parallel > 1 else "")
        return (f"ServePlan: {self.max_decode_slots} decode slots @ max_len "
                f"{self.max_len} ({self.kv_slot_bytes / 2**20:.1f} MiB/slot, "
                f"{src}), prefill micro {self.prefill_micro} ({group}), "
                f"modeled peak {self.modeled_peak_bytes() / 2**30:.2f} GiB of "
                f"budget {self.budget_bytes / 2**30:.2f} GiB{mesh}")


def plan_serve(cfg: ModelConfig, *, budget_bytes: int, max_len: int,
               max_slots: Optional[int] = None,
               prefill_micro: Optional[int] = None,
               mesh=None, cache_bytes: int = 2, act_bytes: int = 2,
               global_window: Optional[int] = None,
               fsdp_params: bool = False,
               slot_cap: int = 256) -> ServePlan:
    """Admission planning for serving — ``plan_mbs`` with KV-slot costs.

    Resolution mirrors the training planner: a pinned ``max_slots`` /
    ``prefill_micro`` is validated against the budget; otherwise the
    largest slot count whose modeled peak fits is admitted, shrinking the
    prefill micro-batch (powers of two, floor 1) when prefill activations
    would crowd out decode slots. ``mesh`` reads ``budget_bytes`` as
    PER-DEVICE bytes (params discounted by the real sharding policy;
    ``fsdp_params=False`` models the replicating data-parallel serve path)
    and plans ``local_slots`` per worker. ``slot_cap`` bounds the pool so a
    huge budget on a tiny config cannot plan an absurd batch dimension.
    """
    check_servable(cfg)
    if max_len < 2:
        raise ValueError(f"max_len must be >= 2 (prompt + one token), "
                         f"got {max_len}")
    from ..core import memory_model  # deferred: core imports engine.plan
    dp = 1
    if mesh is not None:
        from ..launch import mesh as mesh_lib  # deferred: no cycle
        dp = mesh_lib.data_parallel_size(mesh)
    est = memory_model.serve_estimate(
        cfg, max_len, prefill_len=max_len, cache_bytes=cache_bytes,
        act_bytes=act_bytes, global_window=global_window, mesh=mesh,
        fsdp_params=fsdp_params)
    base = est.total(0, 0)

    def slots_at(pm: int) -> int:
        return (budget_bytes - est.total(0, pm)) // est.kv_slot_bytes

    if slots_at(1) < 1:
        need = est.total(1, 1)
        raise ValueError(
            f"{cfg.name}: budget {budget_bytes / 2**30:.2f} GiB cannot hold "
            f"the params + one decode slot + one prefill sample at max_len "
            f"{max_len} (needs {need / 2**30:.2f} GiB) — serving needs model "
            "parallelism or a shorter context; admission cannot shrink the "
            "model itself")

    auto_slots = max_slots is None
    if prefill_micro is not None:
        if prefill_micro < 1:
            raise ValueError(f"prefill_micro must be >= 1, got {prefill_micro}")
        pm = prefill_micro
    else:
        # start at 8 (matches the training planner's probe scale) and halve
        # while prefill activations would leave fewer slots than the micro
        # size itself — a prefill batch larger than the decode pool it
        # feeds is pure waste
        pm = 8
        while pm > 1 and slots_at(pm) < pm:
            pm //= 2

    if auto_slots:
        local = int(min(slots_at(pm), slot_cap))
        if local < 1:  # pinned prefill_micro crowded decode out entirely
            raise ValueError(
                f"{cfg.name}: prefill micro-batch {pm} leaves no room for a "
                f"decode slot in {budget_bytes / 2**30:.2f} GiB — shrink "
                "prefill_micro or raise the budget")
    else:
        if max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        local = -(-max_slots // dp)
        peak = est.total(local, min(pm, local))
        if peak > budget_bytes:
            raise ValueError(
                f"{cfg.name}: pinned {max_slots} slots (local {local}) + "
                f"prefill micro {min(pm, local)} models "
                f"{peak / 2**30:.2f} GiB, over the "
                f"{budget_bytes / 2**30:.2f} GiB budget — "
                f"fits at most {slots_at(min(pm, local))} local slots")
    pm = max(1, min(pm, local))
    return ServePlan(
        max_decode_slots=local * dp, prefill_micro=pm, max_len=max_len,
        budget_bytes=int(budget_bytes), kv_slot_bytes=est.kv_slot_bytes,
        base_bytes=base, prefill_bytes_per_sample=est.prefill_bytes_per_sample,
        cache_bytes=cache_bytes, global_window=global_window,
        ragged_prefill=transformer.supports_ragged_prefill(cfg),
        auto_slots=auto_slots, data_parallel=dp, local_slots=local)


def _sample(logits, key, temperature: float):
    """Greedy (temperature == 0) or temperature sampling over (..., V)."""
    if temperature > 0:
        return jax.random.categorical(key, logits / temperature, axis=-1)
    return jnp.argmax(logits, axis=-1)


def _percentiles(xs: Sequence[float]) -> Dict[str, float]:
    if not len(xs):
        return {"p50": 0.0, "p99": 0.0, "mean": 0.0, "max": 0.0}
    a = np.asarray(xs, np.float64)
    return {"p50": float(np.percentile(a, 50)),
            "p99": float(np.percentile(a, 99)),
            "mean": float(a.mean()), "max": float(a.max())}


class ServingEngine:
    """Continuous-batching scheduler over a :class:`KVPool`.

    One engine = one device pool of ``plan.max_decode_slots`` slots, one
    jitted prefill per prompt-length bucket and ONE jitted decode step for
    the whole pool (fixed shapes — admission/eviction never recompiles).
    The decode jit donates the cache (``plan``-sized pool donated back to
    itself each step); sampling (greedy at ``temperature == 0``, else
    categorical at ``temperature``) runs inside the same jit so the only
    per-step host traffic is the (S,) next-token readback that also serves
    as the per-token latency fence.
    """

    def __init__(self, params, cfg: ModelConfig, plan: ServePlan, *,
                 dtype=jnp.float32, cache_dtype=None, temperature: float = 0.0,
                 seed: int = 0, donate: bool = True, pad_multiple: int = 16):
        check_servable(cfg)
        self.params = params
        self.cfg = cfg
        self.plan = plan
        self.dtype = dtype
        self.temperature = float(temperature)
        self.pad_multiple = int(pad_multiple)
        if cache_dtype is None:
            cache_dtype = jnp.bfloat16 if plan.cache_bytes == 2 else jnp.float32
        self.pool = KVPool(cfg, plan.max_decode_slots, plan.max_len,
                           dtype=cache_dtype, global_window=plan.global_window,
                           donate=donate)
        S = plan.max_decode_slots
        self._tok = np.zeros((S, 1), np.int32)
        self._pos = np.zeros((S,), np.int32)
        self._by_slot: Dict[int, Request] = {}
        self._queue: collections.deque = collections.deque()
        self._key = jax.random.PRNGKey(seed)
        self._step_idx = 0

        gw = plan.global_window
        ml = plan.max_len

        def prefill_ragged(p, toks, lengths):
            return transformer.prefill(p, cfg, toks, max_len=ml, dtype=dtype,
                                       global_window=gw, lengths=lengths)

        def prefill_exact(p, toks):
            return transformer.prefill(p, cfg, toks, max_len=ml, dtype=dtype,
                                       global_window=gw)

        def decode(p, cache, tok, pos, key):
            logits, cache = transformer.decode_step(p, cfg, tok, cache, pos,
                                                    dtype=dtype,
                                                    global_window=gw)
            nxt = _sample(logits[:, 0], key, self.temperature)
            return nxt.astype(jnp.int32), cache

        self._prefill_ragged = jax.jit(prefill_ragged)
        self._prefill_exact = jax.jit(prefill_exact)
        self._decode = jax.jit(decode, donate_argnums=(1,) if donate else ())
        self._sample_first = jax.jit(
            lambda logits, key: _sample(logits, key, self.temperature
                                        ).astype(jnp.int32))
        self.metrics: Dict[str, Any] = {
            "warmup_s": 0.0,
            "prefill_latency_s": [],  # per prefill micro-batch
            "prefill_prompt_tokens": 0,
            "decode_steps": 0,
            "decode_tokens": 0,  # decode-ISSUED tokens only (no prefill token)
            "decode_step_s": [],  # (wall seconds, active slots) per step
            "admitted": 0,
            "finished": 0,
            "max_concurrent": 0,
        }

    # -- admission ---------------------------------------------------------

    def submit(self, req: Request, now: float = 0.0) -> None:
        """Queue a request. The prompt must leave room for at least one
        generated token; max_new_tokens is clamped to the slot's context
        capacity (ring windows only make attention *cheaper* than
        max_len — positions past capacity would silently wrap GLOBAL
        attention into a sliding window, so we refuse instead)."""
        L = req.prompt_len
        if L < 1:
            raise ValueError(f"request {req.rid}: empty prompt")
        if L >= self.plan.max_len:
            raise ValueError(
                f"request {req.rid}: prompt length {L} >= plan.max_len "
                f"{self.plan.max_len} — no capacity left to generate")
        req.max_new_tokens = min(req.max_new_tokens, self.plan.max_len - L)
        req.state = QUEUED
        req.queued_s = now
        self._queue.append(req)

    def _next_group(self) -> List[Request]:
        """Pick the next prefill micro-batch: FIFO up to
        min(prefill_micro, free slots); exact-length families additionally
        filter to the head request's prompt length (the head itself always
        qualifies, so no starvation)."""
        k = min(self.plan.prefill_micro, self.pool.free_count,
                len(self._queue))
        if k < 1:
            return []
        if self.plan.ragged_prefill:
            return [self._queue.popleft() for _ in range(k)]
        head_len = self._queue[0].prompt_len
        group, keep = [], []
        for r in self._queue:
            if len(group) < k and r.prompt_len == head_len:
                group.append(r)
            else:
                keep.append(r)
        self._queue = collections.deque(keep)
        return group

    def _bucket_len(self, prompt_len: int) -> int:
        if not self.plan.ragged_prefill:
            return prompt_len  # exact-length group: no padding at all
        b = self.pad_multiple * math.ceil(prompt_len / self.pad_multiple)
        return min(b, self.plan.max_len - 1)

    def _fold_key(self):
        k = jax.random.fold_in(self._key, self._step_idx)
        self._step_idx += 1
        return k

    def _prefill_group(self, group: List[Request], now: float) -> float:
        """PREFILL: batch the group (padded to the full prefill_micro rows
        so bucket count, not queue state, bounds compile count), sample
        each row's first token, scatter cache rows into allocated slots."""
        m = self.plan.prefill_micro
        for r in group:
            r.state = PREFILL
        if self.plan.ragged_prefill:
            bucket = self._bucket_len(max(r.prompt_len for r in group))
            toks = np.zeros((m, bucket), np.int32)
            lengths = np.ones((m,), np.int32)
            for i, r in enumerate(group):
                toks[i, :r.prompt_len] = r.prompt
                lengths[i] = r.prompt_len
            t0 = time.perf_counter()
            logits, cache = self._prefill_ragged(self.params, toks, lengths)
        else:
            bucket = group[0].prompt_len
            toks = np.zeros((m, bucket), np.int32)
            for i, r in enumerate(group):
                toks[i] = r.prompt
            t0 = time.perf_counter()
            logits, cache = self._prefill_exact(self.params, toks)
        first = np.asarray(self._sample_first(logits, self._fold_key()))
        dt = time.perf_counter() - t0
        t_tok = now + dt
        for i, r in enumerate(group):
            slot = self.pool.alloc()
            self.pool.insert(cache, i, slot)
            r.slot = slot
            r.tokens.append(int(first[i]))
            r.first_token_s = t_tok
            r.state = DECODE
            self._tok[slot, 0] = first[i]
            self._pos[slot] = r.prompt_len
            self._by_slot[slot] = r
            self.metrics["admitted"] += 1
            self.metrics["prefill_prompt_tokens"] += r.prompt_len
            if len(r.tokens) >= r.max_new_tokens:
                self._finish(r, t_tok)
        self.metrics["prefill_latency_s"].append(dt)
        self.metrics["max_concurrent"] = max(self.metrics["max_concurrent"],
                                             len(self._by_slot))
        return dt

    # -- decode ------------------------------------------------------------

    def _decode_once(self, now: float) -> float:
        """One continuous-batching step over the whole pool. Only tokens
        for ACTIVE slots are counted/recorded — the satellite bugfix: the
        prefill-produced token is never in this count, and inactive slots'
        garbage lanes are dropped on the host."""
        t0 = time.perf_counter()
        nxt, self.pool.cache = self._decode(self.params, self.pool.cache,
                                            self._tok, self._pos,
                                            self._fold_key())
        nxt_np = np.asarray(nxt)  # device sync: the per-step latency fence
        dt = time.perf_counter() - t0
        t_tok = now + dt
        active = list(self._by_slot.items())
        for slot, r in active:
            tok = int(nxt_np[slot])
            r.tokens.append(tok)
            self._tok[slot, 0] = tok
            self._pos[slot] += 1
            if len(r.tokens) >= r.max_new_tokens:
                self._finish(r, t_tok)
        self.metrics["decode_steps"] += 1
        self.metrics["decode_tokens"] += len(active)
        self.metrics["decode_step_s"].append((dt, len(active)))
        return dt

    def _finish(self, req: Request, now: float) -> None:
        """FINISHED: evict — the slot returns to the free list and is
        immediately reusable (the next admission overwrites the row)."""
        req.state = FINISHED
        req.finish_s = now
        self.pool.free(req.slot)
        self._by_slot.pop(req.slot, None)
        self.metrics["finished"] += 1

    # -- loop --------------------------------------------------------------

    def warmup(self, prompt_lens: Sequence[int] = ()) -> float:
        """Compile the decode step and the prefill bucket(s) BEFORE the
        clock starts — the satellite bugfix for the old launcher, which
        started t0 ahead of both jit compiles and sold compile time as
        decode throughput. Garbage written into the empty pool is
        harmless: admission overwrites whole slot rows."""
        if self._by_slot:
            raise RuntimeError("warmup() must run before traffic is admitted")
        t0 = time.perf_counter()
        nxt, cache = self._decode(self.params, self.pool.cache, self._tok,
                                  self._pos, self._fold_key())
        jax.block_until_ready(nxt)
        self.pool.cache = cache
        m = self.plan.prefill_micro
        for bucket in sorted({self._bucket_len(L) for L in prompt_lens}):
            toks = np.zeros((m, bucket), np.int32)
            if self.plan.ragged_prefill:
                out = self._prefill_ragged(self.params, toks,
                                           np.ones((m,), np.int32))
            else:
                out = self._prefill_exact(self.params, toks)
            jax.block_until_ready(out[0])  # cache discarded, never inserted
        dt = time.perf_counter() - t0
        self.metrics["warmup_s"] += dt
        return dt

    def run(self, requests: Iterable[Request], *, warmup: bool = True,
            warmup_prompt_lens: Sequence[int] = ()) -> Dict[str, Any]:
        """Drive the full lifecycle over a request stream (an iterable
        ordered by ``arrival_s``). Per loop turn: admit due arrivals, run
        at most one prefill micro-batch if slots are free, then one decode
        step over the pool — so prefill of new requests interleaves with
        decode of admitted ones (continuous batching, not static waves)."""
        it: Iterator[Request] = iter(requests)
        pending = next(it, None)
        if warmup:
            lens = list(warmup_prompt_lens)
            if not lens and pending is not None:
                lens = [pending.prompt_len]
            self.warmup(lens)
        t0 = time.perf_counter()
        while pending is not None or self._queue or self._by_slot:
            now = time.perf_counter() - t0
            while pending is not None and pending.arrival_s <= now:
                self.submit(pending, now)
                pending = next(it, None)
            progressed = False
            group = self._next_group()
            if group:
                now += self._prefill_group(group, now)
                progressed = True
            if self._by_slot:
                self._decode_once(now)
                progressed = True
            if not progressed and pending is not None:
                time.sleep(min(max(pending.arrival_s - now, 0.0), 0.002))
        return self.report()

    # -- reporting ---------------------------------------------------------

    def report(self) -> Dict[str, Any]:
        """Aggregate metrics. Decode throughput is decode-issued tokens
        over decode wall time only (no prefill, no compile); ITL weights
        each step's latency by the tokens it produced."""
        m = self.metrics
        decode_time = sum(dt for dt, _ in m["decode_step_s"])
        itl = np.repeat([dt for dt, _ in m["decode_step_s"]],
                        [n for _, n in m["decode_step_s"]])
        occupancy = _percentiles([n for _, n in m["decode_step_s"]])
        return {
            "warmup_s": m["warmup_s"],
            "requests": {"admitted": m["admitted"], "finished": m["finished"]},
            "prefill": {
                "batches": len(m["prefill_latency_s"]),
                "prompt_tokens": m["prefill_prompt_tokens"],
                "latency_s": _percentiles(m["prefill_latency_s"]),
            },
            "decode": {
                "steps": m["decode_steps"],
                "tokens": m["decode_tokens"],
                "time_s": decode_time,
                "tokens_per_s": (m["decode_tokens"] / decode_time
                                 if decode_time else 0.0),
                "itl_s": _percentiles(itl),
            },
            "slots": {
                "planned": self.plan.max_decode_slots,
                "max_concurrent": m["max_concurrent"],
                "mean_active_per_step": occupancy["mean"],
            },
            "ttft_s": _percentiles([]),  # populated by finished_report
        }

    def finished_report(self, requests: Sequence[Request]) -> Dict[str, Any]:
        """report() plus TTFT percentiles over a finished request list."""
        rep = self.report()
        ttfts = [r.first_token_s - r.arrival_s for r in requests
                 if r.first_token_s is not None]
        rep["ttft_s"] = _percentiles(ttfts)
        return rep


def synthetic_traffic(n_requests: int, *, rate_rps: float,
                      prompt_lens: Sequence[int], new_tokens: Sequence[int],
                      vocab_size: int, seed: int = 0) -> Iterator[Request]:
    """Synthetic heavy-traffic stream: Poisson arrivals (exponential
    inter-arrival gaps at ``rate_rps`` requests/s) with prompt lengths and
    output budgets drawn uniformly from the given mixes. A generator so
    the launcher can stage it through ``core.streaming.prefetch_iterator``
    and overlap prompt synthesis with the serve loop."""
    rng = np.random.default_rng(seed)
    t = 0.0
    for rid in range(n_requests):
        t += float(rng.exponential(1.0 / rate_rps))
        L = int(rng.choice(prompt_lens))
        yield Request(
            rid=rid,
            prompt=rng.integers(0, vocab_size, (L,), dtype=np.int32),
            max_new_tokens=int(rng.choice(new_tokens)),
            arrival_s=t)
