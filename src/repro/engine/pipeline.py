"""Plan-aware async input pipeline (paper §3.1, Fig. 1).

The paper's point is that micro-batch *transfer* must overlap *compute*.
On the JAX/TPU stack that overlap happens at two granularities:

  * host work (dataset batch synthesis + the plan's pad-and-mask split,
    Fig. 2 step ❶) runs in a background thread via
    ``core.streaming.prefetch_iterator`` — worker exceptions propagate to
    the consumer instead of truncating the epoch;
  * host→device staging is an async ``jax.device_put`` (with the
    launcher's batch shardings when given), double-buffered at mini-batch
    granularity: batch i+1's transfer is issued before batch i is yielded
    to the step, so it lands while the step computes.

The :class:`Pipeline` also measures how long the consumer was blocked
waiting on input (``stats.input_wait_fraction``), which is the number the
``BENCH_pipeline`` benchmark records — an input-bound step loop shows up
here, not as mysteriously slow device time.
"""
from __future__ import annotations

import dataclasses
import random as _random
import time
from typing import Any, Dict, Iterator, Optional

import jax

from ..core.streaming import prefetch_iterator
from . import faults
from .plan import MBSPlan


@dataclasses.dataclass
class PipelineStats:
    """Input-side timing of one ``batches()`` pass."""
    batches: int = 0
    wait_s: float = 0.0  # consumer time blocked on host data / staging
    elapsed_s: float = 0.0  # total wall time of the pass
    retries: int = 0  # transient producer failures absorbed by backoff

    @property
    def input_wait_fraction(self) -> float:
        return self.wait_s / self.elapsed_s if self.elapsed_s > 0 else 0.0


class Pipeline:
    """Dataset → pre-split ``(N_Sμ, N_μ, ...)`` batches → device.

    ``sharding`` controls staging:
      * ``None`` — plain ``jax.device_put`` to the default device;
      * a ``jax.sharding.Sharding`` / device — applied to every leaf;
      * a callable ``(split_batch) -> sharding pytree`` — resolved once on
        the first batch (how the launcher passes its mesh batch specs
        without the engine importing the launch layer);
      * with ``stage=False`` no device placement happens at all and the
        pipeline yields host numpy batches (the ``MBSLoader`` facade).

    ``mesh`` is a convenience for the common Layer-6 case: stage every
    split batch with the mesh's batch shardings (``launch/sharding
    .batch_specs`` — dim 0 is the scan axis, the sample dim shards over
    the (pod, data) axes), so the sharded step never reshards its input.
    Mutually exclusive with ``sharding``.

    Batch ``i`` of a pass started at ``start`` is always drawn with seed
    ``seed + start + i``, so a resumed run consumes exactly the stream an
    uninterrupted run would have seen.

    Transient producer failures (the ``faults`` taxonomy's
    ``TransientError`` plus plain ``OSError``) get ``retries`` bounded
    retries with seeded jittered backoff before the existing fail-fast
    propagation; absorbed retries are counted in ``stats.retries`` next to
    ``input_wait_fraction``. The retry re-draws the SAME seeded batch, so
    an absorbed fault never perturbs the data stream.
    """

    def __init__(self, dataset, plan: MBSPlan, *, prefetch: int = 2,
                 stage: bool = True, sharding: Any = None, seed: int = 0,
                 batch_kw: Optional[Dict[str, Any]] = None, mesh: Any = None,
                 retries: int = 2, retry_backoff_s: float = 0.01):
        self.dataset = dataset
        self.plan = plan
        self.prefetch = prefetch
        self.stage = stage
        self.seed = seed
        self.retries = retries
        self.retry_backoff_s = retry_backoff_s
        self.batch_kw = dict(batch_kw or {})
        if mesh is not None:
            if sharding is not None:
                raise ValueError("pass either mesh= or sharding=, not both")

            def sharding(split, _mesh=mesh):
                from ..launch import sharding as sharding_lib  # no cycle
                return sharding_lib.named(
                    sharding_lib.batch_specs(split, _mesh), _mesh)
        self._sharding = sharding
        self._resolved_sharding = None if callable(sharding) else sharding
        self.stats = PipelineStats()

    # -- staging ------------------------------------------------------------

    def _put(self, split):
        if not self.stage:
            return split
        if self._resolved_sharding is None and callable(self._sharding):
            self._resolved_sharding = self._sharding(split)
        if self._resolved_sharding is None:
            return jax.device_put(split)
        return jax.device_put(split, self._resolved_sharding)

    def rebatch(self, step: int):
        """Synthesize, split and stage global step ``step``'s batch again —
        byte-identical to what ``batches()`` would have yielded for it
        (step-indexed seeding), but WITHOUT the fault-injection hooks: this
        is the supervisor's NaN bounded-retry path, re-drawing a poisoned
        batch after the executors' donation already consumed the original
        buffers."""
        mini = self.dataset.batch(self.plan.mini_batch_size,
                                  self.seed + step, **self.batch_kw)
        return self._put(self.plan.split(mini))

    # -- iteration ----------------------------------------------------------

    def batches(self, num_batches: int, start: int = 0
                ) -> Iterator[Dict[str, Any]]:
        """Yield ``num_batches`` staged split batches for global steps
        ``start .. start + num_batches``. Resets ``self.stats``."""
        self.stats = stats = PipelineStats()

        def host_gen():
            rng = _random.Random(self.seed ^ 0x5EED)  # jitter only, not data
            for i in range(start, start + num_batches):
                for attempt in range(self.retries + 1):
                    try:
                        faults.on_host_batch(i)
                        mini = self.dataset.batch(self.plan.mini_batch_size,
                                                  self.seed + i,
                                                  **self.batch_kw)
                        split = self.plan.split(mini)
                        break
                    except (faults.TransientError, OSError):
                        if attempt >= self.retries:
                            raise  # bounded: fail fast like before
                        stats.retries += 1
                        time.sleep(self.retry_backoff_s
                                   * (1 + rng.random()) * (2 ** attempt))
                yield faults.corrupt_batch(split, i)

        it = (prefetch_iterator(host_gen(), self.prefetch)
              if self.prefetch else host_gen())

        def run():
            t_begin = time.perf_counter()
            try:
                nxt = self._next_staged(it, stats)
                while nxt is not _DONE:
                    cur, nxt = nxt, self._next_staged(it, stats)
                    stats.batches += 1
                    yield cur
            finally:
                stats.elapsed_s = time.perf_counter() - t_begin

        return run()

    __call__ = batches  # loader-style invocation

    def _next_staged(self, it, stats: PipelineStats):
        """Pull + stage the next batch, charging the blocked time to
        ``stats.wait_s``. The device_put returns immediately (async
        transfer) — by staging batch i+1 before yielding batch i we get
        the double buffer."""
        t0 = time.perf_counter()
        try:
            staged = self._put(next(it))
        except StopIteration:
            return _DONE
        finally:
            stats.wait_s += time.perf_counter() - t0
        return staged


_DONE = object()
