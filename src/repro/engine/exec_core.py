"""Shared normalization / accumulation / update core.

Every executor (compiled scan, eager streaming, Pallas-fused) expresses the
paper's Algorithm 1 through these helpers, so the numerics live in exactly
one place:

  * loss normalization (§3.4, eq. 14): either folded into the micro loss
    before differentiation ("scaled" form — loss/N_Sμ for "paper",
    Σ/N_B_valid for "exact"), or deferred to the accumulate ("raw" form —
    the gradient of the unscaled micro loss is accumulated with the scale
    fused in, paper Fig. 2 step ❹, which is what the Pallas kernel does);
  * gradient accumulation in ``accum_dtype`` (fp32 by default, even when
    micro gradients arrive in bf16);
  * the single optimizer update per mini-batch (step ❺) + shared metrics.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.grad_accum import grad_accum_tree


def denominators(micro_batches) -> Tuple[int, jnp.ndarray]:
    """(N_Sμ, N_B_valid) of a split batch. N_B_valid is the total sample
    weight when a mask is present — padded tail samples contribute 0 and
    dataset-provided fractional weights contribute their weight (the split
    composes mask × weights, see ``plan.split_minibatch``), so exact-mode
    normalization is the weighted mini-batch mean. Else N_Sμ · N_μ."""
    leaves = jax.tree.leaves(micro_batches)
    n_s = leaves[0].shape[0]
    w = micro_batches.get("sample_weight") if hasattr(micro_batches, "get") else None
    total_valid = (jnp.sum(w) if w is not None
                   else jnp.asarray(float(n_s) * leaves[0].shape[1]))
    return n_s, total_valid


def init_accum(params, dtype):
    """Zero gradient accumulator, shaped like params, in ``accum_dtype``."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, dtype), params)


def micro_loss_fn(loss_fn: Callable, normalization: str, n_s, total_valid,
                  mb, *, defer_scale: bool = False) -> Callable:
    """The per-micro-batch loss to differentiate.

    Exact-mode contract for ``loss_fn``: with ``exact_denom`` set, micro
    contributions must SUM to the mini-batch loss — per-sample losses are
    divided by ``exact_denom``, and any additive (non-per-sample)
    regularizer must carry the micro-batch's valid-sample share
    ``n_valid/exact_denom`` (see ``launch/steps.make_loss_fn``'s MoE
    router aux term). Otherwise executors would weight it inconsistently.

    ``defer_scale=False``: normalization folded in (Algorithm 1 line 11 for
    "paper"; exact denominator for "exact") — the gradient is accumulated
    with a plain add.

    ``defer_scale=True``: the raw micro loss ("paper": micro mean; "exact":
    Σ valid per-sample losses) — the 1/N_Sμ (resp. 1/N_B_valid) scale is
    applied later, fused into the accumulate (see :func:`deferred_scale`).
    """
    def f(p):
        if normalization == "paper":
            loss, metrics = loss_fn(p, mb)
            return (loss, metrics) if defer_scale else (loss / n_s, metrics)
        if normalization != "exact":
            raise ValueError(f"unknown normalization {normalization!r}")
        denom = 1.0 if defer_scale else total_valid
        loss, metrics = loss_fn(p, mb, exact_denom=denom)
        return loss, metrics
    return f


def deferred_scale(normalization: str, n_s, total_valid):
    """The scale fused into the accumulate when the micro loss was raw."""
    if normalization == "paper":
        return 1.0 / n_s
    return 1.0 / total_valid


def accumulate(acc, grads, *, scale=None, fused: bool = False,
               interpret: Optional[bool] = None, block: Optional[int] = None):
    """acc ← acc + [scale ·] grads, in the accumulator's dtype.

    ``fused=True`` routes through the Pallas kernel
    (``kernels/grad_accum.py``): scaled accumulate with in-place aliasing on
    the fp32 buffer, so the scaled gradient is never materialized."""
    if fused:
        kw = {"interpret": interpret}
        if block is not None:
            kw["block"] = block
        return grad_accum_tree(acc, grads, 1.0 if scale is None else scale, **kw)
    if scale is None:
        return jax.tree.map(lambda a, g: a + g.astype(a.dtype), acc, grads)
    return jax.tree.map(lambda a, g: a + (g * scale).astype(a.dtype), acc, grads)


def metrics_zeros(loss_fn: Callable, normalization: str, params, mb0):
    """Zero-valued metrics pytree (via eval_shape — no FLOPs) used to seed
    the accumulation carry."""
    probe = micro_loss_fn(loss_fn, normalization, 1, jnp.asarray(1.0), mb0)
    shapes = jax.eval_shape(lambda p: probe(p)[1], params)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)


def apply_update(optimizer, grads, opt_state, params):
    """Paper Fig. 2 step ❺: one optimizer update per mini-batch."""
    updates, new_opt_state = optimizer.update(grads, opt_state, params)
    new_params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                              params, updates)
    return new_params, new_opt_state


def global_grad_norm(grads) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))


def finalize_metrics(metric_sum: Dict[str, Any], loss, grads) -> Dict[str, Any]:
    out = dict(metric_sum)
    out["loss"] = loss  # Σ normalized micro losses == mini-batch mean loss
    out["grad_norm"] = global_grad_norm(grads)
    return out
