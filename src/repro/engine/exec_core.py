"""Shared normalization / accumulation / update core.

Every executor (compiled scan, eager streaming, Pallas-fused) expresses the
paper's Algorithm 1 through these helpers, so the numerics live in exactly
one place:

  * loss normalization (§3.4, eq. 14): either folded into the micro loss
    before differentiation ("scaled" form — loss/N_Sμ for "paper",
    Σ/N_B_valid for "exact"), or deferred to the accumulate ("raw" form —
    the gradient of the unscaled micro loss is accumulated with the scale
    fused in, paper Fig. 2 step ❹, which is what the Pallas kernel does);
  * gradient accumulation in ``accum_dtype`` (fp32 by default, even when
    micro gradients arrive in bf16);
  * the single optimizer update per mini-batch (step ❺) + shared metrics.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import fused_update, grad_accum_buckets, grad_accum_tree
from .flat import FlatSpec


def denominators(micro_batches) -> Tuple[int, jnp.ndarray]:
    """(N_Sμ, N_B_valid) of a split batch. N_B_valid is the total sample
    weight when a mask is present — padded tail samples contribute 0 and
    dataset-provided fractional weights contribute their weight (the split
    composes mask × weights, see ``plan.split_minibatch``), so exact-mode
    normalization is the weighted mini-batch mean. Else N_Sμ · N_μ."""
    leaves = jax.tree.leaves(micro_batches)
    n_s = leaves[0].shape[0]
    w = micro_batches.get("sample_weight") if hasattr(micro_batches, "get") else None
    total_valid = (jnp.sum(w) if w is not None
                   else jnp.asarray(n_s * leaves[0].shape[1], jnp.float32))
    return n_s, total_valid


def init_accum(params, dtype):
    """Zero gradient accumulator, shaped like params, in ``accum_dtype``."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, dtype), params)


def micro_loss_fn(loss_fn: Callable, normalization: str, n_s, total_valid,
                  mb, *, defer_scale: bool = False) -> Callable:
    """The per-micro-batch loss to differentiate.

    Exact-mode contract for ``loss_fn``: with ``exact_denom`` set, micro
    contributions must SUM to the mini-batch loss — per-sample losses are
    divided by ``exact_denom``, and any additive (non-per-sample)
    regularizer must carry the micro-batch's valid-sample share
    ``n_valid/exact_denom`` (see ``launch/steps.make_loss_fn``'s MoE
    router aux term). Otherwise executors would weight it inconsistently.

    ``defer_scale=False``: normalization folded in (Algorithm 1 line 11 for
    "paper"; exact denominator for "exact") — the gradient is accumulated
    with a plain add.

    ``defer_scale=True``: the raw micro loss ("paper": micro mean; "exact":
    Σ valid per-sample losses) — the 1/N_Sμ (resp. 1/N_B_valid) scale is
    applied later, fused into the accumulate (see :func:`deferred_scale`).
    """
    def f(p):
        if normalization == "paper":
            loss, metrics = loss_fn(p, mb)
            return (loss, metrics) if defer_scale else (loss / n_s, metrics)
        if normalization != "exact":
            raise ValueError(f"unknown normalization {normalization!r}")
        denom = 1.0 if defer_scale else total_valid
        loss, metrics = loss_fn(p, mb, exact_denom=denom)
        return loss, metrics
    return f


def deferred_scale(normalization: str, n_s, total_valid):
    """The scale fused into the accumulate when the micro loss was raw."""
    if normalization == "paper":
        return 1.0 / n_s
    return 1.0 / total_valid


def accumulate(acc, grads, *, scale=None, fused: bool = False,
               interpret: Optional[bool] = None, block: Optional[int] = None):
    """acc ← acc + [scale ·] grads, in the accumulator's dtype.

    ``fused=True`` routes through the Pallas kernel
    (``kernels/grad_accum.py``): scaled accumulate with in-place aliasing on
    the fp32 buffer, so the scaled gradient is never materialized."""
    if fused:
        kw = {"interpret": interpret}
        if block is not None:
            kw["block"] = block
        return grad_accum_tree(acc, grads, 1.0 if scale is None else scale, **kw)
    if scale is None:
        return jax.tree.map(lambda a, g: a + g.astype(a.dtype), acc, grads)
    return jax.tree.map(lambda a, g: a + (g * scale).astype(a.dtype), acc, grads)


def metrics_zeros(loss_fn: Callable, normalization: str, params, mb0):
    """Zero-valued metrics pytree (via eval_shape — no FLOPs) used to seed
    the accumulation carry."""
    probe = micro_loss_fn(loss_fn, normalization, 1, jnp.asarray(1.0), mb0)
    shapes = jax.eval_shape(lambda p: probe(p)[1], params)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)


def apply_update(optimizer, grads, opt_state, params):
    """Paper Fig. 2 step ❺: one optimizer update per mini-batch."""
    updates, new_opt_state = optimizer.update(grads, opt_state, params)
    new_params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                              params, updates)
    return new_params, new_opt_state


def accumulate_flat(acc_buffers, spec: FlatSpec, grads, *, scale=None,
                    interpret: Optional[bool] = None,
                    block: Optional[int] = None):
    """Bucketed step ❹: route a micro-batch's gradient tree into the flat
    ``accum_dtype`` buffers — one masked Pallas launch per dtype bucket
    (O(num_buckets), vs ``accumulate(fused=True)``'s O(num_leaves))."""
    gbufs = spec.flatten(grads, dtype=acc_buffers[0].dtype)
    kw = {"interpret": interpret}
    if block is not None:
        kw["block"] = block
    return grad_accum_buckets(acc_buffers, gbufs,
                              1.0 if scale is None else scale, **kw)


def apply_update_flat(optimizer, spec: FlatSpec, acc_buffers, opt_state,
                      params, *, interpret: Optional[bool] = None,
                      block: Optional[int] = None):
    """Step ❺ over flat buffers: one in-place Pallas launch per bucket.

    Reads the fp32 flat accumulator and writes params + optimizer state
    through ``kernels/fused_update.py`` (``input_output_aliases`` on every
    state buffer) — no ``updates`` tree, no fresh momentum/``m``/``v``
    trees, and the global-norm clip scale (``FusedUpdateSpec.clip_norm``)
    is computed from the flat accumulator and carried into the kernel
    instead of materializing a scaled gradient tree. Optimizers without a
    ``fused`` hook fall back to the reference tree update."""
    fs = getattr(optimizer, "fused", None)
    if fs is None:
        return apply_update(optimizer, spec.unflatten(acc_buffers, cast=False),
                            opt_state, params)
    kw = {"interpret": interpret}
    if block is not None:
        kw["block"] = block
    gscale = jnp.asarray(1.0, jnp.float32)
    if fs.clip_norm is not None:
        norm = global_grad_norm(acc_buffers)
        gscale = jnp.minimum(1.0, fs.clip_norm / (norm + 1e-12))
    step = opt_state["step"]
    lr_t = fs.schedule(step)
    flat_p = spec.flatten(params)

    if fs.kind == "sgd":
        if fs.momentum:
            flat_m = spec.flatten(opt_state["mom"])
            outs = [fused_update.fused_sgd(
                p, g, m, lr_t, gscale, momentum=fs.momentum,
                weight_decay=fs.weight_decay, nesterov=fs.nesterov, **kw)
                for p, g, m in zip(flat_p, acc_buffers, flat_m)]
            return (spec.unflatten([o[0] for o in outs]),
                    {"mom": spec.unflatten([o[1] for o in outs]),
                     "step": step + 1})
        new_p = [fused_update.fused_sgd(
            p, g, None, lr_t, gscale, weight_decay=fs.weight_decay, **kw)
            for p, g in zip(flat_p, acc_buffers)]
        return spec.unflatten(new_p), {"mom": None, "step": step + 1}

    if fs.kind == "adam":
        step1 = step + 1
        bc1 = 1 - fs.b1 ** step1.astype(jnp.float32)
        bc2 = 1 - fs.b2 ** step1.astype(jnp.float32)
        flat_m = spec.flatten(opt_state["m"])
        flat_v = spec.flatten(opt_state["v"])
        outs = [fused_update.fused_adam(
            p, g, m, v, lr_t, bc1, bc2, gscale, b1=fs.b1, b2=fs.b2,
            eps=fs.eps, weight_decay=fs.weight_decay,
            decoupled=fs.decoupled, **kw)
            for p, g, m, v in zip(flat_p, acc_buffers, flat_m, flat_v)]
        return (spec.unflatten([o[0] for o in outs]),
                {"m": spec.unflatten([o[1] for o in outs]),
                 "v": spec.unflatten([o[2] for o in outs]),
                 "step": step1})

    raise ValueError(f"unknown fused update kind {fs.kind!r}")


def global_grad_norm(grads) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))


# ---------------------------------------------------------------------------
# numeric guard (engine Layer 9)
# ---------------------------------------------------------------------------

def finite_all(grads) -> jnp.ndarray:
    """On-device scalar: True iff every element of the gradient accumulator
    is finite. Works on a params-shaped tree AND on the flat executor's
    dtype-bucketed buffer list (``jax.tree.leaves`` of a list is the list),
    so the check composes with ``FlatSpec`` — one reduction per leaf fused
    into the step, zero extra host syncs."""
    ok = jnp.asarray(True)
    for g in jax.tree.leaves(grads):
        ok = jnp.logical_and(ok, jnp.all(jnp.isfinite(g)))
    return ok


def guarded_update(optimizer, grads, opt_state, params):
    """Step ❺ behind the finite-check: if the accumulated gradient has any
    non-finite element, skip the update (params + opt state pass through
    unchanged, including the step counter — the step never happened).
    ``lax.cond`` keeps the skip branch free of update math on device.

    Returns ``(new_params, new_opt_state, ok)`` — ``ok`` is the on-device
    finite flag; readback policy (sync for supervised runs) is the
    caller's choice."""
    ok = finite_all(grads)
    new_params, new_opt_state = jax.lax.cond(
        ok,
        lambda p, s: apply_update(optimizer, grads, s, p),
        lambda p, s: (p, s),
        params, opt_state)
    return new_params, new_opt_state, ok


def guarded_update_flat(optimizer, spec: FlatSpec, acc_buffers, opt_state,
                        params, *, interpret: Optional[bool] = None,
                        block: Optional[int] = None):
    """Flat-buffer variant of :func:`guarded_update`: the finite-check runs
    directly on the dtype buckets (no unflatten), the fused Pallas update
    only on the taken branch."""
    ok = finite_all(acc_buffers)
    new_params, new_opt_state = jax.lax.cond(
        ok,
        lambda p, s: apply_update_flat(optimizer, spec, acc_buffers, s, p,
                                       interpret=interpret, block=block),
        lambda p, s: (p, s),
        params, opt_state)
    return new_params, new_opt_state, ok


def finalize_metrics(metric_sum: Dict[str, Any], loss, grads) -> Dict[str, Any]:
    out = dict(metric_sum)
    out["loss"] = loss  # Σ normalized micro losses == mini-batch mean loss
    out["grad_norm"] = global_grad_norm(grads)
    return out
