"""Fused in-place optimizer updates as Pallas TPU kernels (paper step ❺).

The unfused reference (``optim.Optimizer.update`` + ``exec_core.apply_update``)
materializes a full ``updates`` tree plus fresh momentum/``m``/``v`` trees on
top of the steady state — exactly the transient that bounds the batch size at
the update step (paper Fig. 2 steps ❹–❺ / eq. 14). These kernels read the
fp32 flat gradient accumulator and write new params + optimizer state in ONE
pass with ``input_output_aliases`` on every state buffer, so step ❺ runs with
O(block) scratch instead of O(params) transients.

Operands are the engine's dtype-bucketed 1-D flat buffers
(``engine/flat.py``): one launch per bucket, ragged tails masked by the grid
(no padded copies). The arithmetic mirrors ``optim.sgd``/``optim.adam``
cast-for-cast so the fused path is bit-equivalent to the unfused reference
for matching dtypes.

Traced scalars (learning rate, global-norm clip scale, Adam bias
corrections) arrive through a small fp32 operand broadcast to every block;
static hyperparameters (momentum, decay, betas, flags) are baked into the
kernel closure.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .grad_accum import resolve_block


def _interpret_default(interpret: Optional[bool]) -> bool:
    return jax.default_backend() != "tpu" if interpret is None else interpret


def _specs(n_bufs: int, block: int):
    """(scalars, n_bufs data operands) block specs for a 1-D launch."""
    return ([pl.BlockSpec((4,), lambda i: (0,))]
            + [pl.BlockSpec((block,), lambda i: (i,))] * n_bufs)


def _scalars(*vals) -> jnp.ndarray:
    padded = list(vals) + [0.0] * (4 - len(vals))
    return jnp.stack([jnp.asarray(v, jnp.float32) for v in padded])


# ---------------------------------------------------------------------------
# SGD (+ momentum, coupled weight decay, nesterov)
# ---------------------------------------------------------------------------

def _sgd_mom_kernel(momentum, weight_decay, nesterov,
                    s_ref, p_ref, g_ref, m_ref, p_out, m_out):
    lr, gscale = s_ref[0], s_ref[1]
    p = p_ref[...]
    g = g_ref[...] * gscale.astype(g_ref.dtype)
    if weight_decay:
        g = g + weight_decay * p.astype(g.dtype)
    m = momentum * m_ref[...] + g.astype(m_ref.dtype)
    eff = g + momentum * m if nesterov else m
    u = -lr * eff.astype(jnp.float32)
    p_out[...] = p + u.astype(p_out.dtype)
    m_out[...] = m


def _sgd_kernel(weight_decay, s_ref, p_ref, g_ref, p_out):
    lr, gscale = s_ref[0], s_ref[1]
    p = p_ref[...]
    g = g_ref[...] * gscale.astype(g_ref.dtype)
    if weight_decay:
        g = g + weight_decay * p.astype(g.dtype)
    u = -lr * g.astype(jnp.float32)
    p_out[...] = p + u.astype(p_out.dtype)


def fused_sgd(params, grads, mom, lr, clip_scale=1.0, *,
              momentum: float = 0.0, weight_decay: float = 0.0,
              nesterov: bool = False, block: Optional[int] = None,
              interpret: Optional[bool] = None):
    """One in-place SGD(-momentum) step over a flat bucket.

    params/mom: (N,) in the bucket dtype; grads: (N,) accumulator (fp32).
    Returns (new_params, new_mom) — or new_params alone when ``mom`` is
    None (momentum-less SGD has no state buffer). Both outputs alias their
    input buffers; donate the inputs at the jit boundary to realize the
    in-place update."""
    N = params.shape[0]
    interpret = _interpret_default(interpret)
    if block is None:
        block = resolve_block("fused_update", params.dtype, N, interpret)
    block = min(block, N)
    grid = (pl.cdiv(N, block),)
    scal = _scalars(lr, clip_scale)
    if mom is None:
        return pl.pallas_call(
            functools.partial(_sgd_kernel, weight_decay),
            grid=grid,
            in_specs=_specs(2, block),
            out_specs=pl.BlockSpec((block,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((N,), params.dtype),
            input_output_aliases={1: 0},
            interpret=interpret,
        )(scal, params, grads)
    return tuple(pl.pallas_call(
        functools.partial(_sgd_mom_kernel, momentum, weight_decay, nesterov),
        grid=grid,
        in_specs=_specs(3, block),
        out_specs=[pl.BlockSpec((block,), lambda i: (i,))] * 2,
        out_shape=[jax.ShapeDtypeStruct((N,), params.dtype),
                   jax.ShapeDtypeStruct((N,), mom.dtype)],
        input_output_aliases={1: 0, 3: 1},
        interpret=interpret,
    )(scal, params, grads, mom))


# ---------------------------------------------------------------------------
# Adam / AdamW
# ---------------------------------------------------------------------------

def _adam_kernel(b1, b2, eps, weight_decay, decoupled,
                 s_ref, p_ref, g_ref, m_ref, v_ref, p_out, m_out, v_out):
    lr, gscale, bc1, bc2 = s_ref[0], s_ref[1], s_ref[2], s_ref[3]
    p = p_ref[...]
    g = g_ref[...] * gscale.astype(g_ref.dtype)
    if weight_decay and not decoupled:
        g = g + weight_decay * p.astype(g.dtype)
    m = b1 * m_ref[...] + (1 - b1) * g.astype(m_ref.dtype)
    v = b2 * v_ref[...] + (1 - b2) * jnp.square(g.astype(v_ref.dtype))
    u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
    if weight_decay and decoupled:
        u = u + weight_decay * p.astype(u.dtype)
    u = -lr * u.astype(jnp.float32)
    p_out[...] = p + u.astype(p_out.dtype)
    m_out[...] = m
    v_out[...] = v


def fused_adam(params, grads, m, v, lr, bias_corr1, bias_corr2,
               clip_scale=1.0, *, b1: float = 0.9, b2: float = 0.999,
               eps: float = 1e-8, weight_decay: float = 0.0,
               decoupled: bool = False, block: Optional[int] = None,
               interpret: Optional[bool] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One in-place Adam/AdamW step over a flat bucket.

    params/m/v: (N,) bucket buffers; grads: (N,) fp32 accumulator.
    ``bias_corr{1,2}`` are the traced ``1 - beta**step`` scalars (computed
    once by the caller). Returns (new_params, new_m, new_v), all aliasing
    their input buffers."""
    N = params.shape[0]
    interpret = _interpret_default(interpret)
    if block is None:
        block = resolve_block("fused_update", params.dtype, N, interpret)
    block = min(block, N)
    return tuple(pl.pallas_call(
        functools.partial(_adam_kernel, b1, b2, eps, weight_decay, decoupled),
        grid=(pl.cdiv(N, block),),
        in_specs=_specs(4, block),
        out_specs=[pl.BlockSpec((block,), lambda i: (i,))] * 3,
        out_shape=[jax.ShapeDtypeStruct((N,), params.dtype),
                   jax.ShapeDtypeStruct((N,), m.dtype),
                   jax.ShapeDtypeStruct((N,), v.dtype)],
        input_output_aliases={1: 0, 3: 1, 4: 2},
        interpret=interpret,
    )(_scalars(lr, clip_scale, bias_corr1, bias_corr2), params, grads, m, v))
