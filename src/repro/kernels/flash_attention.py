"""Flash attention as a Pallas TPU kernel.

Blockwise online-softmax attention: the (S, S) score matrix never exists —
each (block_q × block_k) tile of scores lives in VMEM, with running max /
sum / output accumulators carried across the k-block grid steps (the TPU
grid is executed sequentially over the last axis, so VMEM scratch persists
between them). Supports causal masking, sliding windows (fully-masked k
blocks are skipped — O(S·W) work for local layers), tanh soft-capping and
GQA via the k/v index maps.

Tiles default to 128×128: MXU-aligned on both matmul dims.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  softcap: Optional[float], block_q: int, block_k: int,
                  num_k_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rows = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    cols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    # block-level skip: fully-masked tiles do no work
    live = True
    if causal:
        live = (ik * block_k) <= (iq * block_q + block_q - 1)
    if window is not None:
        live = jnp.logical_and(
            live, (ik * block_k + block_k - 1) > (iq * block_q - window))

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        ok = jnp.ones((block_q, block_k), bool)
        if causal:
            ok &= cols <= rows
        if window is not None:
            ok &= cols > rows - window
        s = jnp.where(ok, s, _NEG_INF)

        m_prev = m_ref[...]  # (bq,)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(ok, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_cur

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: Optional[int] = None,
                    block_k: Optional[int] = None,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """q: (B, H, S, hd); k, v: (B, Hkv, S, hd). Returns (B, H, S, hd).
    ``block_q``/``block_k`` default to the tuning cache's winner when one
    exists (``engine.autotune``), else the fixed 128x128 tiles; tile shape
    is value-identical (padded keys are masked)."""
    from .grad_accum import lookup_tuned_block
    B, H, S, hd = q.shape
    Hkv = k.shape[1]
    assert H % Hkv == 0, (H, Hkv)
    G = H // Hkv
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if block_q is None:
        block_q = (lookup_tuned_block("flash_q", q.dtype, S, interpret)
                   or DEFAULT_BLOCK_Q)
    if block_k is None:
        block_k = (lookup_tuned_block("flash_k", q.dtype, S, interpret)
                   or DEFAULT_BLOCK_K)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    pad = (-S) % block_q
    pad_k = (-S) % block_k
    if pad or pad_k:  # pad to tile multiples; padded keys are masked out
        return _padded_call(q, k, v, causal=causal, window=window,
                            softcap=softcap, block_q=block_q,
                            block_k=block_k, interpret=interpret)
    nq, nk = S // block_q, S // block_k
    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(hd), causal=causal,
        window=window, softcap=softcap, block_q=block_q, block_k=block_k,
        num_k_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),     # running max
            pltpu.VMEM((block_q,), jnp.float32),     # running sum
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)


def _padded_call(q, k, v, *, causal, window, softcap, block_q, block_k,
                 interpret):
    B, H, S, hd = q.shape
    bs = block_q * block_k // math.gcd(block_q, block_k)
    S_pad = -(-S // bs) * bs
    padw = ((0, 0), (0, 0), (0, S_pad - S), (0, 0))
    # explicit ragged fallback (block sizes otherwise divide S) — the
    # padded copy is the documented cost of odd sequence lengths
    qp, kp, vp = (jnp.pad(x, padw) for x in (q, k, v))  # repro: noqa(LINT002)
    # padded queries produce garbage rows we slice off; padded keys are
    # always masked for causal rows < S. For non-causal, widen the window
    # mask to exclude them explicitly via causal=True on padding? Keep
    # causal-only support for padding (asserted).
    assert causal, "padding path supports causal attention only"
    out = flash_attention(qp, kp, vp, causal=causal, window=window,
                          softcap=softcap, block_q=block_q, block_k=block_k,
                          interpret=interpret)
    return out[:, :, :S]
