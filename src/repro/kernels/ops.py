"""Jit'd public wrappers around the Pallas kernels, with custom VJPs.

The forward pass runs the Pallas kernel; the backward pass recomputes
through the pure-jnp oracle (``ref.py``) under ``jax.vjp`` — standard
recompute-form backward, numerically identical to differentiating the
reference (tested in tests/test_kernels.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import cross_entropy as ce_kernel
from . import flash_attention as fa_kernel
from . import grad_accum as ga_kernel
from . import ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None):
    """q: (B, H, S, hd); k, v: (B, Hkv, S, hd) → (B, H, S, hd)."""
    return fa_kernel.flash_attention(q, k, v, causal=causal, window=window,
                                     softcap=softcap)


def _fa_fwd(q, k, v, causal, window, softcap):
    out = fa_kernel.flash_attention(q, k, v, causal=causal, window=window,
                                    softcap=softcap)
    return out, (q, k, v)


def _fa_bwd(causal, window, softcap, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.attention_ref(q_, k_, v_, causal=causal,
                                             window=window, softcap=softcap),
        q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# ---------------------------------------------------------------------------
# fused cross-entropy (with MBS normalization scale)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def fused_cross_entropy(logits, labels, scale: float = 1.0):
    """Per-token scaled NLL: (T, V), (T,) → (T,) fp32."""
    return ce_kernel.cross_entropy(logits, labels, scale=scale)


def _ce_fwd(logits, labels, scale):
    return ce_kernel.cross_entropy(logits, labels, scale=scale), (logits, labels)


def _ce_bwd(scale, res, g):
    logits, labels = res
    # d/dlogits [scale * (lse - gold)] = scale * (softmax - onehot)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    d = (probs - onehot) * (g[:, None] * scale)
    return d.astype(logits.dtype), None


fused_cross_entropy.defvjp(_ce_fwd, _ce_bwd)


# ---------------------------------------------------------------------------
# fused normalized grad accumulate
# ---------------------------------------------------------------------------

def grad_accum(acc, grad, scale):
    return ga_kernel.grad_accum(acc, grad, scale)


def grad_accum_tree(acc_tree, grad_tree, scale):
    return ga_kernel.grad_accum_tree(acc_tree, grad_tree, scale)
