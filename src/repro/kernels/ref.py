"""Pure-jnp oracles for every Pallas kernel (the ground truth for the
shape/dtype sweep tests and the recompute path of the custom VJPs)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None,
                  softcap: Optional[float] = None) -> jnp.ndarray:
    """q: (B, H, S, hd); k, v: (B, Hkv, S, hd) with H % Hkv == 0.
    Returns (B, H, S, hd) in q.dtype; math in fp32."""
    B, H, S, hd = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, S, hd)
    logits = jnp.einsum("bkgsd,bktd->bkgst", qf, k.astype(jnp.float32))
    logits = logits / math.sqrt(hd)
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    rows = jnp.arange(S)[:, None]
    cols = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= cols <= rows
    if window is not None:
        ok &= cols > rows - window
    logits = jnp.where(ok, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", probs, v.astype(jnp.float32))
    return out.reshape(B, H, S, hd).astype(q.dtype)


def cross_entropy_ref(logits, labels) -> jnp.ndarray:
    """logits: (T, V); labels: (T,) int32. Returns per-token NLL (T,) fp32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return lse - gold


def grad_accum_ref(acc, grad, scale) -> jnp.ndarray:
    """Paper step ❹ with eq. (14) normalization: acc + scale * grad,
    accumulating in acc's dtype (fp32)."""
    return acc + grad.astype(acc.dtype) * jnp.asarray(scale, acc.dtype)


def fused_sgd_ref(p, g, m, lr, clip_scale=1.0, *, momentum: float = 0.0,
                  weight_decay: float = 0.0, nesterov: bool = False):
    """Oracle for ``fused_update.fused_sgd``: the exact arithmetic of
    ``optim.sgd``'s update + ``exec_core.apply_update``, expressed as one
    pass over flat buffers. Returns (new_p, new_m) — new_m is None when
    ``m`` is None (momentum-less)."""
    lr = jnp.asarray(lr, jnp.float32)
    g = g * jnp.asarray(clip_scale, jnp.float32).astype(g.dtype)
    if weight_decay:
        g = g + weight_decay * p.astype(g.dtype)
    if m is not None:
        m = momentum * m + g.astype(m.dtype)
        eff = g + momentum * m if nesterov else m
    else:
        eff = g
    u = -lr * eff.astype(jnp.float32)
    return p + u.astype(p.dtype), m


def fused_adam_ref(p, g, m, v, lr, bias_corr1, bias_corr2, clip_scale=1.0, *,
                   b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                   weight_decay: float = 0.0, decoupled: bool = False):
    """Oracle for ``fused_update.fused_adam`` (``optim.adam``'s arithmetic
    as one flat pass). Returns (new_p, new_m, new_v)."""
    lr = jnp.asarray(lr, jnp.float32)
    g = g * jnp.asarray(clip_scale, jnp.float32).astype(g.dtype)
    if weight_decay and not decoupled:
        g = g + weight_decay * p.astype(g.dtype)
    m = b1 * m + (1 - b1) * g.astype(m.dtype)
    v = b2 * v + (1 - b2) * jnp.square(g.astype(v.dtype))
    u = (m / bias_corr1) / (jnp.sqrt(v / bias_corr2) + eps)
    if weight_decay and decoupled:
        u = u + weight_decay * p.astype(u.dtype)
    u = -lr * u.astype(jnp.float32)
    return p + u.astype(p.dtype), m, v
