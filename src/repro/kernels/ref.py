"""Pure-jnp oracles for every Pallas kernel (the ground truth for the
shape/dtype sweep tests and the recompute path of the custom VJPs)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None,
                  softcap: Optional[float] = None) -> jnp.ndarray:
    """q: (B, H, S, hd); k, v: (B, Hkv, S, hd) with H % Hkv == 0.
    Returns (B, H, S, hd) in q.dtype; math in fp32."""
    B, H, S, hd = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, S, hd)
    logits = jnp.einsum("bkgsd,bktd->bkgst", qf, k.astype(jnp.float32))
    logits = logits / math.sqrt(hd)
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    rows = jnp.arange(S)[:, None]
    cols = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= cols <= rows
    if window is not None:
        ok &= cols > rows - window
    logits = jnp.where(ok, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", probs, v.astype(jnp.float32))
    return out.reshape(B, H, S, hd).astype(q.dtype)


def cross_entropy_ref(logits, labels) -> jnp.ndarray:
    """logits: (T, V); labels: (T,) int32. Returns per-token NLL (T,) fp32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return lse - gold


def grad_accum_ref(acc, grad, scale) -> jnp.ndarray:
    """Paper step ❹ with eq. (14) normalization: acc + scale * grad,
    accumulating in acc's dtype (fp32)."""
    return acc + grad.astype(acc.dtype) * jnp.asarray(scale, acc.dtype)
