"""Fused cross-entropy as a Pallas TPU kernel.

For 256k-class vocabularies the logits row is the single largest activation
in the training step — exactly the memory pressure the paper targets. The
kernel streams the vocab dimension through VMEM in blocks, maintaining an
online logsumexp and extracting the gold logit on the fly, so the full
(T, V) fp32 logits tile never needs to be resident per-row more than one
block at a time; the loss epilogue also applies the MBS loss-normalization
factor (paper eq. 14) for free.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_T = 256
DEFAULT_BLOCK_V = 2048
_NEG_INF = -1e30


def _ce_kernel(logits_ref, labels_ref, out_ref, m_ref, l_ref, g_ref, *,
               block_t: int, block_v: int, num_v_blocks: int,
               vocab_size: int, scale: float):
    iv = pl.program_id(1)

    @pl.when(iv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        g_ref[...] = jnp.zeros_like(g_ref)

    x = logits_ref[...].astype(jnp.float32)  # (bt, bv)
    cols = iv * block_v + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_t, block_v), 1)
    valid = cols < vocab_size  # mask padded vocab tail
    x = jnp.where(valid, x, _NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(x, axis=-1))
    l_ref[...] = (l_ref[...] * jnp.exp(m_prev - m_cur)
                  + jnp.sum(jnp.where(valid, jnp.exp(x - m_cur[:, None]), 0.0),
                            axis=-1))
    m_ref[...] = m_cur

    labels = labels_ref[...]  # (bt,)
    hit = cols == labels[:, None]
    g_ref[...] = g_ref[...] + jnp.sum(jnp.where(hit, x, 0.0), axis=-1)

    @pl.when(iv == num_v_blocks - 1)
    def _finalize():
        lse = m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))
        out_ref[...] = ((lse - g_ref[...]) * scale).astype(out_ref.dtype)


def cross_entropy(logits, labels, *, scale: float = 1.0,
                  block_t: Optional[int] = None,
                  block_v: Optional[int] = None,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """logits: (T, V); labels: (T,) int32 → per-token NLL (T,) fp32,
    multiplied by ``scale`` (the 1/N_Sμ MBS normalization).
    ``block_t``/``block_v`` default to the tuning cache's winner (when
    ``engine.autotune`` installed a resolver and an entry exists) or the
    fixed defaults; any tile shape is value-identical (padded columns are
    masked)."""
    from .grad_accum import lookup_tuned_block
    T, V = logits.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if block_t is None:
        block_t = (lookup_tuned_block("cross_entropy_t", logits.dtype, T,
                                      interpret) or DEFAULT_BLOCK_T)
    if block_v is None:
        block_v = (lookup_tuned_block("cross_entropy_v", logits.dtype, V,
                                      interpret) or DEFAULT_BLOCK_V)
    block_t = min(block_t, T)
    block_v = min(block_v, V)
    pad_t = (-T) % block_t
    pad_v = (-V) % block_v
    if pad_t or pad_v:
        # ragged fallback only — tuned block sizes divide T/V, so the hot
        # path never copies; in-kernel masking handles the vocab tail
        logits = jnp.pad(logits, ((0, pad_t), (0, pad_v)))  # repro: noqa(LINT002)
        labels = jnp.pad(labels, (0, pad_t))  # repro: noqa(LINT002)
    Tp, Vp = logits.shape
    grid = (Tp // block_t, Vp // block_v)
    kernel = functools.partial(
        _ce_kernel, block_t=block_t, block_v=block_v,
        num_v_blocks=grid[1], vocab_size=V, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, block_v), lambda it, iv: (it, iv)),
            pl.BlockSpec((block_t,), lambda it, iv: (it,)),
        ],
        out_specs=pl.BlockSpec((block_t,), lambda it, iv: (it,)),
        out_shape=jax.ShapeDtypeStruct((Tp,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_t,), jnp.float32),  # running max
            pltpu.VMEM((block_t,), jnp.float32),  # running sum
            pltpu.VMEM((block_t,), jnp.float32),  # gold logit
        ],
        interpret=interpret,
    )(logits, labels)
    return out[:T]
