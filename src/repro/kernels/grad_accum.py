"""Fused normalized gradient accumulation as a Pallas TPU kernel.

Paper Fig. 2 step ❹ + eq. (14): ``acc ← acc + grad · (1/N_Sμ)``, fusing the
loss-normalization scale into the accumulate so the scaled gradient is never
materialized, with in-place aliasing on the fp32 accumulator (the gradient
may arrive in bf16).

Ragged tails are handled by the grid, not by padding: the launch covers
``ceil(N / block)`` blocks and Pallas masks the final partial block
(out-of-bounds lanes are dropped on store), so no ``jnp.pad`` copy of
either operand is ever materialized. ``grad_accum_buckets`` applies the
same kernel to the engine's dtype-bucketed flat buffers — one launch per
bucket instead of one per parameter leaf (see ``engine/flat.py``).
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Legacy fixed defaults, kept importable for explicit-block callers. New
# code should pass ``block=None`` and let :func:`default_block` (or the
# tuning cache, via the installed resolver) size the launch — the fixed
# 65536 bucket block measured 8x SLOWER than per-leaf on a 96-leaf /
# 2M-element bucket in interpret mode (BENCH_update.json), because the
# interpreter pays O(N) per grid step for the aliased buffer.
DEFAULT_BLOCK = 4096
BUCKET_BLOCK = 65536

# size-aware heuristic knobs (see :func:`default_block`)
MIN_BLOCK = 1 << 10  # grid-machinery floor: tiny blocks are pure overhead
MAX_BLOCK = 1 << 18  # 256k elems = 1 MB fp32/operand — 3 operands fit VMEM
NUM_PROGRAMS_MIN = 4  # enough grid steps for the Pallas pipeline to overlap


def _pow2_floor(n: int) -> int:
    return 1 << (max(int(n), 1).bit_length() - 1)


def default_block(n: int, *, interpret: Optional[bool] = None) -> int:
    """Size-aware 1-D launch block for an ``n``-element buffer.

    * **interpret mode** (any non-TPU backend): the interpreter pays O(N)
      per grid step for an aliased full-buffer operand, so the cost of a
      launch is ~``grid * N`` — one full-width program (``block = n``,
      grid 1) is strictly fastest and is what made the fixed 65536 bucket
      block 8x slower than per-leaf on the 96-leaf config.
    * **TPU**: the largest power-of-two block that (a) fits comfortably in
      VMEM (``MAX_BLOCK``) and (b) leaves the grid at least
      ``NUM_PROGRAMS_MIN`` programs so the pipeline can overlap the HBM
      copies of block i+1 with the compute of block i.

    Elementwise kernels are value-identical for ANY block size — this
    choice (and the tuner's) changes speed only.
    """
    n = max(int(n), 1)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if interpret:
        return n
    target = max(MIN_BLOCK, min(MAX_BLOCK, n // NUM_PROGRAMS_MIN))
    return min(_pow2_floor(target), n)


# Tuning-cache hook (installed by ``engine/autotune.py``; kernels stay
# dependency-free). The resolver maps (kind, dtype_str, n, interpret) to a
# measured-best block, or None to defer to :func:`default_block`.
_BLOCK_RESOLVER: Optional[Callable[[str, str, int, bool], Optional[int]]] = None


def set_block_resolver(fn: Optional[Callable]) -> None:
    """Install (or clear, with None) the tuned-block lookup used whenever a
    kernel entry point is called with ``block=None``."""
    global _BLOCK_RESOLVER
    _BLOCK_RESOLVER = fn


def lookup_tuned_block(kind: str, dtype, n: int,
                       interpret: Optional[bool] = None) -> Optional[int]:
    """The tuning cache's measured-best block for this (kind, dtype, size)
    — or None when no resolver is installed / no entry exists. Used by
    kernels whose fallback default is NOT the 1-D heuristic (the 2-D
    tiled cross-entropy and flash-attention kernels)."""
    if _BLOCK_RESOLVER is None:
        return None
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    tuned = _BLOCK_RESOLVER(kind, str(jnp.dtype(dtype)), int(n),
                            bool(interpret))
    return max(1, min(int(tuned), int(n))) if tuned else None


def resolve_block(kind: str, dtype, n: int,
                  interpret: Optional[bool] = None) -> int:
    """Launch block for an ``n``-element 1-D buffer: the tuning cache's
    measured winner when an entry exists (resolver installed by
    ``engine.autotune``), else the size-aware heuristic."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if _BLOCK_RESOLVER is not None:
        tuned = _BLOCK_RESOLVER(kind, str(jnp.dtype(dtype)), int(n),
                                bool(interpret))
        if tuned:
            return max(1, min(int(tuned), int(n)))
    return default_block(n, interpret=interpret)


def _accum_kernel(scale_ref, acc_ref, g_ref, out_ref):
    out_ref[...] = (acc_ref[...]
                    + g_ref[...].astype(acc_ref.dtype) * scale_ref[0])


def grad_accum(acc, grad, scale, *, block: Optional[int] = None,
               interpret: Optional[bool] = None) -> jnp.ndarray:
    """acc: (N,) fp32 (or any 1-D); grad: (N,); scale: scalar.
    Returns acc + scale*grad, aliasing the accumulator buffer in place.
    N need not divide the block: the final block is masked by the grid
    machinery (no padded copies). ``block=None`` (default) sizes the
    launch via the tuning cache / size-aware heuristic
    (:func:`resolve_block`); any block gives bit-identical values."""
    N = acc.shape[0]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if block is None:
        block = resolve_block("grad_accum", acc.dtype, N, interpret)
    block = min(block, N)
    scale_arr = jnp.asarray([scale], acc.dtype)
    return pl.pallas_call(
        _accum_kernel,
        grid=(pl.cdiv(N, block),),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # scale (broadcast)
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), acc.dtype),
        input_output_aliases={1: 0},  # acc buffer reused in place
        interpret=interpret,
    )(scale_arr, acc, grad)


def grad_accum_tree(acc_tree, grad_tree, scale, **kw):
    """Apply the fused accumulate leaf-wise over parameter pytrees
    (flattening each leaf to 1-D) — the per-leaf compatibility path;
    O(num_leaves) launches. Prefer :func:`grad_accum_buckets` on the
    engine's flat buffers (O(num_buckets) launches)."""
    def one(a, g):
        return grad_accum(a.reshape(-1), g.reshape(-1), scale,
                          **kw).reshape(a.shape)
    return jax.tree.map(one, acc_tree, grad_tree)


def grad_accum_buckets(acc_buffers: Sequence[jnp.ndarray],
                       grad_buffers: Sequence[jnp.ndarray], scale, *,
                       block: Optional[int] = None,
                       interpret: Optional[bool] = None) -> Tuple[jnp.ndarray, ...]:
    """Bucketed accumulate: one masked launch per dtype bucket. The buffers
    come from ``engine.flat.FlatSpec.flatten`` (contiguous 1-D per dtype).
    ``block=None`` resolves per bucket (sizes differ across dtypes) through
    the tuning cache / heuristic."""
    return tuple(grad_accum(a, g, scale, block=block, interpret=interpret)
                 for a, g in zip(acc_buffers, grad_buffers))
