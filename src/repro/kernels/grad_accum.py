"""Fused normalized gradient accumulation as a Pallas TPU kernel.

Paper Fig. 2 step ❹ + eq. (14): ``acc ← acc + grad · (1/N_Sμ)``, fusing the
loss-normalization scale into the accumulate so the scaled gradient is never
materialized, with in-place aliasing on the fp32 accumulator (the gradient
may arrive in bf16)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 4096


def _accum_kernel(scale_ref, acc_ref, g_ref, out_ref):
    out_ref[...] = (acc_ref[...]
                    + g_ref[...].astype(acc_ref.dtype) * scale_ref[0])


def grad_accum(acc, grad, scale, *, block: int = DEFAULT_BLOCK,
               interpret: Optional[bool] = None) -> jnp.ndarray:
    """acc: (N,) fp32 (or any 1-D); grad: (N,); scale: scalar.
    Returns acc + scale*grad, aliasing the accumulator buffer in place."""
    N = acc.shape[0]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    block = min(block, N)
    pad = (-N) % block
    if pad:
        acc_p = jnp.pad(acc, (0, pad))
        grad_p = jnp.pad(grad, (0, pad))
    else:
        acc_p, grad_p = acc, grad
    scale_arr = jnp.asarray([scale], acc.dtype)
    out = pl.pallas_call(
        _accum_kernel,
        grid=(acc_p.shape[0] // block,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # scale (broadcast)
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(acc_p.shape, acc.dtype),
        input_output_aliases={1: 0},  # acc buffer reused in place
        interpret=interpret,
    )(scale_arr, acc_p, grad_p)
    return out[:N] if pad else out


def grad_accum_tree(acc_tree, grad_tree, scale, **kw):
    """Apply the fused accumulate leaf-wise over parameter pytrees
    (flattening each leaf to 1-D)."""
    def one(a, g):
        return grad_accum(a.reshape(-1), g.reshape(-1), scale,
                          **kw).reshape(a.shape)
    return jax.tree.map(one, acc_tree, grad_tree)
