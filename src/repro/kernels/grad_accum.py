"""Fused normalized gradient accumulation as a Pallas TPU kernel.

Paper Fig. 2 step ❹ + eq. (14): ``acc ← acc + grad · (1/N_Sμ)``, fusing the
loss-normalization scale into the accumulate so the scaled gradient is never
materialized, with in-place aliasing on the fp32 accumulator (the gradient
may arrive in bf16).

Ragged tails are handled by the grid, not by padding: the launch covers
``ceil(N / block)`` blocks and Pallas masks the final partial block
(out-of-bounds lanes are dropped on store), so no ``jnp.pad`` copy of
either operand is ever materialized. ``grad_accum_buckets`` applies the
same kernel to the engine's dtype-bucketed flat buffers — one launch per
bucket instead of one per parameter leaf (see ``engine/flat.py``).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 4096
# flat dtype buckets hold whole models; amortize the per-block dispatch
BUCKET_BLOCK = 65536


def _accum_kernel(scale_ref, acc_ref, g_ref, out_ref):
    out_ref[...] = (acc_ref[...]
                    + g_ref[...].astype(acc_ref.dtype) * scale_ref[0])


def grad_accum(acc, grad, scale, *, block: int = DEFAULT_BLOCK,
               interpret: Optional[bool] = None) -> jnp.ndarray:
    """acc: (N,) fp32 (or any 1-D); grad: (N,); scale: scalar.
    Returns acc + scale*grad, aliasing the accumulator buffer in place.
    N need not divide the block: the final block is masked by the grid
    machinery (no padded copies)."""
    N = acc.shape[0]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    block = min(block, N)
    scale_arr = jnp.asarray([scale], acc.dtype)
    return pl.pallas_call(
        _accum_kernel,
        grid=(pl.cdiv(N, block),),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # scale (broadcast)
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), acc.dtype),
        input_output_aliases={1: 0},  # acc buffer reused in place
        interpret=interpret,
    )(scale_arr, acc, grad)


def grad_accum_tree(acc_tree, grad_tree, scale, **kw):
    """Apply the fused accumulate leaf-wise over parameter pytrees
    (flattening each leaf to 1-D) — the per-leaf compatibility path;
    O(num_leaves) launches. Prefer :func:`grad_accum_buckets` on the
    engine's flat buffers (O(num_buckets) launches)."""
    def one(a, g):
        return grad_accum(a.reshape(-1), g.reshape(-1), scale,
                          **kw).reshape(a.shape)
    return jax.tree.map(one, acc_tree, grad_tree)


def grad_accum_buckets(acc_buffers: Sequence[jnp.ndarray],
                       grad_buffers: Sequence[jnp.ndarray], scale, *,
                       block: int = BUCKET_BLOCK,
                       interpret: Optional[bool] = None) -> Tuple[jnp.ndarray, ...]:
    """Bucketed accumulate: one masked launch per dtype bucket. The buffers
    come from ``engine.flat.FlatSpec.flatten`` (contiguous 1-D per dtype)."""
    return tuple(grad_accum(a, g, scale, block=block, interpret=interpret)
                 for a, g in zip(acc_buffers, grad_buffers))
