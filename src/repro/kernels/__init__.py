"""Pallas kernels — canonical public import surface.

Import the kernel API from THIS package, not from the submodules::

    from repro.kernels import grad_accum, flash_attention, cross_entropy
    from repro.kernels import fused_update            # fused optimizer kernels
    from repro.kernels import set_block_resolver      # autotuner hook

The submodules (``grad_accum``/``cross_entropy``/``flash_attention`` hold
the raw ``pallas_call`` implementations, ``ops`` the jit'd custom-VJP
wrappers, ``ref`` the pure-jnp oracles) remain importable via the
``import repro.kernels.<submodule>`` form for oracle/benchmark access,
but deep imports from production code are deprecated and flagged by the
static-analysis lint rule LINT005 (``python -m repro.analysis``) — the
package surface below is the one stable contract.

Exports:
  * ``grad_accum`` / ``grad_accum_tree`` / ``grad_accum_buckets`` — the
    fused scaled-accumulate (paper step ❹), in-place on the accumulator;
    ``block=None``/``interpret=None`` resolve via the tuning cache.
  * ``flash_attention`` — differentiable (custom-VJP) attention kernel.
  * ``cross_entropy`` (= ``fused_cross_entropy``) — differentiable scaled
    per-token NLL.
  * ``fused_update`` (module) with ``fused_sgd`` / ``fused_adam`` — the
    in-place fused optimizer kernels (paper step ❺, Layer 4).
  * ``set_block_resolver`` / ``resolve_block`` / ``default_block`` /
    ``lookup_tuned_block`` — launch-geometry hooks (DESIGN.md §Autotuning).
"""
# module bindings first (the function bindings below shadow the
# ``grad_accum``/``cross_entropy``/``flash_attention`` submodule
# attributes, and since py3.7 ``import repro.kernels.grad_accum as m``
# resolves through the shadowed parent attribute too — so the raw kernel
# modules are re-exported under explicit ``*_kernels`` aliases for
# oracle/benchmark access)
from . import ops, ref  # noqa: F401
from . import fused_update  # noqa: F401  (module IS the public fused-opt API)
from . import cross_entropy as cross_entropy_kernels  # noqa: F401
from . import flash_attention as flash_attention_kernels  # noqa: F401
from . import grad_accum as grad_accum_kernels  # noqa: F401
from .fused_update import fused_adam, fused_sgd  # noqa: F401
from .grad_accum import (default_block, grad_accum,  # noqa: F401
                         grad_accum_buckets, grad_accum_tree,
                         lookup_tuned_block, resolve_block,
                         set_block_resolver)
from .ops import flash_attention, fused_cross_entropy  # noqa: F401

cross_entropy = fused_cross_entropy

__all__ = [
    "cross_entropy", "cross_entropy_kernels", "default_block",
    "flash_attention", "flash_attention_kernels", "fused_adam",
    "fused_cross_entropy", "fused_sgd", "fused_update", "grad_accum",
    "grad_accum_buckets", "grad_accum_kernels", "grad_accum_tree",
    "lookup_tuned_block", "ops", "ref", "resolve_block",
    "set_block_resolver",
]
