"""Pallas kernels. Import the jit'd wrappers from ``repro.kernels.ops``
(the submodules flash_attention/cross_entropy/grad_accum hold the raw
pallas_call implementations; ref holds the pure-jnp oracles)."""
from . import (cross_entropy, flash_attention, fused_update,  # noqa: F401
               grad_accum, ops, ref)
