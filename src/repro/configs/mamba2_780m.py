"""mamba2-780m [ssm]: 48L d_model=1536 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality). [arXiv:2405.21060]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="ssm",
        num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
        head_dim=0, d_ff=0, vocab_size=50_280,
        layer_pattern=("ssm",),
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
        conv_width=4, tie_embeddings=True,
        source="arXiv:2405.21060",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m-reduced", family="ssm",
        num_layers=2, d_model=128, num_heads=0, num_kv_heads=0,
        head_dim=0, d_ff=0, vocab_size=512,
        layer_pattern=("ssm",),
        ssm_state=16, ssm_expand=2, ssm_head_dim=32, ssm_chunk=8,
        conv_width=4,
        source="arXiv:2405.21060",
    )
