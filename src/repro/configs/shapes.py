"""Assigned input shapes.

  train_4k     training       seq 4,096    global batch 256
  prefill_32k  inference      seq 32,768   global batch 32
  decode_32k   decode         KV 32,768    global batch 128 (1 new token)
  long_500k    long decode    KV 524,288   global batch 1   (1 new token)
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", "train", 4_096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32_768, 128),
    "long_500k": InputShape("long_500k", "decode", 524_288, 1),
}
