"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global attention, 128k context, QK-norm.
[hf:google/gemma-3-1b-pt family]

For the ``long_500k`` serving shape the global layers use a 32k window
(``long_context_global_window``) — the beyond-paper windowed-global variant
documented in DESIGN.md; all other shapes use true full attention on the
global layers."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b", family="dense",
        num_layers=48, d_model=3840, num_heads=16, num_kv_heads=8,
        head_dim=256, d_ff=15360, vocab_size=262_144,
        layer_pattern=("local",) * 5 + ("global",), sliding_window=1024,
        use_qk_norm=True, ffn_kind="geglu", use_post_norm=True,
        embed_scale=True, tie_embeddings=True,
        rope_theta=10_000.0, rope_theta_global=1_000_000.0,
        long_context_global_window=32_768,
        source="arXiv:2503.19786 (Gemma 3); hf:google/gemma-3-1b-pt",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b-reduced", family="dense",
        num_layers=6, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=512,
        layer_pattern=("local",) * 5 + ("global",), sliding_window=16,
        use_qk_norm=True, ffn_kind="geglu", use_post_norm=True,
        embed_scale=True, rope_theta=10_000.0, rope_theta_global=1_000_000.0,
        long_context_global_window=64,
        source="hf:google/gemma-3-1b-pt",
    )
