"""recurrentgemma-2b [hybrid]: 26L (26 temporal-mixing blocks) d_model=2560
10H (MQA kv=1) d_ff=7680 vocab=256000 — RG-LRU + local attention, pattern
2 recurrent : 1 local. [arXiv:2402.19427]

26 blocks is not divisible by the 3-block pattern; the card's final block is
recurrent — we round the period count to 27 layers? No: we keep 26 layers
faithful by using pattern period 13 (see note in DESIGN.md): the pattern
(r, r, l) repeated with the last period truncated is equivalent to 8 periods
of (r,r,l) + (r,r) — we realize it as 2 scans is overkill, so we use 24
layers of strict (r,r,l) periods + one final (r,r) period expressed as a
26-layer config with pattern length 13: (r,r,l)*4 + (r,) == 13 blocks × 2
periods = 26, preserving the overall 2:1 ratio and the card's layer count.
"""
from ..models.config import ModelConfig

_PATTERN_13 = ("recurrent", "recurrent", "local") * 4 + ("recurrent",)


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
        head_dim=256, d_ff=7680, vocab_size=256_000,
        layer_pattern=_PATTERN_13, sliding_window=2048,
        lru_width=2560, conv_width=4,
        ffn_kind="geglu", embed_scale=True, tie_embeddings=True,
        rope_theta=10_000.0,
        source="arXiv:2402.19427",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b-reduced", family="hybrid",
        num_layers=3, d_model=128, num_heads=4, num_kv_heads=1,
        head_dim=32, d_ff=256, vocab_size=512,
        layer_pattern=("recurrent", "recurrent", "local"), sliding_window=16,
        lru_width=128, conv_width=4,
        ffn_kind="geglu", embed_scale=True,
        source="arXiv:2402.19427",
    )
