"""qwen2-1.5b [dense]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — GQA with QKV bias. [arXiv:2407.10671]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b", family="dense",
        num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
        head_dim=128, d_ff=8960, vocab_size=151_936,
        layer_pattern=("global",), qkv_bias=True,
        ffn_kind="swiglu", tie_embeddings=True,
        rope_theta=1_000_000.0,
        source="arXiv:2407.10671",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b-reduced", family="dense",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=512,
        layer_pattern=("global",), qkv_bias=True,
        ffn_kind="swiglu", rope_theta=1_000_000.0,
        source="arXiv:2407.10671",
    )
