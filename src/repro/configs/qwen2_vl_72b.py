"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE, dynamic resolution (vision tower stubbed; the LM
backbone consumes precomputed patch embeddings). [arXiv:2409.12191]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b", family="vlm",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=29_568, vocab_size=152_064,
        layer_pattern=("global",), qkv_bias=True,
        mrope_sections=(16, 24, 24),  # t/h/w frequency split of head_dim/2
        ffn_kind="swiglu", tie_embeddings=False,
        rope_theta=1_000_000.0, is_vlm=True,
        source="arXiv:2409.12191",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b-reduced", family="vlm",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=512,
        layer_pattern=("global",), qkv_bias=True,
        mrope_sections=(4, 6, 6),
        ffn_kind="swiglu", tie_embeddings=False,
        rope_theta=1_000_000.0, is_vlm=True,
        source="arXiv:2409.12191",
    )
