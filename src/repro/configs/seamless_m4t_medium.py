"""seamless-m4t-medium [audio]: 12L(+12L decoder) d_model=1024 16H (kv=16)
d_ff=4096 vocab=256206 — encoder-decoder; the mel+conv audio frontend is
stubbed (encoder consumes precomputed frame embeddings).
[arXiv:2308.11596]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="audio",
        num_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
        head_dim=64, d_ff=4096, vocab_size=256_206,
        layer_pattern=("global",), encoder_layers=12,
        ffn_kind="gelu", tie_embeddings=True,
        rope_theta=10_000.0,
        source="arXiv:2308.11596",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium-reduced", family="audio",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        head_dim=32, d_ff=256, vocab_size=512,
        layer_pattern=("global",), encoder_layers=2,
        ffn_kind="gelu",
        source="arXiv:2308.11596",
    )
