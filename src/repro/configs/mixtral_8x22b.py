"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", family="moe",
        num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
        head_dim=128, d_ff=0, vocab_size=32_768,
        layer_pattern=("local",), sliding_window=4096,
        num_experts=8, experts_per_token=2, moe_d_ff=16_384,
        ffn_kind="swiglu", tie_embeddings=False,
        rope_theta=1_000_000.0,
        source="arXiv:2401.04088",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b-reduced", family="moe",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=0, vocab_size=512,
        layer_pattern=("local",), sliding_window=16,
        num_experts=4, experts_per_token=2, moe_d_ff=256,
        ffn_kind="swiglu", tie_embeddings=False,
        rope_theta=1_000_000.0,
        source="arXiv:2401.04088",
    )
