"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000 — local+global alternating attention, logit softcapping.
[arXiv:2408.00118]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b", family="dense",
        num_layers=42, d_model=3584, num_heads=16, num_kv_heads=8,
        head_dim=256, d_ff=14336, vocab_size=256_000,
        layer_pattern=("local", "global"), sliding_window=4096,
        attn_softcap=50.0, final_softcap=30.0,
        ffn_kind="geglu", use_post_norm=True, embed_scale=True,
        rope_theta=10_000.0, tie_embeddings=True,
        source="arXiv:2408.00118",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b-reduced", family="dense",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=512,
        layer_pattern=("local", "global"), sliding_window=16,
        attn_softcap=50.0, final_softcap=30.0,
        ffn_kind="geglu", use_post_norm=True, embed_scale=True,
        source="arXiv:2408.00118",
    )
