"""The paper's segmentation model (Table 5): U-Net on Carvana-like data,
Adam lr 0.01 decay 5e-4, BCE+Dice loss."""
from .resnet50 import CNNConfig


def config() -> CNNConfig:
    return CNNConfig(name="unet", kind="unet", image_size=384,
                     out_channels=1, depth=4, width=64,
                     source="paper §4.2.2; Ronneberger et al. 2015")


def reduced() -> CNNConfig:
    return CNNConfig(name="unet-mini", kind="unet", image_size=32,
                     out_channels=1, depth=2, width=8,
                     source="reduced smoke variant")
