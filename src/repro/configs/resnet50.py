"""The paper's own classification models (Table 2): ResNet-50 / ResNet-101
on Flower-102-like data, SGD momentum 0.9, lr 0.01, decay 5e-4."""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    kind: str  # "resnet" | "unet"
    num_classes: int = 102
    image_size: int = 224
    in_channels: int = 3
    stage_sizes: Tuple[int, ...] = (3, 4, 6, 3)
    width: int = 64
    # U-Net
    out_channels: int = 1
    depth: int = 4
    source: str = ""


def config() -> CNNConfig:
    return CNNConfig(name="resnet50", kind="resnet", num_classes=102,
                     image_size=224, stage_sizes=(3, 4, 6, 3), width=64,
                     source="paper §4.2.2; He et al. 2016")


def config_101() -> CNNConfig:
    return CNNConfig(name="resnet101", kind="resnet", num_classes=102,
                     image_size=224, stage_sizes=(3, 4, 23, 3), width=64,
                     source="paper §4.2.2; He et al. 2016")


def reduced() -> CNNConfig:
    return CNNConfig(name="resnet-mini", kind="resnet", num_classes=8,
                     image_size=24, stage_sizes=(1, 1), width=16,
                     source="reduced smoke variant")
