"""Architecture registry: the 10 assigned architectures (+ the paper's own
ResNet/U-Net CNN configs used by the examples/benchmarks).

``get(arch_id)`` / ``get_reduced(arch_id)`` return ModelConfig;
``ARCHS`` lists the assigned ids in assignment order.
"""
from __future__ import annotations

from importlib import import_module
from typing import Dict, List

from ..models.config import ModelConfig
from .shapes import SHAPES, InputShape  # noqa: F401

_MODULES: Dict[str, str] = {
    "gemma2-9b": "gemma2_9b",
    "grok-1-314b": "grok_1_314b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "gemma3-12b": "gemma3_12b",
    "qwen2-1.5b": "qwen2_1_5b",
    "mixtral-8x22b": "mixtral_8x22b",
    "mamba2-780m": "mamba2_780m",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}

ARCHS: List[str] = list(_MODULES)

# archs with at least one unbounded full-attention layer: long_500k decode is
# quadratic-memory there and is skipped (DESIGN.md §long_500k applicability)
LONG_500K_ARCHS = {"mamba2-780m", "recurrentgemma-2b", "mixtral-8x22b",
                   "gemma3-12b"}


def get(arch_id: str) -> ModelConfig:
    return import_module(f".{_MODULES[arch_id]}", __package__).config()


def get_reduced(arch_id: str) -> ModelConfig:
    return import_module(f".{_MODULES[arch_id]}", __package__).reduced()


def supports_shape(arch_id: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_id in LONG_500K_ARCHS
    return True
