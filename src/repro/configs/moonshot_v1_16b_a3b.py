"""moonshot-v1-16b-a3b: 48L d_model=2048 16H (GQA kv=16) per-expert
d_ff=1408 vocab=163840, MoE 64 experts top-6 (+2 shared experts, per the
Moonlight / DeepSeek-V3 family design). [hf:moonshotai/Moonlight-16B-A3B]

Assignment labels this [dense] but specifies "MoE 64e top-6"; the model card
is MoE — we build it as MoE (DESIGN.md §Assumptions)."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b", family="moe",
        num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
        head_dim=128, d_ff=0, vocab_size=163_840,
        layer_pattern=("global",),
        num_experts=64, experts_per_token=6, moe_d_ff=1408,
        num_shared_experts=2, shared_d_ff=1408,
        ffn_kind="swiglu", tie_embeddings=True,
        rope_theta=50_000.0,
        source="hf:moonshotai/Moonlight-16B-A3B",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b-reduced", family="moe",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        head_dim=32, d_ff=0, vocab_size=512,
        layer_pattern=("global",),
        num_experts=4, experts_per_token=2, moe_d_ff=64,
        num_shared_experts=1, shared_d_ff=64,
        ffn_kind="swiglu", rope_theta=50_000.0,
        source="hf:moonshotai/Moonlight-16B-A3B",
    )
