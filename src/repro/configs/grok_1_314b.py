"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2. [hf:xai-org/grok-1]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b", family="moe",
        num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
        head_dim=128, d_ff=0, vocab_size=131_072,
        layer_pattern=("global",),
        num_experts=8, experts_per_token=2, moe_d_ff=32_768,
        attn_softcap=30.0, final_softcap=30.0,
        ffn_kind="geglu", embed_scale=True, tie_embeddings=True,
        rope_theta=10_000.0,
        source="hf:xai-org/grok-1",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b-reduced", family="moe",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=0, vocab_size=512,
        layer_pattern=("global",),
        num_experts=4, experts_per_token=2, moe_d_ff=256,
        attn_softcap=30.0, final_softcap=30.0,
        ffn_kind="geglu", embed_scale=True,
        source="hf:xai-org/grok-1",
    )
