from .optimizers import (Optimizer, adam, adamw, clip_by_global_norm,  # noqa: F401
                         constant, cosine_decay, linear_decay, sgd)
