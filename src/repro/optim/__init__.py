from .optimizers import (FusedUpdateSpec, Optimizer, adam, adamw,  # noqa: F401
                         clip_by_global_norm, constant, cosine_decay,
                         linear_decay, memory_model_kw, sgd)
