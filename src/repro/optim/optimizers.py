"""Optimizers (functional, optax-style update signature) + LR schedules.

The paper trains with SGD (momentum 0.9, decay 5e-4 / 1e-4) for the
classification models and Adam (lr 0.01, decay 5e-4) for U-Net; AmoebaNet-D
uses a linear LR decay — all provided here.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def constant(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_decay(lr: float, total_steps: int, end_factor: float = 0.0) -> Schedule:
    def sched(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return jnp.asarray(lr * (1.0 + (end_factor - 1.0) * frac), jnp.float32)
    return sched


def cosine_decay(lr: float, total_steps: int, warmup: int = 0,
                 min_factor: float = 0.0) -> Schedule:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / max(warmup, 1), 1.0) if warmup else 1.0
        frac = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        cos = min_factor + (1 - min_factor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.asarray(lr * warm * cos, jnp.float32)
    return sched


def _as_schedule(lr: Union[float, Schedule]) -> Schedule:
    return lr if callable(lr) else constant(lr)


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (grads, state, params)


def sgd(lr: Union[float, Schedule], momentum: float = 0.0,
        weight_decay: float = 0.0, nesterov: bool = False) -> Optimizer:
    """SGD + momentum + (coupled) weight decay — the paper's classifier
    optimizer (lr .01/.1, momentum .9, decay 5e-4/1e-4)."""
    sched = _as_schedule(lr)

    def init(params):
        mom = jax.tree.map(jnp.zeros_like, params) if momentum else None
        return {"mom": mom, "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        lr_t = sched(state["step"])
        if weight_decay:
            grads = jax.tree.map(
                lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params)
        if momentum:
            mom = jax.tree.map(lambda m, g: momentum * m + g.astype(m.dtype),
                               state["mom"], grads)
            eff = (jax.tree.map(lambda g, m: g + momentum * m, grads, mom)
                   if nesterov else mom)
        else:
            mom, eff = None, grads
        updates = jax.tree.map(lambda u: -lr_t * u.astype(jnp.float32), eff)
        return updates, {"mom": mom, "step": state["step"] + 1}

    return Optimizer(init, update)


def adam(lr: Union[float, Schedule], b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0,
         decoupled: bool = False) -> Optimizer:
    """Adam / AdamW. The paper's U-Net uses Adam(lr .01, decay 5e-4)."""
    sched = _as_schedule(lr)

    def init(params):
        return {"m": jax.tree.map(jnp.zeros_like, params),
                "v": jax.tree.map(jnp.zeros_like, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(state["step"])
        if weight_decay and not decoupled:
            grads = jax.tree.map(
                lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(m_.dtype),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(v_.dtype)),
                         state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m_, v_, p):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay and decoupled:
                u = u + weight_decay * p.astype(u.dtype)
            return -lr_t * u.astype(jnp.float32)

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"m": m, "v": v, "step": step}

    return Optimizer(init, update)


def adamw(lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01) -> Optimizer:
    return adam(lr, b1, b2, eps, weight_decay, decoupled=True)


def clip_by_global_norm(optimizer: Optimizer, max_norm: float) -> Optimizer:
    def update(grads, state, params):
        norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                            for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
        return optimizer.update(grads, state, params)

    return Optimizer(optimizer.init, update)
