"""Optimizers (functional, optax-style update signature) + LR schedules.

The paper trains with SGD (momentum 0.9, decay 5e-4 / 1e-4) for the
classification models and Adam (lr 0.01, decay 5e-4) for U-Net; AmoebaNet-D
uses a linear LR decay — all provided here.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def constant(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_decay(lr: float, total_steps: int, end_factor: float = 0.0) -> Schedule:
    def sched(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return jnp.asarray(lr * (1.0 + (end_factor - 1.0) * frac), jnp.float32)
    return sched


def cosine_decay(lr: float, total_steps: int, warmup: int = 0,
                 min_factor: float = 0.0) -> Schedule:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / max(warmup, 1), 1.0) if warmup else 1.0
        frac = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        cos = min_factor + (1 - min_factor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.asarray(lr * warm * cos, jnp.float32)
    return sched


def _as_schedule(lr: Union[float, Schedule]) -> Schedule:
    return lr if callable(lr) else constant(lr)


@dataclasses.dataclass(frozen=True)
class FusedUpdateSpec:
    """Per-optimizer hook for the fused flat update path (paper step ❺).

    Describes the update arithmetic so the engine can run it through the
    in-place Pallas kernels (``kernels/fused_update.py``) on dtype-bucketed
    flat buffers instead of ``optimizer.update`` + ``apply_update`` over
    trees. Static hyperparameters are baked into the kernel; the schedule
    (and the global-norm clip, when ``clip_norm`` is set) produce traced
    scalars carried *into* the kernel — no scaled-gradient or ``updates``
    tree is ever materialized. Consumed by
    ``engine.exec_core.apply_update_flat``.
    """
    kind: str  # "sgd" | "adam"
    schedule: Schedule
    momentum: float = 0.0
    nesterov: bool = False
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    decoupled: bool = False
    clip_norm: Optional[float] = None


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (grads, state, params)
    fused: Optional[FusedUpdateSpec] = None  # flat fused-kernel hook


def sgd(lr: Union[float, Schedule], momentum: float = 0.0,
        weight_decay: float = 0.0, nesterov: bool = False) -> Optimizer:
    """SGD + momentum + (coupled) weight decay — the paper's classifier
    optimizer (lr .01/.1, momentum .9, decay 5e-4/1e-4)."""
    sched = _as_schedule(lr)

    def init(params):
        mom = jax.tree.map(jnp.zeros_like, params) if momentum else None
        return {"mom": mom, "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        lr_t = sched(state["step"])
        if weight_decay:
            grads = jax.tree.map(
                lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params)
        if momentum:
            mom = jax.tree.map(lambda m, g: momentum * m + g.astype(m.dtype),
                               state["mom"], grads)
            eff = (jax.tree.map(lambda g, m: g + momentum * m, grads, mom)
                   if nesterov else mom)
        else:
            mom, eff = None, grads
        updates = jax.tree.map(lambda u: -lr_t * u.astype(jnp.float32), eff)
        return updates, {"mom": mom, "step": state["step"] + 1}

    return Optimizer(init, update, FusedUpdateSpec(
        "sgd", sched, momentum=momentum, nesterov=nesterov,
        weight_decay=weight_decay))


def adam(lr: Union[float, Schedule], b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0,
         decoupled: bool = False) -> Optimizer:
    """Adam / AdamW. The paper's U-Net uses Adam(lr .01, decay 5e-4)."""
    sched = _as_schedule(lr)

    def init(params):
        return {"m": jax.tree.map(jnp.zeros_like, params),
                "v": jax.tree.map(jnp.zeros_like, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(state["step"])
        if weight_decay and not decoupled:
            grads = jax.tree.map(
                lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(m_.dtype),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(v_.dtype)),
                         state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m_, v_, p):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay and decoupled:
                u = u + weight_decay * p.astype(u.dtype)
            return -lr_t * u.astype(jnp.float32)

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"m": m, "v": v, "step": step}

    return Optimizer(init, update, FusedUpdateSpec(
        "adam", sched, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay,
        decoupled=decoupled))


def adamw(lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01) -> Optimizer:
    return adam(lr, b1, b2, eps, weight_decay, decoupled=True)


def memory_model_kw(optimizer: Optimizer, *, fused: bool = False) -> dict:
    """Memory-model kwargs (``opt_slots=``/``fused_update=``) for
    ``plan_mbs``/``memory_model.estimate``, derived from the *actual*
    optimizer: the state-slot count is measured from the optimizer's own
    ``init`` (abstractly, via ``eval_shape`` — exact for any custom
    optimizer, not just the built-ins), and ``fused_update`` only holds
    when the optimizer publishes a fused hook — otherwise the engine falls
    back to the unfused tree update and its step-❺ transient must stay in
    the model."""
    probe = jax.ShapeDtypeStruct((2, 3), jnp.float32)
    state = jax.eval_shape(optimizer.init, {"p": probe})
    slots = sum(1 for leaf in jax.tree.leaves(state)
                if getattr(leaf, "shape", None) == probe.shape)
    return {"opt_slots": slots,
            "fused_update": fused and optimizer.fused is not None}


def clip_by_global_norm(optimizer: Optimizer, max_norm: float) -> Optimizer:
    """Scale gradients so their global norm is at most ``max_norm``.

    The unfused path below must materialize a scaled gradient tree before
    the wrapped update; the fused flat path instead carries ``clip_norm``
    in the :class:`FusedUpdateSpec` so the engine computes the scale from
    the flat accumulator and applies it *inside* the update kernel."""
    def update(grads, state, params):
        norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                            for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
        return optimizer.update(grads, state, params)

    # one clip scalar rides into the kernel; a double-wrapped clip cannot,
    # so it drops the hook and falls back to the reference tree update
    fused = (dataclasses.replace(optimizer.fused, clip_norm=max_norm)
             if optimizer.fused is not None
             and optimizer.fused.clip_norm is None else None)
    return Optimizer(optimizer.init, update, fused)
