"""repro: Micro-Batch Streaming (MBS) as a production JAX framework.

Paper: "Enabling Large Batch Size Training for DNN Models Beyond the Memory
Limit While Maintaining Performance" (IEEE Access 2023) — journal version of
"Micro Batch Streaming" (Piao, Synn, Park, Kim; Korea University).
"""

__version__ = "0.1.0"
